"""Dory core: scalable persistent homology (the paper's primary contribution)."""
from .filtration import Filtration, build_filtration, pairwise_distances
from .homology import PHResult, compute_ph
from .h0 import compute_h0
from .pairing import EMPTY_KEY, pack, unpack
from . import diagrams
from . import ref

__all__ = [
    "Filtration", "build_filtration", "pairwise_distances",
    "PHResult", "compute_ph", "compute_h0",
    "EMPTY_KEY", "pack", "unpack", "diagrams", "ref",
]

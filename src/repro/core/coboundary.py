"""Vectorized coboundary enumeration (Dory §4.2, TPU-adapted).

The paper enumerates coboundaries *lazily in filtration order* with
``FindSmallestt/FindNextt/FindGEQt`` — per-element binary searches and pointer
walks over sorted neighborhoods.  That shape of computation (data-dependent
early exit) has no efficient TPU analogue, so we adapt the insight rather than
port the mechanics: the coboundary of an edge ``{a,b}`` is *one triangle per
common neighbor* ``v``, whose paired-index is a closed-form function of three
edge orders::

    kp = max(O_ab, O_av, O_bv)
    ks = v   if kp == O_ab        (paper's case 1: diameter = ab)
       = b   if kp == O_av        (case 2, diameter = av)
       = a   if kp == O_bv        (case 2, diameter = bv)

so the whole coboundary materializes as gathers + elementwise ops + one sort —
``O(max_deg)`` vectorized work per edge, batched over columns.  Same story for
triangles (one tetrahedron per common neighbor of the three vertices, key from
six edge orders).  ``FindGEQ``-style skipping survives as a *mask* over the
eagerly-enumerated keys.

Two lookup structures mirror the paper's two builds:
* ``ns``     — dense order-matrix gathers (DoryNS; ``O(n^2)`` memory),
* ``sparse`` — searchsorted intersection of padded sorted neighborhoods
               (Dory;   ``O(n_e)``   memory).
"""
from __future__ import annotations

import numpy as np

from .filtration import Filtration
from .pairing import EMPTY_KEY, pack_np

INT32_MAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# Edge coboundaries (triangles)
# ---------------------------------------------------------------------------

def edge_cobdy_ns(filt: Filtration, e_orders: np.ndarray) -> np.ndarray:
    """Coboundary keys of a batch of edges, dense-order-matrix path.

    Returns (B, n) int64 packed keys, ascending, EMPTY_KEY padded.

    Near-clique fast path: with the dense order matrix the candidate
    third-vertices already arrive in ascending ``v`` order, and a case-1
    triangle's key is ``<o_ab, v>`` — so the case-1 keys of a row are
    *born sorted*, and every one of them precedes every case-2 key
    (``<m, a|b>`` with ``m > o_ab``, edge orders being globally unique).
    Instead of sorting the whole (B, n) row we compact case 1 with a
    cumsum scatter and lexsort only the case-2 subset, which is exactly
    the part that vanishes as the neighborhood approaches a clique whose
    diameter is the column's own edge (the H1* hot shape).
    """
    e_orders = np.asarray(e_orders, dtype=np.int64)
    a = filt.edges[e_orders, 0].astype(np.int64)
    b = filt.edges[e_orders, 1].astype(np.int64)
    oa = filt.order[a].astype(np.int64)           # (B, n)
    ob = filt.order[b].astype(np.int64)
    keys, c1 = _edge_keys_from_orders(
        e_orders[:, None], a[:, None], b[:, None],
        np.arange(filt.n, dtype=np.int64)[None, :], oa, ob,
        return_case1=True)
    B, n = keys.shape
    out = np.full_like(keys, EMPTY_KEY)
    n1 = c1.sum(axis=1)
    r1, v1 = np.nonzero(c1)
    if r1.size:
        out[r1, (np.cumsum(c1, axis=1) - 1)[r1, v1]] = keys[r1, v1]
    c2 = (keys != EMPTY_KEY) & ~c1
    r2, v2 = np.nonzero(c2)
    if r2.size:
        k2 = keys[r2, v2]
        o = np.lexsort((k2, r2))
        r2s, k2s = r2[o], k2[o]
        starts = np.searchsorted(r2s, np.arange(B, dtype=np.int64))
        rank = np.arange(r2s.size, dtype=np.int64) - starts[r2s]
        out[r2s, n1[r2s] + rank] = k2s
    return out


def edge_cobdy_sparse(filt: Filtration, e_orders: np.ndarray) -> np.ndarray:
    """Coboundary keys of a batch of edges via neighborhood intersection.

    Returns (B, max_deg) int64 packed keys, ascending, EMPTY_KEY padded.
    """
    e_orders = np.asarray(e_orders, dtype=np.int64)
    a = filt.edges[e_orders, 0].astype(np.int64)
    b = filt.edges[e_orders, 1].astype(np.int64)
    v = filt.nbr_vtx[a].astype(np.int64)          # (B, K) candidates from N^a
    oa = filt.nbr_vtx_ord[a].astype(np.int64)     # order of {a, v}
    ob = _lookup_order(filt, b, v)                # order of {b, v} or -1
    keys = _edge_keys_from_orders(e_orders[:, None], a[:, None], b[:, None],
                                  v, oa, ob)
    keys.sort(axis=1)
    return keys


def _edge_keys_from_orders(o_ab, a, b, v, oa, ob, return_case1=False):
    """Triangle keys for candidate third-vertices ``v`` (vectorized core).

    With ``return_case1`` also returns the mask of valid case-1 entries
    (diameter = the edge itself) for the sorted-partition fast path."""
    common = (oa >= 0) & (ob >= 0)
    m = np.maximum(oa, ob)
    kp = np.maximum(o_ab, m)
    case1 = m < o_ab
    ks = np.where(case1, v, np.where(oa > ob, b, a))
    keys = pack_np(kp, ks)
    keys = np.where(common, keys, EMPTY_KEY)
    if return_case1:
        return keys, common & case1
    return keys


def min_edge_cobdy_all(filt: Filtration, sparse: bool = True,
                       batch: int = 4096) -> np.ndarray:
    """Smallest cofacet key per edge, stored a priori (paper §4.3.5:
    "the smallest simplex in the coboundary of each edge is stored a priori
    at the cost of O(n_e) memory")."""
    out = np.full(filt.n_e, EMPTY_KEY, dtype=np.int64)
    fn = edge_cobdy_sparse if sparse else edge_cobdy_ns
    for s in range(0, filt.n_e, batch):
        ids = np.arange(s, min(s + batch, filt.n_e))
        keys = fn(filt, ids)
        out[ids] = keys[:, 0] if keys.shape[1] else EMPTY_KEY
    return out


# ---------------------------------------------------------------------------
# Triangle coboundaries (tetrahedra)
# ---------------------------------------------------------------------------

def tri_vertices(filt: Filtration, tri_keys: np.ndarray):
    """Vertices (a, b, c) of triangles given packed keys <kp, c>."""
    tri_keys = np.asarray(tri_keys, dtype=np.int64)
    kp = tri_keys >> 32
    c = tri_keys & np.int64((1 << 32) - 1)
    a = filt.edges[kp, 0].astype(np.int64)
    b = filt.edges[kp, 1].astype(np.int64)
    return a, b, c.astype(np.int64), kp


def tri_cobdy_ns(filt: Filtration, tri_keys: np.ndarray) -> np.ndarray:
    """Coboundary (tetrahedra) keys for a batch of triangles, NS path.

    Returns (B, n) int64 packed keys ascending, EMPTY_KEY padded.
    """
    a, b, c, kp = tri_vertices(filt, tri_keys)
    oa = filt.order[a].astype(np.int64)           # (B, n) order of {a, v}
    ob = filt.order[b].astype(np.int64)
    oc = filt.order[c].astype(np.int64)
    o_bc = filt.order[b, c].astype(np.int64)[:, None]
    o_ac = filt.order[a, c].astype(np.int64)[:, None]
    keys = _tri_keys_from_orders(kp[:, None], o_ac, o_bc, oa, ob, oc)
    keys.sort(axis=1)
    return keys


def tri_cobdy_sparse(filt: Filtration, tri_keys: np.ndarray) -> np.ndarray:
    """Coboundary keys for triangles via neighborhood intersection.

    Returns (B, max_deg) int64 keys ascending, EMPTY_KEY padded.
    """
    a, b, c, kp = tri_vertices(filt, tri_keys)
    v = filt.nbr_vtx[a].astype(np.int64)          # (B, K)
    oa = filt.nbr_vtx_ord[a].astype(np.int64)
    ob = _lookup_order(filt, b, v)
    oc = _lookup_order(filt, c, v)
    o_bc = _lookup_order(filt, b, c[:, None])
    o_ac = _lookup_order(filt, a, c[:, None])
    keys = _tri_keys_from_orders(kp[:, None], o_ac, o_bc, oa, ob, oc)
    keys.sort(axis=1)
    return keys


def _tri_keys_from_orders(kp, o_ac, o_bc, oa, ob, oc):
    """Tetra keys for candidate fourth-vertices (vectorized core).

    kp: (B,1) triangle diameter-edge order (of {a,b}); o_ac/o_bc: (B,1);
    oa/ob/oc: (B,K) orders of {a,v}/{b,v}/{c,v} (-1 where absent).
    Tetra key: primary = max of the 6 edge orders; secondary = order of the
    edge opposite the diameter:  ab<->cv, av<->bc, bv<->ac, cv<->ab.
    """
    common = (oa >= 0) & (ob >= 0) & (oc >= 0)
    m = np.maximum(np.maximum(oa, ob), oc)
    kp_new = np.maximum(kp, m)
    ks = np.where(
        m < kp, oc,                                  # diameter = ab -> opp {c,v}
        np.where(m == oa, o_bc,                      # diameter = av -> opp {b,c}
                 np.where(m == ob, o_ac, kp)))       # bv -> {a,c} ; cv -> {a,b}
    keys = pack_np(kp_new, ks)
    return np.where(common, keys, EMPTY_KEY)


def greatest_boundary_triangle(filt: Filtration, tet_keys: np.ndarray) -> np.ndarray:
    """For tetra <k1,k2>: greatest facet = <k1, max vertex of edge(k2)>
    (paper §4.3.5) — the candidate trivial-pair owner."""
    tet_keys = np.asarray(tet_keys, dtype=np.int64)
    k1 = tet_keys >> 32
    k2 = tet_keys & np.int64((1 << 32) - 1)
    vmax = filt.edges[k2].max(axis=-1).astype(np.int64) if tet_keys.ndim else \
        np.int64(filt.edges[k2].max())
    return (k1 << 32) | vmax


def min_tri_cobdy(filt: Filtration, tri_keys: np.ndarray,
                  sparse: bool = True) -> np.ndarray:
    """Smallest cofacet key per triangle (trivial-pair check, H2*)."""
    fn = tri_cobdy_sparse if sparse else tri_cobdy_ns
    keys = fn(filt, np.atleast_1d(tri_keys))
    return keys[:, 0]


# ---------------------------------------------------------------------------
# Column enumeration for H2*: case-1 triangles grouped by diameter edge
# ---------------------------------------------------------------------------

def case1_triangles_of_edges(filt: Filtration, e_orders: np.ndarray,
                             sparse: bool = True) -> list[np.ndarray]:
    """For each edge e: triangles with diameter e, i.e. common neighbors v
    with O_av < e and O_bv < e; returned as packed keys <e, v>, ascending.
    These are exactly the H2* columns owned by e (paper Alg. 3 line 13)."""
    e_orders = np.asarray(e_orders, dtype=np.int64)
    a = filt.edges[e_orders, 0].astype(np.int64)
    b = filt.edges[e_orders, 1].astype(np.int64)
    if sparse:
        v = filt.nbr_vtx[a].astype(np.int64)
        oa = filt.nbr_vtx_ord[a].astype(np.int64)
        ob = _lookup_order(filt, b, v)
    else:
        v = np.broadcast_to(np.arange(filt.n, dtype=np.int64),
                            (len(e_orders), filt.n))
        oa = filt.order[a].astype(np.int64)
        ob = filt.order[b].astype(np.int64)
    ok = (oa >= 0) & (ob >= 0) & (oa < e_orders[:, None]) & (ob < e_orders[:, None])
    out = []
    for i, e in enumerate(e_orders):
        vs = np.sort(v[i][ok[i]])
        out.append((np.int64(e) << 32) | vs)
    return out


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _lookup_order(filt: Filtration, row: np.ndarray, v: np.ndarray) -> np.ndarray:
    """Order of edge {row_i, v_ij} via batched binary search in N^row
    (sparse lookup; -1 where absent).  row: (B,), v: (B, K)."""
    nbr = filt.nbr_vtx[row].astype(np.int64)            # (B, K) sorted, pad = n
    ords = filt.nbr_vtx_ord[row].astype(np.int64)
    B, K = nbr.shape
    deg = filt.degree[row].astype(np.int64)[:, None]
    stride = np.int64(filt.n + 1)
    flat = (nbr + np.arange(B, dtype=np.int64)[:, None] * stride).ravel()
    q = (np.clip(v, 0, filt.n) + np.arange(B, dtype=np.int64)[:, None] * stride)
    pos = np.searchsorted(flat, q.ravel()).reshape(B, -1)
    pos_in_row = pos - np.arange(B, dtype=np.int64)[:, None] * K
    valid = (pos_in_row >= 0) & (pos_in_row < deg)
    pos_c = np.clip(pos_in_row, 0, K - 1)
    hit = valid & (np.take_along_axis(nbr, pos_c, axis=1) == v)
    o = np.take_along_axis(ords, pos_c, axis=1)
    return np.where(hit, o, -1)

"""Cohomology reduction engines (Dory §4.3).

Implements the paper's reduction family on packed paired-index keys:

* ``explicit`` mode — paper Algorithm 1: store the reduced coboundary columns
  ``R^⊥`` (sorted key arrays).  Fastest, highest memory.
* ``implicit`` mode — paper Algorithm 2 / §4.3.4 ("fast implicit column"):
  store only the reduction operations ``V^⊥`` (lists of generator column
  ids); a lookback re-materializes ``R^⊥(e') = ⊕ δe''`` by vectorized
  coboundary enumeration + merge-cancel.  Memory ∝ Σ|V| — the paper's
  potential factor-n saving.

Both modes implement:
* **trivial persistence pairs** (§4.3.5): pairs ``(t, e')`` with
  ``t = min δe'`` and ``diam(t) = e'`` are never stored and are detected by
  an O(1) check against the precomputed min-cofacet array; reductions with a
  trivial owner use its freshly-enumerated coboundary.
* **clearing** (§4.5, Chen-Kerber): columns that were pivots in the lower
  dimension are skipped entirely.

The *serial-parallel* batched engine (§4.4) lives in ``serial_parallel.py``
and reuses the same column primitives.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..analyze.invariants import active_sanitizer
from ..obs.metrics import MetricsRegistry
from ..obs.trace import span
from .pairing import EMPTY_KEY


def merge_cancel(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Symmetric difference of two sorted unique int64 key arrays (GF(2) add).

    The TPU form of "column j <- column j (+) column i": concatenate, sort,
    drop equal pairs.  Inputs may carry EMPTY_KEY padding (stripped)."""
    m = np.concatenate([a, b])
    m = m[m != EMPTY_KEY]
    m.sort(kind="stable")
    if m.size == 0:
        return m
    neq_prev = np.empty(m.size, dtype=bool)
    neq_prev[0] = True
    np.not_equal(m[1:], m[:-1], out=neq_prev[1:])
    neq_next = np.empty(m.size, dtype=bool)
    neq_next[-1] = True
    np.not_equal(m[:-1], m[1:], out=neq_next[:-1])
    return m[neq_prev & neq_next]


def parity_reduce(keys: np.ndarray) -> np.ndarray:
    """Keep keys appearing an odd number of times (multi-way GF(2) sum)."""
    keys = keys[keys != EMPTY_KEY]
    if keys.size == 0:
        return keys
    u, c = np.unique(keys, return_counts=True)
    return u[(c % 2) == 1]


@dataclasses.dataclass
class DimensionAdapter:
    """Dimension-specific plumbing for the generic cohomology reduction.

    columns are identified by int64 ids (edge order for H1*, packed triangle
    key for H2*); lows are cofacet keys one dimension up.
    """
    # coboundary of a batch of column ids -> (B, K) sorted keys, EMPTY pad
    cobdy: Callable[[np.ndarray], np.ndarray]
    # candidate trivial owner of a low key -> column id
    owner_of_low: Callable[[np.ndarray], np.ndarray]
    # min cofacet key of a column id (for trivial checks); vectorized
    min_cobdy: Callable[[np.ndarray], np.ndarray]
    # filtration value of a column id / of a low key
    birth_value: Callable[[np.ndarray], np.ndarray]
    death_value: Callable[[np.ndarray], np.ndarray]


@dataclasses.dataclass
class ReductionResult:
    pairs: np.ndarray          # (k, 2) float64 (birth, death), death finite
    essentials: np.ndarray     # (m,) float64 births of never-dying classes
    pivot_lows: np.ndarray     # int64 keys that became pivots (for clearing)
    stats: Dict[str, float]
    # provenance (optional — engines fill them, synthetic results may not):
    # column ids aligned with ``pairs`` rows / ``pivot_lows`` entries /
    # ``essentials`` entries, so callers can split a batched reduction back
    # into per-source diagrams and warm-start engines can replay columns
    pair_cols: Optional[np.ndarray] = None      # (k,) int64
    pivot_cols: Optional[np.ndarray] = None     # (p,) int64, incl. trivial
    essential_ids: Optional[np.ndarray] = None  # (m,) int64

    def diagram(self) -> np.ndarray:
        ess = np.stack([self.essentials,
                        np.full_like(self.essentials, np.inf)], axis=1) \
            if self.essentials.size else np.zeros((0, 2))
        return np.concatenate([self.pairs, ess], axis=0)


class PivotStore:
    """R^⊥/V^⊥ storage with trivial pairs excluded (paper §4.3.1, §4.3.5).

    ``store_budget_bytes`` makes the explicit store *budgeted*: once the
    stored bytes would cross the budget, columns are demoted to implicit
    form (V^⊥ generator lists, re-materialized on lookup) — memory stays
    bounded by the budget plus one column, at the price of re-enumerating
    coboundaries when a spilled column is looked up.  The reduction's output
    is unchanged: both representations reproduce the identical ``R^⊥`` keys.
    Per-column representation is tracked in ``col_modes`` so the two forms
    coexist in one table.

    Spill *policy* is largest-explicit-column-first (a max-heap over
    explicit column sizes): when a commit would cross the budget, the
    biggest explicit columns already in the store are demoted to implicit
    until the incoming column fits — unless the incoming column is itself
    at least as big as everything stored, in which case it is the one that
    goes implicit.  Big columns buy the least lookups per byte, so evicting
    them first keeps the most pivots explicit under a fixed budget (the
    earlier policy never demoted: whatever committed first stayed explicit
    forever, i.e. naive FIFO).

    Mixed mode needs one extra invariant: a spilled column's stored V must
    be a *complete* δ-basis expansion, which requires the expansions of the
    explicit columns it absorbed too (``R(o) = δo ⊕ ⊕_{g∈V(o)} δg`` — an
    explicit ``R`` array alone cannot be expanded after the fact).  So
    whenever spilling is possible, gens are tracked for explicit commits as
    well (``gens_lists``, counted against the budget); the pure explicit
    path stores nothing extra.
    """

    def __init__(self, adapter: DimensionAdapter, mode: str,
                 store_budget_bytes: Optional[int] = None,
                 cache=None, commit_log: Optional[list] = None):
        assert mode in ("explicit", "implicit")
        self.adapter = adapter
        self.mode = mode
        self.store_budget_bytes = store_budget_bytes
        self.track_gens = (mode == "implicit"
                           or store_budget_bytes is not None)
        self.low_to_idx: Dict[int, int] = {}
        self.columns: List[np.ndarray] = []   # explicit: R keys; implicit: V gens
        self.gens_lists: List[Optional[np.ndarray]] = []  # δ-expansions
        self.col_ids: List[int] = []
        self.col_modes: List[str] = []
        self.bytes_stored = 0
        self.n_spilled = 0
        # shared PackedPivotCache (core.pivot_cache): memoizes implicit
        # re-materializations and trivial-owner coboundaries by low — both
        # canonical per low, so cache hits can never perturb bit-identity
        self.cache = cache
        # when set, every non-trivial commit appends a wire-format record
        # here (the distributed driver drains it each superstep)
        self.commit_log = commit_log
        # max-heap (as negated sizes) over explicit column byte sizes for the
        # largest-explicit-column-first spill policy; entries are permanent
        # (a column is popped exactly once, when demoted)
        self._explicit_heap: List[Tuple[int, int]] = []

    def lookup_addend(self, low: int, self_id: int) -> Optional[np.ndarray]:
        """Column to add into r given its current low; None if low is fresh.

        Order of checks mirrors the paper: trivial pair first (O(1) check,
        nothing stored), then the committed pivot table.
        """
        owner = int(self.adapter.owner_of_low(np.array([low], dtype=np.int64))[0])
        if owner != self_id:
            mc = int(self.adapter.min_cobdy(np.array([owner], dtype=np.int64))[0])
            if mc == low:
                # (low, owner) is a trivial pair: R(owner) == δ(owner).
                return self.adapter.cobdy(np.array([owner], dtype=np.int64))[0]
        idx = self.low_to_idx.get(low)
        if idx is None:
            return None
        if self.col_modes[idx] == "explicit":
            return self.columns[idx]
        return self._materialize(idx, low)

    def _materialize(self, idx: int, low: int) -> np.ndarray:
        """R(e') = ⊕_{e'' in V(e') ∪ {e'}} δe'' for an implicit column,
        served from the shared pivot cache when possible — the reduced
        column at a given low is canonical, so the memo is exact."""
        if self.cache is not None:
            keys = self.cache.get_column(low)
            if keys is not None:
                return keys
        gens = np.concatenate([self.columns[idx],
                               np.array([self.col_ids[idx]], dtype=np.int64)])
        r = parity_reduce(self.adapter.cobdy(gens).ravel())
        if self.cache is not None:
            self.cache.put_column(low, r)
        return r

    def _demote(self, idx: int) -> None:
        """Convert a stored explicit column to implicit (V^⊥) in place."""
        assert self.col_modes[idx] == "explicit" \
            and self.gens_lists[idx] is not None
        san = active_sanitizer()
        if san is not None and callable(getattr(self.adapter, "cobdy", None)):
            # a demotion is one-way: verify the δ-expansion reproduces the
            # explicit R keys *before* they are dropped (needs a real
            # adapter — synthetic stores with stub adapters skip this)
            gens = np.concatenate([
                self.gens_lists[idx],
                np.array([self.col_ids[idx]], dtype=np.int64)])
            rematerialized = parity_reduce(self.adapter.cobdy(gens).ravel())
            san.check_rematerialization(self.columns[idx], rematerialized,
                                        self.col_ids[idx])
        self.bytes_stored -= self.columns[idx].nbytes
        self.columns[idx] = self.gens_lists[idx]
        self.col_modes[idx] = "implicit"
        self.n_spilled += 1

    def _make_room(self, incoming_total: int, incoming_r_nbytes: int) -> bool:
        """Largest-explicit-column-first spill: demote the biggest explicit
        columns until ``incoming_total`` more bytes (R keys plus tracked
        gens) fit the budget.  Returns False (caller commits implicitly)
        once the incoming column's R keys are at least as big as every
        remaining explicit column — demoting smaller columns to admit a
        bigger one would only shrink the explicit set.  Demotions are
        planned first and applied only when they actually make the
        incoming column fit: demotion is one-way (the explicit R keys are
        dropped), so a doomed admission must not evict anything."""
        planned: List[Tuple[int, int]] = []
        freed = 0
        fits = True
        while self.bytes_stored - freed + incoming_total \
                > self.store_budget_bytes:
            if not self._explicit_heap:
                fits = False
                break
            neg_size, idx = self._explicit_heap[0]
            if -neg_size <= incoming_r_nbytes:
                fits = False
                break
            planned.append(heapq.heappop(self._explicit_heap))
            freed += -neg_size
        if not fits:
            for item in planned:
                heapq.heappush(self._explicit_heap, item)
            return False
        if planned:
            with span("reduce/spill", n=len(planned), freed_bytes=freed):
                for _, idx in planned:
                    self._demote(idx)
        return True

    def commit(self, low: int, col_id: int, r: np.ndarray, gens: np.ndarray,
               trivial: bool) -> None:
        if trivial:
            return  # never stored (paper §4.3.5)
        san = active_sanitizer()
        if san is not None:
            san.check_fresh_pivot(self.low_to_idx, low)
            if r.size:
                san.check_canonical_column(r)
        mode = self.mode
        if mode == "explicit" and self.store_budget_bytes is not None:
            incoming = r.nbytes + (gens.nbytes if self.track_gens else 0)
            if not self._make_room(incoming, r.nbytes):
                mode = "implicit"   # budget spill: keep V gens, drop R keys
                self.n_spilled += 1
        self.low_to_idx[low] = len(self.columns)
        self.col_ids.append(col_id)
        self.col_modes.append(mode)
        if mode == "explicit":
            self.columns.append(r)
            self.bytes_stored += r.nbytes
            if self.store_budget_bytes is not None:
                heapq.heappush(self._explicit_heap,
                               (-r.nbytes, len(self.columns) - 1))
            # keep the δ-expansion too when spilling is possible: a later
            # spilled column that absorbed this one needs it (see class
            # docstring); counted against the budget for honesty
            self.gens_lists.append(gens if self.track_gens else None)
            if self.track_gens:
                self.bytes_stored += gens.nbytes
        else:
            self.columns.append(gens)
            self.gens_lists.append(gens)
            self.bytes_stored += gens.nbytes
        if self.commit_log is not None:
            self.commit_log.append({
                "low": low, "col_id": col_id, "mode": mode,
                "column": r if mode == "explicit" else None,
                "gens": gens,
            })

    def install(self, low: int, col_id: int, mode: str, column, gens) -> None:
        """Install a decoded replicated pivot verbatim (no budget logic).

        The distributed driver's per-device *replica* stores are built
        exclusively through this path, from records that crossed the
        pivot-exchange wire.  A replica never spills or demotes — it holds
        whatever mode the authoritative store committed (a later demotion on
        the authority is representational only and is not replicated)."""
        assert mode in ("explicit", "implicit")
        san = active_sanitizer()
        if san is not None:
            san.check_fresh_pivot(self.low_to_idx, low)
        self.low_to_idx[low] = len(self.columns)
        self.col_ids.append(col_id)
        self.col_modes.append(mode)
        gens = np.ascontiguousarray(gens, dtype=np.int64)
        if mode == "explicit":
            column = np.ascontiguousarray(column, dtype=np.int64)
            self.columns.append(column)
            self.bytes_stored += column.nbytes
            self.gens_lists.append(gens if self.track_gens else None)
            if self.track_gens:
                self.bytes_stored += gens.nbytes
        else:
            self.columns.append(gens)
            self.gens_lists.append(gens)
            self.bytes_stored += gens.nbytes

    def lookup_addends_batched(self, lows: np.ndarray, self_ids: np.ndarray):
        """Vectorized :meth:`lookup_addend` over a batch of columns.

        ``lows``: (B,) int64 current lows (negative = inactive, skipped);
        ``self_ids``: (B,) int64 owning column ids.  Returns
        ``(addends, owners, owner_gens)`` — per column the addend key array
        (None when the low is fresh), the owner column id, and the owner's
        stored δ-expansion (empty for trivial owners / untracked columns).
        The per-element adapter calls of the scalar path (one
        ``np.array([x])`` per probe) collapse into one ``owner_of_low``, one
        ``min_cobdy``, and one ``cobdy`` call per batch round.
        """
        lows = np.asarray(lows, dtype=np.int64)
        self_ids = np.asarray(self_ids, dtype=np.int64)
        B = len(lows)
        addends: List[Optional[np.ndarray]] = [None] * B
        owners = np.full(B, -1, dtype=np.int64)
        owner_gens: List[Optional[np.ndarray]] = [None] * B
        no_gens = np.zeros(0, dtype=np.int64)
        active = lows >= 0
        if not active.any():
            return addends, owners, owner_gens
        own = np.full(B, -1, dtype=np.int64)
        own[active] = self.adapter.owner_of_low(lows[active])
        # trivial pairs first (order mirrors lookup_addend): owner != self
        # and low == min δ(owner)  =>  addend is δ(owner) itself
        cand = active & (own != self_ids)
        trivial = np.zeros(B, dtype=bool)
        if cand.any():
            ci = np.where(cand)[0]
            mc = self.adapter.min_cobdy(own[ci])
            trivial[ci[mc == lows[ci]]] = True
        if trivial.any():
            ti = np.where(trivial)[0]
            # a trivial addend δ(owner) is canonical per low (owner =
            # owner_of_low(low)), so it lives in the shared cache too;
            # only the misses get the batched enumeration
            miss = []
            for i in ti:
                cached = (self.cache.get_column(int(lows[i]))
                          if self.cache is not None else None)
                if cached is None:
                    miss.append(i)
                else:
                    addends[i] = cached
                owners[i] = own[i]
                owner_gens[i] = no_gens
            if miss:
                mi = np.asarray(miss)
                tcob = self.adapter.cobdy(own[mi])
                for k, i in enumerate(mi):
                    row = tcob[k]
                    addends[i] = row[row != EMPTY_KEY]
                    if self.cache is not None:
                        self.cache.put_column(int(lows[i]), addends[i])
        for i in np.where(active & ~trivial)[0]:
            idx = self.low_to_idx.get(int(lows[i]))
            if idx is None:
                continue
            owners[i] = self.col_ids[idx]
            g = self.gens_lists[idx]
            owner_gens[i] = g if g is not None else no_gens
            if self.col_modes[idx] == "explicit":
                addends[i] = self.columns[idx]
            else:
                addends[i] = self._materialize(idx, int(lows[i]))
        return addends, owners, owner_gens


def clearing_filter(column_ids, cleared) -> np.ndarray:
    """Drop cleared ids from ``column_ids``, order preserved (vectorized).

    ``cleared`` may be a set (legacy callers) or any int array-like; one
    ``np.isin`` replaces the former per-column Python membership loop, which
    dominated at large ``n_e``.
    """
    ids = np.asarray(column_ids, dtype=np.int64)
    if cleared is None:
        return ids
    if isinstance(cleared, (set, frozenset)):
        carr = np.fromiter(cleared, dtype=np.int64, count=len(cleared))
    else:
        carr = np.asarray(cleared, dtype=np.int64)
    if ids.size == 0 or carr.size == 0:
        return ids
    return ids[~np.isin(ids, carr)]


def finalize_result(pairs: List[tuple], essentials: List[float],
                    essential_ids: List[int],
                    stats: Dict[str, float]) -> ReductionResult:
    """Assemble a :class:`ReductionResult` from 4-tuple ``(b, d, low, col)``
    pair records — trivial pairs (d == b) drop out of the diagram but keep
    their lows/cols for clearing and warm restarts (shared by all engines).
    """
    finite = [(b, d) for b, d, _, _ in pairs if d > b]
    pair_arr = np.array(finite, dtype=np.float64).reshape(-1, 2)
    pair_cols = np.array([c for b, d, _, c in pairs if d > b], dtype=np.int64)
    pivot_lows = np.array([low for _, _, low, _ in pairs], dtype=np.int64)
    pivot_cols = np.array([c for _, _, _, c in pairs], dtype=np.int64)
    return ReductionResult(
        pairs=pair_arr,
        essentials=np.array(essentials, dtype=np.float64),
        pivot_lows=pivot_lows,
        stats=stats,
        pair_cols=pair_cols,
        pivot_cols=pivot_cols,
        essential_ids=np.array(essential_ids, dtype=np.int64),
    )


def _parity_gens(gens_parity: Dict[int, int]) -> np.ndarray:
    """Odd-count generator ids of a parity dict as a sorted int64 array."""
    g = np.array([k for k, p in gens_parity.items() if p % 2 == 1],
                 dtype=np.int64)
    g.sort()
    return g


def seed_column(adapter: DimensionAdapter, col_id: int,
                seed: np.ndarray) -> np.ndarray:
    """Initial residual of a warm-started column (resume support).

    ``R0(col) = ⊕_{g ∈ seed ∪ {col}} δg`` — the partial reduction state a
    prior run recorded as the column's V-expansion, re-expressed against the
    *current* coboundary.  Every ``g`` precedes ``col`` in decreasing
    filtration order, so handing this to an engine in place of ``δ(col)``
    is a valid left-to-right partial reduction: completing it greedily
    yields the canonical pairing, bit-identical to a cold run.
    """
    seed = np.asarray(seed, dtype=np.int64)
    gens = np.concatenate([seed, np.array([col_id], dtype=np.int64)])
    return parity_reduce(adapter.cobdy(gens).ravel())


def clearance_commit(store: PivotStore, adapter: DimensionAdapter,
                     ids: np.ndarray, lows: np.ndarray,
                     gens_list, get_columns,
                     pairs: List[tuple], essentials: List[float],
                     essential_ids: Optional[List[int]] = None,
                     essential_log: Optional[list] = None) -> None:
    """Batched clearance (§4.4 "clearance" step), shared by the batch and
    packed engines: batched value lookups, trivial-pair detection, commits
    in batch order.

    ``lows``: (B,) int64 current lows (-1 = empty column -> essential).
    ``get_columns(rows)`` materializes the R key arrays for exactly the
    rows whose explicit columns the store will hold — it is never called
    for trivial pairs (nothing stored, §4.3.5) nor for a pure implicit
    store (only gens stored).  Appends ``(birth, death, low, col_id)``
    tuples and essential births in place.  ``essential_ids`` collects the
    essential column ids alongside; ``essential_log`` additionally records
    each essential column's δ-expansion (``{"col_id", "gens"}``) so a
    warm restart can replay it (:mod:`repro.core.resume`).
    """
    ids_arr = np.asarray(ids, dtype=np.int64)
    lows = np.asarray(lows, dtype=np.int64)
    B = len(ids_arr)
    empty = [i for i in range(B) if lows[i] < 0]
    if empty:
        births = adapter.birth_value(ids_arr[empty])
        essentials.extend(float(b) for b in births)
        if essential_ids is not None:
            essential_ids.extend(int(ids_arr[i]) for i in empty)
        if essential_log is not None:
            for i in empty:
                essential_log.append({
                    "col_id": int(ids_arr[i]),
                    "gens": _parity_gens(gens_list[i]),
                })
    nonempty = [i for i in range(B) if lows[i] >= 0]
    if not nonempty:
        return
    ne_ids = ids_arr[nonempty]
    ne_lows = lows[nonempty]
    mcs = adapter.min_cobdy(ne_ids)
    ne_owners = adapter.owner_of_low(ne_lows)
    births = adapter.birth_value(ne_ids)
    deaths = adapter.death_value(ne_lows)
    san = active_sanitizer()
    if san is not None:
        san.check_pair_orders(births, deaths)
    trivial = (np.asarray(mcs) == ne_lows) & (np.asarray(ne_owners) == ne_ids)
    if store.mode == "implicit":
        store_rows = np.zeros(0, dtype=np.int64)
    else:
        store_rows = np.asarray(nonempty, dtype=np.int64)[~trivial]
    cols = dict(zip(store_rows.tolist(), get_columns(store_rows)))
    no_col = np.zeros(0, dtype=np.int64)
    for k, i in enumerate(nonempty):
        if trivial[k]:
            store.commit(int(ne_lows[k]), int(ne_ids[k]), no_col, no_col,
                         True)
        else:
            g = _parity_gens(gens_list[i])
            store.commit(int(ne_lows[k]), int(ne_ids[k]), cols.get(i, no_col),
                         g, False)
        pairs.append((float(births[k]), float(deaths[k]), int(ne_lows[k]),
                      int(ne_ids[k])))


def reduce_dimension(
    adapter: DimensionAdapter,
    column_ids: np.ndarray,
    mode: str = "explicit",
    cleared=None,
    return_store: bool = False,
    store_budget_bytes: Optional[int] = None,
    seed_gens: Optional[Dict[int, np.ndarray]] = None,
    commit_log: Optional[list] = None,
    essential_log: Optional[list] = None,
):
    """Single-column (paper 1-thread) cohomology reduction.

    ``column_ids`` must be in *decreasing* filtration order (``F^-1``), with
    clearing already applied or supplied via ``cleared`` (set or int array).
    ``store_budget_bytes`` bounds the explicit pivot store: columns past the
    budget are kept implicitly (V^⊥) and re-materialized on lookup — same
    diagram, bounded memory (see :class:`PivotStore`).

    Warm restarts (:mod:`repro.core.resume`): ``seed_gens`` maps column ids
    to the δ-expansion a prior run recorded for them — a seeded column
    starts from :func:`seed_column`'s residual with its gens parity
    pre-loaded, so committed/logged expansions stay *full* raw-δ
    expansions.  ``commit_log`` threads through to :class:`PivotStore`
    (every non-trivial commit appended); ``essential_log`` records
    ``{"col_id", "gens"}`` for every essential column.
    """
    store = PivotStore(adapter, mode, store_budget_bytes=store_budget_bytes,
                       commit_log=commit_log)
    pairs: List[tuple] = []
    essentials: List[float] = []
    essential_ids: List[int] = []
    n_reductions = 0
    n_columns_in = len(column_ids)
    column_ids = clearing_filter(column_ids, cleared)

    for col_id in column_ids:
        col_id = int(col_id)
        seed = seed_gens.get(col_id) if seed_gens else None
        if seed is not None and len(seed):
            r = seed_column(adapter, col_id, seed)
            gens_parity: Dict[int, int] = {int(g): 1 for g in seed}
        else:
            r = adapter.cobdy(np.array([col_id], dtype=np.int64))[0]
            r = r[r != EMPTY_KEY]
            gens_parity = {}
        while True:
            if r.size == 0:
                essentials.append(float(
                    adapter.birth_value(np.array([col_id], dtype=np.int64))[0]))
                essential_ids.append(col_id)
                if essential_log is not None:
                    essential_log.append({"col_id": col_id,
                                          "gens": _parity_gens(gens_parity)})
                break
            low = int(r[0])
            addend = store.lookup_addend(low, col_id)
            if addend is None:
                # Fresh pivot: (low, col_id) is a persistence pair.
                mc = int(adapter.min_cobdy(
                    np.array([col_id], dtype=np.int64))[0])
                owner = int(adapter.owner_of_low(
                    np.array([low], dtype=np.int64))[0])
                trivial = (mc == low) and (owner == col_id)
                gens = _parity_gens(gens_parity)
                store.commit(low, col_id, r, gens, trivial)
                b = float(adapter.birth_value(np.array([col_id], dtype=np.int64))[0])
                d = float(adapter.death_value(np.array([low], dtype=np.int64))[0])
                pairs.append((b, d, low, col_id))
                break
            # r <- r (+) R(owner); track V in parity dict (implicit bookkeeping)
            n_reductions += 1
            owner = int(self_owner_of(store, adapter, low))
            gens_parity[owner] = gens_parity.get(owner, 0) + 1
            for g in store_gens(store, low):
                gens_parity[int(g)] = gens_parity.get(int(g), 0) + 1
            r = merge_cancel(r, addend)

    reg = MetricsRegistry()
    reg.counter("n_columns").inc(n_columns_in)
    reg.counter("n_reductions").inc(n_reductions)
    reg.counter("n_pairs").inc(len(pairs))
    reg.counter("n_essential").inc(len(essentials))
    reg.gauge("stored_bytes").set(store.bytes_stored)
    reg.gauge("n_stored_columns").set(len(store.columns))
    reg.counter("n_spilled").inc(store.n_spilled)
    result = finalize_result(pairs, essentials, essential_ids, reg.as_stats())
    if return_store:
        return result, store
    return result


def self_owner_of(store: PivotStore, adapter: DimensionAdapter, low: int) -> int:
    """Column id that owns pivot ``low`` (committed or trivial)."""
    idx = store.low_to_idx.get(low)
    if idx is not None:
        return store.col_ids[idx]
    return int(adapter.owner_of_low(np.array([low], dtype=np.int64))[0])


def store_gens(store: PivotStore, low: int) -> np.ndarray:
    """δ-expansion V(owner) for implicit bookkeeping.

    Empty for trivial owners (R = δ·owner) and for explicit owners of a
    pure explicit run (nothing tracked, nothing ever needs it); the stored
    expansion otherwise — including explicit owners of a budgeted run,
    whose expansions later spilled columns depend on.
    """
    idx = store.low_to_idx.get(low)
    if idx is not None and store.gens_lists[idx] is not None:
        return store.gens_lists[idx]
    return np.zeros(0, dtype=np.int64)

"""Cohomology reduction engines (Dory §4.3).

Implements the paper's reduction family on packed paired-index keys:

* ``explicit`` mode — paper Algorithm 1: store the reduced coboundary columns
  ``R^⊥`` (sorted key arrays).  Fastest, highest memory.
* ``implicit`` mode — paper Algorithm 2 / §4.3.4 ("fast implicit column"):
  store only the reduction operations ``V^⊥`` (lists of generator column
  ids); a lookback re-materializes ``R^⊥(e') = ⊕ δe''`` by vectorized
  coboundary enumeration + merge-cancel.  Memory ∝ Σ|V| — the paper's
  potential factor-n saving.

Both modes implement:
* **trivial persistence pairs** (§4.3.5): pairs ``(t, e')`` with
  ``t = min δe'`` and ``diam(t) = e'`` are never stored and are detected by
  an O(1) check against the precomputed min-cofacet array; reductions with a
  trivial owner use its freshly-enumerated coboundary.
* **clearing** (§4.5, Chen-Kerber): columns that were pivots in the lower
  dimension are skipped entirely.

The *serial-parallel* batched engine (§4.4) lives in ``serial_parallel.py``
and reuses the same column primitives.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from .pairing import EMPTY_KEY


def merge_cancel(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Symmetric difference of two sorted unique int64 key arrays (GF(2) add).

    The TPU form of "column j <- column j (+) column i": concatenate, sort,
    drop equal pairs.  Inputs may carry EMPTY_KEY padding (stripped)."""
    m = np.concatenate([a, b])
    m = m[m != EMPTY_KEY]
    m.sort(kind="stable")
    if m.size == 0:
        return m
    neq_prev = np.empty(m.size, dtype=bool)
    neq_prev[0] = True
    np.not_equal(m[1:], m[:-1], out=neq_prev[1:])
    neq_next = np.empty(m.size, dtype=bool)
    neq_next[-1] = True
    np.not_equal(m[:-1], m[1:], out=neq_next[:-1])
    return m[neq_prev & neq_next]


def parity_reduce(keys: np.ndarray) -> np.ndarray:
    """Keep keys appearing an odd number of times (multi-way GF(2) sum)."""
    keys = keys[keys != EMPTY_KEY]
    if keys.size == 0:
        return keys
    u, c = np.unique(keys, return_counts=True)
    return u[(c % 2) == 1]


@dataclasses.dataclass
class DimensionAdapter:
    """Dimension-specific plumbing for the generic cohomology reduction.

    columns are identified by int64 ids (edge order for H1*, packed triangle
    key for H2*); lows are cofacet keys one dimension up.
    """
    # coboundary of a batch of column ids -> (B, K) sorted keys, EMPTY pad
    cobdy: Callable[[np.ndarray], np.ndarray]
    # candidate trivial owner of a low key -> column id
    owner_of_low: Callable[[np.ndarray], np.ndarray]
    # min cofacet key of a column id (for trivial checks); vectorized
    min_cobdy: Callable[[np.ndarray], np.ndarray]
    # filtration value of a column id / of a low key
    birth_value: Callable[[np.ndarray], np.ndarray]
    death_value: Callable[[np.ndarray], np.ndarray]


@dataclasses.dataclass
class ReductionResult:
    pairs: np.ndarray          # (k, 2) float64 (birth, death), death finite
    essentials: np.ndarray     # (m,) float64 births of never-dying classes
    pivot_lows: np.ndarray     # int64 keys that became pivots (for clearing)
    stats: Dict[str, float]

    def diagram(self) -> np.ndarray:
        ess = np.stack([self.essentials,
                        np.full_like(self.essentials, np.inf)], axis=1) \
            if self.essentials.size else np.zeros((0, 2))
        return np.concatenate([self.pairs, ess], axis=0)


class PivotStore:
    """R^⊥/V^⊥ storage with trivial pairs excluded (paper §4.3.1, §4.3.5).

    ``store_budget_bytes`` makes the explicit store *budgeted*: once the
    stored bytes would cross the budget, further columns are committed in
    implicit form (V^⊥ generator lists, re-materialized on lookup) instead —
    memory stays bounded by the budget plus one column, at the price of
    re-enumerating coboundaries when a spilled column is looked up.  The
    reduction's output is unchanged: both representations reproduce the
    identical ``R^⊥`` keys.  Per-column representation is tracked in
    ``col_modes`` so the two forms coexist in one table.

    Mixed mode needs one extra invariant: a spilled column's stored V must
    be a *complete* δ-basis expansion, which requires the expansions of the
    explicit columns it absorbed too (``R(o) = δo ⊕ ⊕_{g∈V(o)} δg`` — an
    explicit ``R`` array alone cannot be expanded after the fact).  So
    whenever spilling is possible, gens are tracked for explicit commits as
    well (``gens_lists``, counted against the budget); the pure explicit
    path stores nothing extra.
    """

    def __init__(self, adapter: DimensionAdapter, mode: str,
                 store_budget_bytes: Optional[int] = None):
        assert mode in ("explicit", "implicit")
        self.adapter = adapter
        self.mode = mode
        self.store_budget_bytes = store_budget_bytes
        self.track_gens = (mode == "implicit"
                           or store_budget_bytes is not None)
        self.low_to_idx: Dict[int, int] = {}
        self.columns: List[np.ndarray] = []   # explicit: R keys; implicit: V gens
        self.gens_lists: List[Optional[np.ndarray]] = []  # δ-expansions
        self.col_ids: List[int] = []
        self.col_modes: List[str] = []
        self.bytes_stored = 0
        self.n_spilled = 0

    def lookup_addend(self, low: int, self_id: int) -> Optional[np.ndarray]:
        """Column to add into r given its current low; None if low is fresh.

        Order of checks mirrors the paper: trivial pair first (O(1) check,
        nothing stored), then the committed pivot table.
        """
        owner = int(self.adapter.owner_of_low(np.array([low], dtype=np.int64))[0])
        if owner != self_id:
            mc = int(self.adapter.min_cobdy(np.array([owner], dtype=np.int64))[0])
            if mc == low:
                # (low, owner) is a trivial pair: R(owner) == δ(owner).
                return self.adapter.cobdy(np.array([owner], dtype=np.int64))[0]
        idx = self.low_to_idx.get(low)
        if idx is None:
            return None
        if self.col_modes[idx] == "explicit":
            return self.columns[idx]
        # implicit: re-materialize R(e') = ⊕_{e'' in V(e') ∪ {e'}} δe''.
        gens = np.concatenate([self.columns[idx],
                               np.array([self.col_ids[idx]], dtype=np.int64)])
        keys = self.adapter.cobdy(gens).ravel()
        return parity_reduce(keys)

    def commit(self, low: int, col_id: int, r: np.ndarray, gens: np.ndarray,
               trivial: bool) -> None:
        if trivial:
            return  # never stored (paper §4.3.5)
        mode = self.mode
        if (mode == "explicit" and self.store_budget_bytes is not None
                and self.bytes_stored + r.nbytes > self.store_budget_bytes):
            mode = "implicit"       # budget spill: keep V gens, drop R keys
            self.n_spilled += 1
        self.low_to_idx[low] = len(self.columns)
        self.col_ids.append(col_id)
        self.col_modes.append(mode)
        if mode == "explicit":
            self.columns.append(r)
            self.bytes_stored += r.nbytes
            # keep the δ-expansion too when spilling is possible: a later
            # spilled column that absorbed this one needs it (see class
            # docstring); counted against the budget for honesty
            self.gens_lists.append(gens if self.track_gens else None)
            if self.track_gens:
                self.bytes_stored += gens.nbytes
        else:
            self.columns.append(gens)
            self.gens_lists.append(gens)
            self.bytes_stored += gens.nbytes


def clearing_filter(column_ids, cleared) -> np.ndarray:
    """Drop cleared ids from ``column_ids``, order preserved (vectorized).

    ``cleared`` may be a set (legacy callers) or any int array-like; one
    ``np.isin`` replaces the former per-column Python membership loop, which
    dominated at large ``n_e``.
    """
    ids = np.asarray(column_ids, dtype=np.int64)
    if cleared is None:
        return ids
    if isinstance(cleared, (set, frozenset)):
        carr = np.fromiter(cleared, dtype=np.int64, count=len(cleared))
    else:
        carr = np.asarray(cleared, dtype=np.int64)
    if ids.size == 0 or carr.size == 0:
        return ids
    return ids[~np.isin(ids, carr)]


def reduce_dimension(
    adapter: DimensionAdapter,
    column_ids: np.ndarray,
    mode: str = "explicit",
    cleared=None,
    return_store: bool = False,
    store_budget_bytes: Optional[int] = None,
):
    """Single-column (paper 1-thread) cohomology reduction.

    ``column_ids`` must be in *decreasing* filtration order (``F^-1``), with
    clearing already applied or supplied via ``cleared`` (set or int array).
    ``store_budget_bytes`` bounds the explicit pivot store: columns past the
    budget are kept implicitly (V^⊥) and re-materialized on lookup — same
    diagram, bounded memory (see :class:`PivotStore`).
    """
    store = PivotStore(adapter, mode, store_budget_bytes=store_budget_bytes)
    pairs: List[tuple] = []
    essentials: List[float] = []
    n_reductions = 0
    n_columns_in = len(column_ids)
    column_ids = clearing_filter(column_ids, cleared)

    for col_id in column_ids:
        col_id = int(col_id)
        r = adapter.cobdy(np.array([col_id], dtype=np.int64))[0]
        r = r[r != EMPTY_KEY]
        gens_parity: Dict[int, int] = {}
        while True:
            if r.size == 0:
                essentials.append(float(
                    adapter.birth_value(np.array([col_id], dtype=np.int64))[0]))
                break
            low = int(r[0])
            addend = store.lookup_addend(low, col_id)
            if addend is None:
                # Fresh pivot: (low, col_id) is a persistence pair.
                mc = int(adapter.min_cobdy(
                    np.array([col_id], dtype=np.int64))[0])
                owner = int(adapter.owner_of_low(
                    np.array([low], dtype=np.int64))[0])
                trivial = (mc == low) and (owner == col_id)
                gens = np.array(
                    [g for g, p in gens_parity.items() if p % 2 == 1],
                    dtype=np.int64)
                store.commit(low, col_id, r, gens, trivial)
                b = float(adapter.birth_value(np.array([col_id], dtype=np.int64))[0])
                d = float(adapter.death_value(np.array([low], dtype=np.int64))[0])
                pairs.append((b, d, low))
                break
            # r <- r (+) R(owner); track V in parity dict (implicit bookkeeping)
            n_reductions += 1
            owner = int(self_owner_of(store, adapter, low))
            gens_parity[owner] = gens_parity.get(owner, 0) + 1
            for g in store_gens(store, low):
                gens_parity[int(g)] = gens_parity.get(int(g), 0) + 1
            r = merge_cancel(r, addend)

    pair_arr = np.array([(b, d) for b, d, _ in pairs if d > b],
                        dtype=np.float64).reshape(-1, 2)
    pivot_lows = np.array([low for _, _, low in pairs], dtype=np.int64)
    ess_arr = np.array(essentials, dtype=np.float64)
    result = ReductionResult(
        pairs=pair_arr, essentials=ess_arr, pivot_lows=pivot_lows,
        stats={
            "n_columns": float(n_columns_in),
            "n_reductions": float(n_reductions),
            "n_pairs": float(len(pairs)),
            "n_essential": float(len(essentials)),
            "stored_bytes": float(store.bytes_stored),
            "n_stored_columns": float(len(store.columns)),
            "n_spilled": float(store.n_spilled),
        },
    )
    if return_store:
        return result, store
    return result


def self_owner_of(store: PivotStore, adapter: DimensionAdapter, low: int) -> int:
    """Column id that owns pivot ``low`` (committed or trivial)."""
    idx = store.low_to_idx.get(low)
    if idx is not None:
        return store.col_ids[idx]
    return int(adapter.owner_of_low(np.array([low], dtype=np.int64))[0])


def store_gens(store: PivotStore, low: int) -> np.ndarray:
    """δ-expansion V(owner) for implicit bookkeeping.

    Empty for trivial owners (R = δ·owner) and for explicit owners of a
    pure explicit run (nothing tracked, nothing ever needs it); the stored
    expansion otherwise — including explicit owners of a budgeted run,
    whose expansions later spilled columns depend on.
    """
    idx = store.low_to_idx.get(low)
    if idx is not None and store.gens_lists[idx] is not None:
        return store.gens_lists[idx]
    return np.zeros(0, dtype=np.int64)

"""Resumable reduction state and exact warm-start incremental updates.

PH-as-a-service (``repro.serve.ph``) needs ``compute_ph``-quality answers
without paying a cold reduction for every request.  This module captures the
reduction's *replayable* state — per dimension, every committed pair with its
pivot low and owning column, plus the full raw-δ V-expansion of each
non-trivial committed and essential column — into a
:class:`ReductionCheckpoint`, and serves two exact warm-start updates on top
of it:

* **tau growth** (:func:`warm_tau_growth`) — the threshold grows on a cached
  dataset.  New edges are strictly longer than every old edge, so their
  cofacet keys are strictly larger than every old key; pairs recorded at the
  old threshold are *canonically preserved* and only (a) the new columns and
  (b) the previously-essential columns — seeded with their recorded
  residual ``⊕ δ(gens ∪ {col})`` — need reducing.  The phase-2 reduction
  lives entirely in new-key space (an old essential column's old keys cancel
  inside the seed), so it never probes an old pivot: the warm run skips the
  paired columns outright.
* **point arrival** (:func:`warm_point_arrival`) — points append to a cached
  dataset at the same threshold.  Arrivals can re-route deaths, so no old
  pair may be assumed; instead every old column *replays* from its recorded
  V-expansion (old edge orders remapped into the new filtration through the
  canonical ``(length, i, j)`` sort, which preserves their relative order).
  Seeding a column with ``⊕ δ_new(gens ∪ {col})`` is a valid left-to-right
  partial reduction — every gen precedes the column in decreasing filtration
  order — so completing it greedily reproduces the canonical pairing,
  bit-identical to a cold run (Li & Cisewski-Kehe's mergeable-PH observation,
  arXiv 2410.01839, in cohomology form).

Both paths run on any reduction engine (``single``/``batch``/``packed``,
including the packed engine's distributed ``n_shards`` driver) and re-capture
a fresh checkpoint, so updates chain.  Capture requires tracked
δ-expansions: ``mode="implicit"`` or a finite ``store_budget_bytes``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import os
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..resilience.faults import CheckpointCorruption, active_injector, flip_bit
from .filtration import Filtration, filtration_from_edges
from .h0 import compute_h0
from .homology import h2_columns, make_h1_adapter, make_h2_adapter
from .reduction import reduce_dimension

_KEY_MASK = np.int64((1 << 32) - 1)

#: on-disk format version of ReductionCheckpoint.save; bumped on layout
#: changes so a stale file is rejected as corrupt, never misparsed
CHECKPOINT_VERSION = 1

# ordinal of ReductionCheckpoint.load calls in this process — the
# occurrence index the ``resume.load`` injection point fires against
_LOAD_ORDINAL = 0


@dataclasses.dataclass
class DimState:
    """Replayable reduction state of one dimension (H1* or H2*)."""

    pairs: np.ndarray          # (k, 2) float64 finite diagram pairs (d > b)
    pair_cols: np.ndarray      # (k,) int64 owning column ids
    essentials: np.ndarray     # (m,) float64 births of essential classes
    essential_ids: np.ndarray  # (m,) int64 essential column ids
    pivot_lows: np.ndarray     # (p,) int64 all pivot lows (incl. trivial)
    pivot_cols: np.ndarray     # (p,) int64 their owning columns
    gens: Dict[int, np.ndarray]  # col id -> full raw-δ V-expansion

    def diagram(self) -> np.ndarray:
        ess = np.stack([self.essentials,
                        np.full_like(self.essentials, np.inf)], axis=1) \
            if self.essentials.size else np.zeros((0, 2))
        return np.concatenate([self.pairs, ess], axis=0)

    def nbytes(self) -> int:
        arrs = (self.pairs, self.pair_cols, self.essentials,
                self.essential_ids, self.pivot_lows, self.pivot_cols)
        return int(sum(a.nbytes for a in arrs)
                   + sum(g.nbytes for g in self.gens.values()))


@dataclasses.dataclass
class ReductionCheckpoint:
    """Everything a warm restart needs about a finished reduction."""

    n: int                     # vertex count of the captured filtration
    n_e: int                   # edge count
    edges: np.ndarray          # (n_e, 2) int32 — identity check + remapping
    tau_max: float
    maxdim: int
    dims: Dict[int, DimState]  # 1 and/or 2

    def nbytes(self) -> int:
        return int(self.edges.nbytes
                   + sum(d.nbytes() for d in self.dims.values()))

    # ---- integrity + versioned persistence (docs/resilience.md) ----

    def content_hash(self) -> str:
        """sha256 over the checkpoint's entire replayable content.

        Scalars, edges, and every DimState array (gens in sorted col-id
        order) feed one canonical byte stream — two checkpoints hash equal
        iff a warm restart from them is bit-identical."""
        h = hashlib.sha256()
        h.update(np.array([self.n, self.n_e, self.maxdim],
                          dtype=np.int64).tobytes())
        h.update(np.float64(self.tau_max).tobytes())
        h.update(np.ascontiguousarray(self.edges, dtype=np.int32).tobytes())
        for d in sorted(self.dims):
            st = self.dims[d]
            h.update(np.int64(d).tobytes())
            for arr in (st.pairs, st.pair_cols, st.essentials,
                        st.essential_ids, st.pivot_lows, st.pivot_cols):
                h.update(np.ascontiguousarray(arr).tobytes())
            for cid in sorted(st.gens):
                h.update(np.int64(cid).tobytes())
                h.update(np.ascontiguousarray(st.gens[cid],
                                              dtype=np.int64).tobytes())
        return h.hexdigest()

    def save(self, path: str) -> str:
        """Versioned, hash-stamped save (npz).  Atomic: writes to a temp
        sibling and renames, so a crashed save never shadows a good file.
        Returns :meth:`content_hash`."""
        digest = self.content_hash()
        arrays: Dict[str, np.ndarray] = {
            "__meta__": np.array([CHECKPOINT_VERSION, self.n, self.n_e,
                                  self.maxdim], dtype=np.int64),
            "__tau__": np.float64([self.tau_max]),
            "__hash__": np.frombuffer(bytes.fromhex(digest),
                                      dtype=np.uint8).copy(),
            "edges": np.ascontiguousarray(self.edges, dtype=np.int32),
        }
        for d, st in self.dims.items():
            p = f"dim{d}_"
            arrays[p + "pairs"] = st.pairs
            arrays[p + "pair_cols"] = st.pair_cols
            arrays[p + "essentials"] = st.essentials
            arrays[p + "essential_ids"] = st.essential_ids
            arrays[p + "pivot_lows"] = st.pivot_lows
            arrays[p + "pivot_cols"] = st.pivot_cols
            ids = np.array(sorted(st.gens), dtype=np.int64)
            arrays[p + "gen_ids"] = ids
            offs = np.zeros(ids.size + 1, dtype=np.int64)
            data = [np.ascontiguousarray(st.gens[int(c)], dtype=np.int64)
                    for c in ids]
            if data:
                np.cumsum([g.size for g in data], out=offs[1:])
            arrays[p + "gen_offsets"] = offs
            arrays[p + "gen_data"] = (np.concatenate(data) if data
                                      else np.zeros(0, dtype=np.int64))
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez_compressed(f, **arrays)
        os.replace(tmp, path)
        return digest

    @classmethod
    def load(cls, path: str) -> "ReductionCheckpoint":
        """Inverse of :meth:`save` with integrity checking.

        Raises :class:`~repro.resilience.faults.CheckpointCorruption` on a
        truncated/unparseable file, an unsupported format version, or a
        content-hash mismatch — callers fall back to a cold reduction (the
        detect-corrupt -> fall-back-to-cold contract shared with
        ``checkpoint.Checkpointer``).  The ``resume.load`` injection point
        fires here, corrupting the *in-memory* read buffer so tests and
        the chaos soak exercise every rejection path without touching the
        file on disk."""
        global _LOAD_ORDINAL
        _LOAD_ORDINAL += 1
        with open(path, "rb") as f:
            raw = f.read()
        inj = active_injector()
        if inj is not None:
            for fault in inj.fire("resume.load", index=_LOAD_ORDINAL,
                                  path=path):
                if fault.kind == "bitflip":
                    raw = flip_bit(raw, int(fault.param("bit", 12345)))
                elif fault.kind == "truncate":
                    raw = raw[:max(1, len(raw) // 2)]
        try:
            with np.load(io.BytesIO(raw), allow_pickle=False) as z:
                arrays = {k: z[k] for k in z.files}
        except Exception as e:
            raise CheckpointCorruption(
                f"unreadable checkpoint {path!r}: {e}") from e
        try:
            meta = arrays["__meta__"]
            version = int(meta[0])
            if version != CHECKPOINT_VERSION:
                raise CheckpointCorruption(
                    f"checkpoint {path!r} has version {version}, "
                    f"expected {CHECKPOINT_VERSION}")
            dims: Dict[int, DimState] = {}
            for d in (1, 2):
                p = f"dim{d}_"
                if p + "pairs" not in arrays:
                    continue
                ids = arrays[p + "gen_ids"]
                offs = arrays[p + "gen_offsets"]
                data = arrays[p + "gen_data"]
                gens = {int(c): data[offs[i]:offs[i + 1]].copy()
                        for i, c in enumerate(ids)}
                dims[d] = DimState(
                    pairs=arrays[p + "pairs"],
                    pair_cols=arrays[p + "pair_cols"],
                    essentials=arrays[p + "essentials"],
                    essential_ids=arrays[p + "essential_ids"],
                    pivot_lows=arrays[p + "pivot_lows"],
                    pivot_cols=arrays[p + "pivot_cols"],
                    gens=gens)
            ckpt = cls(n=int(meta[1]), n_e=int(meta[2]),
                       edges=arrays["edges"],
                       tau_max=float(arrays["__tau__"][0]),
                       maxdim=int(meta[3]), dims=dims)
            stored = bytes(arrays["__hash__"]).hex()
        except CheckpointCorruption:
            raise
        except Exception as e:
            raise CheckpointCorruption(
                f"malformed checkpoint {path!r}: {e}") from e
        if ckpt.content_hash() != stored:
            raise CheckpointCorruption(
                f"checkpoint {path!r} content hash mismatch "
                "(bit rot or partial write)")
        return ckpt


def make_reducer(engine: str = "single", mode: str = "implicit",
                 batch_size: int = 128,
                 store_budget_bytes: Optional[int] = None,
                 n_shards: Optional[int] = None) -> Callable:
    """Engine dispatch with the capture/warm-start kwargs threaded through.

    Returns ``run(adapter, cols, cleared, seed_gens, commit_log,
    essential_log) -> ReductionResult``.  Capture needs every committed
    column's *full* δ-expansion, which the stores only track in implicit
    mode or under a store budget — explicit unbudgeted runs are rejected
    up front rather than producing silently incomplete checkpoints.
    """
    if mode == "explicit" and store_budget_bytes is None:
        raise ValueError(
            "checkpoint capture needs tracked δ-expansions: use "
            "mode='implicit' or set store_budget_bytes")
    if n_shards is not None and engine != "packed":
        raise ValueError("n_shards requires engine='packed'")
    if engine == "single":
        def run(adapter, cols, cleared, seed_gens, commit_log, essential_log):
            return reduce_dimension(
                adapter, cols, mode=mode, cleared=cleared,
                store_budget_bytes=store_budget_bytes, seed_gens=seed_gens,
                commit_log=commit_log, essential_log=essential_log)
    elif engine == "batch":
        from .serial_parallel import reduce_dimension_batched

        def run(adapter, cols, cleared, seed_gens, commit_log, essential_log):
            return reduce_dimension_batched(
                adapter, cols, mode=mode, cleared=cleared,
                batch_size=batch_size,
                store_budget_bytes=store_budget_bytes, seed_gens=seed_gens,
                commit_log=commit_log, essential_log=essential_log)
    elif engine == "packed":
        from .packed_reduce import reduce_dimension_packed

        def run(adapter, cols, cleared, seed_gens, commit_log, essential_log):
            return reduce_dimension_packed(
                adapter, cols, mode=mode, cleared=cleared,
                batch_size=batch_size,
                store_budget_bytes=store_budget_bytes, n_shards=n_shards,
                seed_gens=seed_gens, commit_sink=commit_log,
                essential_log=essential_log)
    else:
        raise ValueError(f"unknown engine {engine!r}")
    return run


def _dim_state(res, commit_log: list, essential_log: list) -> DimState:
    gens: Dict[int, np.ndarray] = {}
    for rec in commit_log:
        g = rec.get("gens")
        if g is None:
            raise ValueError("commit record carries no δ-expansion — "
                             "capture requires a gens-tracking store")
        gens[int(rec["col_id"])] = np.asarray(g, dtype=np.int64)
    for rec in essential_log:
        gens[int(rec["col_id"])] = np.asarray(rec["gens"], dtype=np.int64)
    return DimState(
        pairs=res.pairs, pair_cols=res.pair_cols,
        essentials=res.essentials, essential_ids=res.essential_ids,
        pivot_lows=res.pivot_lows, pivot_cols=res.pivot_cols, gens=gens)


def _h1_cols(filt: Filtration) -> np.ndarray:
    return np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)


def _seed_map(state: DimState, only: Optional[np.ndarray] = None
              ) -> Dict[int, np.ndarray]:
    if only is None:
        return dict(state.gens)
    keep = set(int(c) for c in only)
    return {c: g for c, g in state.gens.items() if c in keep}


def cold_reduce(
    filt: Filtration,
    maxdim: int = 2,
    sparse: bool = True,
    memory_budget_bytes: Optional[int] = None,
    reducer: Optional[Callable] = None,
    **reducer_opts,
) -> Tuple[Dict[int, np.ndarray], ReductionCheckpoint]:
    """The ``compute_ph`` pipeline with checkpoint capture.

    Returns ``(diagrams, checkpoint)``; diagrams are bit-identical to
    ``compute_ph(filtration=filt, ...)`` (asserted in the serve test
    suite).  ``reducer`` defaults to :func:`make_reducer`\\ ``(**opts)``.
    """
    run = reducer if reducer is not None else make_reducer(**reducer_opts)
    diagrams: Dict[int, np.ndarray] = {}
    dims: Dict[int, DimState] = {}
    h0 = compute_h0(filt)
    diagrams[0] = h0.diagram()
    res1 = None
    if maxdim >= 1:
        adapter1 = make_h1_adapter(filt, sparse=sparse)
        clog: list = []
        elog: list = []
        res1 = run(adapter1, _h1_cols(filt), h0.death_edges, None, clog, elog)
        diagrams[1] = res1.diagram()
        dims[1] = _dim_state(res1, clog, elog)
    if maxdim >= 2:
        adapter2 = make_h2_adapter(filt, sparse=sparse)
        cols2 = h2_columns(filt, res1.pivot_lows, sparse=sparse,
                           memory_budget_bytes=memory_budget_bytes)
        clog, elog = [], []
        res2 = run(adapter2, cols2, None, None, clog, elog)
        diagrams[2] = res2.diagram()
        dims[2] = _dim_state(res2, clog, elog)
    ckpt = ReductionCheckpoint(
        n=filt.n, n_e=filt.n_e, edges=np.array(filt.edges, dtype=np.int32),
        tau_max=float(filt.tau_max), maxdim=maxdim, dims=dims)
    return diagrams, ckpt


def _merge_tau_growth(old: DimState, new: DimState,
                      new_gens: Dict[int, np.ndarray]) -> DimState:
    """Checkpoint state after a tau-growth phase 2: preserved old pairs plus
    the phase-2 results; every old essential column was re-examined in
    phase 2, so its expansion record is superseded by the warm log."""
    gens = dict(old.gens)
    gens.update(new_gens)
    return DimState(
        pairs=np.concatenate([old.pairs, new.pairs], axis=0),
        pair_cols=np.concatenate([old.pair_cols, new.pair_cols]),
        essentials=new.essentials,
        essential_ids=new.essential_ids,
        pivot_lows=np.concatenate([old.pivot_lows, new.pivot_lows]),
        pivot_cols=np.concatenate([old.pivot_cols, new.pivot_cols]),
        gens=gens)


def warm_tau_growth(
    filt: Filtration,
    ckpt: ReductionCheckpoint,
    sparse: bool = True,
    memory_budget_bytes: Optional[int] = None,
    reducer: Optional[Callable] = None,
    **reducer_opts,
) -> Tuple[Dict[int, np.ndarray], ReductionCheckpoint]:
    """Exact warm start when ``filt`` extends ``ckpt``'s filtration in tau.

    Old pairs are preserved verbatim; only new columns and previously
    essential columns (seeded with their recorded residuals) reduce.  The
    module docstring carries the exactness argument.  Raises ``ValueError``
    when ``filt`` does not extend the checkpoint (callers fall back cold).
    """
    if filt.n != ckpt.n or filt.n_e < ckpt.n_e \
            or not np.array_equal(filt.edges[:ckpt.n_e],
                                  ckpt.edges.astype(filt.edges.dtype)):
        raise ValueError("filtration does not extend the checkpoint "
                         "(tau growth requires identical points and a "
                         "prefix-stable edge order)")
    run = reducer if reducer is not None else make_reducer(**reducer_opts)
    diagrams: Dict[int, np.ndarray] = {}
    dims: Dict[int, DimState] = {}
    h0 = compute_h0(filt)
    diagrams[0] = h0.diagram()
    maxdim = ckpt.maxdim
    merged1 = None
    if maxdim >= 1:
        old1 = ckpt.dims[1]
        adapter1 = make_h1_adapter(filt, sparse=sparse)
        # skip every previously paired column (its pair is canonical) on
        # top of the usual H0 clearing
        cleared = np.concatenate([np.asarray(h0.death_edges, dtype=np.int64),
                                  old1.pivot_cols])
        seeds = _seed_map(old1, only=old1.essential_ids)
        clog: list = []
        elog: list = []
        res1 = run(adapter1, _h1_cols(filt), cleared, seeds, clog, elog)
        warm_gens = _dim_state(res1, clog, elog).gens
        merged1 = _merge_tau_growth(old1, res1, warm_gens)
        diagrams[1] = merged1.diagram()
        dims[1] = merged1
    if maxdim >= 2:
        old2 = ckpt.dims[2]
        adapter2 = make_h2_adapter(filt, sparse=sparse)
        cols2 = h2_columns(filt, merged1.pivot_lows, sparse=sparse,
                           memory_budget_bytes=memory_budget_bytes)
        seeds = _seed_map(old2, only=old2.essential_ids)
        clog, elog = [], []
        res2 = run(adapter2, cols2, old2.pivot_cols, seeds, clog, elog)
        warm_gens = _dim_state(res2, clog, elog).gens
        merged2 = _merge_tau_growth(old2, res2, warm_gens)
        diagrams[2] = merged2.diagram()
        dims[2] = merged2
    new_ckpt = ReductionCheckpoint(
        n=filt.n, n_e=filt.n_e, edges=np.array(filt.edges, dtype=np.int32),
        tau_max=float(filt.tau_max), maxdim=maxdim, dims=dims)
    return diagrams, new_ckpt


def edge_order_map(ckpt: ReductionCheckpoint, filt: Filtration) -> np.ndarray:
    """Old edge order -> new edge order after points arrived.

    Old vertices keep their ids and old edge lengths are unchanged, so each
    old ``(i, j)`` appears exactly once in the new filtration; the canonical
    ``(length, i, j)`` sort preserves the *relative* order of old edges.
    Raises ``ValueError`` if any old edge is missing (not an extension).
    """
    n = max(int(filt.n), int(ckpt.n)) + 1
    old_code = (ckpt.edges[:, 0].astype(np.int64) * n
                + ckpt.edges[:, 1].astype(np.int64))
    new_code = (filt.edges[:, 0].astype(np.int64) * n
                + filt.edges[:, 1].astype(np.int64))
    order = np.argsort(new_code, kind="stable")
    pos = np.searchsorted(new_code[order], old_code)
    if (pos >= len(new_code)).any() \
            or not np.array_equal(new_code[order][pos], old_code):
        raise ValueError("new filtration does not contain every old edge")
    emap = order[pos].astype(np.int64)
    if not (np.diff(emap) > 0).all():
        raise ValueError("old edge order not preserved in new filtration")
    return emap


def _remap_tri_keys(keys: np.ndarray, emap: np.ndarray) -> np.ndarray:
    """Triangle keys ``(diam_edge_order << 32) | vertex`` under an edge-order
    remap (vertex ids are stable across point arrival)."""
    keys = np.asarray(keys, dtype=np.int64)
    return (emap[keys >> 32] << np.int64(32)) | (keys & _KEY_MASK)


def _remap_seeds(state: DimState, dim: int, emap: np.ndarray
                 ) -> Dict[int, np.ndarray]:
    """Recorded V-expansions in the new filtration's id space."""
    out: Dict[int, np.ndarray] = {}
    for col, g in state.gens.items():
        if dim == 1:
            out[int(emap[col])] = emap[np.asarray(g, dtype=np.int64)]
        else:
            key = int(_remap_tri_keys(np.array([col], dtype=np.int64),
                                      emap)[0])
            out[key] = _remap_tri_keys(g, emap)
    return out


def warm_point_arrival(
    filt: Filtration,
    ckpt: ReductionCheckpoint,
    sparse: bool = True,
    memory_budget_bytes: Optional[int] = None,
    reducer: Optional[Callable] = None,
    **reducer_opts,
) -> Tuple[Dict[int, np.ndarray], ReductionCheckpoint]:
    """Exact warm start when points arrived on ``ckpt``'s dataset.

    Arrivals may re-route deaths, so every old column replays — but from
    its recorded V-expansion (remapped through :func:`edge_order_map`), not
    from scratch: a seeded column starts at the residual its old reduction
    ended on, and the greedy completion reproduces the canonical pairing of
    the *new* complex (module docstring).  Returns full diagrams plus a
    fresh checkpoint, bit-identical to a cold run.
    """
    if filt.n < ckpt.n:
        raise ValueError("point arrival requires a vertex superset")
    emap = edge_order_map(ckpt, filt)
    run = reducer if reducer is not None else make_reducer(**reducer_opts)
    diagrams: Dict[int, np.ndarray] = {}
    dims: Dict[int, DimState] = {}
    h0 = compute_h0(filt)
    diagrams[0] = h0.diagram()
    maxdim = ckpt.maxdim
    res1 = None
    if maxdim >= 1:
        adapter1 = make_h1_adapter(filt, sparse=sparse)
        seeds = _remap_seeds(ckpt.dims[1], 1, emap)
        clog: list = []
        elog: list = []
        res1 = run(adapter1, _h1_cols(filt), h0.death_edges, seeds, clog,
                   elog)
        diagrams[1] = res1.diagram()
        dims[1] = _dim_state(res1, clog, elog)
    if maxdim >= 2:
        adapter2 = make_h2_adapter(filt, sparse=sparse)
        cols2 = h2_columns(filt, res1.pivot_lows, sparse=sparse,
                           memory_budget_bytes=memory_budget_bytes)
        seeds = _remap_seeds(ckpt.dims[2], 2, emap)
        clog, elog = [], []
        res2 = run(adapter2, cols2, None, seeds, clog, elog)
        diagrams[2] = res2.diagram()
        dims[2] = _dim_state(res2, clog, elog)
    new_ckpt = ReductionCheckpoint(
        n=filt.n, n_e=filt.n_e, edges=np.array(filt.edges, dtype=np.int32),
        tau_max=float(filt.tau_max), maxdim=maxdim, dims=dims)
    return diagrams, new_ckpt


def split_batch_state(state: DimState, dim: int,
                      edge_bounds: np.ndarray, vtx_bounds: np.ndarray,
                      cloud: int) -> DimState:
    """One cloud's :class:`DimState` out of a batched union reduction.

    A union filtration of disjoint clouds is block-diagonal: the reduction
    decomposes exactly, and every key of cloud ``k`` rebuilds its local id
    by subtracting the cloud's edge-order / vertex offsets.  ``edge_bounds``
    / ``vtx_bounds`` are the (C+1,) cumulative offsets of the union build.
    """
    e0, e1 = int(edge_bounds[cloud]), int(edge_bounds[cloud + 1])
    v0 = int(vtx_bounds[cloud])

    def col_cloud(cols: np.ndarray) -> np.ndarray:
        owner = cols if dim == 1 else (np.asarray(cols, dtype=np.int64) >> 32)
        return (owner >= e0) & (owner < e1)

    def remap_cols(cols: np.ndarray) -> np.ndarray:
        cols = np.asarray(cols, dtype=np.int64)
        if dim == 1:
            return cols - e0
        return ((cols >> 32) - e0 << np.int64(32)) | ((cols & _KEY_MASK) - v0)

    def remap_lows(lows: np.ndarray) -> np.ndarray:
        lows = np.asarray(lows, dtype=np.int64)
        if dim == 1:   # triangle keys: (diam edge << 32) | vertex
            return ((lows >> 32) - e0 << np.int64(32)) \
                | ((lows & _KEY_MASK) - v0)
        # tetra keys: (max edge << 32) | opposite edge
        return ((lows >> 32) - e0 << np.int64(32)) \
            | ((lows & _KEY_MASK) - e0)

    pair_in = col_cloud(state.pair_cols)
    ess_in = col_cloud(state.essential_ids)
    piv_in = col_cloud(state.pivot_cols)
    gens: Dict[int, np.ndarray] = {}
    for col, g in state.gens.items():
        owner = col if dim == 1 else col >> 32
        if e0 <= owner < e1:
            col_l = int(remap_cols(np.array([col], dtype=np.int64))[0])
            gens[col_l] = remap_cols(g)
    return DimState(
        pairs=state.pairs[pair_in],
        pair_cols=remap_cols(state.pair_cols[pair_in]),
        essentials=state.essentials[ess_in],
        essential_ids=remap_cols(state.essential_ids[ess_in]),
        pivot_lows=remap_lows(state.pivot_lows[piv_in]),
        pivot_cols=remap_cols(state.pivot_cols[piv_in]),
        gens=gens)


def union_filtration(filts: List[Filtration]
                     ) -> Tuple[Filtration, np.ndarray, np.ndarray]:
    """Disjoint union of per-cloud filtrations as one block filtration.

    Vertices and edges of cloud ``k`` shift by the cumulative offsets; each
    cloud's canonical edge order is kept as a contiguous block
    (``presorted=True``), so the union coboundary is block-diagonal and any
    engine's reduction of the union restricts *exactly* to each cloud's
    standalone reduction — the batching trick behind the packed serve path.
    Returns ``(filtration, vtx_bounds, edge_bounds)`` with the (C+1,)
    cumulative offsets used by :func:`split_batch_state`.
    """
    if not filts:
        raise ValueError("need at least one filtration")
    ns = np.array([f.n for f in filts], dtype=np.int64)
    nes = np.array([f.n_e for f in filts], dtype=np.int64)
    vtx_bounds = np.concatenate([[0], np.cumsum(ns)])
    edge_bounds = np.concatenate([[0], np.cumsum(nes)])
    iu = np.concatenate([f.edges[:, 0].astype(np.int64) + vtx_bounds[k]
                         for k, f in enumerate(filts)])
    ju = np.concatenate([f.edges[:, 1].astype(np.int64) + vtx_bounds[k]
                         for k, f in enumerate(filts)])
    lens = np.concatenate([f.edge_len for f in filts])
    tau = max(float(f.tau_max) for f in filts)
    filt = filtration_from_edges(int(vtx_bounds[-1]), iu, ju, lens, tau,
                                 presorted=True)
    return filt, vtx_bounds, edge_bounds


def batched_cold_reduce(
    filts: List[Filtration],
    maxdim: int = 2,
    sparse: bool = True,
    memory_budget_bytes: Optional[int] = None,
    reducer: Optional[Callable] = None,
    **reducer_opts,
) -> List[Tuple[Dict[int, np.ndarray], ReductionCheckpoint]]:
    """Reduce many small clouds as *one* union reduction, split exactly.

    One engine invocation per dimension amortizes batching / packing /
    dispatch overhead across all clouds; block-diagonality makes every
    per-cloud diagram and checkpoint bit-identical to a standalone
    :func:`cold_reduce` (asserted in ``tests/test_serve_ph.py``).  H0 runs
    per cloud — union-find is cheap and its death edges concatenate into
    the union clearing list.
    """
    if len(filts) == 1:
        return [cold_reduce(filts[0], maxdim=maxdim, sparse=sparse,
                            memory_budget_bytes=memory_budget_bytes,
                            reducer=reducer, **reducer_opts)]
    run = reducer if reducer is not None else make_reducer(**reducer_opts)
    union, vtx_bounds, edge_bounds = union_filtration(filts)
    h0s = [compute_h0(f) for f in filts]
    out_diagrams: List[Dict[int, np.ndarray]] = [
        {0: h0.diagram()} for h0 in h0s]
    out_dims: List[Dict[int, DimState]] = [dict() for _ in filts]
    res1 = None
    if maxdim >= 1:
        adapter1 = make_h1_adapter(union, sparse=sparse)
        cleared = np.concatenate(
            [np.asarray(h0.death_edges, dtype=np.int64) + edge_bounds[k]
             for k, h0 in enumerate(h0s)])
        clog: list = []
        elog: list = []
        res1 = run(adapter1, _h1_cols(union), cleared, None, clog, elog)
        state1 = _dim_state(res1, clog, elog)
        for k in range(len(filts)):
            out_dims[k][1] = split_batch_state(state1, 1, edge_bounds,
                                               vtx_bounds, k)
            out_diagrams[k][1] = out_dims[k][1].diagram()
    if maxdim >= 2:
        adapter2 = make_h2_adapter(union, sparse=sparse)
        cols2 = h2_columns(union, res1.pivot_lows, sparse=sparse,
                           memory_budget_bytes=memory_budget_bytes)
        clog, elog = [], []
        res2 = run(adapter2, cols2, None, None, clog, elog)
        state2 = _dim_state(res2, clog, elog)
        for k in range(len(filts)):
            out_dims[k][2] = split_batch_state(state2, 2, edge_bounds,
                                               vtx_bounds, k)
            out_diagrams[k][2] = out_dims[k][2].diagram()
    out = []
    for k, f in enumerate(filts):
        ckpt = ReductionCheckpoint(
            n=f.n, n_e=f.n_e, edges=np.array(f.edges, dtype=np.int32),
            tau_max=float(f.tau_max), maxdim=maxdim, dims=out_dims[k])
        out.append((out_diagrams[k], ckpt))
    return out


def canonical_diagram(diagram: np.ndarray) -> np.ndarray:
    """Rows sorted lexicographically by (birth, death) — one canonical
    presentation per diagram multiset, so any two exact pipelines (cold,
    warm, batched-union) compare bit-equal with ``np.array_equal``."""
    d = np.asarray(diagram, dtype=np.float64).reshape(-1, 2)
    if d.size == 0:
        return d
    return d[np.lexsort((d[:, 1], d[:, 0]))]

"""Bit-packed serial-parallel reduction engine (Dory §4.4 × kernels/gf2).

``reduce_dimension_batched`` (the host serial-parallel engine) spends its
time in per-column Python work: one ``merge_cancel`` sort per GF(2) add and
several one-element adapter probes per reduction (the profile is dominated
by ``cobdy``/``min_cobdy``/``owner_of_low`` calls on ``np.array([x])``
singletons).  This engine keeps the paper's batch structure — parallel
phase against the committed pivots, serial phase for intra-batch
collisions, clearance commit — but holds each batch in *one* bit-packed
block for its whole reduction:

* **rank compression** — per batch, the sorted unique key set of the
  batch's coboundaries plus the first round of gathered addends becomes the
  block's bit-space (``kernels.gf2.scatter_bits``): key ``universe[i]``
  lives at bit ``i``, so ascending keys are ascending ranks, a
  first-set-bit scan (``gf2_find_low`` / ``find_low_np``) *is* the engine's
  ``low``, and one 32-word VREG XOR covers 32,768 matrix entries;
* **parallel phase** — one :meth:`PivotStore.lookup_addends_batched` probe
  per round (one ``owner_of_low`` / ``min_cobdy`` / ``cobdy`` call for the
  whole batch), then the hit rows absorb their gathered committed-pivot
  addends: an in-place bit scatter-XOR on host, ``gf2_parallel_xor`` on the
  gathered addend block on TPU.  Only rows whose low moved are probed
  again;
* **segmented growth vs eviction** — an addend with keys outside the
  bit-space either *expands* the space (the new keys append as a fresh
  word-aligned segment; no re-ranking, lows become a min over per-segment
  find-lows) or *evicts* its row to plain sorted-key form (``merge_cancel``
  chains, as in the host engine).  Dense rounds expand — many rows keep
  XOR-ing in block form; sparse rounds (a few deep single-column chains,
  e.g. H1* on a near-clique) evict — one stubborn chain must not balloon
  the whole block's bit-space.  Segments consolidate to one sorted universe
  only past ``_MAX_SEGMENTS`` — or eagerly on the kernel path, where the
  kernels need the single globally-sorted bit-space;
* **serial phase** — intra-batch low collisions resolve in one host walk
  over the batch in filtration order (a ``low -> row`` dict; packed rows
  XOR whole block rows, evicted rows ``merge_cancel``), with gens updated
  per absorption exactly like the host engine.  On the kernel path a
  ``gf2_serial_reduce`` pre-pass first clears the packed-vs-packed
  collisions in VMEM: ``ceil(B/32)`` *V-words* ride at the block's tail,
  reset to the identity before the pass, so afterwards each row's V bits
  name exactly the batch mates it absorbed — the δ-expansion bookkeeping
  recovered by unpacking ``ceil(B/32)`` words instead of per-XOR updates;
* **clearance** — lows unpack back to int64 keys and commit through the
  existing :class:`PivotStore` (budgeted, largest-explicit-first spill), so
  explicit/implicit/budget semantics are shared with the other engines.
  Trivial pairs commit nothing, so their rows are never unpacked at all.

Diagrams are bit-identical to ``reduce_dimension`` for every mode/budget
(asserted in tests): all engines perform left-to-right GF(2) column
additions, and the lows of any fully reduced matrix are canonical.

**Distributed mode** (``n_shards``/``mesh``): column batches partition
round-robin over the shards (batch ``t`` -> shard ``t % P``, the same
dealing :func:`repro.scale.shard.partition_tiles` uses for tiles), and each
*superstep* fuses the P shards' next batches into ONE resident block of
``P·B`` rows — per-device blocks simulated as row slices, which is also
what amortizes the per-batch fixed costs (one coboundary enumeration, one
block build, one store probe per round for all P slices) that bound the
single-device engine.  Phases per superstep:

* **concurrent phase** — the parallel phase of every slice runs against a
  per-device *replica* of the pivot store, complete exactly up to the
  previous superstep (pivots arrive only through the exchange wire — see
  below), with per-slice serial passes for intra-slice collisions;
* **tournament catch-up** — cross-slice collisions resolve in ``log2 P``
  hypercube rounds (partner ``j XOR step``, the pairing of
  ``core.jax_engine.make_distributed_round``): the later-ranked slice's row
  absorbs the earlier one's current (R, gens) snapshot — later batch
  columns follow earlier ones in processing order, so this matches the
  left-to-right schedule and only removes work;
* **commit sweep** — slices commit strictly in global batch order; each
  slice first re-probes the *authoritative* store (which now holds this
  superstep's earlier-slice pivots) until stable, so the final schedule is
  exactly a left-to-right reduction and diagrams stay bit-identical to the
  single-device engines for every shard count;
* **pivot exchange** — the superstep's non-trivial commits encode into one
  Elias–Fano wire payload per shard (:mod:`repro.core.pivot_cache`),
  cross-ship (``jax.lax.all_gather`` under ``shard_map`` with a mesh; host
  loop-back under ``n_shards``), decode, and install into the replica.  The
  concurrent phase reads pivots *only* from the replica, so the wire codec
  sits on the bit-identity critical path by construction.

The shared :class:`~repro.core.pivot_cache.PackedPivotCache` memoizes each
pivot's packed bit positions per block epoch — one pack serves every slice
of the superstep that consumes the pivot, replacing the per-consuming-batch
re-pack — and each implicit pivot's materialized R keys (1 enumeration per
pivot across the whole reduction).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..analyze.invariants import active_sanitizer
from ..kernels.gf2 import (NO_LOW, find_low_np, scatter_bits,
                           scatter_xor_bits, set_bit_positions,
                           stack_wire_payloads, unstack_wire_payloads)
from ..launch.elastic import ShardSupervisor
from ..obs.metrics import MetricsRegistry
from ..obs.trace import Tracer, active_tracer, critical_path
from ..resilience.faults import (TransientFault, active_injector,
                                 corrupt_payload, retry_with_backoff)
from .pairing import EMPTY_KEY
from .reduction import (DimensionAdapter, PivotStore, ReductionResult,
                        clearance_commit, clearing_filter, finalize_result,
                        merge_cancel, seed_column)

_MAX_SEGMENTS = 12   # host path consolidates past this many segments
_EVICT_MAX = 8       # rounds needing new keys for fewer rows evict instead


def _resolve_use_kernels(use_kernels: Optional[bool]) -> bool:
    """Pallas kernels on TPU, numpy mirrors elsewhere (repo-wide policy:
    Mosaic only exists on TPU; interpret-mode Pallas is for tests)."""
    if use_kernels is None:
        import jax
        return jax.default_backend() == "tpu"
    return bool(use_kernels)


def _words(n_keys: int, use_kernels: bool) -> int:
    """Segment width in words; bucketed on the kernel path so the jitted
    Pallas calls see a handful of shapes, not one per universe size."""
    w = max(1, (n_keys + 31) // 32)
    return -(-w // 128) * 128 if use_kernels else w


def _find_low_row(col: np.ndarray) -> int:
    """First-set-bit rank of one packed uint32 row; NO_LOW when zero."""
    nz = col != 0
    if not nz.any():
        return NO_LOW
    w = int(nz.argmax())
    word = int(col[w])
    return w * 32 + ((word & -word).bit_length() - 1)


def _budgeted_batch_size(batch_size: int, cob_width: int,
                         store_budget_bytes: Optional[int]) -> int:
    """Cap the batch so the resident bit block fits the byte budget.

    The batch block is ``B`` rows × ``~B·K/32`` words ≈ ``B²K/8`` bytes
    (plus the same again transiently for a kernel-path addend gather).
    Inverting for ``B`` bounds the packed-block scratch the same way
    ``h2_columns`` bounds its enumeration scratch; neither changes the
    output.  Best-effort: the batch never shrinks below 32 rows (a
    narrower batch loses the batching the engine exists for), so very
    small budgets bound the block at the 32-row floor, not the budget.
    """
    if store_budget_bytes is None:
        return batch_size
    b = int(np.sqrt(max(1.0, 4.0 * store_budget_bytes / max(1, cob_width))))
    return int(np.clip(b, 32, batch_size))


class _PackedBatch:
    """One batch resident in packed form, with a scalar escape hatch.

    Layout: ``block[:, 0:cap]`` is the R region — a sequence of
    word-aligned segments, each a sorted key array mapped to consecutive
    bit ranks — and ``block[:, cap:cap+VW]`` are the V-words the kernel
    serial pre-pass uses for δ-expansion tracking (zero otherwise).
    ``scalar`` maps evicted rows to plain int64 key arrays; ``lows`` holds
    every row's current low *key* (-1 = empty), which survives segment
    growth, consolidation and eviction unchanged.
    """

    def __init__(self, cob: np.ndarray, seed_addends: List[np.ndarray],
                 use_kernels: bool, cache=None):
        B = cob.shape[0]
        self.B = B
        self.VW = (B + 31) // 32
        self.use_kernels = use_kernels
        self.cache = cache
        if cache is not None:
            cache.bump_epoch()   # fresh universe: prior positions are stale
        mask = cob != EMPTY_KEY
        seg0 = np.unique(np.concatenate([cob[mask]] + seed_addends))
        self.segs: List[np.ndarray] = [seg0]
        self.seg_off: List[int] = [0]          # word offset per segment
        self.r_words = _words(len(seg0), use_kernels)
        self.cap = self.r_words
        self.block = np.zeros((B, self.cap + self.VW), dtype=np.uint32)
        ridx, _ = np.nonzero(mask)
        pos = np.searchsorted(seg0, cob[mask])
        scatter_bits(self.block, ridx, pos)
        self.scalar: Dict[int, np.ndarray] = {}
        self.lows = np.where(cob[:, 0] == EMPTY_KEY, np.int64(-1), cob[:, 0])
        self.peak_bytes = self.block.nbytes
        self.n_consolidations = 0
        self.n_expansions = 0
        self.n_evictions = 0

    # -- universe bookkeeping ------------------------------------------------

    def _grow_cap(self, need: int) -> None:
        new_cap = max(need, 2 * self.cap)
        block = np.zeros((self.B, new_cap + self.VW), dtype=np.uint32)
        block[:, :self.r_words] = self.block[:, :self.r_words]
        # V region is zero outside the kernel pre-pass — nothing to move
        self.block = block
        self.cap = new_cap
        self.peak_bytes = max(self.peak_bytes, block.nbytes)

    def add_segment(self, new_keys: np.ndarray) -> None:
        """Append new addend keys as a fresh word-aligned segment — no
        re-ranking of resident bits (rank order only holds per segment;
        lows are reconstructed as a min over segments)."""
        w = _words(len(new_keys), self.use_kernels)
        if self.r_words + w > self.cap:
            self._grow_cap(self.r_words + w)
        self.segs.append(new_keys)
        self.seg_off.append(self.r_words)
        self.r_words += w
        if self.use_kernels or len(self.segs) > _MAX_SEGMENTS:
            self.consolidate()

    def consolidate(self) -> None:
        """Merge all segments into one sorted universe (one global remap).
        The kernel path runs consolidated always: ``gf2_find_low`` /
        ``gf2_serial_reduce`` read the first set *bit*, which equals the
        min *key* only in a single globally-sorted bit-space."""
        if len(self.segs) == 1:
            return
        self.n_consolidations += 1
        san = active_sanitizer()
        if self.cache is not None:
            self.cache.bump_epoch()   # re-ranking invalidates cached positions
        ridx_all, keys_all = [], []
        for seg, off in zip(self.segs, self.seg_off):
            w = _words(len(seg), self.use_kernels)
            ridx, pos, _ = set_bit_positions(self.block[:, off:off + w])
            keep = pos < len(seg)
            if san is not None:
                # the keep filter below silently drops any bit past the
                # segment universe — under the sanitizer that is a lost
                # GF(2) coordinate, not slack
                san.check_segment_bits(pos, len(seg))
            ridx_all.append(ridx[keep])
            keys_all.append(seg[pos[keep]])
        ridx = np.concatenate(ridx_all)
        keys = np.concatenate(keys_all)
        universe = np.unique(np.concatenate(self.segs))
        self.segs = [universe]
        self.seg_off = [0]
        self.r_words = _words(len(universe), self.use_kernels)
        if self.r_words > self.cap:
            self.cap = self.r_words
        self.block = np.zeros((self.B, self.cap + self.VW), dtype=np.uint32)
        self.peak_bytes = max(self.peak_bytes, self.block.nbytes)
        pos = np.searchsorted(universe, keys)
        order = np.lexsort((pos, ridx))
        scatter_bits(self.block, ridx[order], pos[order])
        if san is not None:
            san.check_consolidation(ridx, keys, universe,
                                    self.block[:, :self.r_words])

    def _abs_positions(self, keys: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Absolute bit position of each key (32·segment word offset +
        in-segment rank) plus the mask of keys in no segment yet."""
        out = np.full(len(keys), -1, dtype=np.int64)
        todo = np.ones(len(keys), dtype=bool)
        for seg, off in zip(self.segs, self.seg_off):
            if not len(seg) or not todo.any():
                continue
            pos = np.minimum(np.searchsorted(seg, keys), len(seg) - 1)
            hit = todo & (seg[pos] == keys)
            out[hit] = off * 32 + pos[hit]
            todo &= ~hit
        return out, todo

    # -- representation moves ------------------------------------------------

    def _unpack_row(self, c: int) -> np.ndarray:
        parts = []
        for seg, off in zip(self.segs, self.seg_off):
            if not len(seg):
                continue
            w = _words(len(seg), self.use_kernels)
            _, pos, _ = set_bit_positions(self.block[c:c + 1, off:off + w])
            pos = pos[pos < len(seg)]
            if pos.size:
                parts.append(seg[pos])
        return np.concatenate(parts) if parts else np.zeros(0, dtype=np.int64)

    def evict(self, c: int) -> None:
        """Move row ``c`` to scalar (sorted-key) form: one stubborn chain
        must not balloon the shared bit-space."""
        if c in self.scalar:
            return
        self.n_evictions += 1
        keys = self._unpack_row(c)
        keys.sort(kind="stable")
        self.block[c, :self.r_words] = 0
        self.scalar[c] = keys

    # -- lows ----------------------------------------------------------------

    def refresh_lows(self, rows: np.ndarray) -> None:
        """Recompute ``lows[rows]`` (packed rows) as the min key over
        per-segment find-lows (``gf2_find_low`` on the kernel path)."""
        rows = np.asarray(rows, dtype=np.int64)
        if not rows.size:
            return
        best = np.full(len(rows), EMPTY_KEY, dtype=np.int64)
        for seg, off in zip(self.segs, self.seg_off):
            if not len(seg):
                continue
            w = _words(len(seg), self.use_kernels)
            sub = self.block[rows, off:off + w]
            if self.use_kernels:
                import jax.numpy as jnp

                from ..kernels.gf2 import gf2_find_low
                pad = (-len(rows)) % 32   # bucket row counts for the jit
                if pad:
                    sub = np.vstack(
                        [sub, np.zeros((pad, w), dtype=np.uint32)])
                # analyze: allow[host-sync] lows gate the host serial pass; one bucketed sync per segment is the schedule
                lb = np.asarray(gf2_find_low(jnp.asarray(sub)))[:len(rows)]
            else:
                lb = find_low_np(sub)
            k = np.where(lb == NO_LOW, EMPTY_KEY,
                         seg[np.minimum(lb, len(seg) - 1)])
            best = np.minimum(best, k)
        self.lows[rows] = np.where(best == EMPTY_KEY, -1, best)

    def _row_low(self, c: int) -> int:
        best = -1
        for seg, off in zip(self.segs, self.seg_off):
            if not len(seg):
                continue
            w = _words(len(seg), self.use_kernels)
            lb = _find_low_row(self.block[c, off:off + w])
            if lb != NO_LOW and lb < len(seg):
                k = int(seg[lb])
                if best < 0 or k < best:
                    best = k
        return best

    # -- parallel phase ------------------------------------------------------

    def xor_addends(self, hit: List[int],
                    addends: List[Optional[np.ndarray]],
                    addend_lows: Optional[np.ndarray] = None) -> None:
        """Parallel-phase GF(2) add: gathered addends into the hit rows —
        an in-place scatter-XOR on host, ``gf2_parallel_xor`` on a packed
        addend block on the kernel path; scalar rows ``merge_cancel``.

        Addend keys outside every segment either append as a fresh segment
        (dense rounds) or evict their rows (sparse rounds, ``_EVICT_MAX``).

        ``addend_lows[i]`` names the pivot low row ``i``'s addend came from;
        a pivot's key array is canonical per low, so its packed positions
        memoize in the shared cache per block epoch — repeat consumers (in
        particular the other slices of a fused superstep) skip the
        per-segment ``searchsorted`` re-pack entirely.
        """
        scalar_hit = [i for i in hit if i in self.scalar]
        packed_hit = [i for i in hit if i not in self.scalar]
        memo_rows: List[int] = []
        memo_pos: List[np.ndarray] = []
        if packed_hit and self.cache is not None and addend_lows is not None:
            rest = []
            for i in packed_hit:
                p = self.cache.get_positions(int(addend_lows[i]))
                if p is not None and len(p) == len(addends[i]):
                    memo_rows.append(i)
                    memo_pos.append(p)
                else:
                    rest.append(i)
            packed_hit = rest
        if packed_hit:
            epoch0 = self.n_consolidations
            lens = np.array([len(addends[i]) for i in packed_hit],
                            dtype=np.int64)
            keys = np.concatenate([addends[i] for i in packed_hit])
            ridx = np.repeat(np.asarray(packed_hit, dtype=np.int64), lens)
            pos, missing = self._abs_positions(keys)
            if missing.any():
                miss_rows = np.unique(ridx[missing])
                if len(miss_rows) <= _EVICT_MAX:
                    for i in miss_rows:
                        self.evict(int(i))
                        scalar_hit.append(int(i))
                    keep = ~np.isin(ridx, miss_rows)
                    ridx, pos, keys = ridx[keep], pos[keep], keys[keep]
                    mask = ~np.isin(np.asarray(packed_hit), miss_rows)
                    packed_hit = [i for i in packed_hit
                                  if i not in self.scalar]
                    lens = lens[mask]
                else:
                    self.n_expansions += 1
                    new_seg = np.unique(keys[missing])
                    n_segs = len(self.segs) + 1
                    self.add_segment(new_seg)
                    if len(self.segs) == n_segs:
                        # append-only: found positions are still valid
                        off = self.seg_off[-1]
                        pos[missing] = off * 32 + np.searchsorted(
                            new_seg, keys[missing])
                    else:   # consolidation re-ranked everything
                        pos, miss2 = self._abs_positions(keys)
                        assert not miss2.any()
            if self.cache is not None and addend_lows is not None \
                    and packed_hit:
                starts = np.zeros(len(packed_hit) + 1, dtype=np.int64)
                np.cumsum(lens, out=starts[1:])
                for k, i in enumerate(packed_hit):
                    self.cache.put_positions(int(addend_lows[i]),
                                             pos[starts[k]:starts[k + 1]])
            if memo_rows and self.n_consolidations != epoch0:
                # a consolidation re-ranked the universe under the memoized
                # rows: recompute them (their keys were resident, so they
                # cannot miss) and re-memoize against the new epoch
                mkeys = np.concatenate([addends[i] for i in memo_rows])
                mpos, mmiss = self._abs_positions(mkeys)
                assert not mmiss.any()
                mlens = np.array([len(addends[i]) for i in memo_rows],
                                 dtype=np.int64)
                starts = np.zeros(len(memo_rows) + 1, dtype=np.int64)
                np.cumsum(mlens, out=starts[1:])
                memo_pos = [mpos[starts[k]:starts[k + 1]]
                            for k in range(len(memo_rows))]
                for k, i in enumerate(memo_rows):
                    self.cache.put_positions(int(addend_lows[i]),
                                             memo_pos[k])
        if memo_rows:
            mlens = np.array([len(p) for p in memo_pos], dtype=np.int64)
            mridx = np.repeat(np.asarray(memo_rows, dtype=np.int64), mlens)
            mpos = (np.concatenate(memo_pos) if memo_pos
                    else np.zeros(0, dtype=np.int64))
            if packed_hit:
                ridx = np.concatenate([ridx, mridx])
                pos = np.concatenate([pos, mpos])
                packed_hit = packed_hit + memo_rows
            else:
                ridx, pos = mridx, mpos
                packed_hit = list(memo_rows)
        if packed_hit:
            if self.use_kernels:
                import jax.numpy as jnp

                from ..kernels.gf2 import gf2_parallel_xor
                local = {r: k for k, r in enumerate(packed_hit)}
                lrid = np.array([local[int(r)] for r in ridx],
                                dtype=np.int64)
                order = np.lexsort((pos, lrid))
                packed = np.zeros((len(packed_hit), self.cap),
                                  dtype=np.uint32)
                scatter_bits(packed, lrid[order], pos[order])
                self.peak_bytes = max(self.peak_bytes,
                                      self.block.nbytes + packed.nbytes)
                rview = self.block[:, :self.cap]
                rview[packed_hit] = np.asarray(gf2_parallel_xor(
                    jnp.asarray(rview[packed_hit]), jnp.asarray(packed)))
            else:
                order = np.lexsort((pos, ridx))
                scatter_xor_bits(self.block, ridx[order], pos[order])
            self.refresh_lows(np.asarray(packed_hit, dtype=np.int64))
        for i in scalar_hit:
            merged = merge_cancel(self.scalar[i], addends[i])
            self.scalar[i] = merged
            self.lows[i] = int(merged[0]) if merged.size else -1

    # -- serial phase --------------------------------------------------------

    def _absorb(self, c: int, j: int, gens: List[Dict[int, int]],
                ids_int: List[int]) -> int:
        """Row ``c <- c ⊕ j`` over GF(2) with gens bookkeeping; returns
        ``c``'s new low key (does not write ``lows``).  ``c`` must come
        after ``j`` in processing order.  Packed rows XOR whole block rows;
        scalar rows ``merge_cancel``; a packed row absorbing a scalar mate
        evicts first."""
        c_packed = c not in self.scalar
        j_packed = j not in self.scalar
        if c_packed and not j_packed:
            self.evict(c)
            c_packed = False
        if c_packed:
            self.block[c] ^= self.block[j]
            low = self._row_low(c)
        else:
            jkeys = self.scalar[j] if not j_packed \
                else self._unpack_row(j)
            merged = merge_cancel(self.scalar[c], jkeys)
            self.scalar[c] = merged
            low = int(merged[0]) if merged.size else -1
        gens[c][ids_int[j]] = gens[c].get(ids_int[j], 0) + 1
        for g, p in gens[j].items():
            gens[c][g] = gens[c].get(g, 0) + p
        return low

    def serial_pass(self, gens: List[Dict[int, int]],
                    ids_int: List[int],
                    rows: Optional[np.ndarray] = None
                    ) -> Tuple[int, np.ndarray]:
        """Resolve intra-batch low collisions in filtration order.

        Kernel path: a ``gf2_serial_reduce`` V-augmented pre-pass clears
        packed-vs-packed collisions in VMEM (V bits -> gens merge), then
        the host walk finishes scalar-involved collisions.  Host path: the
        walk does everything via :meth:`_absorb`.  ``rows`` restricts the
        walk to one contiguous slice (the fused-superstep drivers resolve
        per-device slices independently; the kernel pre-pass assumes the
        whole block and only runs unrestricted).  Returns
        ``(n_reductions, changed_row_indices)``.
        """
        n_red = 0
        changed: Dict[int, bool] = {}
        if rows is None:
            if self.use_kernels:
                n_red += self._serial_kernel_prepass(gens, ids_int, changed)
            row_iter = range(self.B)
        else:
            row_iter = [int(r) for r in rows]
        low_to_row: Dict[int, int] = {}
        for c in row_iter:
            low = int(self.lows[c])
            while low >= 0:
                j = low_to_row.get(low)
                if j is None:
                    break
                n_red += 1
                changed[c] = True
                low = self._absorb(c, j, gens, ids_int)
            self.lows[c] = low
            if low >= 0:
                low_to_row[low] = c
        return n_red, np.array(sorted(changed), dtype=np.int64)

    def _serial_kernel_prepass(self, gens: List[Dict[int, int]],
                               ids_int: List[int],
                               changed: Dict[int, bool]) -> int:
        """Kernel pre-pass on the packed rows: V-identity words ride the
        block tail, ``gf2_serial_reduce`` XORs colliding rows in VMEM, and
        the V bits name each row's absorbed mates afterwards (scalar rows'
        block rows are zero, hence inert; zero slack words between the R
        segment and the V-words are skipped by the kernel's find-low; and
        V-rank collisions only ever involve R-empty rows)."""
        import jax.numpy as jnp

        from ..kernels.gf2 import gf2_serial_reduce

        assert len(self.segs) == 1
        B, cap = self.B, self.cap
        vbit = np.arange(B)
        vslice = self.block[:, cap:]
        vslice[...] = 0
        # scalar rows get no identity bit: inert rows must not register lows
        live = np.array([i not in self.scalar for i in range(B)])
        lv = vbit[live]
        vslice[lv, lv >> 5] |= np.uint32(1) << (lv & 31).astype(np.uint32)
        C, W = B, cap + self.VW
        Cp, Wp = -(-C // 32) * 32, -(-W // 128) * 128
        padded = np.zeros((Cp, Wp), dtype=np.uint32)
        padded[:C, :W] = self.block
        red, _, n_red = gf2_serial_reduce(jnp.asarray(padded[None]))
        self.block[...] = np.asarray(red)[0, :C, :W]
        n_red = int(np.asarray(n_red)[0])
        if n_red == 0:
            vslice[...] = 0
            return 0
        vrid, vpos, _ = set_bit_positions(vslice)
        vkeep = vpos < B
        counts = np.bincount(vrid[vkeep], minlength=B).astype(np.int64)
        vrows = np.split(vpos[vkeep], np.cumsum(counts)[:-1])
        touched = [i for i in range(B) if vrows[i].size > 1]
        entry = {int(i): dict(gens[i]) for i in touched}
        for i in touched:
            changed[int(i)] = True
            newg = dict(entry[int(i)])
            for j in vrows[i]:
                j = int(j)
                if j == i:
                    continue
                newg[ids_int[j]] = newg.get(ids_int[j], 0) + 1
                # unchanged mates keep their live gens; changed mates use
                # their pass-entry snapshot (the kernel walk is ascending)
                for g, p in entry.get(j, gens[j]).items():
                    newg[g] = newg.get(g, 0) + p
            gens[i] = newg
        vslice[...] = 0
        if touched:
            self.refresh_lows(np.array(touched, dtype=np.int64))
        return n_red

    # -- clearance -----------------------------------------------------------

    def unpack(self, rows: np.ndarray) -> List[np.ndarray]:
        """``rows`` as int64 key arrays, one block pass per segment.

        Row keys come out ascending *within* each segment's contribution
        (segment-major order overall, not globally sorted) — every consumer
        either re-ranks per key (the pack/scatter paths) or re-sorts
        (``merge_cancel``, ``parity_reduce``), so a global per-row sort
        would buy nothing.  Clearance also only unpacks the rows it will
        store: trivial pairs commit nothing."""
        rows = np.asarray(rows, dtype=np.int64)
        n = len(rows)
        if not n:
            return []
        out_scalar = {int(i): self.scalar[int(i)] for i in rows
                      if int(i) in self.scalar}
        packed_rows = np.array([i for i in rows if int(i) not in self.scalar],
                               dtype=np.int64)
        np_rows = len(packed_rows)
        parts = []
        counts = np.zeros(np_rows, dtype=np.int64)
        for seg, off in zip(self.segs, self.seg_off):
            if not len(seg) or not np_rows:
                continue
            w = _words(len(seg), self.use_kernels)
            ridx, pos, cnt = set_bit_positions(
                self.block[packed_rows, off:off + w])
            keep = pos < len(seg)
            if not keep.all():
                ridx, pos = ridx[keep], pos[keep]
                cnt = np.bincount(ridx, minlength=np_rows).astype(np.int64)
            parts.append((ridx, seg[pos], cnt))
            counts += cnt
        out = np.empty(int(counts.sum()), dtype=np.int64)
        row_start = np.zeros(np_rows + 1, dtype=np.int64)
        np.cumsum(counts, out=row_start[1:])
        fill = row_start[:-1].copy()
        for ridx, keys, cnt in parts:
            if not len(keys):
                continue
            part_off = np.cumsum(cnt) - cnt
            within = np.arange(len(keys), dtype=np.int64) - part_off[ridx]
            out[fill[ridx] + within] = keys
            fill += cnt
        packed_cols = np.split(out, row_start[1:-1]) if np_rows else []
        packed_iter = iter(packed_cols)
        return [out_scalar[int(i)] if int(i) in out_scalar
                else next(packed_iter) for i in rows]


def _tournament_merge(blk: _PackedBatch, gens: List[Dict[int, int]],
                      ids_int: List[int],
                      bounds: np.ndarray) -> Tuple[int, np.ndarray]:
    """Cross-slice catch-up in ``log2 P`` hypercube rounds.

    Pairing is :func:`repro.core.jax_engine.make_distributed_round`'s
    ``(j, j XOR step)``; the later-ranked slice absorbs, because every
    column of a later batch follows every column of an earlier one in
    processing order — so each absorption is a legal left-to-right column
    addition and only removes work.  Collisions the hypercube pairing does
    not cover (and any it creates) are caught by the driver's store-probe /
    per-slice serial-pass loop and the exact commit sweep."""
    n_red = 0
    changed: set = set()
    P = len(bounds) - 1
    step = 1
    while step < P:
        for j in range(P):
            p = j ^ step
            if p >= j or p >= P:
                continue   # absorber is the later-ranked slice of the pair
            plow: Dict[int, int] = {}
            for r in range(int(bounds[p]), int(bounds[p + 1])):
                lw = int(blk.lows[r])
                if lw >= 0:
                    plow[lw] = r
            for c in range(int(bounds[j]), int(bounds[j + 1])):
                lw = int(blk.lows[c])
                while lw >= 0 and lw in plow:
                    n_red += 1
                    changed.add(c)
                    lw = blk._absorb(c, plow[lw], gens, ids_int)
                blk.lows[c] = lw
        step <<= 1
    return n_red, np.array(sorted(changed), dtype=np.int64)


def _resolve_reduce_shards(mesh, n_shards: Optional[int]) -> int:
    """Shard count for the distributed driver: the mesh's data-axis size,
    or ``n_shards`` for the host-partitioned simulation (same work split,
    no devices needed — mirrors ``scale.shard.harvest_edges_sharded``)."""
    if mesh is not None:
        from ..scale.shard import shard_of_mesh
        axis, mesh_shards = shard_of_mesh(mesh)
        if n_shards is not None and int(n_shards) != mesh_shards:
            raise ValueError(
                f"n_shards={n_shards} disagrees with the mesh's "
                f"{axis}-axis size {mesh_shards}; pass only one of them")
        return mesh_shards
    return 1 if n_shards is None else int(n_shards)


def _exchange_round_fn(x, axis_name: str):
    """Per-device body of one pivot-exchange round: block ``(1, L)`` in,
    every shard's ``(P, L)`` out.  Module-level (closed only over the
    static ``axis_name``) so ``repro.analyze.collectives`` can trace its
    collective schedule without building the mesh driver."""
    import jax

    return jax.lax.all_gather(x[0], axis_name)


def _make_exchange(mesh, n_shards: int):
    """Pivot-exchange round: per-shard wire payloads -> all shards' payloads.

    With a mesh, payloads stack into a ``(P, L)`` uint32 buffer (``L``
    bucketed to a power of two so the jitted collective retraces a handful
    of times, not once per superstep) and cross-ship through
    ``jax.lax.all_gather`` under ``shard_map`` with the reduction batch
    specs from :func:`repro.dist.sharding.reduce_specs`.  Without a mesh
    the exchange is the host loop-back — identical payload path (encode ->
    exchange -> decode), no devices."""
    if mesh is None:
        return lambda payloads: payloads
    import jax
    import jax.numpy as jnp

    from ..dist.sharding import reduce_specs

    in_spec, out_spec, axis = reduce_specs(mesh)
    fns: Dict[int, object] = {}

    def exchange(payloads: List[np.ndarray]) -> List[np.ndarray]:
        buf, lens = stack_wire_payloads(payloads)
        L = buf.shape[1]
        if L not in fns:
            fns[L] = jax.jit(jax.shard_map(
                functools.partial(_exchange_round_fn, axis_name=axis),
                mesh=mesh, in_specs=in_spec, out_specs=out_spec,
                check_vma=False))
        return unstack_wire_payloads(fns[L](jnp.asarray(buf)), lens)

    return exchange


def reduce_dimension_packed(
    adapter: DimensionAdapter,
    column_ids: np.ndarray,
    mode: str = "explicit",
    cleared=None,
    batch_size: int = 256,
    store_budget_bytes: Optional[int] = None,
    use_kernels: Optional[bool] = None,
    n_shards: Optional[int] = None,
    mesh=None,
    cache=None,
    exchange_every: int = 4,
    seed_gens: Optional[Dict[int, np.ndarray]] = None,
    commit_sink: Optional[list] = None,
    essential_log: Optional[list] = None,
) -> ReductionResult:
    """Bit-packed serial-parallel cohomology reduction (module docstring).

    Same contract as ``reduce_dimension`` / ``reduce_dimension_batched``:
    ``column_ids`` in decreasing filtration order, diagrams bit-identical to
    both for every shard count.  ``use_kernels=None`` resolves to the Pallas
    kernels on TPU and the numpy block mirrors elsewhere; ``True`` forces
    the kernels (they interpret off-TPU — the test path).

    ``n_shards`` > 1 or a ``mesh`` runs the fused-superstep distributed
    driver: batches deal round-robin over the shards, each superstep's P
    batches reduce concurrently against per-device pivot replicas fed by
    Elias–Fano-compressed pivot-exchange rounds, and commits happen in
    exact global batch order (module docstring).  ``exchange_every``
    batches the exchange rounds — payloads ship every that-many supersteps,
    amortizing the codec's fixed per-round cost (the default of 4 is where
    the fractal benchmark's exchange time flattens; much larger backlogs
    inflate the fused Elias–Fano universe instead).  Staleness is
    exact-safe because the commit sweep re-probes every pivot the replica
    has not seen yet (``pending`` below).  ``cache`` threads a caller-owned
    :class:`~repro.core.pivot_cache.PackedPivotCache` (one is created per
    call otherwise).

    Distributed stats report two walls: the host really runs every shard's
    work back-to-back on one process, so ``sim_wall_s`` accounts the
    critical path a P-device mesh would execute — per-shard busy time for
    the data-parallel phases (fused block ops attributed by row share,
    per-slice serial passes timed directly), plus the genuinely sequential
    parts at full cost (tournament, the in-order commit sweep, decode +
    install, which every device performs on all P payloads).  For P == 1
    the same accounting reproduces the measured wall.

    Every timed region is a span on a local, always-on tracer — each phase
    carries its lane (shard) and superstep, so a run under
    ``compute_ph(trace=...)`` renders as P parallel device lanes — and
    ``sim_wall_s`` is *derived* from that span timeline
    (:func:`repro.obs.trace.critical_path`); the legacy hand-rolled
    accounting is kept only as ``sim_wall_bookkeeping_s`` so the two can be
    cross-checked (``tests/test_obs.py`` asserts they agree at P = 4).
    """
    san = active_sanitizer()
    # local timeline: always on (sim_wall is derived from it), forwarding
    # into the user's tracer when compute_ph(trace=...) activated one
    tl = Tracer(forward_to=active_tracer())
    use_kernels = _resolve_use_kernels(use_kernels)
    P = _resolve_reduce_shards(mesh, n_shards)
    if exchange_every < 1:
        raise ValueError("exchange_every must be >= 1")
    if cache is None:
        from .pivot_cache import PackedPivotCache
        cache = PackedPivotCache()
    # P == 1 appends commits straight into the caller's sink (if any);
    # P > 1 owns a scratch log that is drained into per-shard wire
    # backlogs every slice — the sink then receives copies of each
    # drained record (``seed_gens`` / ``commit_sink`` / ``essential_log``
    # carry the same warm-restart + capture contract as
    # ``reduce_dimension``; see repro.core.resume)
    commit_log: Optional[list] = [] if P > 1 else commit_sink
    store = PivotStore(adapter, mode, store_budget_bytes=store_budget_bytes,
                       cache=cache, commit_log=commit_log)
    if P > 1:
        from .pivot_cache import (decode_commit_delta, encode_commit_delta,
                                  verify_commit_delta)
        # the replica mirrors the authority's track_gens: with an explicit
        # budgeted store the wire ships δ-expansions precisely so that
        # replica probes can return them (install() never spills, so the
        # budget carries no other behavior here)
        replica = PivotStore(adapter, mode,
                             store_budget_bytes=store_budget_bytes,
                             cache=cache)
        exchange = _make_exchange(mesh, P)
        lookup_store = replica
        # commits the replica has not installed yet: each shard's wire
        # backlog plus a map of their pivot lows -> (shard, superstep) —
        # the only lows at which the sweep's store re-probe can possibly
        # hit for rows that already stabilized against the replica, and
        # the provenance that drives the sweep's critical-path accounting
        shard_logs: List[list] = [[] for _ in range(P)]
        pending: Dict[int, Tuple[int, int]] = {}
        # -- resilience (docs/resilience.md): heartbeat supervision on the
        # deterministic superstep clock.  Every live shard beats once per
        # superstep; a shard that misses a beat past the timeout is dead
        # and its remaining batch queue re-deals to the survivors from the
        # last exact commit sweep (nothing commits before the sweep, so
        # the restart line is exact by construction).  Stragglers are
        # sidelined from dealing for a cooldown but stay live.  An armed
        # FaultInjector (repro.resilience) is what kills/slows shards and
        # drops/corrupts wire payloads — on a seeded, reproducible
        # schedule; with none armed this is all no-op bookkeeping.
        sup = ShardSupervisor(n_shards=P, timeout=0.75, factor=3.0,
                              sideline=1)
        inj = active_injector()
        killed: set = set()
        slow_lag: Dict[int, Tuple[float, int]] = {}  # shard -> (lag, until)
        n_shard_deaths = 0
        n_redeals = 0
        n_sidelines = 0
        n_exchange_retries = 0
        n_exchange_deferrals = 0
        n_wire_corruptions = 0
        n_faults_seen = 0
    else:
        lookup_store = store
        inj = None
    pairs: List[tuple] = []
    essentials: List[float] = []
    essential_ids: List[int] = []
    n_reductions = 0
    n_rounds = 0
    n_expansions = 0
    n_evictions = 0
    n_consolidations = 0
    n_supersteps = 0
    n_exchange_rounds = 0
    n_tournament_reductions = 0
    n_sweep_probes = 0
    exchange_bytes = 0
    peak_block_bytes = 0
    # hand-rolled critical-path wall, kept ONLY to cross-check the
    # span-derived accounting (emitted as sim_wall_bookkeeping_s); the
    # reported sim_* stats come from critical_path(tl.spans) below
    sim_wall_book = 0.0
    reg = MetricsRegistry()
    queue = clearing_filter(column_ids, cleared)
    eff_batch = batch_size
    if len(queue):
        cob0 = adapter.cobdy(queue[:min(batch_size, len(queue))])
        eff_batch = _budgeted_batch_size(batch_size, cob0.shape[1],
                                         store_budget_bytes)

    pos = 0
    while pos < len(queue):
        # ---- superstep: the next up-to-|active| batches, dealt
        # round-robin over the supervisor's active shards (all P when
        # nothing failed); slice k is shard active[k]'s local batch ----
        n_supersteps += 1
        step = n_supersteps
        mid_kills: List[int] = []
        if P > 1:
            if inj is not None:
                for s in list(sup.live):
                    for f in inj.fire("reduce.superstep", index=step,
                                      shard=s):
                        if f.kind in ("kill_shard", "slow_shard") \
                                and mesh is not None:
                            raise ValueError(
                                f"{f.kind} injection requires the "
                                "host-partitioned driver (mesh=None): a "
                                "jax mesh cannot shrink mid-collective")
                        n_faults_seen += 1
                        if f.kind == "kill_shard":
                            if f.param("when", "start") == "mid":
                                # participates in the concurrent phase,
                                # dies before its commit sweep
                                mid_kills.append(s)
                            else:
                                killed.add(s)
                        elif f.kind == "slow_shard":
                            # beat lag clamped below the death timeout:
                            # "slow" degrades, it does not kill
                            slow_lag[s] = (
                                min(float(f.param("lag", 0.6)), 0.6),
                                step + int(f.param("duration", 1)))
            beats: Dict[int, float] = {}
            for s in sup.live:
                if s in killed:
                    continue                  # a dead shard stops beating
                lag = slow_lag.get(s)
                beats[s] = (float(step) - lag[0]
                            if lag is not None and step <= lag[1]
                            else float(step))
            plan = sup.observe(float(step), beats)
            if not sup.live:
                raise RuntimeError(
                    "every reduction shard died; cannot recover")
            if plan.dead:
                # re-deal the dead shards' remaining queue to survivors
                # (automatic: dealing below only feeds active shards) and
                # hand their un-replicated wire backlog to an heir so the
                # replicas eventually hear about those commits
                with tl.span("resilience/recover", step=step,
                             kind="kill_start",
                             shards=tuple(plan.dead)) as rsp:
                    n_shard_deaths += len(plan.dead)
                    n_redeals += 1
                    heir = sup.live[0]
                    for d in plan.dead:
                        if shard_logs[d]:
                            shard_logs[heir].extend(shard_logs[d])
                            shard_logs[d] = []
                reg.histogram("resilience_recover_s").observe(rsp.dur)
            if plan.stragglers:
                n_sidelines += len(plan.stragglers)
            active = plan.active
        else:
            active = [0]
        slice_sizes = []
        start = pos
        for _ in range(len(active)):
            if pos >= len(queue):
                break
            take = min(eff_batch, len(queue) - pos)
            slice_sizes.append(take)
            pos += take
        ids_arr = np.asarray(queue[start:pos], dtype=np.int64)
        bounds = np.zeros(len(slice_sizes) + 1, dtype=np.int64)
        np.cumsum(slice_sizes, out=bounds[1:])
        n_slices = len(slice_sizes)
        B = len(ids_arr)
        ids_int = [int(i) for i in ids_arr]
        if san is not None:
            san.set_context(superstep=n_supersteps,
                            batch=f"{start}:{pos}")
        gens: List[Dict[int, int]] = [dict() for _ in range(B)]
        # per-shard busy accounting, span-encoded (obs.trace.critical_path):
        # fused block ops split by row share (the ``weights`` attr),
        # per-slice work on its own device lane, sync parts at full cost
        wt = tuple(float(sz) / max(B, 1) for sz in slice_sizes)
        t_fused = 0.0
        t_slice = np.zeros(max(n_slices, 1))
        t_seq = 0.0
        with tl.span("reduce/fused", step=step, weights=wt) as sp:
            cob = adapter.cobdy(ids_arr)
            if seed_gens:
                # warm restart: seeded rows start from their recorded
                # residual (a valid left-to-right partial reduction state)
                # with gens parity pre-loaded — pad the row width when a
                # residual outgrows one coboundary row
                residuals: Dict[int, np.ndarray] = {}
                for i in range(B):
                    seed = seed_gens.get(ids_int[i])
                    if seed is not None and len(seed):
                        residuals[i] = seed_column(adapter, ids_int[i], seed)
                        gens[i] = {int(g): 1 for g in seed}
                if residuals:
                    width = max(cob.shape[1],
                                max(r.size for r in residuals.values()))
                    if width > cob.shape[1]:
                        pad = np.full((B, width - cob.shape[1]), EMPTY_KEY,
                                      dtype=np.int64)
                        cob = np.concatenate([cob, pad], axis=1)
                    else:
                        cob = cob.copy()
                    for i, r in residuals.items():
                        cob[i, :] = EMPTY_KEY
                        cob[i, :r.size] = r

            # seed the bit-space with the first round of addends so the
            # common case packs exactly once; the concurrent phase probes
            # the replica (P > 1) — complete up to the last exchange
            # round — or the store
            lows0 = np.where(cob[:, 0] == EMPTY_KEY, np.int64(-1), cob[:, 0])
            addends, owners, owner_gens = \
                lookup_store.lookup_addends_batched(lows0, ids_arr)
            addend_lows = lows0
            batchblk = _PackedBatch(
                cob, [a for a in addends if a is not None], use_kernels,
                cache=cache)
        t_fused += sp.dur

        probe = np.zeros(B, dtype=bool)   # rows whose low moved since probe
        while True:
            with tl.span("reduce/fused", step=step, weights=wt) as sp:
                hit = [i for i in range(B) if addends[i] is not None]
                if hit:
                    n_rounds += 1
                    n_reductions += len(hit)
                    for i in hit:
                        o = int(owners[i])
                        gens[i][o] = gens[i].get(o, 0) + 1
                        for g in owner_gens[i]:
                            g = int(g)
                            gens[i][g] = gens[i].get(g, 0) + 1
                    batchblk.xor_addends(hit, addends, addend_lows)
                    probe[hit] = batchblk.lows[hit] >= 0
            t_fused += sp.dur

            # intra-slice collisions -> per-slice serial pass in filtration
            # order (the whole block is one slice when P == 1)
            for k in range(n_slices):
                s0, s1 = int(bounds[k]), int(bounds[k + 1])
                sl_lows = batchblk.lows[s0:s1]
                nz = sl_lows[sl_lows >= 0]
                if len(np.unique(nz)) != len(nz):
                    with tl.span("reduce/slice", lane=k, step=step) as sp:
                        rows = None if n_slices == 1 else np.arange(s0, s1)
                        n_red, changed = batchblk.serial_pass(gens, ids_int,
                                                              rows=rows)
                        n_reductions += n_red
                        probe[changed] = batchblk.lows[changed] >= 0
                    t_slice[k] += sp.dur

            if not probe.any() and n_slices > 1:
                with tl.span("reduce/tournament", step=step) as sp:
                    n_red, changed = _tournament_merge(batchblk, gens,
                                                       ids_int, bounds)
                    n_reductions += n_red
                    n_tournament_reductions += n_red
                    probe[changed] = batchblk.lows[changed] >= 0
                t_seq += sp.dur

            if not probe.any():
                break
            with tl.span("reduce/fused", step=step, weights=wt) as sp:
                probe_lows = np.where(probe, batchblk.lows, -1)
                probe[:] = False
                addends, owners, owner_gens = \
                    lookup_store.lookup_addends_batched(probe_lows, ids_arr)
                addend_lows = probe_lows
            t_fused += sp.dur

        if P > 1 and mid_kills:
            # the shard died after its concurrent phase but before its
            # commit sweep: nothing of this superstep has committed, so
            # the last commit sweep is still the exact recovery line —
            # discard the superstep and restart it from ``start`` with
            # the survivors (bit-identical: commits replay in the same
            # global batch order, just dealt to fewer shards)
            with tl.span("resilience/recover", step=step, kind="kill_mid",
                         shards=tuple(mid_kills)) as rsp:
                for s in mid_kills:
                    killed.add(s)
                    sup.kill(s)
                n_shard_deaths += len(mid_kills)
                n_redeals += 1
                if sup.live:
                    heir = sup.live[0]
                    for s in mid_kills:
                        if shard_logs[s]:
                            shard_logs[heir].extend(shard_logs[s])
                            shard_logs[s] = []
            if not sup.live:
                raise RuntimeError(
                    "every reduction shard died; cannot recover")
            # time-to-recover = the discarded concurrent work + the
            # bookkeeping above (the re-dealt batches rerun next loop)
            reg.histogram("resilience_recover_s").observe(
                t_fused + float(t_slice[:max(n_slices, 1)].sum())
                + t_seq + rsp.dur)
            pos = start
            continue

        # ---- exact commit sweep, slice by slice in global batch order:
        # re-probe the *authoritative* store until stable, then
        # clearance-commit — the realized schedule is a left-to-right
        # reduction, so diagrams are bit-identical to the single-device
        # engines.  Every row already stabilized against the replica, so a
        # store probe can only hit at a ``pending`` low (committed since
        # the last exchange round — including this superstep's
        # earlier-slice pivots); only rows at those lows, or rows the
        # sweep itself changed ("dirty"), need re-probing.  For the
        # simulated wall, slice k's sweep waits only on the slices whose
        # *this-superstep* pivots it actually absorbed (a device learns
        # the earlier stable lows from a tiny broadcast and otherwise
        # sweeps + commits concurrently) — ``deps`` records that DAG ----
        t_sweep = np.zeros(max(n_slices, 1))
        deps: List[set] = [set() for _ in range(max(n_slices, 1))]
        for k in range(n_slices):
            with tl.span("reduce/sweep", lane=k, step=step) as sw_sp:
                if san is not None:
                    san.set_context(slice=k)
                s0, s1 = int(bounds[k]), int(bounds[k + 1])
                rows = np.arange(s0, s1)
                sids = ids_arr[s0:s1]
                if P > 1:
                    pending_arr = np.fromiter(pending, dtype=np.int64,
                                              count=len(pending))
                    dirty = np.zeros(len(sids), dtype=bool)
                    while True:
                        sl_lows = batchblk.lows[s0:s1].copy()
                        cand = dirty.copy()
                        if pending_arr.size:
                            cand |= np.isin(sl_lows, pending_arr)
                        cand &= sl_lows >= 0
                        if not cand.any():
                            break
                        sl_lows[~cand] = -1
                        n_sweep_probes += 1
                        adds, owns, ogens = \
                            store.lookup_addends_batched(sl_lows, sids)
                        dirty[:] = False
                        hit_local = [i for i in range(len(sids))
                                     if adds[i] is not None]
                        if hit_local:
                            n_rounds += 1
                            n_reductions += len(hit_local)
                            for i in hit_local:
                                c = s0 + i
                                o = int(owns[i])
                                gens[c][o] = gens[c].get(o, 0) + 1
                                for g in ogens[i]:
                                    g = int(g)
                                    gens[c][g] = gens[c].get(g, 0) + 1
                                src = pending.get(int(sl_lows[i]))
                                if src is not None \
                                        and src[1] == n_supersteps:
                                    deps[k].add(src[0])
                            full_adds: List[Optional[np.ndarray]] = [None] * B
                            full_lows = np.full(B, -1, dtype=np.int64)
                            for i in hit_local:
                                full_adds[s0 + i] = adds[i]
                                full_lows[s0 + i] = sl_lows[i]
                            batchblk.xor_addends([s0 + i for i in hit_local],
                                                 full_adds, full_lows)
                            dirty[hit_local] = True
                        cur = batchblk.lows[s0:s1]
                        nz = cur[cur >= 0]
                        if len(np.unique(nz)) != len(nz):
                            n_red, changed = batchblk.serial_pass(
                                gens, ids_int, rows=rows)
                            n_reductions += n_red
                            dirty[changed - s0] = True
                        dirty &= batchblk.lows[s0:s1] >= 0

                log_mark = len(commit_log) \
                    if (P > 1 and commit_log is not None) else 0
                clearance_commit(
                    store, adapter, sids, batchblk.lows[s0:s1],
                    gens[s0:s1],
                    lambda rr, rows=rows: batchblk.unpack(
                        rows[np.asarray(rr, dtype=np.int64)]),
                    pairs, essentials, essential_ids=essential_ids,
                    essential_log=essential_log)
                if P > 1 and len(commit_log) > log_mark:
                    # drain this slice's commits straight into its shard's
                    # wire backlog; their lows are pending until the next
                    # exchange.  With gens untracked (explicit, no budget)
                    # neither side of the wire ever reads a δ-expansion —
                    # don't ship them.  The caller's sink gets record
                    # copies *before* the gens strip mutates them.
                    fresh = commit_log[log_mark:]
                    if commit_sink is not None:
                        commit_sink.extend(dict(r) for r in fresh)
                    if not store.track_gens:
                        for r in fresh:
                            r["gens"] = None
                    shard_logs[active[k]].extend(fresh)
                    for r in fresh:
                        pending[r["low"]] = (k, n_supersteps)
                    del commit_log[log_mark:]
                # the dep DAG is known only now — amend the span so the
                # timeline alone reconstructs the sweep critical path
                sw_sp.set(deps=tuple(sorted(deps[k])))
            t_sweep[k] += sw_sp.dur

        # critical path over the sweep DAG: finish(k) = t_sweep[k] +
        # max finish over the slices k absorbed from (deps point strictly
        # backward, so one forward pass is the longest-path DP)
        finish = np.zeros(max(n_slices, 1))
        for k in range(n_slices):
            dep_finish = max((finish[d] for d in deps[k]), default=0.0)
            finish[k] = dep_finish + t_sweep[k]
        sweep_cp = float(finish[:max(n_slices, 1)].max()) if n_slices else 0.0
        t_seq += sweep_cp

        peak_block_bytes = max(peak_block_bytes, batchblk.peak_bytes)
        n_consolidations += batchblk.n_consolidations
        n_expansions += batchblk.n_expansions
        n_evictions += batchblk.n_evictions

        frac = np.asarray(wt, dtype=np.float64)
        step_conc = float(np.max(t_fused * frac + t_slice[:n_slices]))
        reg.histogram("superstep_conc_s").observe(step_conc)
        sim_wall_book += step_conc + t_seq

        # ---- pivot exchange (every ``exchange_every`` supersteps, and
        # skipped once the queue is drained — the replica is never read
        # again): each shard ships its backlog as one EF-compressed
        # payload; every shard installs all decoded payloads into its
        # replica (the host simulation installs once, which is exactly one
        # device's worth of decode + install work) ----
        if (P > 1 and pos < len(queue)
                and n_supersteps % exchange_every == 0
                and any(shard_logs)):
            n_exchange_rounds += 1
            t_enc = np.zeros(P)
            payloads = []
            shipped_lows: List[List[int]] = []
            for k in range(P):
                with tl.span("reduce/encode", lane=k, step=step) as sp:
                    payloads.append(encode_commit_delta(shard_logs[k]))
                shipped_lows.append([r["low"] for r in shard_logs[k]])
                t_enc[k] = sp.dur
            # wire-level faults: each payload's delivery gets a bounded
            # retry with deterministic jittered backoff (the schedule is
            # accounted, not slept — this transport is host-simulated); a
            # payload that exhausts its budget is *deferred* — an empty
            # payload ships in its slot and its backlog + pending lows
            # survive to the next round, exact by the same staleness
            # argument as the exchange cadence itself
            delivered = [True] * P
            if inj is not None:
                empty_payload = encode_commit_delta([])

                def note_retry(a, e, delay):
                    nonlocal n_exchange_retries
                    n_exchange_retries += 1
                    reg.histogram("resilience_backoff_s").observe(delay)

                for k in range(P):
                    def attempt(a, k=k, buf0=payloads[k]):
                        nonlocal n_faults_seen, n_wire_corruptions
                        buf = buf0
                        for f in inj.fire("exchange.wire",
                                          index=n_exchange_rounds,
                                          shard=k):
                            n_faults_seen += 1
                            if f.kind == "drop":
                                raise TransientFault(
                                    f"exchange payload {k} dropped")
                            if f.kind == "corrupt":
                                buf = corrupt_payload(
                                    buf, int(f.param("bit", 17)))
                            elif f.kind == "delay":
                                reg.histogram(
                                    "resilience_backoff_s").observe(
                                    float(f.param("delay_s", 1e-3)))
                        if not verify_commit_delta(buf):
                            n_wire_corruptions += 1
                            raise TransientFault(
                                f"exchange payload {k} corrupt on the "
                                "wire (checksum)")
                        return buf

                    try:
                        payloads[k] = retry_with_backoff(
                            attempt, attempts=3, base_s=1e-4,
                            seed=(n_exchange_rounds << 8) | k,
                            sleep=None, on_retry=note_retry)
                    except TransientFault:
                        n_exchange_deferrals += 1
                        payloads[k] = empty_payload
                        delivered[k] = False
            wire = sum(p.nbytes for p in payloads)
            exchange_bytes += wire
            with tl.span("reduce/exchange", step=step,
                         bytes=int(wire)) as sp:
                for payload in exchange(payloads):
                    for rec in decode_commit_delta(payload):
                        replica.install(rec["low"], rec["col_id"],
                                        rec["mode"], rec["column"],
                                        rec["gens"])
            sim_wall_book += float(t_enc.max()) + sp.dur
            for k in range(P):
                if delivered[k]:
                    for low in shipped_lows[k]:
                        pending.pop(low, None)
                    shard_logs[k] = []

    if san is not None:
        san.set_context(superstep=None, batch=None, slice=None)
    # the reported sim walls are DERIVED from the span timeline — the
    # bookkeeping above survives only as its cross-check
    cp = critical_path(tl.spans)
    reg.counter("n_columns").inc(len(queue))
    reg.counter("n_reductions").inc(n_reductions)
    reg.counter("n_pairs").inc(len(pairs))
    reg.counter("n_essential").inc(len(essentials))
    reg.gauge("stored_bytes").set(store.bytes_stored)
    reg.gauge("n_stored_columns").set(len(store.columns))
    reg.counter("n_spilled").inc(store.n_spilled)
    reg.gauge("batch_size").set(eff_batch)
    reg.counter("n_rounds").inc(n_rounds)
    reg.counter("n_expansions").inc(n_expansions)
    reg.counter("n_evictions").inc(n_evictions)
    reg.counter("n_consolidations").inc(n_consolidations)
    reg.gauge("peak_block_bytes").record_max(peak_block_bytes)
    reg.gauge("use_kernels").set(float(use_kernels))
    reg.gauge("n_shards").set(P)
    reg.counter("n_supersteps").inc(n_supersteps)
    reg.counter("n_exchange_rounds").inc(n_exchange_rounds)
    reg.counter("n_tournament_reductions").inc(n_tournament_reductions)
    reg.counter("n_sweep_probes").inc(n_sweep_probes)
    reg.counter("exchange_bytes").inc(exchange_bytes)
    if P > 1:
        reg.counter("resilience_n_faults").inc(n_faults_seen)
        reg.counter("resilience_n_shard_deaths").inc(n_shard_deaths)
        reg.counter("resilience_n_redeals").inc(n_redeals)
        reg.counter("resilience_n_straggler_sidelines").inc(n_sidelines)
        reg.counter("resilience_n_exchange_retries").inc(n_exchange_retries)
        reg.counter("resilience_n_exchange_deferrals").inc(
            n_exchange_deferrals)
        reg.counter("resilience_n_wire_corruptions").inc(n_wire_corruptions)
    for key, val in cp.items():
        reg.gauge(key).set(val)
    reg.gauge("sim_wall_bookkeeping_s").set(sim_wall_book)
    reg.update_from(cache.stats())
    return finalize_result(pairs, essentials, essential_ids, reg.as_stats())

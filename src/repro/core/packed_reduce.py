"""Bit-packed serial-parallel reduction engine (Dory §4.4 × kernels/gf2).

``reduce_dimension_batched`` (the host serial-parallel engine) spends its
time in per-column Python work: one ``merge_cancel`` sort per GF(2) add and
several one-element adapter probes per reduction (the profile is dominated
by ``cobdy``/``min_cobdy``/``owner_of_low`` calls on ``np.array([x])``
singletons).  This engine keeps the paper's batch structure — parallel
phase against the committed pivots, serial phase for intra-batch
collisions, clearance commit — but holds each batch in *one* bit-packed
block for its whole reduction:

* **rank compression** — per batch, the sorted unique key set of the
  batch's coboundaries plus the first round of gathered addends becomes the
  block's bit-space (``kernels.gf2.scatter_bits``): key ``universe[i]``
  lives at bit ``i``, so ascending keys are ascending ranks, a
  first-set-bit scan (``gf2_find_low`` / ``find_low_np``) *is* the engine's
  ``low``, and one 32-word VREG XOR covers 32,768 matrix entries;
* **parallel phase** — one :meth:`PivotStore.lookup_addends_batched` probe
  per round (one ``owner_of_low`` / ``min_cobdy`` / ``cobdy`` call for the
  whole batch), then the hit rows absorb their gathered committed-pivot
  addends: an in-place bit scatter-XOR on host, ``gf2_parallel_xor`` on the
  gathered addend block on TPU.  Only rows whose low moved are probed
  again;
* **segmented growth vs eviction** — an addend with keys outside the
  bit-space either *expands* the space (the new keys append as a fresh
  word-aligned segment; no re-ranking, lows become a min over per-segment
  find-lows) or *evicts* its row to plain sorted-key form (``merge_cancel``
  chains, as in the host engine).  Dense rounds expand — many rows keep
  XOR-ing in block form; sparse rounds (a few deep single-column chains,
  e.g. H1* on a near-clique) evict — one stubborn chain must not balloon
  the whole block's bit-space.  Segments consolidate to one sorted universe
  only past ``_MAX_SEGMENTS`` — or eagerly on the kernel path, where the
  kernels need the single globally-sorted bit-space;
* **serial phase** — intra-batch low collisions resolve in one host walk
  over the batch in filtration order (a ``low -> row`` dict; packed rows
  XOR whole block rows, evicted rows ``merge_cancel``), with gens updated
  per absorption exactly like the host engine.  On the kernel path a
  ``gf2_serial_reduce`` pre-pass first clears the packed-vs-packed
  collisions in VMEM: ``ceil(B/32)`` *V-words* ride at the block's tail,
  reset to the identity before the pass, so afterwards each row's V bits
  name exactly the batch mates it absorbed — the δ-expansion bookkeeping
  recovered by unpacking ``ceil(B/32)`` words instead of per-XOR updates;
* **clearance** — lows unpack back to int64 keys and commit through the
  existing :class:`PivotStore` (budgeted, largest-explicit-first spill), so
  explicit/implicit/budget semantics are shared with the other engines.
  Trivial pairs commit nothing, so their rows are never unpacked at all.

Diagrams are bit-identical to ``reduce_dimension`` for every mode/budget
(asserted in tests): all engines perform left-to-right GF(2) column
additions, and the lows of any fully reduced matrix are canonical.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..kernels.gf2 import (NO_LOW, find_low_np, scatter_bits,
                           scatter_xor_bits, set_bit_positions)
from .pairing import EMPTY_KEY
from .reduction import (DimensionAdapter, PivotStore, ReductionResult,
                        clearance_commit, clearing_filter, merge_cancel)

_MAX_SEGMENTS = 12   # host path consolidates past this many segments
_EVICT_MAX = 8       # rounds needing new keys for fewer rows evict instead


def _resolve_use_kernels(use_kernels: Optional[bool]) -> bool:
    """Pallas kernels on TPU, numpy mirrors elsewhere (repo-wide policy:
    Mosaic only exists on TPU; interpret-mode Pallas is for tests)."""
    if use_kernels is None:
        import jax
        return jax.default_backend() == "tpu"
    return bool(use_kernels)


def _words(n_keys: int, use_kernels: bool) -> int:
    """Segment width in words; bucketed on the kernel path so the jitted
    Pallas calls see a handful of shapes, not one per universe size."""
    w = max(1, (n_keys + 31) // 32)
    return -(-w // 128) * 128 if use_kernels else w


def _find_low_row(col: np.ndarray) -> int:
    """First-set-bit rank of one packed uint32 row; NO_LOW when zero."""
    nz = col != 0
    if not nz.any():
        return NO_LOW
    w = int(nz.argmax())
    word = int(col[w])
    return w * 32 + ((word & -word).bit_length() - 1)


def _budgeted_batch_size(batch_size: int, cob_width: int,
                         store_budget_bytes: Optional[int]) -> int:
    """Cap the batch so the resident bit block fits the byte budget.

    The batch block is ``B`` rows × ``~B·K/32`` words ≈ ``B²K/8`` bytes
    (plus the same again transiently for a kernel-path addend gather).
    Inverting for ``B`` bounds the packed-block scratch the same way
    ``h2_columns`` bounds its enumeration scratch; neither changes the
    output.  Best-effort: the batch never shrinks below 32 rows (a
    narrower batch loses the batching the engine exists for), so very
    small budgets bound the block at the 32-row floor, not the budget.
    """
    if store_budget_bytes is None:
        return batch_size
    b = int(np.sqrt(max(1.0, 4.0 * store_budget_bytes / max(1, cob_width))))
    return int(np.clip(b, 32, batch_size))


class _PackedBatch:
    """One batch resident in packed form, with a scalar escape hatch.

    Layout: ``block[:, 0:cap]`` is the R region — a sequence of
    word-aligned segments, each a sorted key array mapped to consecutive
    bit ranks — and ``block[:, cap:cap+VW]`` are the V-words the kernel
    serial pre-pass uses for δ-expansion tracking (zero otherwise).
    ``scalar`` maps evicted rows to plain int64 key arrays; ``lows`` holds
    every row's current low *key* (-1 = empty), which survives segment
    growth, consolidation and eviction unchanged.
    """

    def __init__(self, cob: np.ndarray, seed_addends: List[np.ndarray],
                 use_kernels: bool):
        B = cob.shape[0]
        self.B = B
        self.VW = (B + 31) // 32
        self.use_kernels = use_kernels
        mask = cob != EMPTY_KEY
        seg0 = np.unique(np.concatenate([cob[mask]] + seed_addends))
        self.segs: List[np.ndarray] = [seg0]
        self.seg_off: List[int] = [0]          # word offset per segment
        self.r_words = _words(len(seg0), use_kernels)
        self.cap = self.r_words
        self.block = np.zeros((B, self.cap + self.VW), dtype=np.uint32)
        ridx, _ = np.nonzero(mask)
        pos = np.searchsorted(seg0, cob[mask])
        scatter_bits(self.block, ridx, pos)
        self.scalar: Dict[int, np.ndarray] = {}
        self.lows = np.where(cob[:, 0] == EMPTY_KEY, np.int64(-1), cob[:, 0])
        self.peak_bytes = self.block.nbytes
        self.n_consolidations = 0
        self.n_expansions = 0
        self.n_evictions = 0

    # -- universe bookkeeping ------------------------------------------------

    def _grow_cap(self, need: int) -> None:
        new_cap = max(need, 2 * self.cap)
        block = np.zeros((self.B, new_cap + self.VW), dtype=np.uint32)
        block[:, :self.r_words] = self.block[:, :self.r_words]
        # V region is zero outside the kernel pre-pass — nothing to move
        self.block = block
        self.cap = new_cap
        self.peak_bytes = max(self.peak_bytes, block.nbytes)

    def add_segment(self, new_keys: np.ndarray) -> None:
        """Append new addend keys as a fresh word-aligned segment — no
        re-ranking of resident bits (rank order only holds per segment;
        lows are reconstructed as a min over segments)."""
        w = _words(len(new_keys), self.use_kernels)
        if self.r_words + w > self.cap:
            self._grow_cap(self.r_words + w)
        self.segs.append(new_keys)
        self.seg_off.append(self.r_words)
        self.r_words += w
        if self.use_kernels or len(self.segs) > _MAX_SEGMENTS:
            self.consolidate()

    def consolidate(self) -> None:
        """Merge all segments into one sorted universe (one global remap).
        The kernel path runs consolidated always: ``gf2_find_low`` /
        ``gf2_serial_reduce`` read the first set *bit*, which equals the
        min *key* only in a single globally-sorted bit-space."""
        if len(self.segs) == 1:
            return
        self.n_consolidations += 1
        ridx_all, keys_all = [], []
        for seg, off in zip(self.segs, self.seg_off):
            w = _words(len(seg), self.use_kernels)
            ridx, pos, _ = set_bit_positions(self.block[:, off:off + w])
            keep = pos < len(seg)
            ridx_all.append(ridx[keep])
            keys_all.append(seg[pos[keep]])
        ridx = np.concatenate(ridx_all)
        keys = np.concatenate(keys_all)
        universe = np.unique(np.concatenate(self.segs))
        self.segs = [universe]
        self.seg_off = [0]
        self.r_words = _words(len(universe), self.use_kernels)
        if self.r_words > self.cap:
            self.cap = self.r_words
        self.block = np.zeros((self.B, self.cap + self.VW), dtype=np.uint32)
        self.peak_bytes = max(self.peak_bytes, self.block.nbytes)
        pos = np.searchsorted(universe, keys)
        order = np.lexsort((pos, ridx))
        scatter_bits(self.block, ridx[order], pos[order])

    def _abs_positions(self, keys: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Absolute bit position of each key (32·segment word offset +
        in-segment rank) plus the mask of keys in no segment yet."""
        out = np.full(len(keys), -1, dtype=np.int64)
        todo = np.ones(len(keys), dtype=bool)
        for seg, off in zip(self.segs, self.seg_off):
            if not len(seg) or not todo.any():
                continue
            pos = np.minimum(np.searchsorted(seg, keys), len(seg) - 1)
            hit = todo & (seg[pos] == keys)
            out[hit] = off * 32 + pos[hit]
            todo &= ~hit
        return out, todo

    # -- representation moves ------------------------------------------------

    def _unpack_row(self, c: int) -> np.ndarray:
        parts = []
        for seg, off in zip(self.segs, self.seg_off):
            if not len(seg):
                continue
            w = _words(len(seg), self.use_kernels)
            _, pos, _ = set_bit_positions(self.block[c:c + 1, off:off + w])
            pos = pos[pos < len(seg)]
            if pos.size:
                parts.append(seg[pos])
        return np.concatenate(parts) if parts else np.zeros(0, dtype=np.int64)

    def evict(self, c: int) -> None:
        """Move row ``c`` to scalar (sorted-key) form: one stubborn chain
        must not balloon the shared bit-space."""
        if c in self.scalar:
            return
        self.n_evictions += 1
        keys = self._unpack_row(c)
        keys.sort(kind="stable")
        self.block[c, :self.r_words] = 0
        self.scalar[c] = keys

    # -- lows ----------------------------------------------------------------

    def refresh_lows(self, rows: np.ndarray) -> None:
        """Recompute ``lows[rows]`` (packed rows) as the min key over
        per-segment find-lows (``gf2_find_low`` on the kernel path)."""
        rows = np.asarray(rows, dtype=np.int64)
        if not rows.size:
            return
        best = np.full(len(rows), EMPTY_KEY, dtype=np.int64)
        for seg, off in zip(self.segs, self.seg_off):
            if not len(seg):
                continue
            w = _words(len(seg), self.use_kernels)
            sub = self.block[rows, off:off + w]
            if self.use_kernels:
                import jax.numpy as jnp

                from ..kernels.gf2 import gf2_find_low
                pad = (-len(rows)) % 32   # bucket row counts for the jit
                if pad:
                    sub = np.vstack(
                        [sub, np.zeros((pad, w), dtype=np.uint32)])
                lb = np.asarray(gf2_find_low(jnp.asarray(sub)))[:len(rows)]
            else:
                lb = find_low_np(sub)
            k = np.where(lb == NO_LOW, EMPTY_KEY,
                         seg[np.minimum(lb, len(seg) - 1)])
            best = np.minimum(best, k)
        self.lows[rows] = np.where(best == EMPTY_KEY, -1, best)

    def _row_low(self, c: int) -> int:
        best = -1
        for seg, off in zip(self.segs, self.seg_off):
            if not len(seg):
                continue
            w = _words(len(seg), self.use_kernels)
            lb = _find_low_row(self.block[c, off:off + w])
            if lb != NO_LOW and lb < len(seg):
                k = int(seg[lb])
                if best < 0 or k < best:
                    best = k
        return best

    # -- parallel phase ------------------------------------------------------

    def xor_addends(self, hit: List[int],
                    addends: List[Optional[np.ndarray]]) -> None:
        """Parallel-phase GF(2) add: gathered addends into the hit rows —
        an in-place scatter-XOR on host, ``gf2_parallel_xor`` on a packed
        addend block on the kernel path; scalar rows ``merge_cancel``.

        Addend keys outside every segment either append as a fresh segment
        (dense rounds) or evict their rows (sparse rounds, ``_EVICT_MAX``).
        """
        scalar_hit = [i for i in hit if i in self.scalar]
        packed_hit = [i for i in hit if i not in self.scalar]
        if packed_hit:
            lens = np.array([len(addends[i]) for i in packed_hit],
                            dtype=np.int64)
            keys = np.concatenate([addends[i] for i in packed_hit])
            ridx = np.repeat(np.asarray(packed_hit, dtype=np.int64), lens)
            pos, missing = self._abs_positions(keys)
            if missing.any():
                miss_rows = np.unique(ridx[missing])
                if len(miss_rows) <= _EVICT_MAX:
                    for i in miss_rows:
                        self.evict(int(i))
                        scalar_hit.append(int(i))
                    keep = ~np.isin(ridx, miss_rows)
                    ridx, pos = ridx[keep], pos[keep]
                    packed_hit = [i for i in packed_hit
                                  if i not in self.scalar]
                else:
                    self.n_expansions += 1
                    new_seg = np.unique(keys[missing])
                    n_segs = len(self.segs) + 1
                    self.add_segment(new_seg)
                    if len(self.segs) == n_segs:
                        # append-only: found positions are still valid
                        off = self.seg_off[-1]
                        pos[missing] = off * 32 + np.searchsorted(
                            new_seg, keys[missing])
                    else:   # consolidation re-ranked everything
                        pos, miss2 = self._abs_positions(keys)
                        assert not miss2.any()
        if packed_hit:
            if self.use_kernels:
                import jax.numpy as jnp

                from ..kernels.gf2 import gf2_parallel_xor
                local = {r: k for k, r in enumerate(packed_hit)}
                lrid = np.array([local[int(r)] for r in ridx],
                                dtype=np.int64)
                order = np.lexsort((pos, lrid))
                packed = np.zeros((len(packed_hit), self.cap),
                                  dtype=np.uint32)
                scatter_bits(packed, lrid[order], pos[order])
                self.peak_bytes = max(self.peak_bytes,
                                      self.block.nbytes + packed.nbytes)
                rview = self.block[:, :self.cap]
                rview[packed_hit] = np.asarray(gf2_parallel_xor(
                    jnp.asarray(rview[packed_hit]), jnp.asarray(packed)))
            else:
                order = np.lexsort((pos, ridx))
                scatter_xor_bits(self.block, ridx[order], pos[order])
            self.refresh_lows(np.asarray(packed_hit, dtype=np.int64))
        for i in scalar_hit:
            merged = merge_cancel(self.scalar[i], addends[i])
            self.scalar[i] = merged
            self.lows[i] = int(merged[0]) if merged.size else -1

    # -- serial phase --------------------------------------------------------

    def serial_pass(self, gens: List[Dict[int, int]],
                    ids_int: List[int]) -> Tuple[int, np.ndarray]:
        """Resolve intra-batch low collisions in filtration order.

        Kernel path: a ``gf2_serial_reduce`` V-augmented pre-pass clears
        packed-vs-packed collisions in VMEM (V bits -> gens merge), then
        the host walk finishes scalar-involved collisions.  Host path: the
        walk does everything — packed rows XOR whole block rows, scalar
        rows ``merge_cancel``, a packed row absorbing a scalar mate evicts
        first.  Returns ``(n_reductions, changed_row_indices)``.
        """
        n_red = 0
        changed: Dict[int, bool] = {}
        if self.use_kernels:
            n_red += self._serial_kernel_prepass(gens, ids_int, changed)
        low_to_row: Dict[int, int] = {}
        for c in range(self.B):
            low = int(self.lows[c])
            while low >= 0:
                j = low_to_row.get(low)
                if j is None:
                    break
                n_red += 1
                changed[c] = True
                c_packed = c not in self.scalar
                j_packed = j not in self.scalar
                if c_packed and not j_packed:
                    self.evict(c)
                    c_packed = False
                if c_packed:
                    self.block[c] ^= self.block[j]
                    low = self._row_low(c)
                else:
                    jkeys = self.scalar[j] if not j_packed \
                        else self._unpack_row(j)
                    merged = merge_cancel(self.scalar[c], jkeys)
                    self.scalar[c] = merged
                    low = int(merged[0]) if merged.size else -1
                gens[c][ids_int[j]] = gens[c].get(ids_int[j], 0) + 1
                for g, p in gens[j].items():
                    gens[c][g] = gens[c].get(g, 0) + p
            self.lows[c] = low
            if low >= 0:
                low_to_row[low] = c
        return n_red, np.array(sorted(changed), dtype=np.int64)

    def _serial_kernel_prepass(self, gens: List[Dict[int, int]],
                               ids_int: List[int],
                               changed: Dict[int, bool]) -> int:
        """Kernel pre-pass on the packed rows: V-identity words ride the
        block tail, ``gf2_serial_reduce`` XORs colliding rows in VMEM, and
        the V bits name each row's absorbed mates afterwards (scalar rows'
        block rows are zero, hence inert; zero slack words between the R
        segment and the V-words are skipped by the kernel's find-low; and
        V-rank collisions only ever involve R-empty rows)."""
        import jax.numpy as jnp

        from ..kernels.gf2 import gf2_serial_reduce

        assert len(self.segs) == 1
        B, cap = self.B, self.cap
        vbit = np.arange(B)
        vslice = self.block[:, cap:]
        vslice[...] = 0
        # scalar rows get no identity bit: inert rows must not register lows
        live = np.array([i not in self.scalar for i in range(B)])
        lv = vbit[live]
        vslice[lv, lv >> 5] |= np.uint32(1) << (lv & 31).astype(np.uint32)
        C, W = B, cap + self.VW
        Cp, Wp = -(-C // 32) * 32, -(-W // 128) * 128
        padded = np.zeros((Cp, Wp), dtype=np.uint32)
        padded[:C, :W] = self.block
        red, _, n_red = gf2_serial_reduce(jnp.asarray(padded[None]))
        self.block[...] = np.asarray(red)[0, :C, :W]
        n_red = int(np.asarray(n_red)[0])
        if n_red == 0:
            vslice[...] = 0
            return 0
        vrid, vpos, _ = set_bit_positions(vslice)
        vkeep = vpos < B
        counts = np.bincount(vrid[vkeep], minlength=B).astype(np.int64)
        vrows = np.split(vpos[vkeep], np.cumsum(counts)[:-1])
        touched = [i for i in range(B) if vrows[i].size > 1]
        entry = {int(i): dict(gens[i]) for i in touched}
        for i in touched:
            changed[int(i)] = True
            newg = dict(entry[int(i)])
            for j in vrows[i]:
                j = int(j)
                if j == i:
                    continue
                newg[ids_int[j]] = newg.get(ids_int[j], 0) + 1
                # unchanged mates keep their live gens; changed mates use
                # their pass-entry snapshot (the kernel walk is ascending)
                for g, p in entry.get(j, gens[j]).items():
                    newg[g] = newg.get(g, 0) + p
            gens[i] = newg
        vslice[...] = 0
        if touched:
            self.refresh_lows(np.array(touched, dtype=np.int64))
        return n_red

    # -- clearance -----------------------------------------------------------

    def unpack(self, rows: np.ndarray) -> List[np.ndarray]:
        """``rows`` as int64 key arrays, one block pass per segment.

        Row keys come out ascending *within* each segment's contribution
        (segment-major order overall, not globally sorted) — every consumer
        either re-ranks per key (the pack/scatter paths) or re-sorts
        (``merge_cancel``, ``parity_reduce``), so a global per-row sort
        would buy nothing.  Clearance also only unpacks the rows it will
        store: trivial pairs commit nothing."""
        rows = np.asarray(rows, dtype=np.int64)
        n = len(rows)
        if not n:
            return []
        out_scalar = {int(i): self.scalar[int(i)] for i in rows
                      if int(i) in self.scalar}
        packed_rows = np.array([i for i in rows if int(i) not in self.scalar],
                               dtype=np.int64)
        np_rows = len(packed_rows)
        parts = []
        counts = np.zeros(np_rows, dtype=np.int64)
        for seg, off in zip(self.segs, self.seg_off):
            if not len(seg) or not np_rows:
                continue
            w = _words(len(seg), self.use_kernels)
            ridx, pos, cnt = set_bit_positions(
                self.block[packed_rows, off:off + w])
            keep = pos < len(seg)
            if not keep.all():
                ridx, pos = ridx[keep], pos[keep]
                cnt = np.bincount(ridx, minlength=np_rows).astype(np.int64)
            parts.append((ridx, seg[pos], cnt))
            counts += cnt
        out = np.empty(int(counts.sum()), dtype=np.int64)
        row_start = np.zeros(np_rows + 1, dtype=np.int64)
        np.cumsum(counts, out=row_start[1:])
        fill = row_start[:-1].copy()
        for ridx, keys, cnt in parts:
            if not len(keys):
                continue
            part_off = np.cumsum(cnt) - cnt
            within = np.arange(len(keys), dtype=np.int64) - part_off[ridx]
            out[fill[ridx] + within] = keys
            fill += cnt
        packed_cols = np.split(out, row_start[1:-1]) if np_rows else []
        packed_iter = iter(packed_cols)
        return [out_scalar[int(i)] if int(i) in out_scalar
                else next(packed_iter) for i in rows]


def reduce_dimension_packed(
    adapter: DimensionAdapter,
    column_ids: np.ndarray,
    mode: str = "explicit",
    cleared=None,
    batch_size: int = 256,
    store_budget_bytes: Optional[int] = None,
    use_kernels: Optional[bool] = None,
) -> ReductionResult:
    """Bit-packed serial-parallel cohomology reduction (module docstring).

    Same contract as ``reduce_dimension`` / ``reduce_dimension_batched``:
    ``column_ids`` in decreasing filtration order, diagrams bit-identical to
    both.  ``use_kernels=None`` resolves to the Pallas kernels on TPU and
    the numpy block mirrors elsewhere; ``True`` forces the kernels (they
    interpret off-TPU — the test path).
    """
    use_kernels = _resolve_use_kernels(use_kernels)
    store = PivotStore(adapter, mode, store_budget_bytes=store_budget_bytes)
    pairs: List[tuple] = []
    essentials: List[float] = []
    n_reductions = 0
    n_rounds = 0
    n_expansions = 0
    n_evictions = 0
    n_consolidations = 0
    peak_block_bytes = 0
    queue = clearing_filter(column_ids, cleared)
    eff_batch = batch_size

    pos = 0
    first = True
    while pos < len(queue):
        ids = queue[pos:pos + eff_batch]
        cob = adapter.cobdy(ids)
        if first:
            first = False
            eff_batch = _budgeted_batch_size(batch_size, cob.shape[1],
                                             store_budget_bytes)
            if eff_batch < len(ids):
                ids, cob = ids[:eff_batch], cob[:eff_batch]
        pos += len(ids)
        B = len(ids)
        ids_arr = np.asarray(ids, dtype=np.int64)
        ids_int = [int(i) for i in ids_arr]
        gens: List[Dict[int, int]] = [dict() for _ in range(B)]

        # seed the bit-space with the first round of addends so the common
        # case packs exactly once
        lows0 = np.where(cob[:, 0] == EMPTY_KEY, np.int64(-1), cob[:, 0])
        addends, owners, owner_gens = \
            store.lookup_addends_batched(lows0, ids_arr)
        batchblk = _PackedBatch(
            cob, [a for a in addends if a is not None], use_kernels)

        probe = np.zeros(B, dtype=bool)   # rows whose low moved since probe
        while True:
            hit = [i for i in range(B) if addends[i] is not None]
            if hit:
                n_rounds += 1
                n_reductions += len(hit)
                for i in hit:
                    o = int(owners[i])
                    gens[i][o] = gens[i].get(o, 0) + 1
                    for g in owner_gens[i]:
                        g = int(g)
                        gens[i][g] = gens[i].get(g, 0) + 1
                batchblk.xor_addends(hit, addends)
                probe[hit] = batchblk.lows[hit] >= 0

            # intra-batch collisions -> one serial pass, filtration order
            nz = batchblk.lows[batchblk.lows >= 0]
            if len(np.unique(nz)) != len(nz):
                n_red, changed = batchblk.serial_pass(gens, ids_int)
                n_reductions += n_red
                probe[changed] = batchblk.lows[changed] >= 0

            if not probe.any():
                break
            probe_lows = np.where(probe, batchblk.lows, -1)
            probe[:] = False
            addends, owners, owner_gens = \
                store.lookup_addends_batched(probe_lows, ids_arr)

        peak_block_bytes = max(peak_block_bytes, batchblk.peak_bytes)
        n_consolidations += batchblk.n_consolidations
        n_expansions += batchblk.n_expansions
        n_evictions += batchblk.n_evictions

        # ---- clearance: batched value lookups, commit in batch order;
        # get_columns unpacks exactly the rows whose R keys the store will
        # hold (trivial pairs and pure implicit stores unpack nothing) ----
        clearance_commit(store, adapter, ids_arr, batchblk.lows, gens,
                         batchblk.unpack, pairs, essentials)

    pair_arr = np.array([(b, d) for b, d, _ in pairs if d > b],
                        dtype=np.float64).reshape(-1, 2)
    pivot_lows = np.array([low for _, _, low in pairs], dtype=np.int64)
    return ReductionResult(
        pairs=pair_arr,
        essentials=np.array(essentials, dtype=np.float64),
        pivot_lows=pivot_lows,
        stats={
            "n_columns": float(len(queue)),
            "n_reductions": float(n_reductions),
            "n_pairs": float(len(pairs)),
            "n_essential": float(len(essentials)),
            "stored_bytes": float(store.bytes_stored),
            "n_stored_columns": float(len(store.columns)),
            "n_spilled": float(store.n_spilled),
            "batch_size": float(eff_batch),
            "n_rounds": float(n_rounds),
            "n_expansions": float(n_expansions),
            "n_evictions": float(n_evictions),
            "n_consolidations": float(n_consolidations),
            "peak_block_bytes": float(peak_block_bytes),
            "use_kernels": float(use_kernels),
        },
    )

"""Paired-indexing for 2- and 3-simplices (Dory §4.1).

A triangle/tetrahedron is identified by ``<k_p, k_s>``:

* primary key ``k_p``  — filtration order of the simplex *diameter* edge,
* secondary key ``k_s`` — for triangles, the remaining vertex id (``f_0``);
  for tetrahedra, the filtration order of the *opposite* edge (``f_1``).

Both keys are bounded by ``O(n_e)`` (number of permissible edges), never by the
combinatorial index space ``O(n^4)`` — this is the paper's central memory
insight and the reason 8 bytes always suffice.  We pack the pair into one
``int64`` lane (``k_p << 32 | k_s``) which *preserves the paper's ordering*
(eq. 1: lexicographic on ``(k_p, k_s)``), so packed keys sort/compare natively
on TPU int lanes without 128-bit arithmetic (the failure mode of
combinatorial indexing that crashed Ripser on the Hi-C data set).
"""
from __future__ import annotations

import numpy as np

# Sentinel: larger than any valid packed key (k_p < 2**31).  Used as the
# "Empty"/MAX marker of the paper's flowcharts and as the sort-to-the-end pad.
EMPTY_KEY = np.int64(np.iinfo(np.int64).max)

_SHIFT = np.int64(32)
_MASK = np.int64((1 << 32) - 1)


def pack(kp, ks):
    """Pack ``<k_p, k_s>`` into one int64; order-preserving (paper eq. 1)."""
    return (np.int64(kp) << _SHIFT) | (np.int64(ks) & _MASK)


def unpack(key):
    """Inverse of :func:`pack`; returns ``(k_p, k_s)``."""
    key = np.asarray(key, dtype=np.int64)
    return (key >> _SHIFT).astype(np.int64), (key & _MASK).astype(np.int64)


def pack_np(kp: np.ndarray, ks: np.ndarray) -> np.ndarray:
    """Vectorized pack for numpy arrays (any broadcastable shapes)."""
    return (kp.astype(np.int64) << _SHIFT) | (ks.astype(np.int64) & _MASK)


def primary(key):
    """``k_p`` of a packed key (diameter-edge order)."""
    return np.asarray(key, dtype=np.int64) >> _SHIFT


def secondary(key):
    """``k_s`` of a packed key."""
    return np.asarray(key, dtype=np.int64) & _MASK

"""JAX/TPU engine for Dory: jitted column algebra + distributed reduction.

This module is the TPU-native core of the paper's serial-parallel algorithm
(§4.4), expressed as pure-jnp programs that lower under ``pjit``/``shard_map``
on the production meshes:

* columns are fixed-width sorted ``int64`` paired-index key arrays
  (``EMPTY_KEY`` padded) — the static-shape counterpart of the paper's
  hash-table-of-φ-representations;
* GF(2) column addition = ``merge_cancel`` (concat → sort → cancel equal
  pairs), a pure sort-network op that vectorizes on the VPU;
* the **parallel phase** reduces every batch column against the replicated
  committed pivot table (binary-searched lookups, gathered addends) — sharded
  over the ``data`` (and ``pod``) mesh axes with zero collectives;
* the **serial phase** becomes a log-depth *tournament* over the data axis
  (``ppermute`` exchange + local collision XOR) — a beyond-paper improvement
  on the strictly-serial intra-batch pass (log(B) exchange rounds instead of
  a linear sweep, same precedence rule: the earlier-ranked shard's column
  wins).  Residual collisions are completed by the exact host engine, so
  device pre-reduction never changes results, only removes work;
* **H0** is a Borůvka minimum-spanning-forest (segment-min + pointer
  jumping), replacing the paper's sequential union-find with a log-depth
  TPU-friendly program that yields *identical* persistence pairs
  (unique edge orders ⇒ unique MSF).

Paired-index keys are 64-bit, so this module enables jax x64 at import; all
model code elsewhere pins dtypes explicitly and is unaffected.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

EMPTY = np.int64(np.iinfo(np.int64).max)


# ---------------------------------------------------------------------------
# Column algebra (padded, fixed width)
# ---------------------------------------------------------------------------

def merge_cancel_padded(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """GF(2) sum of batched sorted key columns.

    a: (..., Wa), b: (..., Wb) int64 ascending with EMPTY padding; each key
    appears at most once per operand.  Returns (..., Wa+Wb) ascending EMPTY
    padded (callers truncate/track overflow).
    """
    m = jnp.concatenate([a, b], axis=-1)
    m = jnp.sort(m, axis=-1)
    eq_prev = jnp.concatenate(
        [jnp.zeros_like(m[..., :1], dtype=bool), m[..., 1:] == m[..., :-1]],
        axis=-1)
    eq_next = jnp.concatenate(
        [m[..., :-1] == m[..., 1:], jnp.zeros_like(m[..., :1], dtype=bool)],
        axis=-1)
    cancel = (eq_prev | eq_next) & (m != EMPTY)
    m = jnp.where(cancel, EMPTY, m)
    return jnp.sort(m, axis=-1)


def truncate_width(cols: jnp.ndarray, width: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Clip columns back to ``width`` keys, flagging overflow per row."""
    if cols.shape[-1] <= width:
        pad = jnp.full(cols.shape[:-1] + (width - cols.shape[-1],), EMPTY,
                       dtype=cols.dtype)
        return jnp.concatenate([cols, pad], axis=-1), \
            jnp.zeros(cols.shape[:-1], dtype=bool)
    overflow = (cols[..., width:] != EMPTY).any(axis=-1)
    return cols[..., :width], overflow


# ---------------------------------------------------------------------------
# Parallel phase: reduce batch columns against the committed pivot table
# ---------------------------------------------------------------------------

def parallel_reduce(cols: jnp.ndarray, pivot_keys: jnp.ndarray,
                    pivot_cols: jnp.ndarray, n_iters: int = 8
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``n_iters`` rounds of: look up each column's low in the pivot table,
    XOR in the owning reduced column.  cols: (B, W); pivot_keys: (P,) sorted
    ascending (EMPTY padded); pivot_cols: (P, W).

    Returns (cols', hit_last) — a row whose low still matches a pivot after
    the budget is finished by the next round / host orchestration; semantics
    match the paper's parallel phase exactly (reduction with R^⊥ first).
    """
    W = cols.shape[-1]
    P = pivot_keys.shape[0]

    def body(_, carry):
        cols, _ = carry
        low = cols[:, 0]
        idx = jnp.clip(jnp.searchsorted(pivot_keys, low), 0, P - 1)
        hit = (pivot_keys[idx] == low) & (low != EMPTY)
        addend = jnp.where(hit[:, None], pivot_cols[idx], EMPTY)
        merged = merge_cancel_padded(cols, addend)
        return merged[:, :W], hit     # reduction strictly shrinks the low

    return jax.lax.fori_loop(
        0, n_iters, body, (cols, jnp.zeros(cols.shape[0], dtype=bool)))


# ---------------------------------------------------------------------------
# Serial phase as a log-depth tournament over the data axis
# ---------------------------------------------------------------------------

def tournament_merge_local(cols: jnp.ndarray, other: jnp.ndarray) -> jnp.ndarray:
    """Absorb colliding partner columns: every row of ``cols`` whose low
    appears among ``other``'s lows gets that column XOR-ed in (GF(2))."""
    W = cols.shape[-1]
    low = cols[:, 0]
    order = jnp.argsort(other[:, 0])
    olow_s = other[:, 0][order]
    oc_s = other[order]
    idx = jnp.clip(jnp.searchsorted(olow_s, low), 0, other.shape[0] - 1)
    hit = (olow_s[idx] == low) & (low != EMPTY)
    addend = jnp.where(hit[:, None], oc_s[idx], EMPTY)
    return merge_cancel_padded(cols, addend)[:, :W]


def make_distributed_round(mesh: jax.sharding.Mesh,
                           n_parallel_iters: int = 8,
                           n_serial_rounds: int | None = None):
    """Build the sharded serial-parallel round — the dry-run entry most
    representative of the paper's technique.

    Layout: batch columns sharded over ``data`` (x ``pod`` if present);
    pivot table replicated.  One round =
      parallel phase (no collectives)
      -> tournament serial phase over ``data`` (log2 rounds of ppermute +
         collision XOR; later-ranked shard absorbs, matching filtration
         precedence since batches are dealt in filtration order)
      -> clearance traffic: all_gather of resolved lows (+ all_gather over
         ``pod`` so every pod sees the commit set).
    """
    from jax.sharding import PartitionSpec as P

    data = mesh.shape["data"]
    n_rounds = n_serial_rounds if n_serial_rounds is not None else \
        max(1, int(np.log2(data)))
    has_pod = "pod" in mesh.axis_names
    col_axes = ("pod", "data") if has_pod else ("data",)

    def round_fn(cols, pivot_keys, pivot_cols):
        cols, _ = parallel_reduce(cols, pivot_keys, pivot_cols,
                                  n_iters=n_parallel_iters)
        me = jax.lax.axis_index("data")
        step = 1
        for _ in range(n_rounds):
            perm = [(i, i ^ step) for i in range(data)]
            other = jax.lax.ppermute(cols, "data", perm=perm)
            absorb = (me & step) != 0          # partner ranked earlier
            merged = tournament_merge_local(cols, other)
            cols = jnp.where(absorb, merged, cols)
            cols, _ = parallel_reduce(cols, pivot_keys, pivot_cols, n_iters=2)
            step <<= 1
        lows = jax.lax.all_gather(cols[:, 0], "data", tiled=True)
        if has_pod:
            lows = jax.lax.all_gather(lows, "pod", tiled=True)
        return cols, lows

    return jax.shard_map(
        round_fn, mesh=mesh,
        in_specs=(P(col_axes, None), P(None), P(None, None)),
        out_specs=(P(col_axes, None), P(None)),
        check_vma=False,
    )


# ---------------------------------------------------------------------------
# H0 via Borůvka MSF (log-depth, exact persistence pairs)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n",))
def h0_msf_mask(edges: jnp.ndarray, n: int) -> jnp.ndarray:
    """Minimum-spanning-forest mask over edges sorted by filtration order.

    edges: (n_e, 2) int32, row index = filtration order (unique ⇒ unique MSF
    ⇒ identical H0 persistence pairs to Kruskal/union-find).
    Returns bool (n_e,) — True exactly for H0 death edges (clearing input).
    """
    n_e = edges.shape[0]
    eo = jnp.arange(n_e, dtype=jnp.int64)
    INF = jnp.int64(n_e)

    def compress(parent):
        def cond(p):
            return jnp.any(p[p] != p)

        return jax.lax.while_loop(cond, lambda p: p[p], parent)

    def round_body(carry):
        label, in_msf, _ = carry
        la = label[edges[:, 0]]
        lb = label[edges[:, 1]]
        cross = la != lb
        w = jnp.where(cross, eo, INF)
        best = jnp.full((n,), INF, dtype=jnp.int64)
        best = best.at[la].min(w)
        best = best.at[lb].min(w)
        chosen = ((best[la] == eo) | (best[lb] == eo)) & cross
        in_msf = in_msf | chosen
        lo = jnp.minimum(la, lb)
        hi = jnp.maximum(la, lb)
        parent = jnp.arange(n, dtype=jnp.int64)
        parent = parent.at[jnp.where(chosen, hi, n)].min(
            jnp.where(chosen, lo, n), mode="drop")
        parent = compress(parent)
        return parent[label], in_msf, jnp.any(chosen)

    label, in_msf, _ = jax.lax.while_loop(
        lambda c: c[2],
        round_body,
        (jnp.arange(n, dtype=jnp.int64), jnp.zeros(n_e, dtype=bool),
         jnp.bool_(n_e > 0)),
    )
    return in_msf


def connected_labels(edges: jnp.ndarray, n: int, rounds: int = 16) -> jnp.ndarray:
    """Component labels by hook + pointer-jumping (betti_0 at a scale)."""
    parent = jnp.arange(n, dtype=jnp.int64)

    def body(_, parent):
        pa = parent[edges[:, 0]]
        pb = parent[edges[:, 1]]
        lo = jnp.minimum(pa, pb)
        hi = jnp.maximum(pa, pb)
        parent = parent.at[hi].min(lo)
        parent = parent[parent]
        parent = parent[parent]
        return parent

    return jax.lax.fori_loop(0, rounds, body, parent)


# ---------------------------------------------------------------------------
# Host-callable jitted helpers used by the numpy engines
# ---------------------------------------------------------------------------

@jax.jit
def merge_cancel_jax(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return merge_cancel_padded(a, b)


@functools.partial(jax.jit, static_argnames=("n_iters",))
def parallel_reduce_jit(cols, pivot_keys, pivot_cols, n_iters: int = 8):
    return parallel_reduce(cols, pivot_keys, pivot_cols, n_iters=n_iters)

"""Serial-parallel batched reduction (Dory §4.4).

Rather than reducing one column at a time, a *batch* of B columns is
processed per round:

* **parallel** phase — every batch column is reduced against the already
  committed ``R^⊥`` (and against trivial owners) independently; this is the
  embarrassingly-parallel part the paper maps to threads and we map to
  vectorized/batched work (and, in ``jax_engine.py``, to the ``data`` mesh
  axis via ``shard_map``).
* **serial** phase — intra-batch pivot collisions are resolved in filtration
  order: a column may only absorb a *marked* (fully reduced) earlier batch
  mate, falling back to the parallel rule whenever its new low re-enters the
  committed table (paper Fig. 14-15 precedence rules).
* **clearance** — all resolved columns commit pivots/pairs at once and the
  batch window slides.

Semantics are identical to the single-column engine (asserted in tests); the
batch size trades parallel width against serial-merge work, matching the
paper's batch-size hyperparameter discussion.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from .pairing import EMPTY_KEY
from .reduction import (DimensionAdapter, PivotStore, ReductionResult,
                        clearing_filter, merge_cancel)


def _reduce_vs_store(store: PivotStore, adapter: DimensionAdapter,
                     r: np.ndarray, col_id: int,
                     gens: Dict[int, int]) -> np.ndarray:
    """Reduce r against committed pivots + trivial owners until its low is
    fresh (the parallel-phase rule).  Returns the partially-reduced r."""
    while r.size:
        low = int(r[0])
        addend = store.lookup_addend(low, col_id)
        if addend is None:
            break
        owner = _owner_id(store, adapter, low)
        gens[owner] = gens.get(owner, 0) + 1
        for g in _owner_gens(store, low):
            gens[int(g)] = gens.get(int(g), 0) + 1
        r = merge_cancel(r, addend)
    return r


def _owner_id(store: PivotStore, adapter: DimensionAdapter, low: int) -> int:
    idx = store.low_to_idx.get(low)
    if idx is not None:
        return store.col_ids[idx]
    return int(adapter.owner_of_low(np.array([low], dtype=np.int64))[0])


def _owner_gens(store: PivotStore, low: int) -> np.ndarray:
    idx = store.low_to_idx.get(low)
    if idx is not None and store.gens_lists[idx] is not None:
        return store.gens_lists[idx]
    return np.zeros(0, dtype=np.int64)


def reduce_dimension_batched(
    adapter: DimensionAdapter,
    column_ids: np.ndarray,
    mode: str = "explicit",
    cleared=None,
    batch_size: int = 128,
) -> ReductionResult:
    store = PivotStore(adapter, mode)
    pairs: List[tuple] = []
    essentials: List[float] = []
    n_reductions = 0
    queue = clearing_filter(column_ids, cleared)

    for s in range(0, len(queue), batch_size):
        ids = queue[s:s + batch_size]
        B = len(ids)
        # ---- materialize coboundaries for the whole batch (vectorized) ----
        cob = adapter.cobdy(ids)
        rs: List[np.ndarray] = [row[row != EMPTY_KEY] for row in cob]
        gens: List[Dict[int, int]] = [dict() for _ in range(B)]
        marked = [False] * B
        empty = [False] * B

        # ---- parallel phase ----
        for i in range(B):
            rs[i] = _reduce_vs_store(store, adapter, rs[i], int(ids[i]), gens[i])
            n_reductions += 1

        # ---- serial phase (in filtration order within the batch) ----
        for i in range(B):
            r = rs[i]
            while True:
                if r.size == 0:
                    empty[i] = True
                    break
                low = int(r[0])
                addend = store.lookup_addend(low, int(ids[i]))
                if addend is not None:
                    owner = _owner_id(store, adapter, low)
                    gens[i][owner] = gens[i].get(owner, 0) + 1
                    for g in _owner_gens(store, low):
                        gens[i][int(g)] = gens[i].get(int(g), 0) + 1
                    r = merge_cancel(r, addend)
                    n_reductions += 1
                    continue
                # look for an earlier, marked batch mate with the same low
                hit = None
                for j in range(i):
                    if marked[j] and not empty[j] and rs[j].size and \
                            int(rs[j][0]) == low:
                        hit = j
                        break
                if hit is None:
                    marked[i] = True
                    break
                j = hit
                jid = int(ids[j])
                gens[i][jid] = gens[i].get(jid, 0) + 1
                for g, p in gens[j].items():
                    gens[i][g] = gens[i].get(g, 0) + p
                r = merge_cancel(r, rs[j])
                n_reductions += 1
            rs[i] = r

        # ---- clearance: commit the whole batch ----
        for i in range(B):
            col_id = int(ids[i])
            if empty[i]:
                essentials.append(float(
                    adapter.birth_value(np.array([col_id], dtype=np.int64))[0]))
                continue
            low = int(rs[i][0])
            mc = int(adapter.min_cobdy(np.array([col_id], dtype=np.int64))[0])
            owner = int(adapter.owner_of_low(np.array([low], dtype=np.int64))[0])
            trivial = (mc == low) and (owner == col_id)
            g = np.array([k for k, p in gens[i].items() if p % 2 == 1],
                         dtype=np.int64)
            store.commit(low, col_id, rs[i], g, trivial)
            b = float(adapter.birth_value(np.array([col_id], dtype=np.int64))[0])
            d = float(adapter.death_value(np.array([low], dtype=np.int64))[0])
            pairs.append((b, d, low))

    pair_arr = np.array([(b, d) for b, d, _ in pairs if d > b],
                        dtype=np.float64).reshape(-1, 2)
    pivot_lows = np.array([low for _, _, low in pairs], dtype=np.int64)
    return ReductionResult(
        pairs=pair_arr,
        essentials=np.array(essentials, dtype=np.float64),
        pivot_lows=pivot_lows,
        stats={
            "n_columns": float(len(queue)),
            "n_reductions": float(n_reductions),
            "n_pairs": float(len(pairs)),
            "n_essential": float(len(essentials)),
            "stored_bytes": float(store.bytes_stored),
            "n_stored_columns": float(len(store.columns)),
            "batch_size": float(batch_size),
        },
    )

"""Serial-parallel batched reduction (Dory §4.4).

Rather than reducing one column at a time, a *batch* of B columns is
processed per round:

* **parallel** phase — every batch column is reduced against the already
  committed ``R^⊥`` (and against trivial owners) independently; this is the
  embarrassingly-parallel part the paper maps to threads and we map to
  vectorized/batched work (and, in ``jax_engine.py``, to the ``data`` mesh
  axis via ``shard_map``).
* **serial** phase — intra-batch pivot collisions are resolved in filtration
  order: a column may only absorb a *marked* (fully reduced) earlier batch
  mate, falling back to the parallel rule whenever its new low re-enters the
  committed table (paper Fig. 14-15 precedence rules).
* **clearance** — all resolved columns commit pivots/pairs at once and the
  batch window slides.

Semantics are identical to the single-column engine (asserted in tests); the
batch size trades parallel width against serial-merge work, matching the
paper's batch-size hyperparameter discussion.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..obs.metrics import MetricsRegistry
from ..obs.trace import span
from .pairing import EMPTY_KEY
from .reduction import (DimensionAdapter, PivotStore, ReductionResult,
                        clearance_commit, clearing_filter, finalize_result,
                        merge_cancel, seed_column, self_owner_of, store_gens)


def _reduce_vs_store(store: PivotStore, adapter: DimensionAdapter,
                     r: np.ndarray, col_id: int,
                     gens: Dict[int, int]) -> Tuple[np.ndarray, int]:
    """Reduce r against committed pivots + trivial owners until its low is
    fresh (the parallel-phase rule).  Returns the partially-reduced r and
    the number of GF(2) column additions performed (the unit every engine
    counts, so cross-engine reductions/sec is comparable)."""
    n_adds = 0
    while r.size:
        low = int(r[0])
        addend = store.lookup_addend(low, col_id)
        if addend is None:
            break
        owner = self_owner_of(store, adapter, low)
        gens[owner] = gens.get(owner, 0) + 1
        for g in store_gens(store, low):
            gens[int(g)] = gens.get(int(g), 0) + 1
        r = merge_cancel(r, addend)
        n_adds += 1
    return r, n_adds


def reduce_dimension_batched(
    adapter: DimensionAdapter,
    column_ids: np.ndarray,
    mode: str = "explicit",
    cleared=None,
    batch_size: int = 128,
    store_budget_bytes: Optional[int] = None,
    seed_gens: Optional[Dict[int, np.ndarray]] = None,
    commit_log: Optional[list] = None,
    essential_log: Optional[list] = None,
) -> ReductionResult:
    """Serial-parallel batched reduction (module docstring).

    ``store_budget_bytes`` bounds the pivot store exactly like the single
    engine's: explicit ``R^⊥`` columns past the budget spill to implicit
    ``V^⊥`` form, largest-explicit-column-first (see :class:`PivotStore`).
    ``seed_gens`` / ``commit_log`` / ``essential_log`` carry the same warm
    restart + capture contract as :func:`~repro.core.reduction
    .reduce_dimension` (seeded columns start from their recorded residual;
    commits and essential expansions are logged for checkpointing).
    """
    store = PivotStore(adapter, mode, store_budget_bytes=store_budget_bytes,
                       commit_log=commit_log)
    pairs: List[tuple] = []
    essentials: List[float] = []
    essential_ids: List[int] = []
    n_reductions = 0
    queue = clearing_filter(column_ids, cleared)

    for s in range(0, len(queue), batch_size):
        ids = queue[s:s + batch_size]
        B = len(ids)
        # ---- materialize coboundaries for the whole batch (vectorized) ----
        cob = adapter.cobdy(ids)
        rs: List[np.ndarray] = [row[row != EMPTY_KEY] for row in cob]
        gens: List[Dict[int, int]] = [dict() for _ in range(B)]
        if seed_gens:
            for i in range(B):
                seed = seed_gens.get(int(ids[i]))
                if seed is not None and len(seed):
                    rs[i] = seed_column(adapter, int(ids[i]), seed)
                    gens[i] = {int(g): 1 for g in seed}
        marked = [False] * B
        empty = [False] * B

        # ---- parallel phase ----
        with span("reduce/parallel", batch=s // batch_size, n=B):
            for i in range(B):
                rs[i], n_adds = _reduce_vs_store(store, adapter, rs[i],
                                                 int(ids[i]), gens[i])
                n_reductions += n_adds

        # ---- serial phase (in filtration order within the batch) ----
        # marked columns are final and hold pairwise-distinct lows, so one
        # low -> batch-index dict replaces the former O(B^2) linear scan
        # for a marked mate with the same low
        marked_low_to_j: Dict[int, int] = {}
        with span("reduce/serial", batch=s // batch_size):
            for i in range(B):
                r = rs[i]
                while True:
                    if r.size == 0:
                        empty[i] = True
                        break
                    low = int(r[0])
                    addend = store.lookup_addend(low, int(ids[i]))
                    if addend is not None:
                        owner = self_owner_of(store, adapter, low)
                        gens[i][owner] = gens[i].get(owner, 0) + 1
                        for g in store_gens(store, low):
                            gens[i][int(g)] = gens[i].get(int(g), 0) + 1
                        r = merge_cancel(r, addend)
                        n_reductions += 1
                        continue
                    j = marked_low_to_j.get(low)
                    if j is None:
                        marked[i] = True
                        marked_low_to_j[low] = i
                        break
                    jid = int(ids[j])
                    gens[i][jid] = gens[i].get(jid, 0) + 1
                    for g, p in gens[j].items():
                        gens[i][g] = gens[i].get(g, 0) + p
                    r = merge_cancel(r, rs[j])
                    n_reductions += 1
                rs[i] = r

        # ---- clearance: commit the whole batch (batched value lookups) ----
        with span("reduce/commit", batch=s // batch_size):
            lows = np.array([int(rs[i][0]) if rs[i].size else -1
                             for i in range(B)], dtype=np.int64)
            clearance_commit(store, adapter, ids, lows, gens,
                             lambda rows: [rs[int(i)] for i in rows],
                             pairs, essentials, essential_ids=essential_ids,
                             essential_log=essential_log)

    return finalize_result(
        pairs, essentials, essential_ids,
        _final_stats(store, queue, pairs, essentials, n_reductions,
                     batch_size))


def _final_stats(store: PivotStore, queue, pairs, essentials,
                 n_reductions: int, batch_size: int) -> Dict[str, float]:
    """Engine stats through the typed registry (schema: repro.obs.metrics)."""
    reg = MetricsRegistry()
    reg.counter("n_columns").inc(len(queue))
    reg.counter("n_reductions").inc(n_reductions)
    reg.counter("n_pairs").inc(len(pairs))
    reg.counter("n_essential").inc(len(essentials))
    reg.gauge("stored_bytes").set(store.bytes_stored)
    reg.gauge("n_stored_columns").set(len(store.columns))
    reg.counter("n_spilled").inc(store.n_spilled)
    reg.gauge("batch_size").set(batch_size)
    return reg.as_stats()

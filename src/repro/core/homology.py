"""Top-level persistent homology API (Dory Algorithm 3: H0, H1*, H2*).

``compute_ph`` is the user-facing entry point: point cloud or distance matrix
in, persistence diagrams out, with the paper's full pipeline — filtration +
neighborhoods, H0 union-find, cohomology reduction of edges (H1*) with
H0-clearing, then cohomology reduction of triangles (H2*) with H1*-clearing;
trivial pairs detected on the fly throughout.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..obs.metrics import MetricsRegistry
from ..obs.trace import span, stopwatch, tracing
from . import coboundary as cb
from .filtration import Filtration, build_filtration
from .h0 import compute_h0
from .pairing import EMPTY_KEY
from .reduction import DimensionAdapter, ReductionResult, reduce_dimension


def make_h1_adapter(filt: Filtration, sparse: bool = True) -> DimensionAdapter:
    """H1*: columns = edge orders; lows = triangle keys."""
    min_cob = cb.min_edge_cobdy_all(filt, sparse=sparse)
    cobdy_fn = cb.edge_cobdy_sparse if sparse else cb.edge_cobdy_ns

    return DimensionAdapter(
        cobdy=lambda ids: cobdy_fn(filt, ids),
        owner_of_low=lambda lows: np.asarray(lows, dtype=np.int64) >> 32,
        min_cobdy=lambda ids: min_cob[np.asarray(ids, dtype=np.int64)],
        birth_value=lambda ids: filt.edge_len[np.asarray(ids, dtype=np.int64)],
        death_value=lambda lows: filt.edge_len[
            np.asarray(lows, dtype=np.int64) >> 32],
    )


def make_h2_adapter(filt: Filtration, sparse: bool = True) -> DimensionAdapter:
    """H2*: columns = triangle keys; lows = tetrahedron keys."""
    cobdy_fn = cb.tri_cobdy_sparse if sparse else cb.tri_cobdy_ns
    min_cache: Dict[int, int] = {}

    def min_cobdy(ids: np.ndarray) -> np.ndarray:
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        missing = [int(t) for t in ids if int(t) not in min_cache]
        if missing:
            keys = cobdy_fn(filt, np.array(missing, dtype=np.int64))
            for t, k in zip(missing, keys[:, 0]):
                min_cache[t] = int(k)
        return np.array([min_cache[int(t)] for t in ids], dtype=np.int64)

    return DimensionAdapter(
        cobdy=lambda ids: cobdy_fn(filt, ids),
        owner_of_low=lambda lows: cb.greatest_boundary_triangle(
            filt, np.asarray(lows, dtype=np.int64)),
        min_cobdy=min_cobdy,
        birth_value=lambda ids: filt.edge_len[
            np.asarray(ids, dtype=np.int64) >> 32],
        death_value=lambda lows: filt.edge_len[
            np.asarray(lows, dtype=np.int64) >> 32],
    )


def h2_columns(filt: Filtration, h1_pivots: np.ndarray,
               sparse: bool = True,
               memory_budget_bytes: Optional[int] = None) -> np.ndarray:
    """Triangle columns for H2* in decreasing F2 order, with clearing.

    Triangles are grouped by diameter edge (descending), ks descending within
    a group — exactly paper Alg. 3 lines 12-15.  Triangles that were H1*
    pivots (deaths) are cleared — one ``np.isin`` per batch rather than a
    per-triangle Python set probe, so column assembly no longer dominates at
    large ``n_e``.

    Candidate enumeration is budget-aware (the first bite at a budgeted
    reduction phase): edges that cannot own a case-1 triangle (an endpoint
    of degree < 2 has no common neighbor) are dropped up front with one
    vectorized degree gather instead of a per-edge neighborhood walk, and
    with ``memory_budget_bytes`` the per-batch enumeration transient is
    capped by sizing the edge batch to the budget rather than the fixed
    2048.  The transient is ``<= batch * max_deg`` *slots*, but each slot
    costs well more than one key: ``case1_triangles_of_edges`` materializes
    three int64 gather arrays plus a bool mask plus the packed keys
    (~40 B/slot budgeted below).  Neither knob changes the output — both
    only bound how much is materialized at once.
    """
    pivots = np.asarray(h1_pivots, dtype=np.int64)
    chunks = []
    edge_ids = np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)
    deg = filt.degree.astype(np.int64)
    can_own = (deg[filt.edges[edge_ids, 0]] > 1) \
        & (deg[filt.edges[edge_ids, 1]] > 1)
    edge_ids = edge_ids[can_own]
    batch = 2048
    if memory_budget_bytes is not None:
        # v/oa/ob int64 gathers (24) + ok mask (1) + packed keys out (8),
        # rounded up — per (edge, neighbor) slot of the enumeration scratch
        per_edge = 40 * max(1, int(filt.max_deg))
        batch = int(np.clip(memory_budget_bytes // per_edge, 64, 2048))
    for s in range(0, len(edge_ids), batch):
        ids = edge_ids[s:s + batch]
        groups = cb.case1_triangles_of_edges(filt, ids, sparse=sparse)
        keys = np.concatenate([g[::-1] for g in groups]) if groups \
            else np.zeros(0, dtype=np.int64)
        if keys.size and pivots.size:
            keys = keys[~np.isin(keys, pivots)]
        if keys.size:
            chunks.append(keys)
    return np.concatenate(chunks) if chunks else np.zeros(0, dtype=np.int64)


@dataclasses.dataclass
class PHResult:
    diagrams: Dict[int, np.ndarray]    # dim -> (k, 2) (birth, death), inf allowed
    stats: Dict[str, float]

    def betti_at(self, tau: float) -> Dict[int, int]:
        out = {}
        for d, pd in self.diagrams.items():
            if pd.size == 0:
                out[d] = 0
            else:
                out[d] = int(((pd[:, 0] <= tau) & (pd[:, 1] > tau)).sum())
        return out


def compute_ph(
    points: Optional[np.ndarray] = None,
    dists: Optional[np.ndarray] = None,
    tau_max: float = np.inf,
    maxdim: int = 2,
    mode: str = "explicit",
    sparse: Optional[bool] = None,
    filtration: Optional[Filtration] = None,
    engine: str = "single",
    batch_size: int = 128,
    backend: str = "dense",
    memory_budget_bytes: Optional[int] = None,
    tile_m: int = 2048,
    tile_n: int = 2048,
    mesh=None,
    n_shards: Optional[int] = None,
    exchange_every: int = 4,
    sanitize: Optional[bool] = None,
    trace=None,
) -> PHResult:
    """Persistent homology up to ``maxdim`` (<= 2), Dory pipeline.

    mode: "explicit" stores R^⊥ (paper Alg. 1 spirit), "implicit" stores only
    V^⊥ (paper Alg. 2 / fast implicit column spirit).
    sparse: neighborhoods (Dory) vs dense order matrix (DoryNS); default picks
    NS for small n where the O(n^2) table is cheap, and always picks the
    order-free sparse path for streamed filtrations (no dense order matrix).
    engine: "single" (1-thread analog), "batch" (serial-parallel, §4.4) or
    "packed" (serial-parallel on bit-packed GF(2) blocks — the
    ``kernels/gf2`` Pallas kernels on TPU, their numpy mirrors on host; same
    diagrams, by far the fastest reduction path).
    backend: "dense" materializes the (n, n) distance matrix (seed behavior);
    "tiled" streams it through ``repro.scale`` in (tile_m, tile_n) blocks —
    peak filtration memory O(tile + n + n_e), the million-point path.
    mesh: with ``backend="tiled"``, a jax mesh with a ``data`` axis shards
    the tile harvest across its devices (``repro.scale.shard``) — output is
    bit-identical to the serial tiled and dense builds for every device
    count, and ``memory_budget_bytes`` is then interpreted *per device*
    (vertex-array duplication + round gather transient included).  With
    ``engine="packed"`` the same mesh additionally distributes the GF(2)
    reduction over its data axis (``repro.core.packed_reduce``), and is
    then legal with any backend or a prebuilt filtration — harvest
    sharding still requires the tiled backend.
    n_shards: host-partitioned distributed reduction for the packed engine
    (the deviceless simulation of an ``n_shards``-device mesh — identical
    work split, batches dealt round-robin, same diagrams); requires
    ``engine="packed"``.  ``exchange_every`` batches the distributed
    pivot-exchange rounds (one wire round per that-many supersteps);
    diagrams are cadence-independent.
    With ``memory_budget_bytes`` and no finite ``tau_max``, the threshold is
    auto-picked so the paper's ``(3n + 12 n_e) * 4`` account fits the
    budget; the same budget also caps the H2* candidate-enumeration
    transient and bounds the reduction store of *every* engine — explicit
    ``R^⊥`` columns spill to implicit ``V^⊥`` storage largest-first once
    the store exceeds it, and the packed engine additionally sizes its bit
    blocks to the budget.
    sanitize: arm the GF(2) sanitizer (:mod:`repro.analyze.invariants`) for
    this call — cheap incremental invariant checks (pivot-low uniqueness,
    packed-segment consistency, wire round-trips, spill re-materialization
    equality) that raise a structured ``SanitizeViolation`` instead of
    returning a silently wrong diagram.  ``None`` (default) defers to the
    ``REPRO_SANITIZE`` environment variable; ``False`` forces it off.
    trace: phase-scoped tracing (:mod:`repro.obs`) for this call — a path
    string exports a Perfetto-loadable Chrome trace there on return (the
    packed distributed path renders its shards as parallel device lanes);
    a :class:`~repro.obs.trace.Tracer` collects without exporting.
    ``None`` (default) defers to the ``REPRO_TRACE`` environment variable
    (a path, accumulated across calls); ``False`` forces it off.  The
    returned ``stats`` are built on the :mod:`repro.obs.metrics` registry
    schema either way, including the byte-account gauges
    (``predicted_account_bytes`` vs the ``observed_peak_*_bytes``
    high-water marks).
    """
    if mesh is not None and engine != "packed" \
            and (filtration is not None or backend != "tiled"):
        raise ValueError("mesh sharding requires backend='tiled' and no "
                         "prebuilt filtration (or engine='packed', which "
                         "distributes the reduction for any backend)")
    if n_shards is not None and engine != "packed":
        raise ValueError("n_shards distributes the reduction and requires "
                         "engine='packed'")
    reg = MetricsRegistry()
    tile_stats = None
    res1 = res2 = None
    diagrams: Dict[int, np.ndarray] = {}

    from ..analyze.invariants import sanitizing

    with tracing(trace), span("ph/compute_ph", engine=engine, mode=mode,
                              maxdim=maxdim):
        with stopwatch("ph/filtration") as sw_filt:
            if filtration is not None:
                filt = filtration
            elif backend == "tiled":
                from ..scale import (build_filtration_sharded,
                                     build_filtration_tiled,
                                     estimate_tau_max, shard_of_mesh)

                harvest_shards = \
                    shard_of_mesh(mesh)[1] if mesh is not None else 1
                if memory_budget_bytes is not None \
                        and not np.isfinite(tau_max):
                    if points is None:
                        raise ValueError("memory_budget_bytes needs points "
                                         "to estimate tau_max")
                    tau_max = estimate_tau_max(points, memory_budget_bytes,
                                               n_shards=harvest_shards,
                                               tile_m=tile_m, tile_n=tile_n)
                    reg.gauge("tau_max_estimated").set(float(tau_max))
                if mesh is not None:
                    filt, tile_stats = build_filtration_sharded(
                        points=points, dists=dists, tau_max=tau_max,
                        tile_m=tile_m, tile_n=tile_n, mesh=mesh,
                        return_stats=True)
                    reg.gauge("n_shards").set(float(tile_stats.n_shards))
                    reg.gauge("per_device_peak_bytes").set(
                        float(tile_stats.per_device_peak_bytes()))
                    reg.gauge("per_device_base_bytes").set(
                        float(tile_stats.per_device_base_bytes()))
                else:
                    filt, tile_stats = build_filtration_tiled(
                        points=points, dists=dists, tau_max=tau_max,
                        tile_m=tile_m, tile_n=tile_n, return_stats=True)
            elif backend == "dense":
                filt = build_filtration(points=points, dists=dists,
                                        tau_max=tau_max)
            else:
                raise ValueError(f"unknown backend {backend!r}")
        reg.gauge("t_filtration").set(sw_filt.elapsed)
        reg.gauge("n").set(float(filt.n))
        reg.gauge("n_e").set(float(filt.n_e))
        reg.gauge("base_memory_bytes").set(float(filt.base_memory_bytes()))
        if sparse is None:
            sparse = (not filt.has_dense_order) or filt.n > 1024
        if engine == "batch":
            from .serial_parallel import reduce_dimension_batched

            def _reduce(adapter, cols, mode=mode, cleared=None):
                return reduce_dimension_batched(
                    adapter, cols, mode=mode, cleared=cleared,
                    batch_size=batch_size,
                    store_budget_bytes=memory_budget_bytes)
        elif engine == "packed":
            from .packed_reduce import reduce_dimension_packed

            def _reduce(adapter, cols, mode=mode, cleared=None):
                # one pivot cache per dimension (created inside the call):
                # H1 and H2 lows live in different key spaces, so a shared
                # cache across dimensions could alias numerically equal keys
                return reduce_dimension_packed(
                    adapter, cols, mode=mode, cleared=cleared,
                    batch_size=batch_size,
                    store_budget_bytes=memory_budget_bytes,
                    n_shards=n_shards, mesh=mesh,
                    exchange_every=exchange_every)
        elif engine == "single":
            def _reduce(adapter, cols, mode=mode, cleared=None):
                return reduce_dimension(adapter, cols, mode=mode,
                                        cleared=cleared,
                                        store_budget_bytes=memory_budget_bytes)
        else:
            raise ValueError(f"unknown engine {engine!r}")

        with sanitizing(sanitize) as san:
            with stopwatch("ph/h0") as sw:
                h0 = compute_h0(filt)
                diagrams[0] = h0.diagram()
            reg.gauge("t_h0").set(sw.elapsed)

            if maxdim >= 1:
                with stopwatch("ph/h1") as sw:
                    if san is not None:
                        san.set_context(dim=1)
                    adapter1 = make_h1_adapter(filt, sparse=sparse)
                    cols1 = np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)
                    res1 = _reduce(adapter1, cols1, mode=mode,
                                   cleared=h0.death_edges)
                    diagrams[1] = res1.diagram()
                reg.gauge("t_h1").set(sw.elapsed)

            if maxdim >= 2:
                with stopwatch("ph/h2") as sw:
                    if san is not None:
                        san.set_context(dim=2)
                    adapter2 = make_h2_adapter(filt, sparse=sparse)
                    cols2 = h2_columns(filt, res1.pivot_lows, sparse=sparse,
                                       memory_budget_bytes=memory_budget_bytes)
                    res2 = _reduce(adapter2, cols2, mode=mode)
                    diagrams[2] = res2.diagram()
                reg.gauge("t_h2").set(sw.elapsed)
            if san is not None:
                reg.counter("sanitize_checks").inc(sum(san.counts.values()))
                san.set_context(dim=None)

        # memory observability: the observed harvest/reduction high-water
        # marks next to the predicted (3n + 12 n_e) * 4 account, so
        # budget-model drift is a measurable, testable quantity
        from ..scale.budget import account_bytes

        predicted = float(account_bytes(filt.n, filt.n_e))
        reg.gauge("predicted_account_bytes").set(predicted)
        obs_harvest = 0.0
        if tile_stats is not None:
            obs_harvest = float(tile_stats.peak_extra_bytes())
            reg.gauge("observed_peak_harvest_bytes").record_max(obs_harvest)
        obs_reduce = 0.0
        for res in (res1, res2):
            if res is not None:
                obs_reduce = max(
                    obs_reduce,
                    res.stats.get("stored_bytes", 0.0)
                    + res.stats.get("peak_block_bytes", 0.0))
        reg.gauge("observed_peak_reduce_bytes").record_max(obs_reduce)
        base = float(filt.base_memory_bytes())
        reg.gauge("budget_drift_ratio").set(
            (base + max(obs_harvest, obs_reduce)) / max(predicted, 1.0))

    stats: Dict[str, float] = reg.as_stats()
    for prefix, res in (("h1", res1), ("h2", res2)):
        if res is not None:
            for k, v in res.stats.items():
                stats[f"{prefix}_{k}"] = v
    return PHResult(diagrams=diagrams, stats=stats)

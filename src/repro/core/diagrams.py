"""Persistence-diagram utilities: comparison, summaries, TDA features."""
from __future__ import annotations

from typing import Dict

import numpy as np


def canonicalize(pd: np.ndarray, drop_zero: bool = True) -> np.ndarray:
    """Sort a PD (k,2) lexicographically; optionally drop zero-persistence."""
    pd = np.asarray(pd, dtype=np.float64).reshape(-1, 2)
    if drop_zero and pd.size:
        pd = pd[pd[:, 1] > pd[:, 0]]
    if pd.size == 0:
        return pd.reshape(0, 2)
    idx = np.lexsort((pd[:, 1], pd[:, 0]))
    return pd[idx]


def diagrams_equal(pd_a: np.ndarray, pd_b: np.ndarray,
                   atol: float = 1e-9) -> bool:
    """Multiset equality of two diagrams up to tolerance (inf-aware)."""
    a, b = canonicalize(pd_a), canonicalize(pd_b)
    if a.shape != b.shape:
        return False
    if a.size == 0:
        return True
    finite = np.isfinite(a) & np.isfinite(b)
    if not np.array_equal(np.isfinite(a), np.isfinite(b)):
        return False
    return bool(np.allclose(a[finite], b[finite], atol=atol, rtol=0))


def assert_diagrams_equal(pds_a: Dict[int, np.ndarray],
                          pds_b: Dict[int, np.ndarray],
                          dims=None, atol: float = 1e-9) -> None:
    dims = dims if dims is not None else sorted(set(pds_a) & set(pds_b))
    for d in dims:
        a, b = canonicalize(pds_a[d]), canonicalize(pds_b[d])
        if not diagrams_equal(a, b, atol=atol):
            raise AssertionError(
                f"H{d} diagrams differ:\nA ({a.shape[0]} pts):\n{a}\n"
                f"B ({b.shape[0]} pts):\n{b}")


def betti_curve(pd: np.ndarray, taus: np.ndarray) -> np.ndarray:
    """Betti number as a function of scale (vectorized)."""
    pd = np.asarray(pd, dtype=np.float64).reshape(-1, 2)
    if pd.size == 0:
        return np.zeros_like(taus, dtype=np.int64)
    alive = (pd[:, 0][None, :] <= taus[:, None]) & (pd[:, 1][None, :] > taus[:, None])
    return alive.sum(axis=1)


def total_persistence(pd: np.ndarray, tau_cap: float = np.inf) -> float:
    """Sum of (death - birth), with inf deaths capped at ``tau_cap``."""
    pd = canonicalize(pd)
    if pd.size == 0:
        return 0.0
    death = np.minimum(pd[:, 1], tau_cap)
    return float(np.clip(death - pd[:, 0], 0, None).sum())


def summary(pd: np.ndarray, tau_cap: float = np.inf) -> Dict[str, float]:
    pd = canonicalize(pd)
    n_inf = int(np.isinf(pd[:, 1]).sum()) if pd.size else 0
    return {
        "count": float(pd.shape[0]),
        "n_essential": float(n_inf),
        "total_persistence": total_persistence(pd, tau_cap),
        "max_persistence": float(
            np.max(np.minimum(pd[:, 1], tau_cap) - pd[:, 0])) if pd.size else 0.0,
    }


def persistence_image(pd: np.ndarray, resolution: int = 16,
                      sigma: float = 0.1, tau_cap: float = 1.0) -> np.ndarray:
    """Pixelated PD embedding (PI-Net-style target; used by the TDA monitor)."""
    pd = canonicalize(pd)
    img = np.zeros((resolution, resolution), dtype=np.float64)
    if pd.size == 0:
        return img
    birth = np.clip(pd[:, 0], 0, tau_cap)
    pers = np.clip(np.minimum(pd[:, 1], tau_cap) - pd[:, 0], 0, tau_cap)
    xs = np.linspace(0, tau_cap, resolution)
    gx = np.exp(-0.5 * ((xs[None, :] - birth[:, None]) / sigma) ** 2)
    gy = np.exp(-0.5 * ((xs[None, :] - pers[:, None]) / sigma) ** 2)
    img = np.einsum("ki,kj->ij", gy * pers[:, None], gx)
    return img / max(img.max(), 1e-12)

"""Vietoris-Rips filtration construction (Dory §4: ``F_0``, ``F_1``, neighborhoods).

The filtration for 1-simplices, ``F_1``, is the list of permissible edges
(``d(x, y) <= tau_max``) sorted by length (ties broken lexicographically so
every edge has a unique order — a valid refinement of the VR filtration, which
leaves persistence diagrams invariant).

Two neighbor representations are built, mirroring the paper's two code paths:

* **sparse** (Dory): per-vertex *vertex-neighborhoods* ``N^a`` (sorted by
  neighbor id) and *edge-neighborhoods* ``E^a`` (sorted by edge order), as
  padded rectangular arrays — ``O(n + n_e)`` memory, the paper's
  ``(3n + 12 n_e) * 4`` bytes base-memory account is reproduced in
  :meth:`Filtration.base_memory_bytes`.
* **non-sparse** (DoryNS): a dense ``(n, n)`` int32 order matrix — ``O(n^2)``
  memory, replacing binary searches with array access.
"""
from __future__ import annotations

import dataclasses
import numpy as np

NO_EDGE = np.int32(-1)


def pairwise_distances(points: np.ndarray) -> np.ndarray:
    """Dense Euclidean distance matrix (host/numpy path; see kernels/ for TPU)."""
    points = np.asarray(points, dtype=np.float64)
    sq = np.sum(points * points, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (points @ points.T)
    np.maximum(d2, 0.0, out=d2)
    np.fill_diagonal(d2, 0.0)
    return np.sqrt(d2)


@dataclasses.dataclass
class Filtration:
    """Immutable VR filtration state shared by all reduction engines."""

    n: int                      # number of vertices
    n_e: int                    # number of permissible edges
    edges: np.ndarray           # (n_e, 2) int32, edges[o] = (a, b), a < b, o = f_1 order
    edge_len: np.ndarray        # (n_e,) float64 lengths, nondecreasing
    tau_max: float

    # non-sparse (DoryNS) structure: dense order matrix, -1 where no edge.
    order: np.ndarray           # (n, n) int32

    # sparse (Dory) structure: padded neighborhoods.
    degree: np.ndarray          # (n,) int32
    max_deg: int
    nbr_vtx: np.ndarray         # (n, max_deg) int32 neighbor ids sorted ascending; pad = n
    nbr_vtx_ord: np.ndarray     # (n, max_deg) int32 edge order for nbr_vtx; pad = -1
    nbr_edge_ord: np.ndarray    # (n, max_deg) int32 edge orders sorted ascending; pad = 2**31-1
    nbr_edge_vtx: np.ndarray    # (n, max_deg) int32 neighbor for nbr_edge_ord; pad = n

    def base_memory_bytes(self) -> int:
        """Paper appendix E: base memory = ``(3n + 12 n_e) * 4`` bytes."""
        return (3 * self.n + 12 * self.n_e) * 4

    def edge_order_of(self, a: int, b: int) -> int:
        return int(self.order[a, b])

    def diam_value(self, key_primary) -> np.ndarray:
        """Filtration value (length of diameter edge) for primary key(s)."""
        return self.edge_len[np.asarray(key_primary, dtype=np.int64)]


def build_filtration(
    points: np.ndarray | None = None,
    dists: np.ndarray | None = None,
    tau_max: float = np.inf,
) -> Filtration:
    """Build ``F_1`` + neighborhoods from a point cloud or a distance matrix."""
    if dists is None:
        if points is None:
            raise ValueError("provide points or dists")
        dists = pairwise_distances(points)
    dists = np.asarray(dists, dtype=np.float64)
    n = dists.shape[0]
    if dists.shape != (n, n):
        raise ValueError(f"dists must be square, got {dists.shape}")

    iu, ju = np.triu_indices(n, k=1)
    lens = dists[iu, ju]
    keep = lens <= tau_max
    iu, ju, lens = iu[keep], ju[keep], lens[keep]
    # Unique, deterministic edge order: (length, i, j) lexicographic.
    sort_idx = np.lexsort((ju, iu, lens))
    iu, ju, lens = iu[sort_idx], ju[sort_idx], lens[sort_idx]
    n_e = int(lens.shape[0])
    edges = np.stack([iu, ju], axis=1).astype(np.int32)

    order = np.full((n, n), NO_EDGE, dtype=np.int32)
    o = np.arange(n_e, dtype=np.int32)
    order[iu, ju] = o
    order[ju, iu] = o

    degree = np.zeros(n, dtype=np.int32)
    np.add.at(degree, iu, 1)
    np.add.at(degree, ju, 1)
    max_deg = int(degree.max()) if n_e else 1
    max_deg = max(max_deg, 1)

    nbr_vtx = np.full((n, max_deg), n, dtype=np.int32)
    nbr_vtx_ord = np.full((n, max_deg), NO_EDGE, dtype=np.int32)
    nbr_edge_ord = np.full((n, max_deg), np.iinfo(np.int32).max, dtype=np.int32)
    nbr_edge_vtx = np.full((n, max_deg), n, dtype=np.int32)

    # Build per-vertex lists: each edge contributes to both endpoints.
    src = np.concatenate([iu, ju])
    dst = np.concatenate([ju, iu])
    eo = np.concatenate([o, o])
    # N^a: sorted by neighbor id.
    key = src.astype(np.int64) * (n + 1) + dst
    srt = np.argsort(key, kind="stable")
    s_src, s_dst, s_eo = src[srt], dst[srt], eo[srt]
    slot = _running_slot(s_src, n)
    nbr_vtx[s_src, slot] = s_dst
    nbr_vtx_ord[s_src, slot] = s_eo
    # E^a: sorted by edge order.
    key = src.astype(np.int64) * (n_e + 1) + eo
    srt = np.argsort(key, kind="stable")
    s_src, s_dst, s_eo = src[srt], dst[srt], eo[srt]
    slot = _running_slot(s_src, n)
    nbr_edge_ord[s_src, slot] = s_eo
    nbr_edge_vtx[s_src, slot] = s_dst

    return Filtration(
        n=n, n_e=n_e, edges=edges, edge_len=lens, tau_max=float(tau_max),
        order=order, degree=degree, max_deg=max_deg,
        nbr_vtx=nbr_vtx, nbr_vtx_ord=nbr_vtx_ord,
        nbr_edge_ord=nbr_edge_ord, nbr_edge_vtx=nbr_edge_vtx,
    )


def _running_slot(sorted_ids: np.ndarray, n: int) -> np.ndarray:
    """Position of each element within its (already grouped) id run."""
    if sorted_ids.size == 0:
        return np.zeros(0, dtype=np.int64)
    counts = np.bincount(sorted_ids, minlength=n)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(sorted_ids.size) - starts[sorted_ids]

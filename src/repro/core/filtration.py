"""Vietoris-Rips filtration construction (Dory §4: ``F_0``, ``F_1``, neighborhoods).

The filtration for 1-simplices, ``F_1``, is the list of permissible edges
(``d(x, y) <= tau_max``) sorted by length (ties broken lexicographically so
every edge has a unique order — a valid refinement of the VR filtration, which
leaves persistence diagrams invariant).

Two neighbor representations are built, mirroring the paper's two code paths:

* **sparse** (Dory): per-vertex *vertex-neighborhoods* ``N^a`` (sorted by
  neighbor id) and *edge-neighborhoods* ``E^a`` (sorted by edge order), as
  padded rectangular arrays — ``O(n + n_e)`` memory, the paper's
  ``(3n + 12 n_e) * 4`` bytes base-memory account is reproduced in
  :meth:`Filtration.base_memory_bytes`.
* **non-sparse** (DoryNS): a dense ``(n, n)`` int32 order matrix — ``O(n^2)``
  memory, replacing binary searches with array access.  The matrix is now
  *lazy*: sparse-only pipelines (``repro.scale`` streaming builds) carry
  ``dense_order=None`` and never pay the ``O(n^2)`` allocation; touching
  :attr:`Filtration.order` materializes it on demand.

Distance arithmetic is deliberately BLAS-free for the cross term: matmul
kernels pick different accumulation orders per operand shape, so ``X @ Y.T``
is not bit-reproducible across tilings.  ``cross_term`` accumulates over the
feature axis in fixed ascending order, which makes every blocked / tiled /
per-pair distance path in this repo produce identical bits for identical
pairs — the invariant ``repro.scale`` relies on to be a drop-in replacement.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

NO_EDGE = np.int32(-1)


def cross_term(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """``sum_k x[i, k] * y[j, k]`` with fixed ascending-k accumulation.

    Bit-identical for a given pair (i, j) regardless of how rows are blocked
    into tiles (BLAS matmul is not — its kernel choice depends on shape).
    """
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    acc = np.zeros((x.shape[0], y.shape[0]))
    for k in range(x.shape[1]):
        acc += x[:, k, None] * y[None, :, k]
    return acc


def pair_sq_dists(points: np.ndarray, iu: np.ndarray, ju: np.ndarray,
                  sq: Optional[np.ndarray] = None) -> np.ndarray:
    """Clamped squared distances for an explicit pair list (i, j).

    Same scalar operation sequence per pair as :func:`block_sq_dists`, so the
    result is bit-identical to the corresponding tile/matrix entries.
    """
    points = np.asarray(points, dtype=np.float64)
    if sq is None:
        sq = np.sum(points * points, axis=1)
    acc = np.zeros(len(iu))
    for k in range(points.shape[1]):
        acc += points[iu, k] * points[ju, k]
    d2 = sq[iu] + sq[ju] - 2.0 * acc
    np.maximum(d2, 0.0, out=d2)
    return d2


def block_sq_dists(x: np.ndarray, y: np.ndarray,
                   sq_x: Optional[np.ndarray] = None,
                   sq_y: Optional[np.ndarray] = None) -> np.ndarray:
    """Clamped squared distances between two row blocks (canonical form).

    ``sq_*`` are the precomputed row squared-norms (``np.sum(p * p, axis=1)``
    of the *full* array, sliced — per-row reductions are slice-invariant).
    """
    if sq_x is None:
        sq_x = np.sum(x * x, axis=1)
    if sq_y is None:
        sq_y = np.sum(y * y, axis=1)
    d2 = sq_x[:, None] + sq_y[None, :] - 2.0 * cross_term(x, y)
    np.maximum(d2, 0.0, out=d2)
    return d2


def pairwise_distances(points: np.ndarray, block_rows: int = 1024) -> np.ndarray:
    """Dense Euclidean distance matrix (host/numpy path; see kernels/ for TPU).

    Computed in row blocks so peak scratch is ``O(block_rows * n)`` on top of
    the ``(n, n)`` output — no second full-matrix temporary — and clamped at 0
    before the sqrt (the Gram form ``|x|^2 + |y|^2 - 2 x.y`` cancels
    catastrophically for near-duplicate points).
    """
    points = np.asarray(points, dtype=np.float64)
    n = points.shape[0]
    sq = np.sum(points * points, axis=1)
    out = np.empty((n, n))
    for s in range(0, n, block_rows):
        e = min(s + block_rows, n)
        d2 = block_sq_dists(points[s:e], points, sq[s:e], sq)
        out[s:e] = np.sqrt(d2, out=d2)
    np.fill_diagonal(out, 0.0)
    return out


@dataclasses.dataclass
class Filtration:
    """Immutable VR filtration state shared by all reduction engines."""

    n: int                      # number of vertices
    n_e: int                    # number of permissible edges
    edges: np.ndarray           # (n_e, 2) int32, edges[o] = (a, b), a < b, o = f_1 order
    edge_len: np.ndarray        # (n_e,) float64 lengths, nondecreasing
    tau_max: float

    # sparse (Dory) structure: padded neighborhoods.
    degree: np.ndarray          # (n,) int32
    max_deg: int
    nbr_vtx: np.ndarray         # (n, max_deg) int32 neighbor ids sorted ascending; pad = n
    nbr_vtx_ord: np.ndarray     # (n, max_deg) int32 edge order for nbr_vtx; pad = -1
    nbr_edge_ord: np.ndarray    # (n, max_deg) int32 edge orders sorted ascending; pad = 2**31-1
    nbr_edge_vtx: np.ndarray    # (n, max_deg) int32 neighbor for nbr_edge_ord; pad = n

    # non-sparse (DoryNS) structure: dense order matrix, -1 where no edge.
    # None for streamed builds (repro.scale); materialized lazily on access.
    dense_order: Optional[np.ndarray] = None    # (n, n) int32 or None

    @property
    def has_dense_order(self) -> bool:
        """True iff the O(n^2) order matrix is already materialized."""
        return self.dense_order is not None

    @property
    def order(self) -> np.ndarray:
        """Dense (n, n) order matrix; built on first access if absent."""
        if self.dense_order is None:
            self.dense_order = dense_order_matrix(self.n, self.edges)
        return self.dense_order

    def base_memory_bytes(self) -> int:
        """Paper appendix E: base memory = ``(3n + 12 n_e) * 4`` bytes."""
        return (3 * self.n + 12 * self.n_e) * 4

    def edge_order_of(self, a: int, b: int) -> int:
        return int(self.order[a, b])

    def diam_value(self, key_primary) -> np.ndarray:
        """Filtration value (length of diameter edge) for primary key(s)."""
        return self.edge_len[np.asarray(key_primary, dtype=np.int64)]


def dense_order_matrix(n: int, edges: np.ndarray) -> np.ndarray:
    """(n, n) int32 edge-order lookup table (DoryNS), -1 where no edge."""
    order = np.full((n, n), NO_EDGE, dtype=np.int32)
    iu = edges[:, 0].astype(np.int64)
    ju = edges[:, 1].astype(np.int64)
    o = np.arange(len(edges), dtype=np.int32)
    order[iu, ju] = o
    order[ju, iu] = o
    return order


def build_filtration(
    points: np.ndarray | None = None,
    dists: np.ndarray | None = None,
    tau_max: float = np.inf,
) -> Filtration:
    """Build ``F_1`` + neighborhoods from a point cloud or a distance matrix."""
    if dists is None:
        if points is None:
            raise ValueError("provide points or dists")
        dists = pairwise_distances(points)
    dists = np.asarray(dists, dtype=np.float64)
    n = dists.shape[0]
    if dists.shape != (n, n):
        raise ValueError(f"dists must be square, got {dists.shape}")

    iu, ju = np.triu_indices(n, k=1)
    lens = dists[iu, ju]
    keep = lens <= tau_max
    iu, ju, lens = iu[keep], ju[keep], lens[keep]
    return filtration_from_edges(n, iu, ju, lens, tau_max,
                                 with_dense_order=True)


def filtration_from_edges(
    n: int,
    iu: np.ndarray,
    ju: np.ndarray,
    lens: np.ndarray,
    tau_max: float,
    presorted: bool = False,
    with_dense_order: bool = False,
) -> Filtration:
    """Assemble a :class:`Filtration` from a COO edge list (i < j required).

    The shared back half of every builder — dense (``build_filtration``),
    tiled/streamed and sparse-input (``repro.scale``).  Sorts edges into the
    canonical unique order ``(length, i, j)`` lexicographic unless
    ``presorted``; neighborhoods are built with ``O(n + n_e)`` memory.  The
    dense order matrix is only allocated when ``with_dense_order`` (the
    DoryNS path); otherwise it stays lazy (``dense_order=None``).
    """
    iu = np.asarray(iu, dtype=np.int64)
    ju = np.asarray(ju, dtype=np.int64)
    lens = np.asarray(lens, dtype=np.float64)
    if not presorted:
        # Unique, deterministic edge order: (length, i, j) lexicographic.
        sort_idx = np.lexsort((ju, iu, lens))
        iu, ju, lens = iu[sort_idx], ju[sort_idx], lens[sort_idx]
    n_e = int(lens.shape[0])
    edges = np.stack([iu, ju], axis=1).astype(np.int32)
    o = np.arange(n_e, dtype=np.int32)

    degree = np.zeros(n, dtype=np.int32)
    np.add.at(degree, iu, 1)
    np.add.at(degree, ju, 1)
    max_deg = int(degree.max()) if n_e else 1
    max_deg = max(max_deg, 1)

    nbr_vtx = np.full((n, max_deg), n, dtype=np.int32)
    nbr_vtx_ord = np.full((n, max_deg), NO_EDGE, dtype=np.int32)
    nbr_edge_ord = np.full((n, max_deg), np.iinfo(np.int32).max, dtype=np.int32)
    nbr_edge_vtx = np.full((n, max_deg), n, dtype=np.int32)

    # Build per-vertex lists: each edge contributes to both endpoints.
    src = np.concatenate([iu, ju])
    dst = np.concatenate([ju, iu])
    eo = np.concatenate([o, o])
    # N^a: sorted by neighbor id.
    key = src.astype(np.int64) * (n + 1) + dst
    srt = np.argsort(key, kind="stable")
    s_src, s_dst, s_eo = src[srt], dst[srt], eo[srt]
    slot = _running_slot(s_src, n)
    nbr_vtx[s_src, slot] = s_dst
    nbr_vtx_ord[s_src, slot] = s_eo
    # E^a: sorted by edge order.
    key = src.astype(np.int64) * (n_e + 1) + eo
    srt = np.argsort(key, kind="stable")
    s_src, s_dst, s_eo = src[srt], dst[srt], eo[srt]
    slot = _running_slot(s_src, n)
    nbr_edge_ord[s_src, slot] = s_eo
    nbr_edge_vtx[s_src, slot] = s_dst

    return Filtration(
        n=n, n_e=n_e, edges=edges, edge_len=lens, tau_max=float(tau_max),
        degree=degree, max_deg=max_deg,
        nbr_vtx=nbr_vtx, nbr_vtx_ord=nbr_vtx_ord,
        nbr_edge_ord=nbr_edge_ord, nbr_edge_vtx=nbr_edge_vtx,
        dense_order=dense_order_matrix(n, edges) if with_dense_order else None,
    )


def _running_slot(sorted_ids: np.ndarray, n: int) -> np.ndarray:
    """Position of each element within its (already grouped) id run."""
    if sorted_ids.size == 0:
        return np.zeros(0, dtype=np.int64)
    counts = np.bincount(sorted_ids, minlength=n)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.arange(sorted_ids.size) - starts[sorted_ids]

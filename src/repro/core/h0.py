"""H0 persistence via Kruskal/union-find over the edge filtration.

The paper computes H0 by (serial-parallel) boundary reduction of edges in
ascending order; for a VR filtration this is exactly minimum-spanning-forest
construction: an edge either merges two components (an H0 *death*: pair
``(0, len(e))``) or closes a cycle (an H1 *birth* candidate).  The set of
merge edges is what the clearing step of Algorithm 3 consumes
("if e is in a persistence pair in H0: continue").

Union-find with path halving + union by size — O(n_e α(n)) on the host.  A
Boruvka-style label-propagation variant (JAX, log-depth, TPU-friendly) lives
in ``jax_engine.py`` and is cross-validated in tests.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from .filtration import Filtration


@dataclasses.dataclass
class H0Result:
    pairs: np.ndarray        # (k, 2) float64: (0, death)
    n_essential: int         # number of components never merged (death = inf)
    death_edges: np.ndarray  # (k,) int64 edge orders that killed a component

    def diagram(self) -> np.ndarray:
        ess = np.full((self.n_essential, 2), [0.0, np.inf])
        return np.concatenate([self.pairs, ess], axis=0)


def compute_h0(filt: Filtration) -> H0Result:
    n = filt.n
    parent = np.arange(n, dtype=np.int64)

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:        # path compression
            parent[x], x = root, parent[x]
        return root

    deaths = []
    death_edges = []
    for o in range(filt.n_e):
        a, b = filt.edges[o]
        ra, rb = find(int(a)), find(int(b))
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)
            deaths.append(filt.edge_len[o])
            death_edges.append(o)
    pairs = np.stack([np.zeros(len(deaths)), np.array(deaths, dtype=np.float64)],
                     axis=1) if deaths else np.zeros((0, 2))
    pairs = pairs[pairs[:, 1] > 0.0] if pairs.size else pairs  # drop 0-persistence
    n_essential = n - len(deaths)
    return H0Result(
        pairs=pairs.reshape(-1, 2),
        n_essential=int(n_essential),
        death_edges=np.array(death_edges, dtype=np.int64),
    )

"""Shared packed pivot cache: memoization + replication unit for reduction.

The packed engine (:mod:`repro.core.packed_reduce`) re-derives the same
per-pivot work once per *consuming batch*: every batch that probes a
committed pivot re-searches its keys into the batch's packed universe
(``_PackedBatch._abs_positions``), and in implicit mode re-materializes the
pivot's R column from its V generators (``parity_reduce`` over a fresh
coboundary enumeration).  Profiling the fractal n=64 / maxdim=2 workload
puts those two re-packs at ~0.6s of a 1.7s reduction.  This cache is the
single shared home for both memoizations, and doubles as the replication
unit of the distributed engine:

* **position memo** — packed bit positions of a pivot's keys inside the
  *current* block universe, keyed by pivot low and invalidated whenever the
  block's segment layout changes (``consolidate`` / ``add_segment`` bump an
  epoch).  In the fused-superstep distributed driver all P device slices
  share one block, so one pack serves every slice that consumes the pivot
  that superstep.
* **materialization memo** — the pivot's canonical sorted R keys, keyed by
  low, budget-bounded with FIFO eviction.  R columns are canonical (the
  reduced column at a given low is unique over GF(2)), so caching them can
  never perturb bit-identity.  This is what drives the implicit-mode
  re-materialization count down to 1 per pivot (``n_materializations`` vs
  ``n_mat_hits`` in the bench counters).
* **replication codec** — ``encode_commit_delta``/``decode_commit_delta``
  turn a superstep's freshly committed pivots into one flat uint32 wire
  payload (Elias–Fano compressed, :mod:`repro.dist.compression`) and back.
  The distributed driver's *concurrent* phase reads pivots only through a
  replica installed from decoded payloads, so the codec is load-bearing for
  the bit-identity tests — a corrupt wire format changes diagrams, it does
  not hide.
"""
from __future__ import annotations

import zlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..analyze.invariants import active_sanitizer
from ..obs.metrics import MetricsRegistry
from ..resilience.faults import WireCorruption

__all__ = ["PackedPivotCache", "encode_commit_delta", "decode_commit_delta",
           "verify_commit_delta"]

_MODE_CODE = {"explicit": 0, "implicit": 1}
_CODE_MODE = {0: "explicit", 1: "implicit"}
_DELTA_MAGIC = np.uint32(0xD0F2)


class PackedPivotCache:
    """Per-reduction shared cache (one instance per ``reduce_dimension_packed``
    call, or one shared across dimensions when the caller threads it)."""

    def __init__(self, budget_bytes: Optional[int] = None):
        # materialization memo: low -> canonical sorted int64 R keys
        self._columns: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._col_bytes = 0
        self.budget_bytes = budget_bytes
        # position memo: low -> int64 absolute bit positions in the live
        # block universe; valid only for the current epoch
        self._positions: Dict[int, np.ndarray] = {}
        self._epoch = 0
        # counters (surfaced by reduce_bench.py)
        self.n_packs = 0          # position computations performed
        self.n_pack_hits = 0      # position lookups served from the memo
        self.n_materializations = 0   # R columns enumerated from gens
        self.n_mat_hits = 0           # R columns served from the memo
        self.n_col_evictions = 0

    # -- position memo ------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self._epoch

    def bump_epoch(self) -> int:
        """Invalidate all packed positions (block segment layout changed)."""
        self._epoch += 1
        self._positions.clear()
        return self._epoch

    def get_positions(self, low: int) -> Optional[np.ndarray]:
        pos = self._positions.get(low)
        if pos is not None:
            self.n_pack_hits += 1
        return pos

    def put_positions(self, low: int, pos: np.ndarray) -> None:
        """Record fully-resolved positions (caller guarantees no key was
        missing from the universe — partial resolutions must not be cached
        because a later ``add_segment`` could make stale misses ambiguous)."""
        self.n_packs += 1
        self._positions[low] = pos

    # -- materialization memo -----------------------------------------------

    def get_column(self, low: int) -> Optional[np.ndarray]:
        keys = self._columns.get(low)
        if keys is not None:
            self.n_mat_hits += 1
        return keys

    def put_column(self, low: int, keys: np.ndarray) -> None:
        self.n_materializations += 1
        if low in self._columns:
            return
        keys = np.ascontiguousarray(keys, dtype=np.int64)
        san = active_sanitizer()
        if san is not None:
            # memoized R columns must be canonical (strictly increasing):
            # the cache serves every later consumer of this low verbatim
            san.check_canonical_column(keys)
        self._columns[low] = keys
        self._col_bytes += keys.nbytes
        if self.budget_bytes is not None:
            while self._col_bytes > self.budget_bytes and len(self._columns) > 1:
                _, old = self._columns.popitem(last=False)
                self._col_bytes -= old.nbytes
                self.n_col_evictions += 1

    def drop_column(self, low: int) -> None:
        old = self._columns.pop(low, None)
        if old is not None:
            self._col_bytes -= old.nbytes

    # -- introspection -------------------------------------------------------

    @property
    def column_bytes(self) -> int:
        return self._col_bytes

    def stats(self) -> Dict[str, float]:
        """Cache counters through the typed registry (repro.obs.metrics),
        so the emitted keys stay schema-checked."""
        reg = MetricsRegistry()
        reg.counter("cache_n_packs").inc(self.n_packs)
        reg.counter("cache_n_pack_hits").inc(self.n_pack_hits)
        reg.counter("cache_n_materializations").inc(self.n_materializations)
        reg.counter("cache_n_mat_hits").inc(self.n_mat_hits)
        reg.counter("cache_n_col_evictions").inc(self.n_col_evictions)
        reg.gauge("cache_column_bytes").set(self._col_bytes)
        return reg.as_stats()


# ---------------------------------------------------------------------------
# Replication codec: superstep commit records <-> one uint32 wire payload
# ---------------------------------------------------------------------------

def encode_commit_delta(records: Sequence[dict]) -> np.ndarray:
    """Encode committed-pivot records for the pivot-exchange round.

    Each record: ``{"low": int, "col_id": int, "mode": "explicit"|"implicit",
    "column": sorted int64 keys or None, "gens": int64 ids}``.  Explicit
    records ship their R column; implicit records ship their V generators
    (sorted for transport — generator *sets* are what parity reduction
    consumes, order is representational only).  The R columns and generator
    lists ride one fused :func:`~repro.dist.compression.pack_column_payload`
    batch (columns first, gens second) so a delta costs a constant number
    of Elias–Fano passes however many pivots it carries.  Lossless by
    construction: the bit-identity suite round-trips diagrams through this
    wire format.
    """
    from ..dist.compression import pack_column_payload

    n = len(records)
    lows = np.array([r["low"] for r in records], dtype=np.int64)
    ids = np.array([r["col_id"] for r in records], dtype=np.int64)
    modes = np.array([_MODE_CODE[r["mode"]] for r in records],
                     dtype=np.uint32)
    empty = np.zeros(0, dtype=np.int64)
    cols, gens = [], []
    for r in records:
        c = r.get("column")
        cols.append(empty if c is None
                    else np.ascontiguousarray(c, dtype=np.int64))
        g = r.get("gens")
        gens.append(empty if g is None
                    else np.sort(np.ascontiguousarray(g, dtype=np.int64)))
    body = pack_column_payload(cols + gens)
    tail = np.concatenate([
        lows.view(np.uint32) if n else np.zeros(0, dtype=np.uint32),
        ids.view(np.uint32) if n else np.zeros(0, dtype=np.uint32),
        modes,
        body,
    ])
    # header slot 3: CRC32 over the other header words AND the tail — the
    # length fields must be covered too, or a flipped bit in `n` passes
    # the check and mis-slices the decode
    head = np.array([_DELTA_MAGIC, n, body.size], dtype=np.uint32)
    crc = np.uint32(zlib.crc32(head.tobytes() + tail.tobytes())
                    & 0xFFFFFFFF)
    header = np.array([_DELTA_MAGIC, n, body.size, crc], dtype=np.uint32)
    payload = np.concatenate([header, tail])
    san = active_sanitizer()
    if san is not None:
        # the replica installs exactly what decodes: check the round-trip
        # before the payload crosses the wire
        san.check_wire_roundtrip(records, payload, decode_commit_delta)
    return payload


def verify_commit_delta(payload: np.ndarray) -> bool:
    """Cheap receiver-side integrity check: header magic + CRC32 of the
    payload tail against header slot 3.  ``True`` iff the payload would
    decode to the records that produced it."""
    w = np.ascontiguousarray(payload, dtype=np.uint32)
    if w.size < 4 or w[0] != _DELTA_MAGIC:
        return False
    crc = np.uint32(zlib.crc32(w[:3].tobytes() + w[4:].tobytes())
                    & 0xFFFFFFFF)
    return bool(crc == w[3])


def decode_commit_delta(payload: np.ndarray) -> List[dict]:
    """Inverse of :func:`encode_commit_delta`.

    Raises :class:`~repro.resilience.faults.WireCorruption` (a
    ``ValueError``) on a bad magic word or checksum mismatch — a corrupt
    exchange payload is *rejected for retransmission*, never installed
    into a replica store."""
    from ..dist.compression import unpack_column_payload

    w = np.ascontiguousarray(payload, dtype=np.uint32)
    if w.size < 4 or w[0] != _DELTA_MAGIC:
        raise WireCorruption("not a commit-delta payload")
    if not verify_commit_delta(w):
        raise WireCorruption("commit-delta checksum mismatch")
    n = int(w[1])
    body_len = int(w[2])
    off = 4
    lows = w[off:off + 2 * n].view(np.int64); off += 2 * n
    ids = w[off:off + 2 * n].view(np.int64); off += 2 * n
    modes = w[off:off + n]; off += n
    both = unpack_column_payload(w[off:off + body_len])
    cols, gens = both[:n], both[n:]
    out = []
    for i in range(n):
        mode = _CODE_MODE[int(modes[i])]
        out.append({
            "low": int(lows[i]), "col_id": int(ids[i]), "mode": mode,
            "column": cols[i] if mode == "explicit" else None,
            "gens": gens[i],
        })
    return out

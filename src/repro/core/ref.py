"""Textbook persistent-homology oracle (standard column algorithm).

This is the pure-numpy/python reference against which every Dory-JAX engine
path is validated.  It materializes the *entire* VR filtration up to dim-3
simplices and runs the standard column reduction of the boundary matrix
(paper appendix A, algorithm 4) with sparse set-valued columns — exactly the
``O(n^4)`` approach whose memory wall motivates the paper.  Deliberately
simple and slow; only usable for small ``n``.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Tuple

import numpy as np

from .filtration import pairwise_distances


def vr_simplices(dists: np.ndarray, tau_max: float, maxdim: int):
    """All simplices of dim <= maxdim+1 with diameter <= tau_max.

    Returns a list of (diameter, dim, vertex-tuple), sorted in a valid
    filtration order: (diameter, dim, lexicographic) — faces always precede
    cofaces.
    """
    n = dists.shape[0]
    simplices: List[Tuple[float, int, Tuple[int, ...]]] = []
    for v in range(n):
        simplices.append((0.0, 0, (v,)))
    for dim in range(1, maxdim + 2):
        for comb in itertools.combinations(range(n), dim + 1):
            idx = np.array(comb)
            diam = float(dists[np.ix_(idx, idx)].max())
            if diam <= tau_max:
                simplices.append((diam, dim, comb))
    simplices.sort(key=lambda s: (s[0], s[1], s[2]))
    return simplices


def standard_reduction(dists: np.ndarray, tau_max: float = np.inf, maxdim: int = 2):
    """Standard column algorithm on the boundary matrix; returns diagrams.

    Output: dict ``dim -> float array (k, 2)`` of (birth, death) with
    ``death = inf`` for essential classes.  Zero-persistence pairs
    (birth == death) are dropped, matching persistence-diagram convention.
    """
    simplices = vr_simplices(dists, tau_max, maxdim)
    index_of: Dict[Tuple[int, ...], int] = {
        s[2]: j for j, s in enumerate(simplices)
    }
    diam = [s[0] for s in simplices]
    dim = [s[1] for s in simplices]

    # Sparse GF(2) columns as python sets of row indices.
    columns: List[set] = []
    for _, d, verts in simplices:
        if d == 0:
            columns.append(set())
        else:
            col = set()
            for face in itertools.combinations(verts, d):
                col.add(index_of[face])
            columns.append(col)

    n_cols = len(columns)
    pivot_of_row: Dict[int, int] = {}  # low row -> column index owning it
    lows = [-1] * n_cols
    for j in range(n_cols):
        col = columns[j]
        while col:
            low = max(col)
            owner = pivot_of_row.get(low)
            if owner is None:
                pivot_of_row[low] = j
                lows[j] = low
                break
            col ^= columns[owner]
        columns[j] = col

    pairs: Dict[int, List[Tuple[float, float]]] = {d: [] for d in range(maxdim + 1)}
    paired_rows = set(pivot_of_row.keys())
    paired_cols = set(pivot_of_row.values())
    for j in range(n_cols):
        if lows[j] >= 0:
            i = lows[j]
            b, d_ = diam[i], diam[j]
            if dim[i] <= maxdim and d_ > b:
                pairs[dim[i]].append((b, d_))
        else:
            # column reduced to zero: birth; essential iff never a pivot row.
            if j not in paired_rows and dim[j] <= maxdim:
                pairs[dim[j]].append((diam[j], np.inf))
    _ = paired_cols
    return {
        d: np.array(sorted(pairs[d]), dtype=np.float64).reshape(-1, 2)
        for d in range(maxdim + 1)
    }


def standard_reduction_points(points: np.ndarray, tau_max: float = np.inf,
                              maxdim: int = 2):
    return standard_reduction(pairwise_distances(points), tau_max, maxdim)


def betti_numbers(dists: np.ndarray, tau: float, maxdim: int = 2):
    """Betti numbers of the complex at scale ``tau`` (from the oracle PDs)."""
    pds = standard_reduction(dists, tau_max=np.inf, maxdim=maxdim)
    betti = {}
    for d in range(maxdim + 1):
        pd = pds[d]
        if pd.size == 0:
            betti[d] = 0
            continue
        alive = (pd[:, 0] <= tau) & (pd[:, 1] > tau)
        betti[d] = int(alive.sum())
    return betti

"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published config;
``get_config(name, reduced=True)`` the CPU smoke-test variant.
``SHAPES`` defines the assigned input-shape cells; eligibility for
``long_500k`` follows DESIGN.md §Arch-applicability (sub-quadratic only).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCHS = (
    "qwen3_0_6b", "gemma3_1b", "granite_34b", "glm4_9b", "qwen2_vl_2b",
    "whisper_small", "xlstm_1_3b", "deepseek_v2_lite_16b",
    "granite_moe_1b_a400m", "recurrentgemma_9b",
)

ALIASES = {
    "qwen3-0.6b": "qwen3_0_6b", "gemma3-1b": "gemma3_1b",
    "granite-34b": "granite_34b", "glm4-9b": "glm4_9b",
    "qwen2-vl-2b": "qwen2_vl_2b", "whisper-small": "whisper_small",
    "xlstm-1.3b": "xlstm_1_3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def cells(arch: str):
    """The (shape -> spec) cells for an arch, marking long_500k skips."""
    cfg = get_config(arch)
    out = {}
    for shape, spec in SHAPES.items():
        skip = (shape == "long_500k" and not cfg.sub_quadratic)
        out[shape] = dict(spec, skip=skip,
                          skip_reason="full-attention (quadratic); "
                          "per task spec" if skip else "")
    return out

"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 — 5:1 local:global (window 1024), 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab_size=262144, head_dim=256,
    rope_theta=1e6, attn_window=1024, global_every=6,
    tie_embeddings=True,
    # 5/6 of layers are 1k-windowed; decode cost is O(seq) only on the few
    # global layers with seq-sharded KV -> eligible for long_500k.
    sub_quadratic=True,
)

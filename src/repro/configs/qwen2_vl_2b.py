"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution; vision frontend is a STUB
(input_specs feeds precomputed patch embeddings + (3,B,S) position grids).
[arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab_size=151936, rope_kind="mrope", mrope_sections=(16, 24, 24),
    rope_theta=1e6, tie_embeddings=True, input_kind="embeddings",
    sub_quadratic=False,
)

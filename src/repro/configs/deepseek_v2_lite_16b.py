"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400 — MLA kv_lora=512 (+64-dim shared rotary head), 1 leading
dense layer (d_ff 10944), 64 routed experts top-6 + 2 shared.
[arXiv:2405.04434; hf]"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102400, rope_theta=1e4, tie_embeddings=False,
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  capacity_factor=1.25, first_dense_layers=1,
                  dense_d_ff=10944),
    sub_quadratic=False,
)

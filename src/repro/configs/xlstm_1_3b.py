"""xlstm-1.3b [ssm]: 48 blocks d_model=2048 4H — sLSTM + mLSTM blocks
(1 sLSTM per 8), matrix-memory mLSTM with chunkwise-parallel form; no
separate FFN (d_ff=0, gated up-projection inside blocks).
[arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, rope_kind="none", tie_embeddings=True,
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, conv_width=4,
                      chunk=64),
    sub_quadratic=True,   # O(1)/token recurrent state
)

"""recurrentgemma-9b [hybrid]: 38 blocks d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000 — Griffin pattern (RG-LRU, RG-LRU, local-attn
window 2048) x12 + 2 RG-LRU remainder.  [arXiv:2402.19427; unverified]"""
from repro.models.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab_size=256000, rope_theta=1e4, tie_embeddings=True,
    act="gelu",
    rglru=RGLRUConfig(d_rnn=4096, conv_width=4,
                      block_pattern=("rglru", "rglru", "local_attn"),
                      attn_window=2048),
    sub_quadratic=True,
)

"""whisper-small [audio]: 12L enc + 12L dec, d_model=768 12H d_ff=3072
vocab=51865 (padded to 52224 for TP divisibility) — enc-dec; conv frontend
STUB (input_specs feeds precomputed frame embeddings, S_enc = seq_len//2,
S_dec = seq_len//2 per DESIGN.md).  [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, enc_dec=True, rope_kind="none",
    act="gelu", tie_embeddings=True,
    sub_quadratic=False,
)

"""Pallas TPU kernels for the compute hot spots (pairwise distances, GF(2)
bit-packed reduction, flash attention) with jit wrappers (ops) and pure-jnp
oracles (ref)."""

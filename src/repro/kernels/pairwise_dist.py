"""Pallas TPU kernel: blocked pairwise squared Euclidean distances.

Filtration construction starts with the distance matrix — for n up to
millions of points this is the paper's first compute wall.  On TPU it is a
classic MXU workload via ``|x|^2 - 2 x.y + |y|^2``: the cross term is a
(bm, d) x (d, bn) matmul per tile, staged HBM->VMEM by BlockSpecs.

Tiling: grid (M/bm, N/bn); X tile (bm, d) and Y tile (bn, d) live in VMEM
(d is kept whole — point dims are small for VR workloads), output tile
(bm, bn).  bm = bn = 256 keeps the working set at
2*256*d*4 + 256*256*4 ≈ 0.5 MB for d<=64 — far under the ~16 MB VMEM budget,
leaving room for double buffering; the 256x256 output tile is MXU-aligned
(multiples of 128).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import pad_to_multiple, resolve_interpret


def _pairwise_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] = jnp.maximum(xx + yy - 2.0 * xy, 0.0)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def pairwise_sq_dists(x: jnp.ndarray, y: jnp.ndarray,
                      block_m: int = 256, block_n: int = 256,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Squared distances (M, N) between rows of x (M, d) and y (N, d).

    Ragged M/N are zero-padded to the block multiples and the result sliced
    back, so any point count works.  ``interpret=None`` resolves per backend
    (compiled on TPU only).
    """
    interpret = resolve_interpret(interpret)
    m, d = x.shape
    n = y.shape[0]
    x = pad_to_multiple(x, block_m, axis=0)
    y = pad_to_multiple(y, block_n, axis=0)
    grid = (x.shape[0] // block_m, y.shape[0] // block_n)
    out = pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], y.shape[0]),
                                       jnp.float32),
        interpret=interpret,
    )(x, y)
    return out[:m, :n]

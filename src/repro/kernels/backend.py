"""Backend selection shared by the Pallas kernel wrappers.

Mosaic (the Pallas TPU compiler) only exists on TPU; everywhere else the
kernels run in interpret mode for correctness.  Kernel entry points take
``interpret=None`` and resolve it here at trace time, so real hardware gets
compiled kernels by default while tests can still force either mode
explicitly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    """Interpret everywhere except on a TPU backend."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def pad_to_multiple(x: jnp.ndarray, multiple: int, axis: int) -> jnp.ndarray:
    """Zero-pad ``axis`` up to the next multiple (the shared pad-then-slice
    policy of the kernel wrappers; callers slice the result back)."""
    pad = (-x.shape[axis]) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)

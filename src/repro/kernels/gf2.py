"""Pallas TPU kernels: bit-packed GF(2) column reduction.

The inner loop of persistent-homology reduction is "add (mod 2) column i into
column j" — on bit-packed uint32 words one VREG XOR covers 8x128x32 = 32,768
matrix entries.  Two kernels:

* ``gf2_find_low`` — per-column index of the first set bit (the paper's
  ``low``): word-granular scan + count-trailing-zeros arithmetic, fully
  vectorized on the VPU.
* ``gf2_serial_reduce`` — the *serial phase* of the paper's serial-parallel
  algorithm (§4.4) for one batch block held entirely in VMEM: walk the block
  columns in filtration order; while a column's low collides with an earlier
  column's low, XOR the earlier column in.  Grid parallelizes over blocks
  (= the paper's thread batches / our mesh shards); the data-dependent inner
  walk is a ``lax.while_loop`` inside the kernel.

Block geometry: a (C=128 cols, W=2048 words) block = 1 MB of VMEM, i.e. a
65,536-row bit space per block — comfortably double-bufferable in ~16 MB
VMEM.  Column count per block stays modest because the serial walk is O(C)
deep; wide row spaces are nearly free (vector XOR).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import pad_to_multiple, resolve_interpret

NO_LOW = 2**31 - 1  # python int: kernels must not capture traced constants


def _find_low_word(col: jnp.ndarray) -> jnp.ndarray:
    """Index of first set bit of a packed (W,) uint32 column; NO_LOW if 0."""
    nz = col != 0
    any_nz = jnp.any(nz)
    w = jnp.argmax(nz)                      # first non-zero word
    word = col[w]
    lsb = word & (~word + jnp.uint32(1))    # isolate lowest set bit
    bit = jnp.asarray(jnp.bitwise_count(lsb - jnp.uint32(1)), jnp.int32)
    return jnp.where(any_nz, jnp.asarray(w, jnp.int32) * 32 + bit,
                     jnp.int32(NO_LOW))


def _find_low_kernel(cols_ref, lows_ref):
    cols = cols_ref[...]                    # (C, W) uint32
    nz = cols != 0
    any_nz = jnp.any(nz, axis=1)
    w = jnp.argmax(nz, axis=1)
    word = jnp.take_along_axis(cols, w[:, None], axis=1)[:, 0]
    lsb = word & (~word + jnp.uint32(1))
    bit = jnp.asarray(jnp.bitwise_count(lsb - jnp.uint32(1)), jnp.int32)
    lows_ref[...] = jnp.where(any_nz, jnp.asarray(w, jnp.int32) * 32 + bit,
                              jnp.int32(NO_LOW))


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def gf2_find_low(cols: jnp.ndarray, block_c: int = 128,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """First-set-bit index per bit-packed column. cols: (C, W) uint32.

    Odd column counts are zero-padded to the block multiple and sliced back
    (a padded all-zero column reads as NO_LOW and is dropped anyway).
    ``interpret=None`` resolves per backend (compiled on TPU only).
    """
    interpret = resolve_interpret(interpret)
    c, w = cols.shape
    cols = pad_to_multiple(cols, block_c, axis=0)
    cp = cols.shape[0]
    lows = pl.pallas_call(
        _find_low_kernel,
        grid=(cp // block_c,),
        in_specs=[pl.BlockSpec((block_c, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cp,), jnp.int32),
        interpret=interpret,
    )(cols)
    return lows[:c]


def _serial_reduce_kernel(in_ref, out_ref, lows_ref, reds_ref):
    """One block: in-order column reduction with collision XOR (paper serial
    phase).  The block rides the loop carries as a value — refs are written
    only at the top level, so the kernel lowers identically under Mosaic and
    the interpreter (ref mutation inside ``while_loop`` has no interpret-mode
    discharge rule)."""
    C = in_ref.shape[1]
    lows0 = jnp.full((C,), NO_LOW, dtype=jnp.int32)

    def reduce_one(c, state):
        block, lows, n_red = state
        earlier = jax.lax.broadcasted_iota(jnp.int32, (C,), 0) < c

        def cond(st):
            _, low, _ = st
            return jnp.any((lows == low) & earlier
                           & (low != jnp.int32(NO_LOW)))

        def body(st):
            col, low, n = st
            j = jnp.argmax((lows == low) & earlier)
            col = col ^ block[j]
            return col, _find_low_word(col), n + 1

        col0 = block[c]
        col, low, n_red = jax.lax.while_loop(
            cond, body, (col0, _find_low_word(col0), n_red))
        return block.at[c].set(col), lows.at[c].set(low), n_red

    block, lows, n_red = jax.lax.fori_loop(
        0, C, reduce_one, (in_ref[0], lows0, jnp.int32(0)))
    out_ref[0] = block
    lows_ref[0] = lows
    reds_ref[0] = n_red


@functools.partial(jax.jit, static_argnames=("interpret",))
def gf2_serial_reduce(blocks: jnp.ndarray, interpret: Optional[bool] = None):
    """Intra-block serial reduction per grid step.

    blocks: (G, C, W) uint32 bit-packed columns, filtration order along C.
    Returns (reduced (G, C, W), lows (G, C) int32, n_reductions (G,) int32).
    After the call every block's non-empty columns have pairwise-distinct
    lows — the invariant the paper's clearance step commits.
    """
    interpret = resolve_interpret(interpret)
    g, c, w = blocks.shape
    return pl.pallas_call(
        _serial_reduce_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((1, c, w), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, c, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, c, w), jnp.uint32),
            jax.ShapeDtypeStruct((g, c), jnp.int32),
            jax.ShapeDtypeStruct((g,), jnp.int32),
        ],
        interpret=interpret,
    )(blocks)

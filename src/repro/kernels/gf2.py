"""Pallas TPU kernels: bit-packed GF(2) column reduction.

The inner loop of persistent-homology reduction is "add (mod 2) column i into
column j" — on bit-packed uint32 words one VREG XOR covers 8x128x32 = 32,768
matrix entries.  Two kernels:

* ``gf2_find_low`` — per-column index of the first set bit (the paper's
  ``low``): word-granular scan + count-trailing-zeros arithmetic, fully
  vectorized on the VPU.
* ``gf2_serial_reduce`` — the *serial phase* of the paper's serial-parallel
  algorithm (§4.4) for one batch block held entirely in VMEM: walk the block
  columns in filtration order; while a column's low collides with an earlier
  column's low, XOR the earlier column in.  Grid parallelizes over blocks
  (= the paper's thread batches / our mesh shards); the data-dependent inner
  walk is a ``lax.while_loop`` inside the kernel.
* ``gf2_parallel_xor`` — the *parallel phase* counterpart: XOR a column
  block against a gathered addend block (each batch column against the
  committed pivot column owning its low) in one elementwise VREG pass.

The host-side rank-compression vocabulary lives here too: the sorted
unique ``universe`` of active cofacet keys maps key ``universe[i]`` to bit
``i``, so ascending key order equals ascending bit order and the kernels'
first-set-bit *is* the engines' ``low``.  The packed engine
(``core/packed_reduce.py``) moves between key arrays and bit blocks with
the primitive trio ``scatter_bits`` / ``scatter_xor_bits`` /
``set_bit_positions`` (plus ``find_low_np``, the word-level numpy mirror
of ``gf2_find_low``); ``pack_keys_to_bits`` / ``bits_to_keys`` are the
whole-block reference forms of the same mapping (the oracle the property
tests check the primitives and kernels against).

Block geometry: a (C=128 cols, W=2048 words) block = 1 MB of VMEM, i.e. a
65,536-row bit space per block — comfortably double-bufferable in ~16 MB
VMEM.  Column count per block stays modest because the serial walk is O(C)
deep; wide row spaces are nearly free (vector XOR).
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .backend import pad_to_multiple, resolve_interpret

NO_LOW = 2**31 - 1  # python int: kernels must not capture traced constants


# ---------------------------------------------------------------------------
# Host-side bit packing (rank compression into the block bit-space)
# ---------------------------------------------------------------------------

def pack_keys_to_bits(rows: Sequence[np.ndarray], universe: np.ndarray,
                      n_words: Optional[int] = None) -> np.ndarray:
    """Pack sorted int64 key rows into a (B, W) uint32 bit block.

    ``universe`` is the sorted unique key array of the compressed bit-space;
    every key of every row must be present in it.  Key ``universe[i]`` maps
    to bit ``i`` (word ``i >> 5``, bit ``i & 31``) — ascending keys become
    ascending bit indices, so ``gf2_find_low`` on the packed block returns
    the rank of each row's minimum key.  ``n_words`` widens the block (extra
    zero words) so callers can append augmentation bits.
    """
    W = max(1, (len(universe) + 31) // 32)
    if n_words is not None:
        W = max(W, int(n_words))
    B = len(rows)
    packed = np.zeros((B, W), dtype=np.uint32)
    lens = np.array([len(r) for r in rows], dtype=np.int64)
    if lens.sum() == 0:
        return packed
    keys = np.concatenate([np.asarray(r, dtype=np.int64) for r in rows])
    ridx = np.repeat(np.arange(B, dtype=np.int64), lens)
    pos = np.searchsorted(universe, keys)
    scatter_bits(packed, ridx, pos)
    return packed


def _scatter_groups(block: np.ndarray, ridx: np.ndarray, pos: np.ndarray):
    """Shared grouping for the bit scatters: flat word indices + per-word
    bit sums.

    ``pos`` must be ascending within each row and each (row, rank) pair
    unique — then the flat word index is globally sorted, distinct bits of
    one word sum without carries, and the whole grouping is one
    ``add.reduceat`` over the nnz coordinates (no full-width buffer, unlike
    ``bincount``; no per-element loop, unlike ``ufunc.at``)."""
    W = block.shape[1]
    word = ridx * W + (pos >> 5)
    val = np.uint32(1) << (pos & 31).astype(np.uint32)
    first = np.empty(len(word), dtype=bool)
    first[0] = True
    np.not_equal(word[1:], word[:-1], out=first[1:])
    starts = np.flatnonzero(first)
    return word[starts], np.add.reduceat(val, starts)


def scatter_bits(block: np.ndarray, ridx: np.ndarray,
                 pos: np.ndarray) -> None:
    """OR bits at ``(row, bit-rank)`` coordinates into a uint32 block
    (packing into fresh/zero words; see :func:`_scatter_groups` for the
    coordinate contract)."""
    if not pos.size:
        return
    idx, sums = _scatter_groups(block, ridx, pos)
    block.reshape(-1)[idx] |= sums


def scatter_xor_bits(block: np.ndarray, ridx: np.ndarray,
                     pos: np.ndarray) -> None:
    """XOR bits at ``(row, bit-rank)`` coordinates into a uint32 block —
    the in-place GF(2) column add of the packed engine's parallel phase
    (same coordinate contract as :func:`scatter_bits`)."""
    if not pos.size:
        return
    idx, sums = _scatter_groups(block, ridx, pos)
    block.reshape(-1)[idx] ^= sums


def set_bit_positions(block: np.ndarray):
    """Set-bit coordinates of a (B, W) uint32 block, word-granular.

    Returns ``(ridx, pos, counts)`` — row index and bit rank of every set
    bit (ascending rank within each row) and the per-row set-bit counts.
    Only the non-zero *words* are expanded to bits, so sparse blocks cost
    ``O(B·W)`` word scans plus ``O(32·nnz_words)``, not ``O(32·B·W)``.
    """
    block = np.ascontiguousarray(block, dtype=np.uint32)
    B, _ = block.shape
    rw, cw = np.nonzero(block)
    words = block[rw, cw]
    bits = np.unpackbits(words.view(np.uint8).reshape(-1, 4),
                         axis=1, bitorder="little")
    m, b = np.nonzero(bits)
    ridx = rw[m]
    pos = cw[m] * 32 + b
    counts = np.bincount(ridx, minlength=B).astype(np.int64)
    return ridx, pos, counts


def bits_to_keys(block: np.ndarray, universe: np.ndarray) -> List[np.ndarray]:
    """Inverse of :func:`pack_keys_to_bits`: bit block -> sorted key rows.

    Bits at rank >= len(universe) (augmentation words) are ignored.
    """
    ridx, pos, counts = set_bit_positions(block)
    keep = pos < len(universe)
    if not keep.all():
        counts = np.bincount(ridx[keep],
                             minlength=block.shape[0]).astype(np.int64)
        pos = pos[keep]
    return np.split(universe[pos], np.cumsum(counts)[:-1])


def find_low_np(block: np.ndarray) -> np.ndarray:
    """Numpy mirror of :func:`gf2_find_low` (host fast path): first-set-bit
    rank per row of a (B, W) uint32 block; NO_LOW for all-zero rows.

    Word-granular like the kernel: first non-zero word by argmax, then the
    isolated lowest set bit's exponent via ``frexp`` (exact for powers of
    two) — no per-bit expansion of the block.
    """
    block = np.asarray(block, dtype=np.uint32)
    B, _ = block.shape
    nzw = block != 0
    any_set = nzw.any(axis=1)
    w = nzw.argmax(axis=1)
    words = block[np.arange(B), w].astype(np.int64)
    lsb = (words & -words).astype(np.float64)
    bit = np.frexp(lsb)[1] - 1
    return np.where(any_set, w * 32 + bit, NO_LOW).astype(np.int32)


def stack_wire_payloads(payloads: Sequence[np.ndarray],
                        min_words: int = 1024):
    """Stack per-shard packed uint32 wire payloads into one ``(P, L)``
    collective buffer, ``L`` bucketed to a power of two.

    The distributed engine's pivot exchange cross-ships the buffer through
    ``jax.lax.all_gather``; bucketing ``L`` keeps the jitted collective at
    a handful of retraces instead of one per superstep, and ``min_words``
    floors the bucket so early (small) rounds share one trace.  Returns
    ``(buf, lens)``; :func:`unstack_wire_payloads` crops the gather result
    back to the real payloads.
    """
    lens = [int(p.size) for p in payloads]
    L = max(int(min_words), max(lens, default=1))
    L = 1 << (L - 1).bit_length()
    buf = np.zeros((len(payloads), L), dtype=np.uint32)
    for k, p in enumerate(payloads):
        buf[k, :p.size] = p
    return buf, lens


def unstack_wire_payloads(gathered: np.ndarray,
                          lens: Sequence[int]) -> List[np.ndarray]:
    """Inverse of :func:`stack_wire_payloads` on the gathered ``(P, L)``
    buffer: every shard's payload, zero padding cropped."""
    out = np.asarray(gathered, dtype=np.uint32)
    return [out[k, :n] for k, n in enumerate(lens)]


def _find_low_word(col: jnp.ndarray) -> jnp.ndarray:
    """Index of first set bit of a packed (W,) uint32 column; NO_LOW if 0."""
    nz = col != 0
    any_nz = jnp.any(nz)
    w = jnp.argmax(nz)                      # first non-zero word
    word = col[w]
    lsb = word & (~word + jnp.uint32(1))    # isolate lowest set bit
    bit = jnp.asarray(jnp.bitwise_count(lsb - jnp.uint32(1)), jnp.int32)
    return jnp.where(any_nz, jnp.asarray(w, jnp.int32) * 32 + bit,
                     jnp.int32(NO_LOW))


def _find_low_kernel(cols_ref, lows_ref):
    cols = cols_ref[...]                    # (C, W) uint32
    nz = cols != 0
    any_nz = jnp.any(nz, axis=1)
    w = jnp.argmax(nz, axis=1)
    word = jnp.take_along_axis(cols, w[:, None], axis=1)[:, 0]
    lsb = word & (~word + jnp.uint32(1))
    bit = jnp.asarray(jnp.bitwise_count(lsb - jnp.uint32(1)), jnp.int32)
    lows_ref[...] = jnp.where(any_nz, jnp.asarray(w, jnp.int32) * 32 + bit,
                              jnp.int32(NO_LOW))


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def gf2_find_low(cols: jnp.ndarray, block_c: int = 128,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """First-set-bit index per bit-packed column. cols: (C, W) uint32.

    Odd column counts are zero-padded to the block multiple and sliced back
    (a padded all-zero column reads as NO_LOW and is dropped anyway).
    ``interpret=None`` resolves per backend (compiled on TPU only).
    """
    interpret = resolve_interpret(interpret)
    c, w = cols.shape
    cols = pad_to_multiple(cols, block_c, axis=0)
    cp = cols.shape[0]
    lows = pl.pallas_call(
        _find_low_kernel,
        grid=(cp // block_c,),
        in_specs=[pl.BlockSpec((block_c, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cp,), jnp.int32),
        interpret=interpret,
    )(cols)
    return lows[:c]


def _serial_reduce_kernel(in_ref, out_ref, lows_ref, reds_ref):
    """One block: in-order column reduction with collision XOR (paper serial
    phase).  The block rides the loop carries as a value — refs are written
    only at the top level, so the kernel lowers identically under Mosaic and
    the interpreter (ref mutation inside ``while_loop`` has no interpret-mode
    discharge rule)."""
    C = in_ref.shape[1]
    lows0 = jnp.full((C,), NO_LOW, dtype=jnp.int32)

    def reduce_one(c, state):
        block, lows, n_red = state
        earlier = jax.lax.broadcasted_iota(jnp.int32, (C,), 0) < c

        def cond(st):
            _, low, _ = st
            return jnp.any((lows == low) & earlier
                           & (low != jnp.int32(NO_LOW)))

        def body(st):
            col, low, n = st
            j = jnp.argmax((lows == low) & earlier)
            col = col ^ block[j]
            return col, _find_low_word(col), n + 1

        col0 = block[c]
        col, low, n_red = jax.lax.while_loop(
            cond, body, (col0, _find_low_word(col0), n_red))
        return block.at[c].set(col), lows.at[c].set(low), n_red

    block, lows, n_red = jax.lax.fori_loop(
        0, C, reduce_one, (in_ref[0], lows0, jnp.int32(0)))
    out_ref[0] = block
    lows_ref[0] = lows
    reds_ref[0] = n_red


@functools.partial(jax.jit, static_argnames=("interpret",))
def gf2_serial_reduce(blocks: jnp.ndarray, interpret: Optional[bool] = None):
    """Intra-block serial reduction per grid step.

    blocks: (G, C, W) uint32 bit-packed columns, filtration order along C.
    Returns (reduced (G, C, W), lows (G, C) int32, n_reductions (G,) int32).
    After the call every block's non-empty columns have pairwise-distinct
    lows — the invariant the paper's clearance step commits.
    """
    interpret = resolve_interpret(interpret)
    g, c, w = blocks.shape
    return pl.pallas_call(
        _serial_reduce_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((1, c, w), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, c, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, c, w), jnp.uint32),
            jax.ShapeDtypeStruct((g, c), jnp.int32),
            jax.ShapeDtypeStruct((g,), jnp.int32),
        ],
        interpret=interpret,
    )(blocks)


def _parallel_xor_kernel(cols_ref, addends_ref, out_ref):
    out_ref[...] = cols_ref[...] ^ addends_ref[...]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def gf2_parallel_xor(cols: jnp.ndarray, addends: jnp.ndarray,
                     block_c: int = 128,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Parallel-phase GF(2) add: XOR a column block against a gathered
    addend block.  cols, addends: (C, W) uint32; returns (C, W) uint32.

    The addend block is the host-side gather of committed pivot columns
    (one per batch column, zero rows where a column has no hit) packed into
    the same bit-space as ``cols`` — one VREG XOR covers 32,768 matrix
    entries.  Odd column counts self-pad to the block multiple.
    """
    interpret = resolve_interpret(interpret)
    c, w = cols.shape
    cols = pad_to_multiple(cols, block_c, axis=0)
    addends = pad_to_multiple(addends, block_c, axis=0)
    cp = cols.shape[0]
    out = pl.pallas_call(
        _parallel_xor_kernel,
        grid=(cp // block_c,),
        in_specs=[pl.BlockSpec((block_c, w), lambda i: (i, 0)),
                  pl.BlockSpec((block_c, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_c, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, w), jnp.uint32),
        interpret=interpret,
    )(cols, addends)
    return out[:c]

"""Jitted dispatch wrappers over the Pallas kernels.

On the CPU container Pallas executes in interpret mode (correctness); on TPU
the same ``pl.pallas_call`` compiles to Mosaic.  ``use_pallas`` resolves to
False on CPU *for jit-compiled production paths* (dry-run lowerings use the
pure-jnp reference so the HLO reflects XLA's native lowering), while tests
force interpret-mode kernels to validate them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as kref
from .backend import default_interpret  # noqa: F401  (re-export)
from .flash_attention import flash_attention
from .gf2 import gf2_find_low, gf2_serial_reduce
from .pairwise_dist import pairwise_sq_dists


def default_use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def pairwise_distances(x, y=None, block: int = 256, use_pallas=None,
                       interpret=None) -> jnp.ndarray:
    """Euclidean distances through the Pallas kernel (which pads ragged
    row counts to the block multiples internally)."""
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas
    self_dist = y is None
    y = x if y is None else y
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    if not use_pallas:
        d2 = kref.pairwise_sq_dists_ref(x, y)
    else:
        d2 = pairwise_sq_dists(x, y, block_m=block, block_n=block,
                               interpret=interpret)
    if self_dist:
        # kill catastrophic-cancellation residue on the diagonal
        d2 = d2 * (1.0 - jnp.eye(d2.shape[0], dtype=d2.dtype))
    return jnp.sqrt(d2)


def find_low(cols, use_pallas=None, interpret=None) -> jnp.ndarray:
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas
    if not use_pallas:
        return jnp.asarray(kref.gf2_find_low_ref(np.asarray(cols)))
    return gf2_find_low(jnp.asarray(cols), interpret=interpret)


def serial_reduce_bits(blocks, use_pallas=None, interpret=None):
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas
    if not use_pallas:
        b, l, r = kref.gf2_serial_reduce_ref(np.asarray(blocks))
        return jnp.asarray(b), jnp.asarray(l), jnp.asarray(r)
    return gf2_serial_reduce(jnp.asarray(blocks), interpret=interpret)


def attention(q, k, v, causal: bool = True, window: int = -1,
              use_pallas=None, interpret=None,
              block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """(BH, S, d) attention; Pallas flash kernel or jnp reference."""
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas
    if not use_pallas:
        return kref.attention_ref(q, k, v, causal=causal, window=window)
    # blocks pass through unshrunk: the kernel pads ragged/short S itself,
    # keeping Pallas blocks MXU-aligned on the compiled (TPU) path
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)

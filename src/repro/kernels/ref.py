"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pairwise_sq_dists_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    return jnp.maximum(xx + yy - 2.0 * (x @ y.T), 0.0)


def gf2_find_low_ref(cols: np.ndarray) -> np.ndarray:
    """First set bit per packed column; 2^31-1 when empty. cols: (C, W)."""
    cols = np.asarray(cols, dtype=np.uint32)
    out = np.full(cols.shape[0], 2**31 - 1, dtype=np.int32)
    for i, col in enumerate(cols):
        nz = np.nonzero(col)[0]
        if nz.size:
            w = int(nz[0])
            bit = int(col[w] & -col[w]).bit_length() - 1
            out[i] = w * 32 + bit
    return out


def gf2_serial_reduce_ref(blocks: np.ndarray):
    """Reference intra-block serial reduction (standard column algorithm
    restricted to the block)."""
    blocks = np.array(blocks, dtype=np.uint32, copy=True)
    G, C, W = blocks.shape
    lows = np.full((G, C), 2**31 - 1, dtype=np.int32)
    reds = np.zeros(G, dtype=np.int32)
    for g in range(G):
        for c in range(C):
            while True:
                low = gf2_find_low_ref(blocks[g, c:c + 1])[0]
                if low == 2**31 - 1:
                    break
                hit = np.nonzero(lows[g, :c] == low)[0]
                if hit.size == 0:
                    break
                blocks[g, c] ^= blocks[g, hit[0]]
                reds[g] += 1
            lows[g, c] = low
    return blocks, lows, reds


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, window: int = -1) -> jnp.ndarray:
    """Naive softmax attention. q,k,v: (BH, S, d)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    sq, sk = q.shape[1], k.shape[1]
    q_idx = jnp.arange(sq)[:, None]
    k_idx = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= k_idx <= q_idx
    if window > 0:
        mask &= (q_idx - k_idx) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

"""Pallas TPU kernel: blocked (flash) attention with causal/local masking.

The LM substrate's dominant compute is attention; this kernel computes
softmax(QK^T / sqrt(d)) V without materializing the (S, S) score matrix,
using the online-softmax recurrence over KV blocks.

Grid: (batch*heads, S_q / block_q); each step loops KV blocks
(S_k / block_k) with running (max, sum, acc) carries in VMEM.  Tiles:
q (block_q, d), k/v (block_k, d), acc (block_q, d) — for block 128 and
d = 128 the working set is ~0.4 MB, MXU-aligned on every contraction.

GQA: callers map over KV groups (see ops.attention), so the kernel sees one
query group per KV head.  Local (sliding-window) masks cover the gemma3 /
recurrentgemma local-attention layers; ``window < 0`` means global.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import pad_to_multiple, resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_k: int,
                  valid: int, causal: bool, window: int, sm_scale: float):
    q = q_ref[0].astype(jnp.float32) * sm_scale          # (bq, d)
    bq, d = q.shape
    q_idx = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, block_k), 0)

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_idx = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (bq, block_k), 1)
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= k_idx <= q_idx
        if window > 0:
            mask &= (q_idx - k_idx) < window
        if valid < seq_k:
            mask &= k_idx < valid             # padded keys never attend
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return m_cur, l_cur, acc

    n_kb = seq_k // block_k
    m0 = jnp.full((bq,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, d), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = -1,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q, k, v: (BH, S, d) with matching head counts (GQA pre-expanded).

    Ragged S is zero-padded to the block multiples (padded keys are masked
    out of every softmax, padded query rows sliced off).
    ``interpret=None`` resolves per backend (compiled on TPU only).
    """
    interpret = resolve_interpret(interpret)
    bh, s, d = q.shape
    mult = block_q * block_k // math.gcd(block_q, block_k)
    q = pad_to_multiple(q, mult, axis=1)
    k = pad_to_multiple(k, mult, axis=1)
    v = pad_to_multiple(v, mult, axis=1)
    sp = q.shape[1]
    sm_scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _flash_kernel, block_k=block_k, seq_k=sp, valid=s, causal=causal,
        window=window, sm_scale=sm_scale)
    out = pl.pallas_call(
        kernel,
        grid=(bh, sp // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, sp, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, sp, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sp, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]

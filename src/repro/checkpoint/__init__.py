"""Fault-tolerant sharded checkpointing."""
from .checkpointer import Checkpointer

__all__ = ["Checkpointer"]

"""Sharded checkpointing: atomic, async, reshard-on-restore.

Layout::

    <dir>/step_<N>/manifest.json     # paths, shapes, dtypes, metadata
    <dir>/step_<N>/<leaf-path>.npy   # one file per pytree leaf

Writes go to ``step_<N>.tmp`` then atomically rename — a crashed save never
corrupts the latest checkpoint (fault-tolerance requirement).  ``save_async``
runs the write on a thread so the train loop overlaps I/O with compute.
``restore`` device_puts with *target* shardings, so a checkpoint written on
one mesh restores onto any other (elastic re-mesh path, exercised by
``launch/elastic.py`` and tests).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.dist.sharding import tree_path_str
from repro.resilience.faults import CheckpointCorruption


def _leaf_digest(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def _leaf_files(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {tree_path_str(kp).replace("/", "__"): leaf for kp, leaf in flat}


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save ----
    def save(self, step: int, tree, metadata: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device->host sync here
        self._write(step, host_tree, metadata or {})

    def save_async(self, step: int, tree, metadata: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, metadata or {}))
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, metadata: dict):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _leaf_files(host_tree)
        manifest = {"step": step, "metadata": metadata, "leaves": {}}
        for name, leaf in leaves.items():
            np.save(os.path.join(tmp, name + ".npy"), leaf)
            manifest["leaves"][name] = {
                "shape": list(leaf.shape), "dtype": str(leaf.dtype),
                "sha256": _leaf_digest(leaf)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---- restore ----
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None, verify: bool = True):
        """Restore into the structure of ``template``; if ``shardings`` is a
        matching tree of NamedShardings the leaves are placed sharded (the
        reshard-on-restore path for elastic re-meshing).

        With ``verify`` (the default) every leaf whose manifest entry
        carries a ``sha256`` is re-hashed after load; a mismatch — bit rot,
        a torn write that beat the atomic rename, a truncated .npy — raises
        :class:`~repro.resilience.faults.CheckpointCorruption` instead of
        silently restoring wrong weights.  Pre-hash checkpoints (no
        ``sha256`` field) restore unverified for compatibility."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointCorruption(
                f"unreadable manifest in {d!r}: {e}") from e

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = None
        if shardings is not None:
            shard_flat = [s for _, s in
                          jax.tree_util.tree_flatten_with_path(shardings)[0]]
        leaves = []
        for i, (kp, leaf) in enumerate(flat):
            name = tree_path_str(kp).replace("/", "__")
            try:
                arr = np.load(os.path.join(d, name + ".npy"))
                expect = manifest["leaves"][name]
            except (OSError, ValueError, KeyError) as e:
                raise CheckpointCorruption(
                    f"unreadable leaf {name!r} in {d!r}: {e}") from e
            if list(arr.shape) != expect["shape"]:
                raise CheckpointCorruption(
                    f"leaf {name!r} shape {list(arr.shape)} != manifest "
                    f"{expect['shape']} in {d!r}")
            if verify and expect.get("sha256") is not None \
                    and _leaf_digest(arr) != expect["sha256"]:
                raise CheckpointCorruption(
                    f"leaf {name!r} failed sha256 verification in {d!r}")
            if shard_flat is not None:
                leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype)
                              if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), \
            manifest["metadata"]

    def restore_latest_valid(self, template, shardings=None):
        """Walk checkpoints newest-first, restoring the first one that
        passes verification — the fall-back-to-older-step recovery line
        when the latest save is corrupt.  Returns ``(tree, metadata,
        step)``; raises :class:`CheckpointCorruption` when every step is
        bad and ``FileNotFoundError`` when there are none."""
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        last_err: Optional[Exception] = None
        for step in reversed(steps):
            try:
                tree, meta = self.restore(template, step=step,
                                          shardings=shardings)
                return tree, meta, step
            except CheckpointCorruption as e:
                last_err = e
        raise CheckpointCorruption(
            f"every checkpoint in {self.dir!r} is corrupt; "
            f"last error: {last_err}")

"""Opt-in runtime GF(2) sanitizer for the reduction engines.

The reduction pipeline is exact algebra over GF(2): every committed pivot
low is unique per dimension, every explicit R column is a strictly
increasing key list, every packed bit-block holds exactly the coordinates
it was consolidated from, every Elias–Fano wire payload decodes back to
the records that produced it, and every budget spill must be reversible
(``R = reduce(∂(gens + [col]))``).  None of these are checked on the hot
path — a single flipped bit produces a *plausible but wrong* diagram.

This module is the cheap, always-correct referee.  It is disabled by
default and costs one ``None`` check per instrumented site.  Enable it
with either::

    compute_ph(points, tau_max, sanitize=True)

or the environment variable ``REPRO_SANITIZE=1`` (checked at import
time, so it also covers code paths that never go through
``compute_ph``).  On the first violated invariant the active
:class:`Sanitizer` raises a structured :class:`SanitizeViolation` that
names the check, the instrumented call site (``file:line``), and the
reduction context (dimension, superstep, batch, sweep slice) — instead
of letting the error propagate into a silently wrong barcode.

Import discipline: this module is imported by ``repro.core.reduction``
and friends at module load, so it must stay dependency-light (stdlib +
numpy).  Anything heavier (``repro.kernels``) is imported lazily inside
the check that needs it, and only when the sanitizer is active.
"""
from __future__ import annotations

import os
import sys
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterator, List, Mapping, Optional, Sequence

import numpy as np


class SanitizeViolation(RuntimeError):
    """A GF(2) invariant did not hold at an instrumented site.

    Attributes:
        check: short name of the violated invariant (e.g.
            ``"pivot-low-unique"``).
        detail: human-readable description of what went wrong.
        location: ``file:line`` of the instrumented call site.
        context: reduction context at failure time (``dim``,
            ``superstep``, ``batch``, ``slice`` — whatever the engine had
            published via :meth:`Sanitizer.set_context`).
    """

    def __init__(self, check: str, detail: str, location: str = "",
                 context: Optional[Mapping[str, Any]] = None) -> None:
        self.check = check
        self.detail = detail
        self.location = location
        self.context: Dict[str, Any] = dict(context or {})
        parts = [f"REPRO_SANITIZE[{check}]"]
        if location:
            parts.append(f"at {location}")
        if self.context:
            ctx = ", ".join(f"{k}={v}" for k, v in sorted(self.context.items()))
            parts.append(f"({ctx})")
        super().__init__(" ".join(parts) + f": {detail}")


class Sanitizer:
    """Incremental GF(2) invariant checks, armed by :func:`sanitizing`.

    All ``check_*`` methods are cheap relative to the work they guard
    (at most one extra pass over the data already in hand) and raise
    :class:`SanitizeViolation` on the first broken invariant.  Engines
    publish where they are via :meth:`set_context` so the violation can
    say *which* superstep/batch/slice went wrong.
    """

    def __init__(self) -> None:
        self.context: Dict[str, Any] = {}
        self.counts: Dict[str, int] = {}

    # -- bookkeeping ----------------------------------------------------
    def set_context(self, **kwargs: Any) -> None:
        """Publish (or clear, with ``None``) reduction context keys."""
        for key, value in kwargs.items():
            if value is None:
                self.context.pop(key, None)
            else:
                self.context[key] = value

    def _tick(self, check: str) -> None:
        self.counts[check] = self.counts.get(check, 0) + 1

    def _fail(self, check: str, detail: str) -> None:
        # Frame 0 is _fail, 1 the check_* method, 2 the instrumented site.
        frame = sys._getframe(2)
        location = f"{frame.f_code.co_filename}:{frame.f_lineno}"
        raise SanitizeViolation(check, detail, location, self.context)

    # -- pivot bookkeeping (reduction.py) -------------------------------
    def check_fresh_pivot(self, known_lows: Mapping[int, Any], low: int) -> None:
        """A pivot low may be claimed at most once per dimension."""
        self._tick("pivot-low-unique")
        if low in known_lows:
            self._fail(
                "pivot-low-unique",
                f"pivot low {int(low)} committed twice; a duplicate low means "
                "two columns were both declared reduced with the same pivot "
                "(lost XOR or a stale pivot-exchange replica)")

    def check_canonical_column(self, keys: np.ndarray) -> None:
        """Stored/encoded R columns are strictly increasing key lists."""
        self._tick("canonical-column")
        if keys.size > 1 and bool(np.any(np.diff(keys.astype(np.int64)) <= 0)):
            self._fail(
                "canonical-column",
                f"column keys are not strictly increasing ({keys.size} keys, "
                "GF(2) columns must be canonical sorted supports)")

    def check_pair_orders(self, births: np.ndarray, deaths: np.ndarray) -> None:
        """In a valid filtration order no pair can die before it is born."""
        self._tick("pair-order")
        bad = np.flatnonzero(np.asarray(deaths) < np.asarray(births))
        if bad.size:
            k = int(bad[0])
            self._fail(
                "pair-order",
                f"{bad.size} persistence pair(s) with death < birth (first: "
                f"birth={float(np.asarray(births)[k])!r}, "
                f"death={float(np.asarray(deaths)[k])!r}); the canonical "
                "(length, i, j) filtration tie-break was violated upstream")

    def check_rematerialization(self, explicit_r: np.ndarray,
                                rematerialized: np.ndarray,
                                col_id: int) -> None:
        """Spilling a column to implicit form must be lossless.

        ``explicit_r`` is the stored R column about to be dropped;
        ``rematerialized`` is ``reduce(∂(gens + [col]))`` — what every
        later :meth:`PivotStore._materialize` call will reconstruct.
        """
        self._tick("spill-rematerialization")
        if not np.array_equal(np.asarray(explicit_r), np.asarray(rematerialized)):
            self._fail(
                "spill-rematerialization",
                f"column {int(col_id)}: explicit R ({np.asarray(explicit_r).size} "
                f"keys) != δ-expansion of its generator list "
                f"({np.asarray(rematerialized).size} keys); demoting now would "
                "silently corrupt every later implicit lookup")

    # -- packed bit-blocks (packed_reduce.py) ---------------------------
    def check_segment_bits(self, positions: np.ndarray, seg_len: int) -> None:
        """No set bit may live beyond its segment's key universe."""
        self._tick("packed-segment")
        n_stray = int(np.count_nonzero(np.asarray(positions) >= seg_len))
        if n_stray:
            self._fail(
                "packed-segment",
                f"{n_stray} set bit(s) at rank >= the segment universe "
                f"(len {int(seg_len)}); stray bits would be silently dropped "
                "by consolidation, i.e. a lost GF(2) coordinate")

    def check_consolidation(self, row_idx: np.ndarray, keys: np.ndarray,
                            universe: np.ndarray, block: np.ndarray) -> None:
        """Consolidation must preserve the exact (row, key) bit multiset."""
        self._tick("packed-consolidation")
        from ..kernels.gf2 import set_bit_positions  # lazy: jax-adjacent

        got_rows, got_pos, _ = set_bit_positions(np.ascontiguousarray(block))
        if int(np.count_nonzero(np.asarray(got_pos) >= len(universe))):
            self._fail(
                "packed-consolidation",
                "consolidated block has set bits beyond the merged universe "
                f"(len {len(universe)})")
        got_keys = np.asarray(universe)[got_pos]
        want = np.lexsort((keys, row_idx))
        have = np.lexsort((got_keys, got_rows))
        same = (len(got_rows) == len(row_idx)
                and np.array_equal(np.asarray(row_idx)[want], got_rows[have])
                and np.array_equal(np.asarray(keys)[want], got_keys[have]))
        if not same:
            self._fail(
                "packed-consolidation",
                f"consolidation changed the block contents: "
                f"{len(row_idx)} (row, key) bits in, {len(got_rows)} out")

    # -- wire codec (pivot_cache.py) ------------------------------------
    def check_wire_roundtrip(
            self, records: Sequence[Mapping[str, Any]], payload: np.ndarray,
            decode: Callable[[np.ndarray], List[Dict[str, Any]]]) -> None:
        """Every encoded pivot-exchange delta must decode back exactly."""
        self._tick("wire-roundtrip")
        try:
            back = decode(np.asarray(payload))
        except Exception as exc:  # noqa: BLE001 - converted to a violation
            self._fail("wire-roundtrip",
                       f"decode of a just-encoded delta failed: {exc!r}")
            return
        if len(back) != len(records):
            self._fail(
                "wire-roundtrip",
                f"encoded {len(records)} commit record(s) but decoded "
                f"{len(back)}")
        for rec, got in zip(records, back):
            if int(rec["low"]) != int(got["low"]) or \
                    int(rec["col_id"]) != int(got["col_id"]) or \
                    str(rec["mode"]) != str(got["mode"]):
                self._fail(
                    "wire-roundtrip",
                    f"record header changed on the wire: sent "
                    f"(low={int(rec['low'])}, col={int(rec['col_id'])}, "
                    f"mode={rec['mode']}), got (low={int(got['low'])}, "
                    f"col={int(got['col_id'])}, mode={got['mode']})")
            sent_col = rec.get("column")
            got_col = got.get("column")
            if (sent_col is None) != (got_col is None) or (
                    sent_col is not None and not np.array_equal(
                        np.asarray(sent_col), np.asarray(got_col))):
                self._fail(
                    "wire-roundtrip",
                    f"R column for low {int(rec['low'])} changed on the wire")
            sent_gens = rec.get("gens")
            sent_gens = (np.sort(np.asarray(sent_gens, dtype=np.int64))
                         if sent_gens is not None
                         else np.empty(0, dtype=np.int64))
            got_gens = np.asarray(
                got.get("gens") if got.get("gens") is not None else [],
                dtype=np.int64)
            if not np.array_equal(sent_gens, got_gens):
                self._fail(
                    "wire-roundtrip",
                    f"generator list for low {int(rec['low'])} changed on "
                    "the wire")


_ACTIVE: Optional[Sanitizer] = (
    Sanitizer()
    if os.environ.get("REPRO_SANITIZE", "0") not in ("", "0")
    else None)


def active_sanitizer() -> Optional[Sanitizer]:
    """The armed :class:`Sanitizer`, or ``None`` when checks are off."""
    return _ACTIVE


@contextmanager
def sanitizing(enabled: Optional[bool] = True) -> Iterator[Optional[Sanitizer]]:
    """Scope the sanitizer on (``True``), off (``False``), or as-is (``None``).

    ``None`` leaves the ambient state (the ``REPRO_SANITIZE`` env default
    or an enclosing :func:`sanitizing` scope) untouched — this is what
    lets ``compute_ph(sanitize=None)`` defer to the environment.
    """
    global _ACTIVE
    if enabled is None:
        yield _ACTIVE
        return
    previous = _ACTIVE
    _ACTIVE = Sanitizer() if enabled else None
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = previous

"""repro.analyze — correctness tooling for the whole pipeline.

Dory's output is only as good as a set of fragile invariants: exact GF(2)
algebra (``R = ∂V``, unique pivot lows), canonical filtration tie-breaking,
and lock-step collective schedules across mesh shards.  The repo's own
history shows these break *silently* — the PR 2 interpret-mode Ref-mutation
discharge bug, the f32-candidate/f64-refine dtype discipline of the tiled
harvest, the ``exchange_every`` cadence rules of the distributed reduction.
This package is the gate that catches that bug class before (or the moment)
it ships, in three layers:

* :mod:`repro.analyze.lint` — an AST lint pass with repo-specific rules
  derived from bugs we have actually shipped (Pallas ``Ref`` stores inside
  traced loop bodies, host↔device syncs in superstep/harvest hot loops,
  raw sorts on filtration values without the canonical ``(length, i, j)``
  tie-break, f32 candidates compared against exact thresholds, unseeded
  RNG in benchmarks).  Deliberate exceptions carry a justified
  ``# analyze: allow[rule] why`` pragma — a bare pragma is itself a
  finding.
* :mod:`repro.analyze.collectives` — a jaxpr/HLO walker that extracts the
  ordered collective schedule of every ``shard_map`` program in the repo
  and statically verifies axis names, shard-uniformity (divergent
  ``cond`` branches and data-dependent ``while`` trip counts around
  collectives are the distributed-deadlock bug class), and
  replica-consistency of the pivot-exchange wire.
* :mod:`repro.analyze.invariants` — an opt-in runtime GF(2) sanitizer
  (``compute_ph(sanitize=True)`` / ``REPRO_SANITIZE=1``) instrumenting the
  reduction engines with cheap incremental checks: pivot-low uniqueness,
  packed-block segment consistency, Elias–Fano wire round-trips, and
  R-column re-materialization equality on budget spills — reporting a
  structured :class:`SanitizeViolation` (file:line, batch, superstep)
  instead of a silently wrong diagram.

``python -m repro.analyze`` runs the static layers over the repo and exits
non-zero on any unjustified finding; CI runs it on every push.  See
``docs/analysis.md`` for the field guide.
"""
from . import lint
from .invariants import (SanitizeViolation, Sanitizer, active_sanitizer,
                         sanitizing)

__all__ = [
    "SanitizeViolation",
    "Sanitizer",
    "active_sanitizer",
    "sanitizing",
    "lint",
]

"""``python -m repro.analyze`` — run the static correctness gates.

Exit status is non-zero on any unjustified lint finding or any collective
violation; CI runs this on every push and every later PR inherits the
gate.  Subcommands::

    python -m repro.analyze            # lint + collectives (default)
    python -m repro.analyze lint       # AST lint only
    python -m repro.analyze collectives  # schedule checks only
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from .lint import Finding, lint_paths


def _find_root(explicit: Optional[str]) -> str:
    """The repo root: --root, else cwd, else walk up from this file."""
    if explicit:
        return os.path.abspath(explicit)
    cwd = os.getcwd()
    if os.path.isdir(os.path.join(cwd, "src", "repro")):
        return cwd
    here = os.path.abspath(__file__)
    # src/repro/analyze/__main__.py -> repo root is four levels up.
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(here))))


def _run_lint(root: str, verbose: bool) -> int:
    findings: List[Finding] = lint_paths(root)
    bad = [f for f in findings if not f.allowed]
    allowed = [f for f in findings if f.allowed]
    for finding in bad:
        print(finding.format())
    if verbose:
        for finding in allowed:
            print(finding.format())
    print(f"lint: {len(bad)} finding(s), {len(allowed)} allowed with "
          "justification")
    return 1 if bad else 0


def _run_collectives(verbose: bool) -> int:
    from .collectives import check_repo

    schedules, violations = check_repo()
    for schedule in schedules:
        ops = ", ".join(map(str, schedule.ops)) or "no collectives"
        print(f"collectives: {schedule.where}: {ops}")
    for violation in violations:
        print(f"collectives: {violation}")
    print(f"collectives: {len(schedules)} program(s) traced, "
          f"{len(violations)} violation(s)")
    return 1 if violations else 0


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="Static invariant checks: repo lint rules and "
                    "shard_map collective schedules.")
    parser.add_argument("what", nargs="?", default="all",
                        choices=("all", "lint", "collectives"))
    parser.add_argument("--root", default=None,
                        help="repo root (default: cwd if it holds "
                             "src/repro, else derived from this file)")
    parser.add_argument("-v", "--verbose", action="store_true",
                        help="also print allowed findings and schedules")
    args = parser.parse_args(argv)

    root = _find_root(args.root)
    status = 0
    if args.what in ("all", "lint"):
        status |= _run_lint(root, args.verbose)
    if args.what in ("all", "collectives"):
        status |= _run_collectives(args.verbose)
    return status


if __name__ == "__main__":
    sys.exit(main())

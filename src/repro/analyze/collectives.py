"""Static collective-schedule extraction and deadlock detection.

Every ``shard_map`` program in this repo must satisfy one property to be
deadlock-free: **all shards execute the identical ordered sequence of
collectives**.  A collective reached from only one branch of a
data-dependent ``lax.cond`` (the ``exchange_every`` / tournament bug
class), or sitting inside a ``while_loop`` whose trip count can differ
per shard, hangs the mesh — and only at scale, never under the
single-process simulator the tests run.

This module verifies the property *statically*, at trace level, with no
devices attached:

* :func:`collective_schedule` traces a function under
  ``jax.make_jaxpr(..., axis_env=...)`` and walks the jaxpr (recursing
  through ``pjit`` / ``scan`` / ``shard_map`` sub-jaxprs), emitting the
  ordered :class:`CollectiveOp` list plus :class:`Violation` records for
  divergent ``cond`` branches and collectives under ``while``.
* :func:`collective_schedule_from_hlo` does the same walk over compiled
  HLO text, reusing the ``launch/hlo.py`` parser — the post-XLA
  cross-check (DCE or rewrites can change the schedule the jaxpr
  promised).
* :func:`check_repo` traces the registered ``shard_map`` round functions
  of ``scale/shard.py``, ``core/packed_reduce.py`` and
  ``dist/compression.py``, verifies their axis names against the mesh
  they run on, pins each traced schedule against the registry, and
  exercises replica-consistency of the pivot-exchange wire
  (``stack_wire_payloads`` round-trip + Elias–Fano delta codec) on
  deliberately uneven per-shard payloads.

Heavy imports (``jax``, the repro modules under test) happen inside
functions so that importing this module stays cheap.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "CollectiveOp",
    "Violation",
    "Schedule",
    "collective_schedule",
    "collective_schedule_from_hlo",
    "schedule_signature",
    "verify_axes",
    "Program",
    "repo_programs",
    "check_exchange_consistency",
    "check_repo",
]

# jaxpr primitive names that lower to cross-replica communication.
COLLECTIVE_PRIMS = frozenset({
    "all_gather", "all_to_all", "pbroadcast", "pmax", "pmin", "ppermute",
    "pshuffle", "psum", "psum_scatter", "reduce_scatter",
})


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective in program order."""

    name: str
    axes: Tuple[str, ...] = ()
    shapes: Tuple[Tuple[int, ...], ...] = ()
    group_size: int = 0

    def __str__(self) -> str:
        axes = ",".join(self.axes) if self.axes else "?"
        return f"{self.name}[{axes}]"


@dataclasses.dataclass(frozen=True)
class Violation:
    """A statically detected shard-uniformity / axis problem."""

    kind: str  # divergent-cond | while-collective | unknown-axis | ...
    where: str
    detail: str

    def __str__(self) -> str:
        return f"[{self.kind}] {self.where}: {self.detail}"


@dataclasses.dataclass
class Schedule:
    """Ordered collective schedule of one traced program."""

    where: str
    ops: List[CollectiveOp]
    violations: List[Violation]

    def signature(self) -> Tuple[Tuple[str, Tuple[str, ...]], ...]:
        return schedule_signature(self.ops)


def schedule_signature(
        ops: Sequence[CollectiveOp]) -> Tuple[Tuple[str, Tuple[str, ...]], ...]:
    """The order-sensitive (primitive, axes) fingerprint of a schedule."""
    return tuple((op.name, op.axes) for op in ops)


def _normalize_axes(value: Any) -> Tuple[str, ...]:
    if value is None:
        return ()
    if isinstance(value, (tuple, list)):
        return tuple(str(v) for v in value)
    return (str(value),)


def _eqn_axes(params: Mapping[str, Any]) -> Tuple[str, ...]:
    for key in ("axes", "axis_name", "axis_names"):
        if key in params:
            return _normalize_axes(params[key])
    return ()


def _as_jaxpr(obj: Any) -> Any:
    """Unwrap ClosedJaxpr-likes to the inner Jaxpr (duck-typed)."""
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    if hasattr(obj, "eqns"):
        return obj
    return None


def _sub_jaxprs(params: Mapping[str, Any],
                skip: Tuple[str, ...]) -> List[Any]:
    subs: List[Any] = []
    for key, value in params.items():
        if key in skip:
            continue
        values = value if isinstance(value, (tuple, list)) else (value,)
        for item in values:
            jaxpr = _as_jaxpr(item)
            if jaxpr is not None:
                subs.append(jaxpr)
    return subs


def _walk(jaxpr: Any, where: str, ops: List[CollectiveOp],
          violations: List[Violation]) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        params = eqn.params
        if prim in COLLECTIVE_PRIMS:
            shapes = tuple(tuple(int(d) for d in v.aval.shape)
                           for v in eqn.outvars)
            ops.append(CollectiveOp(prim, _eqn_axes(params), shapes))
        elif prim == "cond":
            branch_runs = []
            for i, branch in enumerate(params.get("branches", ())):
                sub_ops: List[CollectiveOp] = []
                _walk(_as_jaxpr(branch), f"{where}/cond.branch{i}", sub_ops,
                      violations)
                branch_runs.append(sub_ops)
            signatures = {schedule_signature(run) for run in branch_runs}
            if len(signatures) > 1:
                pretty = sorted(
                    "(" + ", ".join(map(str, run)) + ")"
                    for run in branch_runs)
                violations.append(Violation(
                    "divergent-cond", where,
                    "lax.cond branches disagree on their collective "
                    f"schedule: {' vs '.join(pretty)}; a shard taking the "
                    "other branch deadlocks the mesh"))
            if branch_runs:
                ops.extend(max(branch_runs, key=len))
        elif prim == "while":
            body_ops: List[CollectiveOp] = []
            for key in ("cond_jaxpr", "body_jaxpr"):
                sub = _as_jaxpr(params.get(key))
                if sub is not None:
                    _walk(sub, f"{where}/while.{key}", body_ops, violations)
            if body_ops:
                violations.append(Violation(
                    "while-collective", where,
                    "collective(s) "
                    f"({', '.join(map(str, body_ops))}) inside a "
                    "while_loop; shards that disagree on the trip count "
                    "deadlock — hoist the collective or fix the trip count"))
                ops.extend(body_ops)
        else:
            for sub in _sub_jaxprs(params, skip=("branches",)):
                _walk(sub, f"{where}/{prim}", ops, violations)


def collective_schedule(fn: Callable[..., Any], args: Sequence[Any],
                        axis_env: Sequence[Tuple[str, int]],
                        where: Optional[str] = None) -> Schedule:
    """Trace ``fn(*args)`` under ``axis_env`` and extract its schedule.

    ``axis_env`` is a sequence of ``(axis_name, size)`` pairs, exactly as
    accepted by ``jax.make_jaxpr`` — no devices or mesh required.
    """
    import jax

    label = where or getattr(fn, "__name__", repr(fn))
    closed = jax.make_jaxpr(fn, axis_env=list(axis_env))(*args)
    ops: List[CollectiveOp] = []
    violations: List[Violation] = []
    _walk(closed.jaxpr, label, ops, violations)
    return Schedule(label, ops, violations)


def verify_axes(schedule: Schedule,
                mesh_axes: Sequence[str]) -> List[Violation]:
    """Every collective axis must exist on the mesh it runs under."""
    known = set(mesh_axes)
    violations: List[Violation] = []
    for op in schedule.ops:
        missing = [a for a in op.axes if a not in known]
        if missing:
            violations.append(Violation(
                "unknown-axis", schedule.where,
                f"{op} names axis(es) {missing} absent from the mesh axes "
                f"{sorted(known)}"))
    return violations


# ---------------------------------------------------------------------------
# HLO-level cross-check (post-XLA), reusing the launch/hlo.py parser.
# ---------------------------------------------------------------------------

def collective_schedule_from_hlo(hlo_text: str, where: str = "<hlo>",
                                 pod_size: int = 256) -> Schedule:
    """Extract the collective schedule from compiled HLO text.

    Walks the entry computation in program order, inlining called and
    fusion-called computations and while bodies, reusing the
    ``launch/hlo.py`` parser.  A collective reached through a while loop
    whose trip count the parser cannot prove is flagged
    ``while-collective`` — the same deadlock class as the jaxpr walker,
    but after XLA had its say (DCE and rewrites can change the schedule
    the jaxpr promised).
    """
    import re

    from ..launch.hlo import (COLLECTIVES, _group_info, _parse_computation,
                              _split_computations)

    raw = _split_computations(hlo_text)
    parsed = {name: _parse_computation(name, lines, pod_size)
              for name, lines in raw.items()}
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            match = re.search(r"ENTRY\s+%?([\w.\-]+)", line)
            if match:
                entry = match.group(1)
            break
    if entry is None and parsed:
        entry = next(iter(parsed))

    ops: List[CollectiveOp] = []
    violations: List[Violation] = []

    def visit(name: str, in_unproven_while: bool,
              stack: Tuple[str, ...]) -> None:
        comp = parsed.get(name)
        if comp is None or name in stack:
            return
        stack = stack + (name,)
        for op in comp.ops:
            base = op.opcode[:-len("-start")] \
                if op.opcode.endswith("-start") else op.opcode
            if base in COLLECTIVES:
                group_size, _ = _group_info(op.line, pod_size)
                ops.append(CollectiveOp(base, (), (), group_size))
                if in_unproven_while:
                    violations.append(Violation(
                        "while-collective", where,
                        f"HLO {base} executes under a while loop with an "
                        "unproven trip count; shards that disagree on the "
                        "trip count deadlock"))
        for callee in comp.calls:
            visit(callee, in_unproven_while, stack)
        for callee in comp.fusion_calls:
            visit(callee, in_unproven_while, stack)
        for cond, body, trip in comp.whiles:
            risky = in_unproven_while or trip <= 0
            visit(cond, risky, stack)
            visit(body, risky, stack)

    if entry is not None:
        visit(entry, False, ())
    return Schedule(where, ops, violations)


# ---------------------------------------------------------------------------
# The repo registry: every shard_map program we ship, with its pinned
# schedule.  A mismatch is a violation — update the registry only together
# with the driver change that alters the schedule.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Program:
    """A registered shard_map round function and its pinned schedule."""

    name: str
    build: Callable[[], Tuple[Callable[..., Any], Tuple[Any, ...],
                              Tuple[Tuple[str, int], ...]]]
    mesh_axes: Tuple[str, ...]
    expect: Tuple[Tuple[str, Tuple[str, ...]], ...]


def repo_programs() -> List[Program]:
    """Build closures for every shard_map round function in the repo."""
    import functools

    def candidate_round() -> Tuple[Callable[..., Any], Tuple[Any, ...],
                                   Tuple[Tuple[str, int], ...]]:
        import jax.numpy as jnp
        from ..scale.shard import _candidate_round_fn
        fn = functools.partial(_candidate_round_fn, interpret=True)
        x = jnp.zeros((1, 8, 3), jnp.float32)
        return fn, (x, x), (("data", 4),)

    def dists_round() -> Tuple[Callable[..., Any], Tuple[Any, ...],
                               Tuple[Tuple[str, int], ...]]:
        import numpy as np
        import jax.numpy as jnp
        from ..scale.shard import _dists_round_fn
        fn = functools.partial(_dists_round_fn, thr32=np.float32(1.0))
        return fn, (jnp.zeros((1, 8, 8), jnp.float32),), (("data", 4),)

    def exchange_round() -> Tuple[Callable[..., Any], Tuple[Any, ...],
                                  Tuple[Tuple[str, int], ...]]:
        import jax.numpy as jnp
        from ..core.packed_reduce import _exchange_round_fn
        fn = functools.partial(_exchange_round_fn, axis_name="data")
        return fn, (jnp.zeros((1, 1024), jnp.uint32),), (("data", 4),)

    def psum_grads() -> Tuple[Callable[..., Any], Tuple[Any, ...],
                              Tuple[Tuple[str, int], ...]]:
        import jax.numpy as jnp
        from ..dist.compression import compressed_psum_grads

        def fn(grads: Any, errs: Any) -> Any:
            return compressed_psum_grads(grads, errs, axis_name="data")

        leaf = jnp.zeros((4, 4), jnp.float32)
        return fn, ({"w": leaf}, {"w": jnp.zeros_like(leaf)}), (("data", 4),)

    return [
        Program("scale.shard._candidate_round_fn", candidate_round,
                ("data",), expect=()),
        Program("scale.shard._dists_round_fn", dists_round,
                ("data",), expect=()),
        Program("core.packed_reduce._exchange_round_fn", exchange_round,
                ("data",), expect=(("all_gather", ("data",)),)),
        Program("dist.compression.compressed_psum_grads", psum_grads,
                ("data",),
                expect=(("all_gather", ("data",)),
                        ("all_gather", ("data",)))),
    ]


def check_exchange_consistency() -> List[Violation]:
    """Replica-consistency of the pivot-exchange wire, statically.

    Every shard enters the ``all_gather`` with the *same* padded payload
    length, whatever its local commit count — that is the job of
    ``stack_wire_payloads``.  And a replica applies exactly the records
    the owner committed — that is the job of the Elias–Fano delta codec.
    Both are pure host code, so we can verify them here on deliberately
    uneven per-shard loads without any devices.
    """
    import numpy as np

    from ..core.pivot_cache import decode_commit_delta, encode_commit_delta
    from ..kernels.gf2 import stack_wire_payloads, unstack_wire_payloads

    violations: List[Violation] = []
    where = "pivot-exchange wire"

    for sizes in [(0, 0, 0, 0), (0, 1, 7, 1000), (5, 5, 5, 5),
                  (1023, 1025, 1, 64)]:
        payloads = [np.arange(s, dtype=np.uint32) % 97 for s in sizes]
        stacked, lengths = stack_wire_payloads(payloads)
        if stacked.ndim != 2 or stacked.shape[0] != len(sizes):
            violations.append(Violation(
                "wire-shape", where,
                f"stack_wire_payloads({sizes}) produced shape "
                f"{stacked.shape}; shards would all_gather unequal blocks"))
            continue
        width = int(stacked.shape[1])
        if width < max(sizes) or (width & (width - 1)) != 0:
            violations.append(Violation(
                "wire-shape", where,
                f"padded wire width {width} for shard loads {sizes} is not "
                "a power-of-two cover; shards would disagree on the "
                "all_gather element count"))
        back = unstack_wire_payloads(stacked, lengths)
        if not all(np.array_equal(a, b) for a, b in zip(payloads, back)):
            violations.append(Violation(
                "wire-roundtrip", where,
                f"stack/unstack round-trip corrupted a payload ({sizes})"))

    lows = np.array([3, 11, 12, 40], dtype=np.int64)
    records = [
        {"low": int(lows[0]), "col_id": 7, "mode": "explicit",
         "column": np.array([3, 5, 9], dtype=np.int64),
         "gens": np.array([1], dtype=np.int64)},
        {"low": int(lows[1]), "col_id": 8, "mode": "implicit",
         "column": None, "gens": np.array([2, 4], dtype=np.int64)},
        {"low": int(lows[2]), "col_id": 9, "mode": "explicit",
         "column": np.array([12], dtype=np.int64), "gens": None},
        {"low": int(lows[3]), "col_id": 13, "mode": "implicit",
         "column": None, "gens": None},
    ]
    for count in (0, 1, len(records)):
        subset = records[:count]
        decoded = decode_commit_delta(encode_commit_delta(subset))
        same = len(decoded) == len(subset) and all(
            int(a["low"]) == int(b["low"])
            and int(a["col_id"]) == int(b["col_id"])
            and str(a["mode"]) == str(b["mode"])
            for a, b in zip(subset, decoded))
        if not same:
            violations.append(Violation(
                "wire-roundtrip", where,
                f"Elias–Fano commit-delta codec failed the {count}-record "
                "round-trip; replicas would apply a different pivot set "
                "than the owner committed"))
    return violations


def check_repo() -> Tuple[List[Schedule], List[Violation]]:
    """Trace every registered program; collect all violations."""
    schedules: List[Schedule] = []
    violations: List[Violation] = []
    for program in repo_programs():
        try:
            fn, args, axis_env = program.build()
            schedule = collective_schedule(fn, args, axis_env, program.name)
        except Exception as exc:  # noqa: BLE001 - reported, not raised
            violations.append(Violation(
                "trace-error", program.name,
                f"failed to trace the registered program: {exc!r}"))
            continue
        schedules.append(schedule)
        violations.extend(schedule.violations)
        violations.extend(verify_axes(schedule, program.mesh_axes))
        signature = schedule.signature()
        if signature != program.expect:
            violations.append(Violation(
                "schedule-mismatch", program.name,
                f"traced collective schedule {signature} != registered "
                f"{program.expect}; update the registry only together with "
                "the driver change that re-orders the schedule"))
    violations.extend(check_exchange_consistency())
    return schedules, violations

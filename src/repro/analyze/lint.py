"""AST lint pass with repo-specific rules derived from shipped bugs.

Every rule here encodes a bug class this repo has actually hit (or is one
code review away from hitting):

* ``pallas-ref-mutation`` — a Pallas kernel may mutate a ``Ref`` only via
  top-level ``ref[...] = value`` stores.  Stores issued from inside a
  nested ``def``/``lambda`` (a ``fori_loop``/``scan``/``cond`` body) are
  traced into a *different* scope and are silently dropped when the
  kernel is discharged in interpret mode — the PR 2 discharge bug class.
* ``host-sync`` — ``.item()``, ``np.asarray(device_fn(...))``,
  ``jax.device_get`` and ``block_until_ready`` inside a superstep or
  harvest hot loop serialize the pipeline on a device round-trip per
  iteration.  Applies to the known hot modules and to any file carrying
  an ``# analyze: hot`` marker.
* ``raw-filtration-sort`` — sorting filtration values (edge lengths,
  diameters, distances) with a bare ``sort``/``argsort``/short
  ``lexsort`` loses the canonical ``(length, i, j)`` tie-break that
  makes diagrams reproducible across engines and tile schedules; use
  ``filtration_from_edges`` / ``merge_edge_chunks``.
* ``f32-exact-compare`` — f32 candidate quantities must never be
  compared against the exact (f64) threshold; compare against the
  margin-widened f32 threshold (``_f32_threshold``) and re-measure
  survivors in f64.
* ``unseeded-rng`` — benchmarks and examples must use
  ``np.random.default_rng(seed)``; legacy global or unseeded RNG makes
  perf and diagram numbers irreproducible.
* ``raw-timing`` — ad-hoc ``time.time()`` / ``time.perf_counter()``
  pairs outside ``repro/obs/`` and ``benchmarks/`` bypass the tracer:
  the measurement never lands in the span timeline or the BENCH JSON
  phase breakdown.  Use :func:`repro.obs.trace.stopwatch` (always
  yields ``.elapsed``, records a span when tracing is active).
* ``span-leak`` — ``span(...)`` / ``stopwatch(...)`` must be used as a
  ``with`` context item (or via the ``traced()`` decorator).  A bare
  call creates a context manager that is never entered/exited, so the
  span silently never closes — especially on exception paths.
* ``bare-except`` — recovery paths must catch *typed* faults
  (``TransientFault``, ``WireCorruption``, ``CheckpointCorruption``, …).
  A bare ``except:`` swallows ``KeyboardInterrupt``/``SystemExit`` and —
  worse for this repo — silently absorbs injected faults the resilience
  suite relies on propagating, turning a CI-gated exactness failure into
  a wrong-answer run.
* ``retry-without-backoff`` — a retry loop that sleeps a *constant*
  between attempts hammers a struggling peer in lockstep and replays
  differently under load; use
  :func:`repro.resilience.faults.retry_with_backoff`, whose jittered
  exponential schedule is deterministic given its seed.  Sleeps of a
  computed (non-constant) duration are assumed to be such a schedule.

Deliberate exceptions are suppressed in place with a *justified* pragma
on the offending line (or the line above)::

    d2 = np.asarray(fn(x))  # analyze: allow[host-sync] one sync per round is the schedule

A pragma without a justification is itself a finding (``bare-allow``):
the pragma is the audit trail, not an off switch.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Finding",
    "Rule",
    "RefMutationRule",
    "HostSyncRule",
    "RawFiltrationSortRule",
    "DtypeBoundaryRule",
    "UnseededRngRule",
    "RawTimingRule",
    "SpanLeakRule",
    "BareExceptRule",
    "RetryWithoutBackoffRule",
    "default_rules",
    "lint_source",
    "lint_file",
    "lint_paths",
]


@dataclasses.dataclass
class Finding:
    """One rule violation at a source location."""

    path: str
    line: int
    col: int
    rule: str
    message: str
    allowed: bool = False
    justification: str = ""

    def format(self) -> str:
        text = f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"
        if self.allowed:
            text += f"  (allowed: {self.justification})"
        return text


class Rule:
    """Base class: one repo-specific lint rule."""

    name = "rule"

    def applies(self, relpath: str, source: str) -> bool:
        return True

    def check(self, tree: ast.Module, source: str,
              relpath: str) -> List[Finding]:
        raise NotImplementedError

    # -- shared helpers -------------------------------------------------
    @staticmethod
    def _attr_chain(node: ast.AST) -> Tuple[str, ...]:
        """``np.random.default_rng`` -> ("np", "random", "default_rng")."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
            return tuple(reversed(parts))
        return ()

    def _finding(self, relpath: str, node: ast.AST, message: str) -> Finding:
        return Finding(relpath, getattr(node, "lineno", 0),
                       getattr(node, "col_offset", 0), self.name, message)


class RefMutationRule(Rule):
    """Pallas ``Ref`` stores are only legal at kernel top level.

    A function is treated as a kernel when it has parameters named
    ``*_ref`` / ``*_refs`` (the repo-wide Pallas naming convention).
    Inside it, any ``ref[...] = ...`` (or ``ref[...] ^= ...``) issued
    from a nested ``def`` or ``lambda`` — i.e. a ``fori_loop`` / ``scan``
    / ``cond`` body that Pallas traces as a separate scope — is flagged:
    interpret-mode discharge drops those stores silently.
    """

    name = "pallas-ref-mutation"

    def check(self, tree: ast.Module, source: str,
              relpath: str) -> List[Finding]:
        findings: List[Finding] = []
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            args = fn.args
            params = [a.arg for a in (args.posonlyargs + args.args
                                      + args.kwonlyargs)]
            refs = {p for p in params
                    if p.endswith("_ref") or p.endswith("_refs")}
            if not refs:
                continue
            findings.extend(self._check_kernel(fn, refs, relpath))
        return findings

    def _check_kernel(self, kernel: ast.AST, refs: Set[str],
                      relpath: str) -> List[Finding]:
        findings: List[Finding] = []

        def is_ref_store(target: ast.AST) -> bool:
            return (isinstance(target, ast.Subscript)
                    and isinstance(target.value, ast.Name)
                    and target.value.id in refs)

        def visit(node: ast.AST, nested: bool) -> None:
            for child in ast.iter_child_nodes(node):
                child_nested = nested or isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda))
                if nested and isinstance(child, ast.Assign) and any(
                        is_ref_store(t) for t in child.targets):
                    findings.append(self._finding(
                        relpath, child,
                        "Ref store inside a nested trace scope (fori_loop/"
                        "scan/cond body); interpret-mode discharge drops it "
                        "— hoist the store to kernel top level or carry the "
                        "value through the loop carry"))
                elif nested and isinstance(child, ast.AugAssign) and \
                        is_ref_store(child.target):
                    findings.append(self._finding(
                        relpath, child,
                        "in-place Ref update inside a nested trace scope; "
                        "interpret-mode discharge drops it"))
                visit(child, child_nested)

        visit(kernel, nested=False)
        return findings


class HostSyncRule(Rule):
    """No host↔device synchronization inside hot loops.

    Applies only to the superstep/harvest hot modules (and to any source
    carrying an ``# analyze: hot`` marker).  Inside any ``for``/``while``
    body there, flags ``.item()``, ``.block_until_ready()``,
    ``jax.device_get(...)``, and ``np.asarray``/``np.array`` wrapped
    around a call to a known device function (anything imported from
    ``repro.kernels`` or assigned from ``jax.jit`` / ``jax.shard_map`` /
    ``pl.pallas_call``).
    """

    name = "host-sync"
    HOT_SUFFIXES = (
        "repro/core/packed_reduce.py",
        "repro/core/serial_parallel.py",
        "repro/scale/shard.py",
        "repro/scale/tiles.py",
    )
    HOT_MARKER = "# analyze: hot"

    def applies(self, relpath: str, source: str) -> bool:
        posix = relpath.replace(os.sep, "/")
        return (posix.endswith(self.HOT_SUFFIXES)
                or self.HOT_MARKER in source)

    def check(self, tree: ast.Module, source: str,
              relpath: str) -> List[Finding]:
        device_names = self._device_names(tree)
        findings: List[Finding] = []
        seen: Set[Tuple[int, int, str]] = set()

        def emit(node: ast.AST, message: str) -> None:
            key = (getattr(node, "lineno", 0),
                   getattr(node, "col_offset", 0), message)
            if key not in seen:
                seen.add(key)
                findings.append(self._finding(relpath, node, message))

        for loop in ast.walk(tree):
            if not isinstance(loop, (ast.For, ast.AsyncFor, ast.While)):
                continue
            for node in ast.walk(loop):
                if node is loop or not isinstance(node, ast.Call):
                    continue
                self._check_call(node, device_names, emit)
        return findings

    def _check_call(self, call: ast.Call, device_names: Set[str],
                    emit) -> None:
        func = call.func
        if isinstance(func, ast.Attribute):
            if func.attr == "item" and not call.args:
                emit(call, ".item() synchronizes the device stream once per "
                           "loop iteration; batch the transfer outside the "
                           "loop")
                return
            if func.attr == "block_until_ready":
                emit(call, "block_until_ready() inside a hot loop serializes "
                           "dispatch; sync once after the loop")
                return
        chain = self._attr_chain(func)
        if chain == ("jax", "device_get"):
            emit(call, "jax.device_get inside a hot loop forces a device "
                       "round-trip per iteration")
            return
        if (len(chain) == 2 and chain[0] in ("np", "numpy")
                and chain[1] in ("asarray", "array") and call.args
                and self._calls_device_fn(call.args[0], device_names)):
            emit(call, "host gather of a device computation "
                       "(np.asarray(device_fn(...))) inside a hot loop; one "
                       "blocking transfer per iteration")

    @staticmethod
    def _calls_device_fn(node: ast.AST, device_names: Set[str]) -> bool:
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            func = sub.func
            if isinstance(func, ast.Name) and func.id in device_names:
                return True
            if isinstance(func, ast.Subscript) and \
                    isinstance(func.value, ast.Name) and \
                    func.value.id in device_names:
                return True
        return False

    def _device_names(self, tree: ast.Module) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module and \
                    "kernels" in node.module.split("."):
                names.update(a.asname or a.name for a in node.names)
            elif isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    self._is_device_ctor(node.value):
                for target in node.targets:
                    if isinstance(target, ast.Name):
                        names.add(target.id)
                    elif isinstance(target, ast.Subscript) and \
                            isinstance(target.value, ast.Name):
                        names.add(target.value.id)
        return names

    def _is_device_ctor(self, call: ast.Call) -> bool:
        chain = self._attr_chain(call.func)
        if chain and chain[-1] in ("jit", "shard_map", "pallas_call", "pmap"):
            return True
        # jax.jit(jax.shard_map(...)) — look one call deeper.
        return any(isinstance(a, ast.Call) and self._is_device_ctor(a)
                   for a in call.args)


class RawFiltrationSortRule(Rule):
    """Filtration values must be ordered with the canonical tie-break.

    Flags ``sort``/``argsort``/``sorted`` whose primary key *names* a
    filtration quantity (``lens``, ``length``, ``dist``, ``diam``, …) and
    ``np.lexsort`` calls whose primary key is such a quantity but which
    carry fewer than the three canonical ``(length, i, j)`` keys.
    """

    name = "raw-filtration-sort"
    _VALUE = re.compile(
        r"(^|_)(len|lens|length|lengths|dist|dists|distance|distances|"
        r"diam|diams|diameter|diameters|edge_len|filt|filtration)(_|$|\d*$)")

    def _names_value(self, node: ast.AST) -> bool:
        for sub in ast.walk(node):
            name = None
            if isinstance(sub, ast.Name):
                name = sub.id
            elif isinstance(sub, ast.Attribute):
                name = sub.attr
            if name is not None and self._VALUE.search(name):
                return True
        return False

    def check(self, tree: ast.Module, source: str,
              relpath: str) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            chain = self._attr_chain(node.func)
            is_sort = (chain[-1:] and chain[-1] in ("sort", "argsort")) or \
                      chain == ("sorted",)
            if is_sort and node.args and self._names_value(node.args[0]):
                findings.append(self._finding(
                    relpath, node,
                    "raw sort on filtration values; ties must break by the "
                    "canonical (length, i, j) lexsort "
                    "(filtration_from_edges / merge_edge_chunks)"))
                continue
            if is_sort and not node.args and len(chain) >= 2 and \
                    self._VALUE.search(chain[-2]):
                findings.append(self._finding(
                    relpath, node,
                    "in-place sort of filtration values; use the canonical "
                    "(length, i, j) lexsort"))
                continue
            if chain[-1:] == ("lexsort",) and node.args and \
                    isinstance(node.args[0], (ast.Tuple, ast.List)):
                keys = node.args[0].elts
                if keys and self._names_value(keys[-1]) and len(keys) < 3:
                    findings.append(self._finding(
                        relpath, node,
                        "lexsort on filtration values without the full "
                        "(length, i, j) tie-break; diagrams become "
                        "schedule-dependent on ties"))
        return findings


class DtypeBoundaryRule(Rule):
    """f32 candidates are never compared against the exact threshold.

    The tiled harvest measures candidates in f32 and must compare them
    against the margin-widened f32 threshold (``_f32_threshold``), never
    against ``tau_max``/``tau`` directly — f32 rounding near the
    threshold would otherwise drop edges the f64 refine pass expects.
    Names are the contract: anything assigned through ``float32`` /
    ``.astype(np.float32)`` (or a ``*32``/``*_f32`` parameter) is
    f32-tainted; ``tau``-named values are the exact threshold.
    """

    name = "f32-exact-compare"
    _TAU = re.compile(r"(^|_)tau(_|$)")

    def check(self, tree: ast.Module, source: str,
              relpath: str) -> List[Finding]:
        findings: List[Finding] = []
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            tainted = self._tainted_names(fn)
            if not tainted:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Compare):
                    continue
                sides = [node.left] + list(node.comparators)
                has_f32 = any(self._uses(s, tainted) for s in sides)
                has_tau = any(self._names_tau(s) for s in sides)
                if has_f32 and has_tau:
                    findings.append(self._finding(
                        relpath, node,
                        "f32 candidate compared against the exact threshold; "
                        "compare against the margin-widened f32 threshold "
                        "(_f32_threshold) and re-measure survivors in f64"))
        return findings

    def _tainted_names(self, fn: ast.AST) -> Set[str]:
        args = fn.args
        tainted = {a.arg for a in (args.posonlyargs + args.args
                                   + args.kwonlyargs)
                   if a.arg.endswith("32") or a.arg.endswith("_f32")}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                f32 = self._is_f32_expr(node.value)
                for target in node.targets:
                    if isinstance(target, ast.Name) and (
                            f32 or target.id.endswith("32")
                            or target.id.endswith("_f32")):
                        tainted.add(target.id)
        return tainted

    def _is_f32_expr(self, node: ast.AST) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute) and sub.attr == "float32":
                return True
            if isinstance(sub, ast.Constant) and sub.value == "float32":
                return True
        return False

    @staticmethod
    def _uses(node: ast.AST, names: Set[str]) -> bool:
        return any(isinstance(sub, ast.Name) and sub.id in names
                   for sub in ast.walk(node))

    def _names_tau(self, node: ast.AST) -> bool:
        for sub in ast.walk(node):
            name = sub.id if isinstance(sub, ast.Name) else (
                sub.attr if isinstance(sub, ast.Attribute) else None)
            if name is not None and self._TAU.search(name):
                return True
        return False


class UnseededRngRule(Rule):
    """Benchmarks and examples must seed their RNG explicitly."""

    name = "unseeded-rng"
    _LEGACY = ("rand", "randn", "randint", "random", "choice", "shuffle",
               "permutation", "uniform", "normal", "standard_normal", "seed")
    _STDLIB = ("random", "randint", "randrange", "choice", "shuffle",
               "uniform", "gauss", "sample")

    def applies(self, relpath: str, source: str) -> bool:
        posix = relpath.replace(os.sep, "/")
        return posix.startswith(("benchmarks/", "examples/")) or \
            "/benchmarks/" in posix or "/examples/" in posix

    def check(self, tree: ast.Module, source: str,
              relpath: str) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            chain = self._attr_chain(node.func)
            if len(chain) == 3 and chain[0] in ("np", "numpy") and \
                    chain[1] == "random" and chain[2] in self._LEGACY:
                findings.append(self._finding(
                    relpath, node,
                    f"legacy global RNG np.random.{chain[2]} is unseeded "
                    "across runs; use np.random.default_rng(seed)"))
            elif chain[-1:] == ("default_rng",) and (
                    not node.args or (isinstance(node.args[0], ast.Constant)
                                      and node.args[0].value is None)):
                findings.append(self._finding(
                    relpath, node,
                    "np.random.default_rng() without a seed; benchmark "
                    "numbers become irreproducible"))
            elif len(chain) == 2 and chain[0] == "random" and \
                    chain[1] in self._STDLIB:
                findings.append(self._finding(
                    relpath, node,
                    f"stdlib random.{chain[1]} uses unseeded global state; "
                    "use np.random.default_rng(seed)"))
        return findings


class RawTimingRule(Rule):
    """Timing must flow through the tracer, not ad-hoc clock reads.

    Flags ``time.time()``, ``time.perf_counter()``,
    ``time.perf_counter_ns()`` and ``time.process_time()`` — via the
    module attribute or imported bare (``from time import
    perf_counter``) — everywhere except ``repro/obs/`` (which owns the
    one blessed clock) and ``benchmarks/`` (whose wall-clock gates are
    the measurement itself, not a phase to attribute).
    ``time.monotonic`` (deadline arithmetic) and ``time.sleep`` are
    deliberately not timing measurements and stay legal.
    """

    name = "raw-timing"
    _CLOCKS = ("time", "perf_counter", "perf_counter_ns", "process_time")

    def applies(self, relpath: str, source: str) -> bool:
        posix = relpath.replace(os.sep, "/")
        if posix.startswith("benchmarks/") or "/benchmarks/" in posix:
            return False
        return "repro/obs/" not in posix

    def check(self, tree: ast.Module, source: str,
              relpath: str) -> List[Finding]:
        imported = self._imported_clocks(tree)
        findings: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            clock = self._clock_name(node.func, imported)
            if clock is not None:
                findings.append(self._finding(
                    relpath, node,
                    f"raw clock read time.{clock}() bypasses the tracer; "
                    "use repro.obs.trace.stopwatch(name) so the interval "
                    "lands in the span timeline"))
        return findings

    def _clock_name(self, func: ast.AST,
                    imported: Set[str]) -> Optional[str]:
        chain = self._attr_chain(func)
        if len(chain) == 2 and chain[0] == "time" and \
                chain[1] in self._CLOCKS:
            return chain[1]
        if isinstance(func, ast.Name) and func.id in imported:
            return func.id
        return None

    def _imported_clocks(self, tree: ast.Module) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                names.update(a.asname or a.name for a in node.names
                             if a.name in self._CLOCKS)
        return names


class SpanLeakRule(Rule):
    """Every opened span must close — even on the exception path.

    A ``span(...)`` / ``stopwatch(...)`` call (bare or as a
    ``Tracer``-method ``tl.span(...)``) that is not a ``with`` context
    item produces a context manager that is never entered: the span
    never records, or — worse — an explicit ``__enter__`` without the
    guarded ``__exit__`` leaks an open span when the body raises.  The
    ``with`` statement is the only form whose exit runs on exceptions.
    """

    name = "span-leak"
    _OPENERS = ("span", "stopwatch")

    def applies(self, relpath: str, source: str) -> bool:
        return "repro/obs/" not in relpath.replace(os.sep, "/")

    def check(self, tree: ast.Module, source: str,
              relpath: str) -> List[Finding]:
        with_items: Set[int] = set()
        for node in ast.walk(tree):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                with_items.update(id(item.context_expr)
                                  for item in node.items)
        findings: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call) or id(node) in with_items:
                continue
            chain = self._attr_chain(node.func)
            if chain[-1:] and chain[-1] in self._OPENERS:
                findings.append(self._finding(
                    relpath, node,
                    f"{chain[-1]}(...) not used as a `with` item; the span "
                    "never closes on the exception path — write "
                    f"`with {chain[-1]}(...):` (or use the traced() "
                    "decorator)"))
        return findings


class BareExceptRule(Rule):
    """Exception handlers must name what they recover from.

    A bare ``except:`` catches ``KeyboardInterrupt``/``SystemExit`` and
    every injected fault the resilience suite expects to propagate —
    recovery code that swallows :class:`~repro.resilience.faults
    .CheckpointCorruption` or a :class:`~repro.resilience.faults
    .TransientFault` whose retry budget is spent converts a loud,
    CI-gated failure into silently wrong state.  Catch the typed fault
    (or at widest ``Exception``) instead.
    """

    name = "bare-except"

    def check(self, tree: ast.Module, source: str,
              relpath: str) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.ExceptHandler) and node.type is None:
                findings.append(self._finding(
                    relpath, node,
                    "bare `except:` swallows KeyboardInterrupt and injected "
                    "faults; catch the typed fault the recovery path "
                    "actually handles (TransientFault, WireCorruption, "
                    "CheckpointCorruption, ... or at widest Exception)"))
        return findings


class RetryWithoutBackoffRule(Rule):
    """Retry loops must back off, not hammer at a fixed cadence.

    Flags a ``time.sleep`` (or bare ``sleep`` imported from ``time``)
    with a *constant* duration inside a ``for``/``while`` loop that also
    contains a ``try``/``except`` — the signature of a hand-rolled retry
    loop.  Fixed-interval retries pile onto a struggling peer in
    lockstep and make the failure history irreproducible; use
    ``repro.resilience.faults.retry_with_backoff`` (deterministic
    jittered exponential schedule).  A sleep whose duration is computed
    is assumed to already be such a schedule.
    """

    name = "retry-without-backoff"

    def check(self, tree: ast.Module, source: str,
              relpath: str) -> List[Finding]:
        imported = self._imported_sleep(tree)
        findings: List[Finding] = []
        for loop in ast.walk(tree):
            if not isinstance(loop, (ast.For, ast.AsyncFor, ast.While)):
                continue
            if not any(isinstance(sub, ast.Try) for sub in ast.walk(loop)):
                continue
            for node in ast.walk(loop):
                if isinstance(node, ast.Call) \
                        and self._is_sleep(node.func, imported) \
                        and node.args \
                        and isinstance(node.args[0], ast.Constant):
                    findings.append(self._finding(
                        relpath, node,
                        "constant-interval sleep in a retry loop; use "
                        "repro.resilience.faults.retry_with_backoff for a "
                        "deterministic jittered exponential schedule"))
        return findings

    def _is_sleep(self, func: ast.AST, imported: Set[str]) -> bool:
        chain = self._attr_chain(func)
        if chain == ("time", "sleep"):
            return True
        return isinstance(func, ast.Name) and func.id in imported

    @staticmethod
    def _imported_sleep(tree: ast.Module) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                names.update(a.asname or a.name for a in node.names
                             if a.name == "sleep")
        return names


def default_rules() -> List[Rule]:
    return [RefMutationRule(), HostSyncRule(), RawFiltrationSortRule(),
            DtypeBoundaryRule(), UnseededRngRule(), RawTimingRule(),
            SpanLeakRule(), BareExceptRule(), RetryWithoutBackoffRule()]


_ALLOW = re.compile(
    r"#\s*analyze:\s*allow(?:\[(?P<rules>[\w,\s-]+)\])?(?P<why>[^#\n]*)")


def _parse_pragmas(source: str) -> Dict[int, Tuple[Optional[Set[str]], str]]:
    """Map line number -> (allowed rule names or None for all, justification)."""
    pragmas: Dict[int, Tuple[Optional[Set[str]], str]] = {}
    for lineno, line in enumerate(source.splitlines(), start=1):
        match = _ALLOW.search(line)
        if match is None:
            continue
        rules = match.group("rules")
        names = ({r.strip() for r in rules.split(",") if r.strip()}
                 if rules else None)
        pragmas[lineno] = (names, match.group("why").strip())
    return pragmas


def lint_source(source: str, relpath: str = "<string>",
                rules: Optional[Sequence[Rule]] = None,
                force: bool = False) -> List[Finding]:
    """Lint one source string; returns all findings (allowed ones marked).

    ``force=True`` skips each rule's path applicability check — used by
    tests to point a single rule at a fixture regardless of where it
    lives.
    """
    active = list(rules) if rules is not None else default_rules()
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding(relpath, exc.lineno or 0, exc.offset or 0,
                        "syntax-error", str(exc.msg))]
    pragmas = _parse_pragmas(source)
    findings: List[Finding] = []
    for rule in active:
        if force or rule.applies(relpath, source):
            findings.extend(rule.check(tree, source, relpath))
    for finding in findings:
        for lineno in (finding.line, finding.line - 1):
            entry = pragmas.get(lineno)
            if entry is None:
                continue
            names, why = entry
            if names is None or finding.rule in names:
                if why:
                    finding.allowed = True
                    finding.justification = why
                break
    for lineno, (names, why) in sorted(pragmas.items()):
        if not why:
            findings.append(Finding(
                relpath, lineno, 0, "bare-allow",
                "allow pragma without a justification; write why the "
                "exception is safe (# analyze: allow[rule] <why>)"))
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings


def lint_file(path: str, root: Optional[str] = None,
              rules: Optional[Sequence[Rule]] = None,
              force: bool = False) -> List[Finding]:
    with open(path, encoding="utf-8") as handle:
        source = handle.read()
    relpath = os.path.relpath(path, root) if root else path
    return lint_source(source, relpath.replace(os.sep, "/"), rules, force)


_DEFAULT_GLOBS = ("src", "benchmarks", "examples", "tools")


def _iter_python_files(root: str,
                       subdirs: Iterable[str] = _DEFAULT_GLOBS) -> List[str]:
    out: List[str] = []
    for sub in subdirs:
        base = os.path.join(root, sub)
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            out.extend(os.path.join(dirpath, f) for f in filenames
                       if f.endswith(".py"))
    return sorted(out)


def lint_paths(root: str, files: Optional[Sequence[str]] = None,
               rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Lint the repo tree under ``root`` (src/, benchmarks/, examples/, tools/)."""
    targets = list(files) if files is not None else _iter_python_files(root)
    findings: List[Finding] = []
    for path in targets:
        findings.extend(lint_file(path, root=root, rules=rules))
    return findings

"""Compressed transport for the distributed paths.

Two codecs live here, one lossy and one lossless, for two different wires:

**int8 error-feedback (lossy, gradients).**  The slow axis of a multi-pod
mesh moves gradients, and gradients tolerate lossy transport when the
quantization error is *fed back*: each step quantizes ``g + err`` instead of
``g`` and carries the residual to the next step, so the accumulated signal
is unbiased (1-bit/int8 SGD with error feedback; Seide et al., Karimireddy
et al.).

``ef_compress`` quantizes to symmetric int8 with a per-tensor scale:

    scale = max|g + err| / 127,  q = round((g + err) / scale)

so the per-element residual is at most half a quantization step.

``compressed_psum_grads`` is the wire format: inside ``shard_map`` each
device quantizes locally, ``all_gather``s the int8 payload + f32 scales over
``axis_name``, dequantizes per peer, and averages locally.  Per-link ring
bytes with every device contributing a full-size gradient (R = f32 bytes):
all-gather of the int8 buffers moves (N-1) * R/4 versus 2 * R * (N-1)/N for
the f32 psum — an 8/N advantage, i.e. 4x at N=2.  This targets the *pod*
(DCN) axis, which is N=2 in the production meshes; beyond N=8 a gather-based
exchange loses and a reduce-scatter formulation would be needed (ROADMAP
open item).  Int8 summation happens *after* dequantization, so no overflow
at any world size.

**Elias–Fano (lossless, pivot exchange).**  The distributed packed
reduction (``core.packed_reduce``) ships committed pivot columns between
devices once per superstep, and GF(2) pivot data tolerates *zero* loss —
one flipped key breaks bit-identity of the diagrams.  Pivot columns are
strictly-increasing int64 key arrays, the textbook Elias–Fano case:
``n`` values below universe ``U`` cost ``n * (2 + ceil(log2(U/n)))`` bits —
each key stores its low ``l = floor(log2(U/n))`` bits verbatim and its high
bits unary in a bitvector with exactly one set bit per value
(``high + index``), so both streams decode vectorized (``np.unpackbits`` +
``flatnonzero``).  ``ef_encode_sorted``/``ef_decode_sorted`` are the exact
round-trip pair; ``pack_column_payload``/``unpack_column_payload`` lift them
to a *batch* of sorted columns by embedding column ``c``'s keys into the
single strictly-increasing sequence ``keys + c * U`` (monotone within a
column, and across a column boundary the ``+U`` step dominates any key
reset), so one vectorized encode covers the whole delta — no per-column
Python loop on the hot path.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["compressed_psum_grads", "dequantize_int8", "ef_compress",
           "ef_encode_sorted", "ef_decode_sorted",
           "pack_column_payload", "unpack_column_payload"]


def ef_compress(x, err) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize ``x + err`` to int8. Returns ``(q, scale, new_err)``.

    ``|new_err| <= scale / 2`` elementwise, and ``dequantize_int8(q, scale)
    + new_err == x + err`` exactly (the feedback identity).  A zero or
    denormal-underflow scale degrades to q=0 with the full signal carried in
    ``new_err`` — never a NaN/inf.
    """
    y = x.astype(jnp.float32) + err.astype(jnp.float32)
    scale = jnp.max(jnp.abs(y)) / jnp.float32(127.0)
    scale = jnp.where(scale > 0, scale, jnp.float32(1.0))
    q = jnp.clip(jnp.round(y / scale), -127.0, 127.0).astype(jnp.int8)
    new_err = y - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize_int8(q, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_grads(grads, errs, axis_name: str) -> Tuple[Any, Any]:
    """Mean of ``grads`` over ``axis_name`` with int8-EF transport.

    Call inside ``shard_map``.  ``grads``/``errs`` are congruent pytrees;
    returns ``(means, new_errs)`` with the same structure.  Each leaf moves
    as (int8 payload, f32 scale) via ring all-gather — 8/N the collective
    bytes of an f32 psum, so 4x fewer on the N=2 pod axis this is built for
    (see module docstring for the scaling caveat) — and each device
    reconstructs the mean locally, so the result differs from the exact
    mean by at most one quantization step (and the difference is what
    ``new_errs`` feeds back).
    """
    n = jax.lax.psum(1, axis_name)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errs)
    assert len(flat_g) == len(flat_e), (len(flat_g), len(flat_e))
    means, new_errs = [], []
    for g, e in zip(flat_g, flat_e):
        q, scale, ne = ef_compress(g, e)
        qg = jax.lax.all_gather(q, axis_name)        # (N, ...) int8 on wire
        sg = jax.lax.all_gather(scale, axis_name)    # (N,) f32
        deq = qg.astype(jnp.float32) * sg.reshape((-1,) + (1,) * g.ndim)
        means.append(deq.sum(axis=0) / n)
        new_errs.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, means),
            jax.tree_util.tree_unflatten(treedef, new_errs))


# ---------------------------------------------------------------------------
# Lossless Elias–Fano for sorted non-negative int64 sequences (pivot wire)
# ---------------------------------------------------------------------------

_EF_MAGIC = np.uint32(0xEF50)


def _bits_to_words(bits: np.ndarray) -> np.ndarray:
    """Little-endian bit array (uint8 of 0/1) -> uint32 words."""
    packed = np.packbits(bits, bitorder="little")
    pad = (-packed.size) % 4
    if pad:
        packed = np.concatenate([packed, np.zeros(pad, dtype=np.uint8)])
    return packed.view(np.uint32)


def _words_to_bits(words: np.ndarray, n_bits: int) -> np.ndarray:
    return np.unpackbits(words.view(np.uint8), bitorder="little",
                         count=n_bits)


def ef_encode_sorted(values: np.ndarray,
                     universe: Optional[int] = None) -> np.ndarray:
    """Elias–Fano encode a non-decreasing non-negative int64 array.

    Returns a flat uint32 word array (the wire payload).  Exact round trip:
    ``ef_decode_sorted(ef_encode_sorted(v)) == v`` for every valid input,
    including empty.  ``universe`` (exclusive upper bound) defaults to
    ``values[-1] + 1``; pass a larger one only to pin the split parameter
    across payloads.
    """
    v = np.ascontiguousarray(values, dtype=np.int64)
    n = v.size
    if n == 0:
        return np.array([_EF_MAGIC, 0, 0, 0, 0], dtype=np.uint32)
    if v[0] < 0:
        raise ValueError("ef_encode_sorted requires non-negative values")
    if np.any(np.diff(v) < 0):
        raise ValueError("ef_encode_sorted requires a sorted sequence")
    top = int(v[-1])
    u = top + 1 if universe is None else int(universe)
    if u <= top:
        raise ValueError(f"universe {u} too small for max value {top}")
    # l = floor(log2(u / n)) clipped to [0, 63): low bits verbatim, high
    # bits unary.  Total: n*l + n + (u >> l) bits ~ n * (2 + log2(u/n)).
    l = max(int(u // n).bit_length() - 1, 0)
    l = min(l, 62)
    low = v & ((np.int64(1) << l) - 1) if l else np.zeros(n, dtype=np.int64)
    high = (v >> l).astype(np.int64)
    # low stream: n*l bits, value i at bits [i*l, (i+1)*l)
    if l:
        low_bits = ((low[:, None] >> np.arange(l, dtype=np.int64)) & 1)
        low_words = _bits_to_words(low_bits.astype(np.uint8).ravel())
    else:
        low_words = np.zeros(0, dtype=np.uint32)
    # high stream: unary bitvector, one set bit per value at high[i] + i
    hi_len = int(high[-1]) + n
    hi_bits = np.zeros(hi_len, dtype=np.uint8)
    hi_bits[high + np.arange(n, dtype=np.int64)] = 1
    hi_words = _bits_to_words(hi_bits)
    header = np.array([_EF_MAGIC, n & 0xFFFFFFFF, n >> 32, l, hi_len],
                      dtype=np.uint32)
    return np.concatenate([header, low_words, hi_words])


def ef_decode_sorted(payload: np.ndarray) -> np.ndarray:
    """Inverse of :func:`ef_encode_sorted`: payload words -> int64 array."""
    w = np.ascontiguousarray(payload, dtype=np.uint32)
    if w.size < 5 or w[0] != _EF_MAGIC:
        raise ValueError("not an Elias–Fano payload")
    n = int(w[1]) | (int(w[2]) << 32)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    l = int(w[3])
    hi_len = int(w[4])
    n_low_words = (n * l + 31) // 32
    low_words = w[5:5 + n_low_words]
    hi_words = w[5 + n_low_words:]
    if l:
        low_bits = _words_to_bits(low_words, n * l).reshape(n, l)
        low = low_bits.astype(np.int64) @ (np.int64(1) << np.arange(l))
    else:
        low = np.zeros(n, dtype=np.int64)
    hi_bits = _words_to_bits(hi_words, hi_len)
    pos = np.flatnonzero(hi_bits).astype(np.int64)
    if pos.size != n:
        raise ValueError(f"corrupt payload: {pos.size} high bits, expect {n}")
    high = pos - np.arange(n, dtype=np.int64)
    return (high << l) | low


def pack_column_payload(columns: Sequence[np.ndarray]) -> np.ndarray:
    """Encode a batch of strictly-sorted int64 columns as one payload.

    Column ``c``'s keys embed into the global strictly-increasing sequence
    ``keys + c * U`` (``U`` = 1 + max key over the batch): within a column
    the keys already ascend, and across a boundary the ``+U`` step exceeds
    any key reset — so a *single* vectorized Elias–Fano encode carries the
    whole delta, and the decoder splits columns back out with one
    divmod.  Empty columns round-trip (they occupy no keys but keep their
    slot via the count header; an all-empty batch is a 5-word payload).
    Falls back to raw 2-word-per-key packing when ``U * n_columns`` would
    overflow int64 (header word 1 says which: 0 EF, 1 raw, 2 all-empty).
    """
    cols = [np.ascontiguousarray(c, dtype=np.int64) for c in columns]
    counts = np.array([c.size for c in cols], dtype=np.int64)
    ncols = len(cols)
    flat = (np.concatenate(cols) if ncols
            else np.zeros(0, dtype=np.int64))
    header = np.array([np.uint32(0xEFBA), 0, ncols & 0xFFFFFFFF,
                       ncols >> 32], dtype=np.uint32)
    if ncols and not flat.size:
        # every column empty (e.g. the R side of an implicit-mode delta):
        # the count header alone reconstructs the batch
        header[1] = 2
        return np.concatenate([header, np.zeros(1, dtype=np.uint32)])
    counts_payload = ef_encode_sorted(np.cumsum(counts)) if ncols else \
        np.zeros(0, dtype=np.uint32)
    u = int(flat.max()) + 1 if flat.size else 1
    if flat.size and np.any(flat < 0):
        raise ValueError("pack_column_payload requires non-negative keys")
    if ncols and u <= (2**62) // max(ncols, 1):
        col_idx = np.repeat(np.arange(ncols, dtype=np.int64), counts)
        seq = flat + col_idx * u
        keys_payload = ef_encode_sorted(seq, universe=u * ncols)
        ubits = np.array([u & 0xFFFFFFFF, u >> 32], dtype=np.uint32)
        body = np.concatenate([ubits, keys_payload])
    else:
        header[1] = 1  # raw fallback
        body = flat.view(np.uint32) if flat.size else \
            np.zeros(0, dtype=np.uint32)
    cp_len = np.array([counts_payload.size], dtype=np.uint32)
    return np.concatenate([header, cp_len, counts_payload, body])


def unpack_column_payload(payload: np.ndarray) -> List[np.ndarray]:
    """Inverse of :func:`pack_column_payload`."""
    w = np.ascontiguousarray(payload, dtype=np.uint32)
    if w.size < 5 or w[0] != np.uint32(0xEFBA):
        raise ValueError("not a column payload")
    raw = int(w[1])
    ncols = int(w[2]) | (int(w[3]) << 32)
    cp_len = int(w[4])
    if ncols == 0:
        return []
    if raw == 2:
        empty = np.zeros(0, dtype=np.int64)
        return [empty] * ncols
    counts_cum = ef_decode_sorted(w[5:5 + cp_len])
    counts = np.diff(counts_cum, prepend=0)
    body = w[5 + cp_len:]
    if raw:
        flat = body.view(np.int64) if body.size else np.zeros(0, np.int64)
    else:
        u = int(body[0]) | (int(body[1]) << 32)
        seq = ef_decode_sorted(body[2:])
        flat = seq % u
    splits = np.cumsum(counts)[:-1]
    return [c for c in np.split(flat, splits)]

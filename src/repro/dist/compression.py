"""int8 error-feedback gradient compression for the cross-pod (DCN) axis.

The slow axis of a multi-pod mesh moves gradients, and gradients tolerate
lossy transport when the quantization error is *fed back*: each step
quantizes ``g + err`` instead of ``g`` and carries the residual to the next
step, so the accumulated signal is unbiased (1-bit/int8 SGD with error
feedback; Seide et al., Karimireddy et al.).

``ef_compress`` quantizes to symmetric int8 with a per-tensor scale:

    scale = max|g + err| / 127,  q = round((g + err) / scale)

so the per-element residual is at most half a quantization step.

``compressed_psum_grads`` is the wire format: inside ``shard_map`` each
device quantizes locally, ``all_gather``s the int8 payload + f32 scales over
``axis_name``, dequantizes per peer, and averages locally.  Per-link ring
bytes with every device contributing a full-size gradient (R = f32 bytes):
all-gather of the int8 buffers moves (N-1) * R/4 versus 2 * R * (N-1)/N for
the f32 psum — an 8/N advantage, i.e. 4x at N=2.  This targets the *pod*
(DCN) axis, which is N=2 in the production meshes; beyond N=8 a gather-based
exchange loses and a reduce-scatter formulation would be needed (ROADMAP
open item).  Int8 summation happens *after* dequantization, so no overflow
at any world size.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum_grads", "dequantize_int8", "ef_compress"]


def ef_compress(x, err) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize ``x + err`` to int8. Returns ``(q, scale, new_err)``.

    ``|new_err| <= scale / 2`` elementwise, and ``dequantize_int8(q, scale)
    + new_err == x + err`` exactly (the feedback identity).  A zero or
    denormal-underflow scale degrades to q=0 with the full signal carried in
    ``new_err`` — never a NaN/inf.
    """
    y = x.astype(jnp.float32) + err.astype(jnp.float32)
    scale = jnp.max(jnp.abs(y)) / jnp.float32(127.0)
    scale = jnp.where(scale > 0, scale, jnp.float32(1.0))
    q = jnp.clip(jnp.round(y / scale), -127.0, 127.0).astype(jnp.int8)
    new_err = y - q.astype(jnp.float32) * scale
    return q, scale, new_err


def dequantize_int8(q, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_grads(grads, errs, axis_name: str) -> Tuple[Any, Any]:
    """Mean of ``grads`` over ``axis_name`` with int8-EF transport.

    Call inside ``shard_map``.  ``grads``/``errs`` are congruent pytrees;
    returns ``(means, new_errs)`` with the same structure.  Each leaf moves
    as (int8 payload, f32 scale) via ring all-gather — 8/N the collective
    bytes of an f32 psum, so 4x fewer on the N=2 pod axis this is built for
    (see module docstring for the scaling caveat) — and each device
    reconstructs the mean locally, so the result differs from the exact
    mean by at most one quantization step (and the difference is what
    ``new_errs`` feeds back).
    """
    n = jax.lax.psum(1, axis_name)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errs)
    assert len(flat_g) == len(flat_e), (len(flat_g), len(flat_e))
    means, new_errs = [], []
    for g, e in zip(flat_g, flat_e):
        q, scale, ne = ef_compress(g, e)
        qg = jax.lax.all_gather(q, axis_name)        # (N, ...) int8 on wire
        sg = jax.lax.all_gather(scale, axis_name)    # (N,) f32
        deq = qg.astype(jnp.float32) * sg.reshape((-1,) + (1,) * g.ndim)
        means.append(deq.sum(axis=0) / n)
        new_errs.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, means),
            jax.tree_util.tree_unflatten(treedef, new_errs))

"""Parameter / activation sharding rule engine.

Physical axes (by convention across the repo):

* ``model`` — tensor parallelism (TP): attention heads, FFN hidden, vocab,
  MoE experts;
* ``data`` — data parallelism + FSDP parameter sharding;
* ``pod``  — the cross-pod DCN data axis (gradients cross it compressed,
  see ``dist.compression``).

Two rule families live here:

* **parameter rules** (``spec_for_param`` / ``shard_params``): role-based
  column/row parallelism keyed on the leaf name and head alignment — a
  projection whose head count does not divide the TP axis falls back to
  row-parallelism on its d_model dim rather than sharding heads unevenly;
  parameters that cannot be sharded at all are recorded in the caller's
  ``rep`` list so the launcher can report them.
* **activation rules** (``activation_rules`` / ``constrain``): logical-axis
  -> mesh-axis mapping bound around a step function with
  ``bind_activation_rules``.  Model code calls ``constrain(x, "batch", None,
  "heads", None)`` with logical names only; unbound (no mesh) it is a no-op,
  so every model imports cleanly and runs un-sharded on a laptop.

Decode is different from train: the KV cache is sequence-sharded over
``model`` (heads stay unsharded — one token's Q/K/V is tiny), and when the
serving batch cannot cover the data axis the whole cache goes seq-parallel
over (data, model) — the batch-size-aware fallback ``activation_rules``
implements.

The PH half of the repo consumes the same mesh vocabulary: ``tile_specs``
maps the ``scale.shard`` tile-harvest round (one distance tile per device)
onto the data axis, so filtration construction and LM training agree on
what ``data`` means.
"""
from __future__ import annotations

import contextvars
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "activation_rules", "batch_specs", "bind_activation_rules", "bound_axis",
    "bound_mesh", "bound_rules", "cache_specs", "constrain", "reduce_specs",
    "shard_params", "shardings_from_specs", "spec_for_param", "tile_specs",
    "tree_path_str",
]


# ---------------------------------------------------------------------------
# tree paths
# ---------------------------------------------------------------------------

def tree_path_str(kp) -> str:
    """'groups/0/attn/wq'-style path from a jax key path."""
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k).strip("[].'\""))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# mesh introspection (works on jax.sharding.Mesh and duck-typed test meshes)
# ---------------------------------------------------------------------------

def _axis_size(mesh, name: Optional[str]) -> int:
    if not name:
        return 1
    try:
        return int(mesh.shape[name])
    except (KeyError, TypeError):
        return 1


def _mesh_axes(mesh) -> Tuple[Optional[str], Tuple[str, ...]]:
    """(tp axis, data axes) present on the mesh."""
    names = tuple(getattr(mesh, "axis_names", ()))
    tp = "model" if "model" in names else None
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    return tp, data_axes


def _dp_size(mesh, data_axes: Tuple[str, ...]) -> int:
    n = 1
    for a in data_axes:
        n *= _axis_size(mesh, a)
    return n


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------

_COLUMN_NAMES = ("w_up", "w_gate", "shared_up", "shared_gate", "w_uk", "w_uv")
_ROW_NAMES = ("w_down", "shared_down")
_EXPERT_NAMES = ("w_up", "w_gate", "w_down")


def spec_for_param(path: str, shape: Tuple[int, ...], mesh,
                   rep: List[str], heads: Optional[Dict[str, int]] = None,
                   fsdp: bool = True) -> P:
    """PartitionSpec for one parameter leaf.

    ``path`` is the '/'-joined tree path; params under ``groups/`` carry a
    leading stacked-repeats dim that always stays unsharded.  ``heads``
    (``{"q": n_heads, "kv": n_kv_heads}``) drives head alignment: an aligned
    projection is column-parallel (out dim over ``model``); a misaligned one
    is row-parallel (d_model over ``model``) so no head is ever split.
    ``fsdp=False`` (serving) keeps params replicated over the data axis.
    Leaves with no shardable dim are appended to ``rep``.
    """
    tp, data_axes = _mesh_axes(mesh)
    tp_n = _axis_size(mesh, tp)
    dp = "data" if (fsdp and "data" in data_axes) else None
    dp_n = _axis_size(mesh, dp)

    name = path.split("/")[-1]
    nd = len(shape)
    lead = 1 if (path.startswith("groups") or "/groups/" in path) \
        and nd >= 2 else 0
    core = shape[lead:]
    cn = len(core)
    spec: List[Any] = [None] * nd

    def fit(dim: int, ax: Optional[str], n: int) -> Optional[str]:
        return ax if ax is not None and n > 1 and dim % n == 0 else None

    def put(i: int, ax: Optional[str]) -> None:
        spec[lead + i] = ax

    q_aligned = bool(heads and heads.get("q") and tp
                     and heads["q"] % tp_n == 0)
    kv_aligned = bool(heads and heads.get("kv") and tp
                      and heads["kv"] % tp_n == 0)

    if cn == 2 and name in ("wq", "wk", "wv") and heads:
        # in-projections: column-parallel when the head count divides the TP
        # axis, else row-parallel on d_model (never split a head)
        aligned = q_aligned if name == "wq" else kv_aligned
        if aligned:
            put(0, fit(core[0], dp, dp_n))
            put(1, fit(core[1], tp, tp_n))
        else:
            put(0, fit(core[0], tp, tp_n))
            put(1, fit(core[1], dp, dp_n))
    elif cn == 2 and name == "wo" and heads:
        # out-projection: row-parallel on the h*hd contraction when heads
        # are aligned (pairs with the column-parallel wq)
        if q_aligned:
            put(0, fit(core[0], tp, tp_n))
            put(1, fit(core[1], dp, dp_n))
        else:
            put(0, fit(core[0], dp, dp_n))
            put(1, fit(core[1], tp, tp_n))
    elif cn == 3 and name in _EXPERT_NAMES:
        # stacked routed experts (E, a, b): expert dim over model (EP)
        put(0, fit(core[0], tp, tp_n))
        big = 1 if core[1] >= core[2] else 2
        other = 3 - big
        if fit(core[big], dp, dp_n):
            put(big, dp)
        elif fit(core[other], dp, dp_n):
            put(other, dp)
    elif cn == 2 and name in _COLUMN_NAMES:
        put(0, fit(core[0], dp, dp_n))
        put(1, fit(core[1], tp, tp_n))
    elif cn == 2 and name in _ROW_NAMES:
        put(0, fit(core[0], tp, tp_n))
        put(1, fit(core[1], dp, dp_n))
    elif cn == 2 and name == "table":
        # embedding / lm_head: vocab over model (padded_vocab guarantees
        # divisibility), d_model over data
        put(0, fit(core[0], tp, tp_n))
        put(1, fit(core[1], dp, dp_n))
    elif cn == 2 and name == "router":
        put(0, fit(core[0], dp, dp_n))      # router is tiny: FSDP only
    elif cn >= 2:
        # generic 2D+: biggest dim over model, next shardable over data
        order = sorted(range(cn), key=lambda i: -core[i])
        put(order[0], fit(core[order[0]], tp, tp_n))
        for i in order[1:]:
            if fit(core[i], dp, dp_n):
                put(i, dp)
                break
    # cn <= 1 (norm scales, biases): replicated by design, not a fallback

    if cn >= 2 and all(s is None for s in spec):
        rep.append(path)
    return P(*spec)


def shard_params(params, mesh, fsdp: bool = True,
                 heads: Optional[Dict[str, int]] = None):
    """PartitionSpecs for every leaf of ``params``.

    Returns ``(spec_tree, report)`` where report is JSON-serializable:
    leaf/sharded counts and the replicated-fallback paths.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    rep: List[str] = []
    specs = []
    n_sharded = 0
    for kp, leaf in flat:
        path = tree_path_str(kp)
        s = spec_for_param(path, tuple(leaf.shape), mesh, rep, heads=heads,
                           fsdp=fsdp)
        specs.append(s)
        if any(a is not None for a in s):
            n_sharded += 1
    report = {"n_leaves": len(flat), "n_sharded": n_sharded,
              "replicated": rep, "fsdp": bool(fsdp)}
    return jax.tree_util.tree_unflatten(treedef, specs), report


def shardings_from_specs(specs, mesh):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(shapes: Dict[str, Any], mesh) -> Dict[str, P]:
    """Specs for host data inputs: batch dim over the data axes (when it
    covers them); ``positions3`` carries batch on axis 1; scalars replicate."""
    _, data_axes = _mesh_axes(mesh)
    dp_n = _dp_size(mesh, data_axes)
    dp = data_axes[0] if len(data_axes) == 1 else (data_axes or None)

    def one(key: str, leaf) -> P:
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        b_ax = 1 if key == "positions3" else 0
        spec: List[Any] = [None] * len(shape)
        if dp is not None and dp_n > 1 and shape[b_ax] % dp_n == 0:
            spec[b_ax] = dp
        return P(*spec)

    return {k: one(k, v) for k, v in shapes.items()}


def tile_specs(mesh) -> Tuple[Tuple[P, P], P, str]:
    """Specs for the sharded tile-harvest ``shard_map`` (``scale.shard``).

    One round stacks each device's ``(tile_m, d)`` / ``(tile_n, d)`` point
    blocks on a leading axis of size ``data``; that leading axis shards over
    the innermost data axis and everything else — including any ``model`` or
    ``pod`` axis present — sees the work replicated (tile harvesting is pure
    data parallelism; TP axes contribute nothing and must not split a tile).

    Returns ``(in_specs, out_specs, axis_name)`` ready to pass to
    ``jax.shard_map``: ``in_specs`` for the (x-blocks, y-blocks) pair,
    ``out_specs`` for the stacked ``(data, tile_m, tile_n)`` output.
    """
    _, data_axes = _mesh_axes(mesh)
    if not data_axes:
        raise ValueError(
            f"mesh axes {tuple(getattr(mesh, 'axis_names', ()))} have no "
            "data axis to shard the tile grid over")
    ax = data_axes[-1]          # 'data' when present, else 'pod'
    spec = P(ax)
    return (spec, spec), spec, ax


def reduce_specs(mesh) -> Tuple[P, P, str]:
    """Specs for the distributed reduction's pivot-exchange ``shard_map``
    (``core.packed_reduce``).

    The exchange round moves one ``(P, L)`` uint32 payload buffer — shard
    ``k``'s Elias–Fano-encoded commit delta in row ``k`` — through an
    ``all_gather`` over the same innermost data axis the tile harvest
    shards on: in, the leading axis shards over ``data`` (each device holds
    its own row); out, every device returns the full gathered ``(P, L)``
    buffer, i.e. the result is replicated (spec ``P()``), which is exactly
    the replica-install contract: every shard sees every shard's pivots.

    Returns ``(in_spec, out_spec, axis_name)`` for ``jax.shard_map``.
    """
    _, data_axes = _mesh_axes(mesh)
    if not data_axes:
        raise ValueError(
            f"mesh axes {tuple(getattr(mesh, 'axis_names', ()))} have no "
            "data axis to exchange reduction pivots over")
    ax = data_axes[-1]
    return P(ax), P(), ax


def cache_specs(layers, mesh, seq_len: int, batch: int):
    """Specs for the stacked decode cache: batch (axis 1) over data, the
    seq-capacity axis over model (the decode kv_seq rule); recurrent states
    (no seq axis) shard batch only.

    Mirrors the ``activation_rules`` decode fallback: when ``batch`` cannot
    cover the data axes the cache batch stays unsharded and its seq axis
    goes fully seq-parallel over (data..., model), so the stored sharding
    matches the in-step kv_seq constraint instead of forcing a per-step
    reshard."""
    tp, data_axes = _mesh_axes(mesh)
    tp_n = _axis_size(mesh, tp)
    dp_n = _dp_size(mesh, data_axes)
    dp = data_axes[0] if len(data_axes) == 1 else (data_axes or None)

    batch_ok = dp is not None and dp_n > 1 and batch and batch % dp_n == 0
    seq_axes = ((data_axes if not batch_ok else ())
                + ((tp,) if tp and tp_n > 1 else ()))
    seq_n = 1
    for a in seq_axes:
        seq_n *= _axis_size(mesh, a)
    if seq_axes and seq_len % seq_n != 0:       # uneven: TP-only, or nothing
        seq_axes = (tp,) if tp and tp_n > 1 and seq_len % tp_n == 0 else ()
    seq_entry = (seq_axes[0] if len(seq_axes) == 1 else seq_axes) or None

    def one(leaf) -> P:
        shape = tuple(leaf.shape)
        spec: List[Any] = [None] * len(shape)
        if len(shape) >= 2 and batch_ok and shape[1] == batch:
            spec[1] = dp
        for i in range(2, len(shape)):
            if seq_entry is not None and shape[i] == seq_len:
                spec[i] = seq_entry
                break
        return P(*spec)

    return jax.tree.map(one, layers)


# ---------------------------------------------------------------------------
# activation rules
# ---------------------------------------------------------------------------

class Rules(dict):
    """Logical-axis -> mesh-axis mapping plus the mesh it was built for."""

    def __init__(self, *args, mesh=None, **kwargs):
        super().__init__(*args, **kwargs)
        self.mesh = mesh


def activation_rules(cfg, mesh, decode: bool = False,
                     batch: Optional[int] = None) -> Rules:
    """Build the logical-axis map for ``cfg`` on ``mesh``.

    Train: heads/kv_heads shard over ``model`` when aligned; activations
    batch-shard over the data axes; no sequence sharding.  Decode: heads stay
    unsharded and the KV cache seq-shards over ``model``; if ``batch`` cannot
    cover the data axes the batch rule drops to None and the cache goes fully
    seq-parallel over (data..., model).
    """
    tp, data_axes = _mesh_axes(mesh)
    tp_n = _axis_size(mesh, tp)
    dp_n = _dp_size(mesh, data_axes)

    def tp_fit(n: Optional[int]) -> Optional[str]:
        return tp if tp and tp_n > 1 and n and n % tp_n == 0 else None

    batch_axes: Optional[Tuple[str, ...]] = data_axes or None
    if batch is not None and dp_n > 1 and batch % dp_n != 0:
        batch_axes = None               # batch-size-aware seq-parallel fall.

    rules = Rules(mesh=mesh)
    if decode:
        rules["heads"] = None           # one-token Q is tiny; cache rules win
        rules["kv_heads"] = None
        seq_axes = (data_axes if batch_axes is None else ()) \
            + ((tp,) if tp else ())
        rules["kv_seq"] = tuple(a for a in seq_axes if a) or None
    else:
        rules["heads"] = tp_fit(getattr(cfg, "n_heads", None))
        rules["kv_heads"] = tp_fit(getattr(cfg, "n_kv_heads", None))
        rules["kv_seq"] = None
    if batch_axes is None:
        rules["batch"] = None
    else:
        rules["batch"] = batch_axes[0] if len(batch_axes) == 1 else batch_axes
    rules["mlp"] = tp_fit(getattr(cfg, "d_ff", None))
    rules["vocab"] = tp_fit(getattr(cfg, "padded_vocab", None))
    moe = getattr(cfg, "moe", None)
    rules["expert"] = tp_fit(moe.n_experts) if moe is not None else None
    rules["capacity"] = None
    rules["tokens"] = rules["batch"]
    return rules


_ACTIVE: contextvars.ContextVar[Optional[Rules]] = contextvars.ContextVar(
    "repro_dist_activation_rules", default=None)


def bind_activation_rules(fn, rules: Rules):
    """Wrap ``fn`` so ``constrain``/``bound_*`` see ``rules`` while it runs
    (including while jit traces it)."""

    @functools.wraps(fn)
    def bound(*args, **kwargs):
        token = _ACTIVE.set(rules)
        try:
            return fn(*args, **kwargs)
        finally:
            _ACTIVE.reset(token)

    return bound


def bound_rules() -> Optional[Rules]:
    return _ACTIVE.get()


def bound_axis(name: str):
    """Mesh axis (or axes tuple) the logical ``name`` maps to, if bound."""
    rules = _ACTIVE.get()
    return None if rules is None else rules.get(name)


def bound_mesh() -> Optional[Mesh]:
    """The bound mesh, only if it is a real jax Mesh (not a test double)."""
    rules = _ACTIVE.get()
    mesh = None if rules is None else getattr(rules, "mesh", None)
    return mesh if isinstance(mesh, Mesh) else None


def constrain(x, *axes):
    """``with_sharding_constraint`` by logical axis names; no-op unbound.

    ``axes`` has one entry per dim of ``x``: a logical name resolved through
    the bound rules, or None for an unsharded dim.
    """
    rules = _ACTIVE.get()
    if rules is None:
        return x
    mesh = getattr(rules, "mesh", None)
    if not isinstance(mesh, Mesh):
        return x
    spec = [rules.get(a) if a is not None else None for a in axes]
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))

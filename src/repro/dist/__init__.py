"""Distributed execution layer: sharding rules + gradient compression.

``dist.sharding`` is the single place the repo maps *logical* tensor axes
(batch, heads, kv_seq, mlp, vocab, expert, ...) and *parameter roles*
(column/row-parallel projections, MoE expert stacks, vocab tables) onto the
physical mesh axes (``pod``, ``data``, ``model``).  ``dist.compression``
implements the int8 error-feedback gradient exchange used on the slow
cross-pod (DCN) axis.
"""
from .compression import compressed_psum_grads, dequantize_int8, ef_compress
from .sharding import (activation_rules, batch_specs, bind_activation_rules,
                       bound_axis, bound_mesh, bound_rules, cache_specs,
                       constrain, shard_params, shardings_from_specs,
                       spec_for_param, tile_specs, tree_path_str)

__all__ = [
    "activation_rules", "batch_specs", "bind_activation_rules", "bound_axis",
    "bound_mesh", "bound_rules", "cache_specs", "compressed_psum_grads",
    "constrain", "dequantize_int8", "ef_compress", "shard_params",
    "shardings_from_specs", "spec_for_param", "tile_specs", "tree_path_str",
]

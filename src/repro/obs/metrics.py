"""Typed metrics registry + the canonical stats schema (ISSUE 8, part 2).

Every ``stats`` producer in the pipeline (``core/reduction.py``,
``core/serial_parallel.py``, ``core/packed_reduce.py``,
``core/pivot_cache.py``, ``core/homology.py``, ``serve/engine.py``) builds
its numbers through a :class:`MetricsRegistry` instead of an ad-hoc dict,
so every emitted key has a declared kind (counter / gauge / histogram), a
unit, and one line of documentation — :data:`SCHEMA` below *is* the schema
referenced by ``docs/observability.md`` and validated by
``tests/test_obs.py``.

``registry.as_stats()`` flattens to the same ``Dict[str, float]`` shape the
pipeline has always returned (histograms expand to ``name_count`` /
``name_sum`` / ``name_min`` / ``name_max``), so ``compute_ph(...).stats``
stays backward-compatible: every legacy key survives with the same value.

Three kinds:

* **counter** — monotone event count (``inc``); e.g. ``n_reductions``.
* **gauge** — a level; ``set`` overwrites, ``record_max`` keeps a
  high-water mark (the byte-account gauges use it).
* **histogram** — a distribution summarized as count/sum/min/max
  (``observe``); e.g. per-superstep concurrent-phase wall.

A metric only appears in ``as_stats()`` once touched, which is how
conditional keys (``tau_max_estimated``, the ``sim_*`` walls) stay
conditional.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

__all__ = [
    "MetricSpec", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "SCHEMA", "schema_markdown",
]


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    name: str
    kind: str           # "counter" | "gauge" | "histogram"
    unit: str           # "", "bytes", "s", "columns", ...
    help: str


def _spec(name: str, kind: str, unit: str, help: str) -> MetricSpec:
    return MetricSpec(name=name, kind=kind, unit=unit, help=help)


# The one documented schema.  Names are the *legacy* stats keys — the
# migration keeps every existing key, it just types and documents them.
# (Concept names from the issue map as: spills -> n_spilled, wire_bytes ->
# exchange_bytes, pack_hits -> cache_n_pack_hits.)
SCHEMA: Dict[str, MetricSpec] = {s.name: s for s in [
    # -- reduction engines (per dimension; compute_ph prefixes h1_/h2_) --
    _spec("n_columns", "counter", "columns", "columns fed to the reduction"),
    _spec("n_reductions", "counter", "ops", "GF(2) column additions"),
    _spec("n_pairs", "counter", "pairs", "finite persistence pairs emitted"),
    _spec("n_essential", "counter", "classes", "essential (infinite) classes"),
    _spec("stored_bytes", "gauge", "bytes", "pivot-store resident bytes"),
    _spec("n_stored_columns", "gauge", "columns", "pivot columns resident"),
    _spec("n_spilled", "counter", "columns",
          "explicit columns spilled to implicit storage (budget pressure)"),
    _spec("batch_size", "gauge", "columns", "effective reduction batch size"),
    # -- packed block engine --
    _spec("n_rounds", "counter", "rounds", "batched probe/XOR rounds"),
    _spec("n_expansions", "counter", "ops", "bit-block capacity expansions"),
    _spec("n_evictions", "counter", "ops", "bit-block segment evictions"),
    _spec("n_consolidations", "counter", "ops", "bit-block consolidations"),
    _spec("peak_block_bytes", "gauge", "bytes",
          "high-water bytes of the packed bit block"),
    _spec("use_kernels", "gauge", "flag", "1 when Pallas kernels were used"),
    # -- distributed packed driver --
    _spec("n_shards", "gauge", "devices", "reduction shard count P"),
    _spec("n_supersteps", "counter", "steps", "fused supersteps executed"),
    _spec("n_exchange_rounds", "counter", "rounds", "pivot-exchange rounds"),
    _spec("n_tournament_reductions", "counter", "ops",
          "reductions during tournament catch-up"),
    _spec("n_sweep_probes", "counter", "probes",
          "authoritative-store re-probes during commit sweeps"),
    _spec("exchange_bytes", "counter", "bytes",
          "wire bytes shipped by pivot-exchange payloads (wire_bytes)"),
    _spec("sim_wall_s", "gauge", "s",
          "simulated P-device critical-path reduction wall (span-derived)"),
    _spec("sim_conc_s", "gauge", "s", "concurrent-phase share of sim wall"),
    _spec("sim_sweep_s", "gauge", "s", "commit-sweep DAG share of sim wall"),
    _spec("sim_sync_s", "gauge", "s",
          "tournament + exchange share of sim wall"),
    _spec("sim_wall_bookkeeping_s", "gauge", "s",
          "hand-rolled sim wall kept for cross-checking the span-derived one"),
    _spec("superstep_conc_s", "histogram", "s",
          "per-superstep concurrent-phase wall distribution"),
    # -- shared pivot cache --
    _spec("cache_n_packs", "counter", "ops", "pivot columns bit-packed"),
    _spec("cache_n_pack_hits", "counter", "ops",
          "pack requests served from cache (pack_hits)"),
    _spec("cache_n_materializations", "counter", "ops",
          "implicit columns re-materialized"),
    _spec("cache_n_mat_hits", "counter", "ops",
          "materialization requests served from cache"),
    _spec("cache_n_col_evictions", "counter", "ops",
          "cached columns evicted (cache budget)"),
    _spec("cache_column_bytes", "gauge", "bytes",
          "bytes of packed columns resident in the cache"),
    # -- compute_ph pipeline (per-phase wall + memory account) --
    _spec("t_filtration", "gauge", "s", "filtration build wall"),
    _spec("t_h0", "gauge", "s", "H0 union-find wall"),
    _spec("t_h1", "gauge", "s", "H1* reduction wall"),
    _spec("t_h2", "gauge", "s", "H2* reduction wall"),
    _spec("n", "gauge", "points", "vertex count"),
    _spec("n_e", "gauge", "edges", "edge count at tau_max"),
    _spec("base_memory_bytes", "gauge", "bytes",
          "filtration result arrays: the (3n + 12 n_e) * 4 account realized"),
    _spec("tau_max_estimated", "gauge", "", "budget-derived tau_max"),
    _spec("sanitize_checks", "counter", "checks", "GF(2) sanitizer checks run"),
    _spec("per_device_peak_bytes", "gauge", "bytes",
          "sharded harvest: predicted per-device high-water"),
    _spec("per_device_base_bytes", "gauge", "bytes",
          "sharded harvest: per-device share of the base account"),
    _spec("predicted_account_bytes", "gauge", "bytes",
          "the paper's predicted (3n + 12 n_e) * 4 account (scale/budget)"),
    _spec("observed_peak_harvest_bytes", "gauge", "bytes",
          "observed harvest transient high-water (TileStats)"),
    _spec("observed_peak_reduce_bytes", "gauge", "bytes",
          "observed reduction high-water: store + packed block, max over dims"),
    _spec("budget_drift_ratio", "gauge", "ratio",
          "(base + worst observed transient) / predicted account"),
    # -- serving engine --
    _spec("serve_n_prefills", "counter", "batches", "prefill launches"),
    _spec("serve_n_decode_steps", "counter", "steps", "decode steps run"),
    _spec("serve_n_tokens", "counter", "tokens", "tokens decoded"),
    _spec("serve_n_completed", "counter", "requests", "requests completed"),
    _spec("serve_tokens_per_request", "histogram", "tokens",
          "decoded tokens per completed request"),
    # -- PH serving engine (repro.serve.ph) --
    _spec("serve_ph_n_requests", "counter", "requests",
          "PH requests submitted"),
    _spec("serve_ph_n_admitted", "counter", "requests",
          "requests admitted by the tau_max memory account"),
    _spec("serve_ph_n_rejected", "counter", "requests",
          "requests rejected at admission (budget cannot hold O(n) part)"),
    _spec("serve_ph_n_cache_hits", "counter", "requests",
          "requests served against a cached dataset checkpoint"),
    _spec("serve_ph_n_cache_misses", "counter", "requests",
          "requests with no usable cached state (cold path)"),
    _spec("serve_ph_n_warm_tau", "counter", "requests",
          "warm tau-growth restarts served"),
    _spec("serve_ph_n_warm_points", "counter", "requests",
          "warm point-arrival restarts served"),
    _spec("serve_ph_n_cold", "counter", "requests",
          "cold reductions run (no reusable pivots)"),
    _spec("serve_ph_n_batched", "counter", "requests",
          "cold requests packed into union-batch reductions"),
    _spec("serve_ph_n_batches", "counter", "batches",
          "union-batch reductions launched"),
    _spec("serve_ph_batch_clouds", "histogram", "requests",
          "clouds packed per union-batch reduction"),
    _spec("serve_ph_n_evictions", "counter", "datasets",
          "cached dataset states evicted under a tenant store budget"),
    _spec("serve_ph_store_bytes", "gauge", "bytes",
          "resident bytes of cached checkpoints (all tenants)"),
    _spec("serve_ph_queue_depth", "gauge", "requests",
          "pending requests at the last step boundary"),
    _spec("serve_ph_latency_s", "histogram", "s",
          "per-request service wall (span-derived)"),
    # -- resilience (repro.resilience): fault recovery + degradation --
    _spec("resilience_n_faults", "counter", "faults",
          "injected faults observed by recovery paths"),
    _spec("resilience_n_shard_deaths", "counter", "shards",
          "reduction shards declared dead by heartbeat supervision"),
    _spec("resilience_n_redeals", "counter", "supersteps",
          "supersteps re-dealt to survivors after a shard death"),
    _spec("resilience_n_straggler_sidelines", "counter", "shards",
          "straggling shards sidelined from batch dealing"),
    _spec("resilience_n_exchange_retries", "counter", "attempts",
          "pivot-exchange payload delivery retries"),
    _spec("resilience_n_exchange_deferrals", "counter", "payloads",
          "exchange payloads deferred to a later round after retry budget"),
    _spec("resilience_n_wire_corruptions", "counter", "payloads",
          "exchange payloads rejected by checksum"),
    _spec("resilience_n_tile_retries", "counter", "tiles",
          "harvest tiles recomputed after a transient fault"),
    _spec("resilience_n_ckpt_corruptions", "counter", "checkpoints",
          "checkpoints rejected by integrity checks"),
    _spec("resilience_n_ckpt_fallbacks", "counter", "requests",
          "cold fallbacks taken after checkpoint corruption"),
    _spec("resilience_recover_s", "histogram", "s",
          "time to recover per fault (discarded + re-dealt work)"),
    _spec("resilience_backoff_s", "histogram", "s",
          "scheduled backoff delay per retry"),
    # -- serving degradation (repro.serve.ph) --
    _spec("serve_ph_n_degraded", "counter", "requests",
          "responses served degraded (clamped tau / lower maxdim)"),
    _spec("serve_ph_n_shed", "counter", "requests",
          "requests load-shed under queue/store pressure"),
    _spec("serve_ph_n_deadline_degraded", "counter", "requests",
          "requests degraded to meet a deadline"),
    _spec("serve_ph_n_circuit_open", "counter", "requests",
          "requests short-circuited by an open breaker"),
    _spec("serve_ph_n_cold_retries", "counter", "attempts",
          "cold reduction retries after transient faults"),
]}


class Counter:
    __slots__ = ("spec", "value")

    def __init__(self, spec: MetricSpec):
        self.spec = spec
        self.value = 0.0

    def inc(self, v: Union[int, float] = 1) -> None:
        self.value += float(v)


class Gauge:
    __slots__ = ("spec", "value")

    def __init__(self, spec: MetricSpec):
        self.spec = spec
        self.value = 0.0

    def set(self, v: Union[int, float]) -> None:
        self.value = float(v)

    def record_max(self, v: Union[int, float]) -> None:
        """High-water semantics: keep the max ever observed."""
        self.value = max(self.value, float(v))


class Histogram:
    __slots__ = ("spec", "count", "sum", "min", "max")

    def __init__(self, spec: MetricSpec):
        self.spec = spec
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: Union[int, float]) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)


_Metric = Union[Counter, Gauge, Histogram]
_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Schema-checked metric store; flattens back to the legacy stats dict.

    Accessors are typed: asking for ``counter("stored_bytes")`` when the
    schema declares a gauge raises, so a producer cannot silently change a
    metric's meaning.  Names outside :data:`SCHEMA` must be registered
    first via :meth:`register` — the schema stays the single source of
    truth for what the pipeline can emit.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, _Metric] = {}
        self._extra_specs: Dict[str, MetricSpec] = {}

    def register(self, name: str, kind: str, unit: str = "",
                 help: str = "") -> MetricSpec:
        """Declare an out-of-schema metric (tests, experiments)."""
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r}")
        spec = MetricSpec(name=name, kind=kind, unit=unit, help=help)
        self._extra_specs[name] = spec
        return spec

    def _get(self, name: str, kind: str) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            if m.spec.kind != kind:
                raise TypeError(f"metric {name!r} is a {m.spec.kind}, "
                                f"requested as {kind}")
            return m
        spec = SCHEMA.get(name) or self._extra_specs.get(name)
        if spec is None:
            raise KeyError(f"metric {name!r} is not in the schema; "
                           f"register() it or add it to SCHEMA")
        if spec.kind != kind:
            raise TypeError(f"metric {name!r} is declared a {spec.kind}, "
                            f"requested as {kind}")
        m = _KINDS[kind](spec)
        self._metrics[name] = m
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter")    # type: ignore[return-value]

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge")      # type: ignore[return-value]

    def histogram(self, name: str) -> Histogram:
        return self._get(name, "histogram")  # type: ignore[return-value]

    def as_stats(self) -> Dict[str, float]:
        """Flatten to the pipeline's historical ``Dict[str, float]`` shape."""
        out: Dict[str, float] = {}
        for name, m in self._metrics.items():
            if isinstance(m, Histogram):
                out[f"{name}_count"] = float(m.count)
                out[f"{name}_sum"] = m.sum
                if m.count:
                    out[f"{name}_min"] = m.min
                    out[f"{name}_max"] = m.max
            else:
                out[name] = m.value
        return out

    def update_from(self, stats: Dict[str, float]) -> None:
        """Absorb a legacy stats dict (schema-checked, gauges overwritten).

        Counters *add* and gauges *set*, so a registry can aggregate
        several producers (e.g. the serve engine absorbing per-request
        stats).
        """
        for k, v in stats.items():
            spec = SCHEMA.get(k) or self._extra_specs.get(k)
            if spec is None or spec.kind == "histogram":
                continue
            if spec.kind == "counter":
                self.counter(k).inc(v)
            else:
                self.gauge(k).set(v)


def schema_markdown() -> str:
    """The schema as a markdown table (rendered in docs/observability.md)."""
    lines = ["| name | kind | unit | meaning |", "|---|---|---|---|"]
    for name in sorted(SCHEMA):
        s = SCHEMA[name]
        lines.append(f"| `{name}` | {s.kind} | {s.unit or '-'} | {s.help} |")
    return "\n".join(lines)

"""repro.obs — tracing + metrics for the PH pipeline (ISSUE 8).

* :mod:`repro.obs.trace` — nested spans with device-lane attribution,
  Chrome ``trace_event`` export (Perfetto), the always-on :func:`stopwatch`
  timer, and the span-derived simulated critical path.
* :mod:`repro.obs.metrics` — the typed counter/gauge/histogram registry
  behind every ``stats`` dict the pipeline returns, with one documented
  schema (``docs/observability.md``).

Deliberately dependency-free (stdlib + nothing): importable from the
hottest core modules without cycles, and from environments without jax.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, MetricSpec,
                      SCHEMA, schema_markdown)
from .trace import (Span, Tracer, active_tracer, chrome_trace, coverage,
                    critical_path, span, stopwatch, traced, tracing)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "MetricSpec",
    "SCHEMA", "schema_markdown",
    "Span", "Tracer", "active_tracer", "chrome_trace", "coverage",
    "critical_path", "span", "stopwatch", "traced", "tracing",
]

"""Phase-scoped tracing for the PH pipeline (ISSUE 8 tentpole, part 1).

One span API for the whole repo: nested, thread-safe, and near-free when
tracing is off.  A span is an interval ``[t0, t1)`` on a *lane* — ``None``
for host work, an integer ``k`` for (simulated or real) device ``k`` — with
arbitrary attributes.  The simulated distributed supersteps in
``core/packed_reduce.py`` attribute their per-shard phases to integer lanes,
so a 4-shard run renders as 4 parallel device tracks in Perfetto instead of
one serial host track.

Three entry points:

* :func:`span` — the module-level context manager.  When no tracer is
  active it returns a shared no-op object (no allocation, no clock read).
* :func:`stopwatch` — *always* times (``.elapsed`` after exit) and records
  a span only when tracing is active; the migration target for every raw
  ``time.perf_counter()`` pair outside ``benchmarks/`` (the ``raw-timing``
  lint rule in :mod:`repro.analyze` enforces this).
* :func:`tracing` — activates a tracer for a region and exports Chrome
  ``trace_event`` JSON on exit; ``compute_ph(trace=...)`` and the
  ``REPRO_TRACE`` environment variable both resolve through it.

Naming convention (see ``docs/observability.md``): ``area/what`` — e.g.
``ph/filtration``, ``harvest/tile``, ``reduce/sweep``, ``serve/decode``.

The exported JSON loads directly in https://ui.perfetto.dev (or
``chrome://tracing``): one process, thread 0 is the host track, thread
``k + 1`` is ``device:k``.  Setting ``REPRO_TRACE_JAX=1`` additionally
wraps every live span in a ``jax.profiler.TraceAnnotation`` so the same
names show up inside XLA profiles.
"""
from __future__ import annotations

import contextlib
import functools
import json
import os
import threading
import time
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Union

__all__ = [
    "Span", "Tracer", "active_tracer", "span", "stopwatch", "traced",
    "tracing", "critical_path", "chrome_trace", "coverage",
]

_CLOCK = time.perf_counter        # analyze: allow[raw-timing] the one blessed clock


class Span:
    """A closed, recorded interval: ``name`` on ``lane`` over ``[t0, t1)``."""

    __slots__ = ("name", "lane", "t0", "t1", "attrs")

    def __init__(self, name: str, lane: Optional[int], t0: float, t1: float,
                 attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.lane = lane
        self.t0 = t0
        self.t1 = t1
        self.attrs = attrs or {}

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, lane={self.lane}, "
                f"dur={self.dur:.6f}, attrs={self.attrs})")


class _NoopSpan:
    """Shared do-nothing span: the disabled-mode fast path."""

    __slots__ = ()
    dur = 0.0

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> None:
        pass


_NOOP = _NoopSpan()


class _SpanCtx:
    """Live (open) span: context manager handed out by :meth:`Tracer.span`.

    Closing — including on the exception path, since ``__exit__`` always
    runs — records an immutable :class:`Span` on the owning tracer.
    ``.set(**attrs)`` amends attributes mid-flight (e.g. the sweep's
    dependency set, known only once the sweep finishes).
    """

    __slots__ = ("_tracer", "name", "lane", "attrs", "t0", "dur", "_ann")

    def __init__(self, tracer: "Tracer", name: str, lane: Optional[int],
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.lane = lane
        self.attrs = attrs
        self.t0 = 0.0
        self.dur = 0.0
        self._ann = None

    def set(self, **attrs: Any) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "_SpanCtx":
        tr = self._tracer
        tr._open_enter(self)
        if tr.bridge:
            self._ann = _jax_annotation(self.name)
            if self._ann is not None:
                self._ann.__enter__()
        self.t0 = _CLOCK()
        return self

    def __exit__(self, *exc: Any) -> bool:
        t1 = _CLOCK()
        self.dur = t1 - self.t0
        if self._ann is not None:
            self._ann.__exit__(*exc)
            self._ann = None
        tr = self._tracer
        tr._open_exit(self)
        tr.record(Span(self.name, self.lane, self.t0, t1, self.attrs))
        return False


def _jax_annotation(name: str):
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:       # jax absent or profiler unavailable: skip bridge
        return None


class Tracer:
    """Thread-safe span collector.

    ``forward_to`` dual-writes every recorded span to a second tracer —
    ``packed_reduce`` keeps an always-on local timeline (its simulated wall
    is *derived* from it) and forwards into the user's tracer when one is
    active, so one measurement feeds both accountings.
    ``bridge=True`` wraps live spans in ``jax.profiler.TraceAnnotation``.
    """

    def __init__(self, forward_to: Optional["Tracer"] = None,
                 bridge: bool = False):
        self.spans: List[Span] = []
        self.bridge = bridge
        self._forward = forward_to
        self._lock = threading.Lock()
        self._open: Dict[int, str] = {}     # id(ctx) -> name, for balance

    # -- recording ---------------------------------------------------------
    def span(self, name: str, lane: Optional[int] = None,
             **attrs: Any) -> _SpanCtx:
        return _SpanCtx(self, name, lane, attrs)

    def record(self, sp: Span) -> None:
        with self._lock:
            self.spans.append(sp)
        if self._forward is not None:
            self._forward.record(sp)

    def _open_enter(self, ctx: _SpanCtx) -> None:
        with self._lock:
            self._open[id(ctx)] = ctx.name

    def _open_exit(self, ctx: _SpanCtx) -> None:
        with self._lock:
            self._open.pop(id(ctx), None)

    # -- invariants / summaries -------------------------------------------
    def open_spans(self) -> List[str]:
        """Names of spans entered but not yet exited (should be [] at export)."""
        with self._lock:
            return list(self._open.values())

    def assert_balanced(self) -> None:
        leaked = self.open_spans()
        if leaked:
            raise RuntimeError(f"unclosed spans at export: {leaked}")

    def coverage(self) -> float:
        return coverage(self.spans)

    # -- export ------------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        return chrome_trace(self.spans)

    def export_chrome(self, path: str) -> None:
        """Write Perfetto-loadable Chrome ``trace_event`` JSON to ``path``."""
        self.assert_balanced()
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
            f.write("\n")


def _lane_tid(lane: Optional[int]) -> int:
    # tid 0 = host track; device lane k = tid k + 1 (named "device:k")
    return 0 if lane is None else int(lane) + 1


def chrome_trace(spans: Iterable[Span]) -> Dict[str, Any]:
    """Spans -> Chrome ``trace_event`` dict (``ph: "X"`` complete events).

    Timestamps are microseconds relative to the earliest span, one event
    per span, plus ``M`` metadata events naming the process and each lane's
    thread so Perfetto renders ``host`` / ``device:k`` tracks.
    """
    spans = list(spans)
    base = min((s.t0 for s in spans), default=0.0)
    events: List[Dict[str, Any]] = [{
        "ph": "M", "pid": 1, "tid": 0, "name": "process_name",
        "args": {"name": "repro"},
    }]
    tids = sorted({_lane_tid(s.lane) for s in spans} | {0})
    for tid in tids:
        events.append({
            "ph": "M", "pid": 1, "tid": tid, "name": "thread_name",
            "args": {"name": "host" if tid == 0 else f"device:{tid - 1}"},
        })
        events.append({
            "ph": "M", "pid": 1, "tid": tid, "name": "thread_sort_index",
            "args": {"sort_index": tid},
        })
    for s in spans:
        args = {k: _json_safe(v) for k, v in s.attrs.items()}
        events.append({
            "ph": "X", "pid": 1, "tid": _lane_tid(s.lane),
            "name": s.name,
            "ts": (s.t0 - base) * 1e6,
            "dur": s.dur * 1e6,
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def _json_safe(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    try:
        return float(v)          # numpy scalars
    except Exception:
        return str(v)


def coverage(spans: Iterable[Span]) -> float:
    """Fraction of the trace extent covered by the union of all spans."""
    ivals = sorted((s.t0, s.t1) for s in spans)
    if not ivals:
        return 0.0
    lo = ivals[0][0]
    hi = max(t1 for _, t1 in ivals)
    if hi <= lo:
        return 1.0
    covered = 0.0
    cur0, cur1 = ivals[0]
    for t0, t1 in ivals[1:]:
        if t0 > cur1:
            covered += cur1 - cur0
            cur0, cur1 = t0, t1
        else:
            cur1 = max(cur1, t1)
    covered += cur1 - cur0
    return covered / (hi - lo)


# ---------------------------------------------------------------------------
# module-level active tracer + the cheap entry points
# ---------------------------------------------------------------------------

_active: Optional[Tracer] = None
_process_tracer: Optional[Tracer] = None    # the REPRO_TRACE accumulator


def active_tracer() -> Optional[Tracer]:
    """The tracer activated by :func:`tracing`, or ``None`` (tracing off)."""
    return _active


def span(name: str, lane: Optional[int] = None,
         **attrs: Any) -> Union[_SpanCtx, _NoopSpan]:
    """Open a span on the active tracer; a shared no-op when tracing is off.

    The disabled path is one global read and a return of a singleton — no
    clock read, no allocation — so instrumented hot paths stay hot.
    """
    tr = _active
    if tr is None:
        return _NOOP
    return tr.span(name, lane=lane, **attrs)


class _Stopwatch:
    """Always-on timer that doubles as a span when tracing is active.

    ``.elapsed`` is valid after exit (including the exception path).
    """

    __slots__ = ("name", "lane", "attrs", "t0", "elapsed")

    def __init__(self, name: str, lane: Optional[int], attrs: Dict[str, Any]):
        self.name = name
        self.lane = lane
        self.attrs = attrs
        self.t0 = 0.0
        self.elapsed = 0.0

    def __enter__(self) -> "_Stopwatch":
        self.t0 = _CLOCK()
        return self

    def __exit__(self, *exc: Any) -> bool:
        t1 = _CLOCK()
        self.elapsed = t1 - self.t0
        tr = _active
        if tr is not None:
            tr.record(Span(self.name, self.lane, self.t0, t1, self.attrs))
        return False


def stopwatch(name: str, lane: Optional[int] = None,
              **attrs: Any) -> _Stopwatch:
    """``with stopwatch("ph/h1") as sw: ...`` then read ``sw.elapsed``."""
    return _Stopwatch(name, lane, attrs)


def traced(name: Optional[str] = None, lane: Optional[int] = None,
           **attrs: Any) -> Callable:
    """Decorator form of :func:`span` (defaults to the function qualname)."""
    def deco(fn: Callable) -> Callable:
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any) -> Any:
            with span(label, lane=lane, **attrs):
                return fn(*args, **kwargs)
        return wrapper
    return deco


@contextlib.contextmanager
def tracing(trace: Union[None, bool, str, Tracer] = None) -> Iterator[Optional[Tracer]]:
    """Activate tracing for a region; resolves the user-facing knob.

    * ``None`` — defer to the environment: with ``REPRO_TRACE=out.json``
      set, activate the shared process tracer and (re-)export it to that
      path on exit, accumulating across calls; otherwise keep whatever is
      already active (no-op nesting).
    * ``False`` — do not start tracing (an already-active outer tracer
      keeps collecting).
    * a path ``str`` — fresh tracer for this region, exported to the path
      on exit.
    * a :class:`Tracer` — activate it, no auto-export (tests, benchmarks).

    ``REPRO_TRACE_JAX=1`` turns on the ``jax.profiler.TraceAnnotation``
    bridge for tracers this function creates.
    """
    global _active, _process_tracer
    export_path: Optional[str] = None
    bridge = os.environ.get("REPRO_TRACE_JAX", "") not in ("", "0")
    if trace is None:
        env = os.environ.get("REPRO_TRACE", "")
        if not env or _active is not None:
            yield _active
            return
        if _process_tracer is None:
            _process_tracer = Tracer(bridge=bridge)
        tr: Optional[Tracer] = _process_tracer
        export_path = env
    elif trace is False:
        yield _active
        return
    elif isinstance(trace, Tracer):
        tr = trace
    elif isinstance(trace, str):
        tr = Tracer(bridge=bridge)
        export_path = trace
    else:
        raise TypeError(f"trace must be None, False, a path, or a Tracer; "
                        f"got {trace!r}")
    prev = _active
    _active = tr
    try:
        yield tr
    finally:
        _active = prev
        if export_path is not None and tr is not None:
            tr.export_chrome(export_path)


# ---------------------------------------------------------------------------
# simulated critical path from the reduce/* span timeline
# ---------------------------------------------------------------------------

def critical_path(spans: Iterable[Span]) -> Dict[str, float]:
    """Simulated P-device critical-path wall from ``reduce/*`` spans.

    This is the single source of truth for the distributed packed driver's
    ``sim_wall_s`` (ISSUE 8 bugfix: derived from the span timeline, not
    hand-rolled bookkeeping).  Span conventions, all carrying a ``step``
    attribute grouping them into supersteps:

    * ``reduce/fused`` — shared block ops; its ``weights`` attribute is the
      per-lane row share, so lane ``k`` is charged ``dur * weights[k]``.
    * ``reduce/slice`` (``lane=k``) — lane-local serial passes, charged
      fully to lane ``k``; the concurrent phase costs
      ``max_k(fused * weights[k] + slice_k)``.
    * ``reduce/tournament`` — sequential catch-up, full cost.
    * ``reduce/sweep`` (``lane=k``, ``deps=(..)``) — commit sweeps; cost is
      the longest path through the dependency DAG (``deps`` lists the lanes
      whose this-superstep pivots lane ``k`` absorbed; they point strictly
      backward, so one forward pass is the longest-path DP).
    * ``reduce/encode`` (``lane=k``) / ``reduce/exchange`` — an exchange
      round costs the slowest shard's encode plus decode + install.

    For ``P == 1`` the result reproduces the measured reduction wall.
    """
    steps: Dict[int, List[Span]] = {}
    for s in spans:
        if not s.name.startswith("reduce/"):
            continue
        st = s.attrs.get("step")
        if st is None:
            continue
        steps.setdefault(int(st), []).append(s)

    wall = conc = sweep_total = sync = 0.0
    for st in sorted(steps):
        group = steps[st]
        weights: List[float] = [1.0]
        fused = 0.0
        slice_d: Dict[int, float] = {}
        sweep_d: Dict[int, float] = {}
        deps: Dict[int, tuple] = {}
        enc: Dict[int, float] = {}
        tourn = 0.0
        exch = 0.0
        has_exchange = False
        for s in group:
            if s.name == "reduce/fused":
                fused += s.dur
                w = s.attrs.get("weights")
                if w is not None:
                    weights = [float(x) for x in w]
            elif s.name == "reduce/slice":
                k = int(s.lane or 0)
                slice_d[k] = slice_d.get(k, 0.0) + s.dur
            elif s.name == "reduce/tournament":
                tourn += s.dur
            elif s.name == "reduce/sweep":
                k = int(s.lane or 0)
                sweep_d[k] = sweep_d.get(k, 0.0) + s.dur
                deps[k] = tuple(s.attrs.get("deps", ()))
            elif s.name == "reduce/encode":
                k = int(s.lane or 0)
                enc[k] = enc.get(k, 0.0) + s.dur
            elif s.name == "reduce/exchange":
                exch += s.dur
                has_exchange = True

        step_conc = max(
            (fused * weights[k] + slice_d.get(k, 0.0)
             for k in range(len(weights))), default=0.0)
        finish: Dict[int, float] = {}
        for k in sorted(sweep_d):       # deps point strictly backward
            start = max((finish.get(d, 0.0) for d in deps.get(k, ())),
                        default=0.0)
            finish[k] = start + sweep_d[k]
        step_sweep = max(finish.values(), default=0.0)
        step_sync = tourn
        if has_exchange or enc:
            step_sync += max(enc.values(), default=0.0) + exch

        conc += step_conc
        sweep_total += step_sweep
        sync += step_sync
        wall += step_conc + step_sweep + step_sync

    return {
        "sim_wall_s": wall,
        "sim_conc_s": conc,
        "sim_sweep_s": sweep_total,
        "sim_sync_s": sync,
    }

"""Training substrate: native AdamW, microbatched train step, train state."""
from .optimizer import AdamW, AdamWState, global_norm, warmup_cosine
from .train_step import (TrainState, init_train_state, lm_loss, make_loss_fn,
                         make_train_step)

__all__ = [
    "AdamW", "AdamWState", "global_norm", "warmup_cosine",
    "TrainState", "init_train_state", "lm_loss", "make_loss_fn",
    "make_train_step",
]

"""Native AdamW + warmup-cosine schedule + global-norm clipping.

Built in-repo (no optax) per the everything-from-substrate mandate.  The
optimizer state tree mirrors the param tree, so the sharding rule engine
shards first/second moments exactly like their parameters (ZeRO-style when
params are FSDP-sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params),
                          v=zeros(params))

    def update(self, grads, state: AdamWState, params):
        if self.clip_norm > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = global_norm(grads)
        step = state.step + 1
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        flat = jax.tree.map(upd, grads, state.m, state.v, params)
        m = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
        new_p = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, AdamWState(step=step, m=m, v=v), gnorm


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)))
        for l in jax.tree.leaves(tree)))


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr

"""Training step: loss, microbatch gradient accumulation, train state.

``make_train_step`` builds the jit-able step used by the launcher and the
dry-run: scan over ``n_micro`` microbatches (each remat'd per the model
config), accumulate fp32 grads, clip, AdamW update.  Gradient accumulation +
per-block remat is what fits the train_4k cells into 16 GB/chip (see
EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward
from .optimizer import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray, vocab_size: int,
            z_loss: float = 1e-4) -> jnp.ndarray:
    """Cross-entropy over the unpadded vocab + z-loss regularizer."""
    v_pad = logits.shape[-1]
    if v_pad > vocab_size:
        pad_mask = jnp.arange(v_pad) >= vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(logz))
    return loss


def _shift_batch(batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    """inputs = tokens[:, :-1]; labels = tokens[:, 1:] (token models);
    embedding-input models carry explicit labels."""
    if cfg.input_kind == "tokens":
        toks = batch["tokens"]
        inp = dict(batch, tokens=toks[:, :-1])
        if "positions" in batch:
            inp["positions"] = batch["positions"][:, :-1]
        return inp, toks[:, 1:]
    return batch, batch["labels"]


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        inp, labels = _shift_batch(batch, cfg)
        logits, aux = forward(params, cfg, inp)
        if cfg.input_kind != "tokens":
            labels = labels[:, :logits.shape[1]]
        loss = lm_loss(logits, labels, cfg.vocab_size, cfg.z_loss)
        return loss + aux, (loss, aux)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: AdamW, n_micro: int = 1,
                    micro_batch_axes=None):
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves have leading dim = global_batch; they are split into
    ``n_micro`` microbatches scanned sequentially with fp32 accumulation.

    ``micro_batch_axes`` (mesh axis name/tuple, e.g. ``("pod", "data")``)
    pins the *per-micro batch* dim after the reshape.  Without it the SPMD
    partitioner may shard the scan (microbatch) axis instead — every device
    then redundantly computes the full microbatch and data-parallelism is
    silently lost (caught by the dry-run roofline: 16x FLOP inflation on the
    16-way data mesh; see EXPERIMENTS.md §Perf iteration 0).
    """
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    from jax.sharding import PartitionSpec as P

    def train_step(state: TrainState, batch):
        def reshape_micro(x):
            b = x.shape[0]
            assert b % n_micro == 0, (b, n_micro)
            return x.reshape((n_micro, b // n_micro) + x.shape[1:])

        # positions3 has batch on axis 1
        micro = {}
        for k, v in batch.items():
            if k == "positions3":
                m = v.reshape(v.shape[0], n_micro, -1, v.shape[-1])
                micro[k] = jnp.moveaxis(m, 1, 0)
            else:
                micro[k] = reshape_micro(v)
        if micro_batch_axes is not None:
            def pin(k, x):
                b_ax = 2 if k == "positions3" else 1
                spec = [None] * x.ndim
                spec[b_ax] = micro_batch_axes
                return jax.lax.with_sharding_constraint(x, P(*spec))
            micro = {k: pin(k, v) for k, v in micro.items()}

        def body(acc, mb):
            g_acc, l_acc, a_acc = acc
            (tot, (loss, aux)), grads = grad_fn(state.params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_micro,
                g_acc, grads)
            return (g_acc, l_acc + loss / n_micro, a_acc + aux / n_micro), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          state.params)
        (grads, loss, aux), _ = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            micro)
        new_params, new_opt, gnorm = opt.update(grads, state.opt, state.params)
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm,
                   "lr": opt.lr(new_opt.step)}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt: AdamW, key) -> TrainState:
    from repro.models.transformer import init_params
    params = init_params(cfg, key)
    return TrainState(params=params, opt=opt.init(params))

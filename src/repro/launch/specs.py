"""Per-cell build: (arch × input-shape × mesh) -> jittable fn + ShapeDtypeStruct
inputs + in/out shardings.

Shape semantics (task spec): ``train_*`` lowers ``train_step``;
``prefill_*`` lowers the batched prefill; ``decode_*`` / ``long_*`` lower
``serve_step`` (one new token against a KV cache of ``seq_len``).  Whisper
(enc-dec) splits every cell's budget S into S_enc = S_dec = S/2 (DESIGN.md);
VLM cells feed precomputed patch embeddings + (3, B, S) M-RoPE grids —
modality frontends are stubs per the task spec.

``input_specs`` returns weak-type-correct ShapeDtypeStructs only — nothing
here allocates; params/optimizer/cache shapes come from ``jax.eval_shape``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.dist.sharding import (activation_rules, batch_specs,
                                 bind_activation_rules, cache_specs,
                                 shard_params, shardings_from_specs,
                                 tree_path_str)
from repro.models.config import ModelConfig
from repro.models.transformer import init_params, make_cache
from repro.serve.steps import make_decode_step, make_prefill_step
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.train_step import TrainState, make_train_step
from repro.train.optimizer import AdamWState


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, kind: str, seq_len: int, batch: int
                ) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the model's *data* inputs."""
    d = cfg.d_model
    if cfg.enc_dec:
        s_enc = seq_len // 2
        s_dec = seq_len // 2
        if kind == "train":
            return {"tokens": sds((batch, s_dec + 1), jnp.int32),
                    "enc_embeds": sds((batch, s_enc, d), cfg.cdtype)}
        if kind == "prefill":
            return {"tokens": sds((batch, s_dec), jnp.int32),
                    "enc_embeds": sds((batch, s_enc, d), cfg.cdtype)}
        # decode: one decoder token; cross-attends cached encoder output
        return {"tokens": sds((batch, 1), jnp.int32),
                "cache_pos": sds((), jnp.int32)}
    if cfg.input_kind != "tokens":                    # vlm: patch embeddings
        if kind == "train":
            out = {"embeds": sds((batch, seq_len, d), cfg.cdtype),
                   "labels": sds((batch, seq_len), jnp.int32)}
        elif kind == "prefill":
            out = {"embeds": sds((batch, seq_len, d), cfg.cdtype)}
        else:
            out = {"embeds": sds((batch, 1, d), cfg.cdtype),
                   "cache_pos": sds((), jnp.int32)}
        s = seq_len if kind in ("train", "prefill") else 1
        if cfg.rope_kind == "mrope":
            out["positions3"] = sds((3, batch, s), jnp.int32)
        return out
    if kind == "train":
        return {"tokens": sds((batch, seq_len + 1), jnp.int32)}
    if kind == "prefill":
        return {"tokens": sds((batch, seq_len), jnp.int32)}
    return {"tokens": sds((batch, 1), jnp.int32),
            "cache_pos": sds((), jnp.int32)}


def cache_shapes(cfg: ModelConfig, batch: int, s_max: int):
    """Decode-cache ShapeDtypeStructs (eval_shape — no allocation)."""
    s_cache = s_max // 2 if cfg.enc_dec else s_max

    def build():
        # enc-dec decode reads cached cross-K/V (computed at prefill), so
        # the raw encoder output no longer rides in the decode cache
        return make_cache(cfg, batch, s_cache, enc_out=None)

    return jax.eval_shape(build)


# ---------------------------------------------------------------------------
# parameter / FLOP accounting
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def count_params(cfg: ModelConfig) -> Dict[str, float]:
    """total / embedding / routed-expert / active parameter counts."""
    shapes = param_shapes(cfg)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = emb = routed = 0
    for kp, leaf in flat:
        path = tree_path_str(kp)
        n = int(np.prod(leaf.shape))
        total += n
        name = path.split("/")[-1]
        if path in ("embed/table", "lm_head/table"):
            emb += n
        elif name in ("w_gate", "w_up", "w_down") and leaf.ndim >= 4:
            routed += n          # stacked (reps, E, d, f) routed experts
    active = total
    if cfg.moe is not None and routed:
        frac = cfg.moe.top_k / cfg.moe.n_experts
        active = total - routed * (1.0 - frac)
    return {"total": float(total), "embedding": float(emb),
            "routed_expert": float(routed), "active": float(active)}


def model_flops(cfg: ModelConfig, kind: str, seq_len: int, batch: int
                ) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference), with
    N = non-embedding active params and D = processed tokens (task spec)."""
    c = count_params(cfg)
    n = c["active"] - c["embedding"]
    if cfg.enc_dec:
        tokens = batch * (seq_len // 2) if kind != "decode" else batch
    elif kind == "decode":
        tokens = batch
    else:
        tokens = batch * seq_len
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


# ---------------------------------------------------------------------------
# per-cell assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                       # train | prefill | decode
    fn: Callable                    # jit-able step
    args: Tuple[Any, ...]           # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    meta: Dict[str, Any]
    static_argnums: Tuple[int, ...] = ()
    donate_argnums: Tuple[int, ...] = ()


def _dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in ("pod", "data")
                        if a in mesh.axis_names]))


def train_micro(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> int:
    """Microbatch count: per-device-per-micro batch of 1 (max remat win),
    subject to (B / n_micro) % dp == 0."""
    dp = _dp_size(mesh)
    n_micro = max(1, global_batch // dp)
    while global_batch % n_micro or (global_batch // n_micro) % dp:
        n_micro -= 1
    return n_micro


def build_cell(arch: str, shape: str, mesh: Mesh,
               overrides: Optional[dict] = None) -> Cell:
    spec = SHAPES[shape]
    kind, seq_len, batch = spec["kind"], spec["seq_len"], spec["global_batch"]
    cfg = get_config(arch)
    force_n_micro = None
    if overrides:
        overrides = dict(overrides)
        force_n_micro = overrides.pop("n_micro", None)
        cfg = dataclasses.replace(cfg, **overrides)
    meta: Dict[str, Any] = dict(
        arch=arch, shape=shape, kind=kind, seq_len=seq_len,
        global_batch=batch, params=count_params(cfg),
        model_flops=model_flops(cfg, kind, seq_len, batch))
    heads = {"q": cfg.n_heads, "kv": cfg.n_kv_heads}
    act_rules = activation_rules(cfg, mesh, decode=(kind == "decode"),
                                 batch=batch)
    meta["activation_rules"] = {k: str(v) for k, v in act_rules.items()}

    if kind == "train":
        cfg = dataclasses.replace(cfg, remat=cfg.remat if cfg.remat != "none"
                                  else "full")
        n_micro = force_n_micro or train_micro(cfg, mesh, batch)
        meta["n_micro"] = n_micro
        opt = AdamW(lr=warmup_cosine(3e-4, 100, 10_000))
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        step_fn = make_train_step(cfg, opt, n_micro=n_micro,
                                  micro_batch_axes=dp_axes)
        step_fn = bind_activation_rules(step_fn, act_rules)
        pshapes = param_shapes(cfg)
        state_shapes = TrainState(
            params=pshapes,
            opt=AdamWState(
                step=sds((), jnp.int32),
                m=jax.tree.map(lambda l: sds(l.shape, jnp.float32), pshapes),
                v=jax.tree.map(lambda l: sds(l.shape, jnp.float32), pshapes)))
        batch_shapes = input_specs(cfg, "train", seq_len, batch)

        pspecs, report = shard_params(pshapes, mesh, fsdp=True, heads=heads)
        state_specs = TrainState(
            params=pspecs,
            opt=AdamWState(step=P(), m=pspecs, v=pspecs))
        bspecs = batch_specs(batch_shapes, mesh)
        meta["sharding_report"] = report
        state_sh = shardings_from_specs(state_specs, mesh)
        batch_sh = shardings_from_specs(bspecs, mesh)
        return Cell(arch=arch, shape=shape, kind=kind, fn=step_fn,
                    args=(state_shapes, batch_shapes),
                    in_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, None), meta=meta,
                    donate_argnums=(0,))

    pshapes = param_shapes(cfg)
    pspecs, report = shard_params(pshapes, mesh, fsdp=False, heads=heads)
    meta["sharding_report"] = report
    param_sh = shardings_from_specs(pspecs, mesh)

    if kind == "prefill":
        step_fn = bind_activation_rules(make_prefill_step(cfg), act_rules)
        batch_shapes = input_specs(cfg, "prefill", seq_len, batch)
        bspecs = batch_specs(batch_shapes, mesh)
        batch_sh = shardings_from_specs(bspecs, mesh)
        return Cell(arch=arch, shape=shape, kind=kind, fn=step_fn,
                    args=(pshapes, batch_shapes),
                    in_shardings=(param_sh, batch_sh),
                    out_shardings=None, meta=meta)

    # decode / long: serve_step — one token against a seq_len cache
    step_fn = bind_activation_rules(make_decode_step(cfg), act_rules)
    cshapes = cache_shapes(cfg, batch, seq_len)
    batch_shapes = input_specs(cfg, "decode", seq_len, batch)
    cspecs = {
        "layers": cache_specs(cshapes["layers"], mesh, seq_len=(
            seq_len // 2 if cfg.enc_dec else seq_len), batch=batch),
        # enc_out is None for decoder-only archs; a P() *prefix leaf* matches
        # the empty subtree so in/out cache pytrees stay congruent
        "enc_out": (P() if cshapes.get("enc_out") is None else
                    batch_specs({"e": cshapes["enc_out"]}, mesh)["e"]),
    }
    bspecs = batch_specs(batch_shapes, mesh)
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                            is_leaf=lambda x: isinstance(x, P))
    batch_sh = shardings_from_specs(bspecs, mesh)
    return Cell(arch=arch, shape=shape, kind=kind, fn=step_fn,
                args=(pshapes, cshapes, batch_shapes),
                in_shardings=(param_sh, cache_sh, batch_sh),
                out_shardings=(None, cache_sh), meta=meta,
                donate_argnums=(1,))

"""Batched serving driver: ServeEngine over synthetic request traffic.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 16 --max-new 24
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.obs.trace import stopwatch
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    engine = ServeEngine(cfg, max_batch=args.max_batch,
                         prompt_len=args.prompt_len, s_max=args.s_max,
                         seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, args.prompt_len),
                              dtype=np.int32)
        engine.submit(Request(uid=uid, prompt=prompt, max_new=args.max_new))

    with stopwatch("serve/run") as sw:
        done = engine.run()
    wall = sw.elapsed
    total_tokens = sum(len(v) for v in done.values())
    print(f"served {len(done)}/{args.requests} requests, "
          f"{total_tokens} tokens in {wall:.2f}s "
          f"({total_tokens / wall:.1f} tok/s batched on CPU)")
    for uid in sorted(done)[:4]:
        print(f"  req {uid}: {done[uid][:12]}...")


if __name__ == "__main__":
    main()

"""Batched serving drivers over synthetic traffic.

Two workloads share the launcher:

* ``--workload tokens`` — the transformer ``ServeEngine`` (fixed-slot
  continuous batching over a shared KV cache).
* ``--workload ph`` — ``PHServeEngine``: admission-controlled persistent
  homology serving with union-batched cold requests and warm-start
  incremental updates (tau growth / point arrival) against the dataset
  cache.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --workload tokens \
        --arch qwen3-0.6b --requests 16 --max-new 24
    PYTHONPATH=src python -m repro.launch.serve --workload ph \
        --requests 24 --cloud-size 48 --update-fraction 0.5
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.obs.trace import stopwatch


def run_tokens(args) -> None:
    from repro.configs import get_config
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch, reduced=True)
    engine = ServeEngine(cfg, max_batch=args.max_batch,
                         prompt_len=args.prompt_len, s_max=args.s_max,
                         seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, args.prompt_len),
                              dtype=np.int32)
        engine.submit(Request(uid=uid, prompt=prompt, max_new=args.max_new))

    with stopwatch("serve/run") as sw:
        done = engine.run()
    wall = sw.elapsed
    total_tokens = sum(len(v) for v in done.values())
    print(f"served {len(done)}/{args.requests} requests, "
          f"{total_tokens} tokens in {wall:.2f}s "
          f"({total_tokens / wall:.1f} tok/s batched on CPU)")
    for uid in sorted(done)[:4]:
        print(f"  req {uid}: {done[uid][:12]}...")


def run_ph(args) -> None:
    from repro.serve.ph import PHRequest, PHServeEngine

    engine = PHServeEngine(
        memory_budget_bytes=args.budget_bytes,
        store_budget_bytes=args.store_budget_bytes,
        max_batch_clouds=args.max_batch_clouds,
        landmark_cap=args.landmark_cap,
        seed=args.seed,
        engine=args.reduce_engine,
        batch_size=args.batch_size,
        n_shards=args.n_shards)
    rng = np.random.default_rng(args.seed)
    n_cold = max(1, int(round(args.requests * (1 - args.update_fraction))))
    clouds = [rng.normal(size=(args.cloud_size, 3)) for _ in range(n_cold)]
    uid = 0
    for k, p in enumerate(clouds):
        engine.submit(PHRequest(uid=uid, points=p, tau_max=args.tau,
                                dataset=f"ds{k}"))
        uid += 1
    with stopwatch("serve_ph/cold_wave") as sw_cold:
        engine.run()
    # update wave: alternate tau growth and point arrival on cached datasets
    while uid < args.requests:
        k = int(rng.integers(0, n_cold))
        if uid % 2 == 0:
            engine.submit(PHRequest(uid=uid, points=clouds[k],
                                    tau_max=args.tau * 1.5,
                                    dataset=f"ds{k}"))
        else:
            grown = np.concatenate(
                [clouds[k], rng.normal(size=(args.arrivals, 3))], axis=0)
            engine.submit(PHRequest(uid=uid, points=grown,
                                    tau_max=args.tau, dataset=f"ds{k}"))
        uid += 1
    with stopwatch("serve_ph/update_wave") as sw_warm:
        engine.run()
    s = engine.stats()
    served = int(s.get("serve_ph_n_admitted", 0))
    wall = sw_cold.elapsed + sw_warm.elapsed
    hits = s.get("serve_ph_n_cache_hits", 0.0)
    hit_ratio = hits / max(1.0, s.get("serve_ph_n_requests", 0.0))
    print(f"served {served}/{args.requests} PH requests in {wall:.2f}s "
          f"({served / wall:.1f} req/s), cache-hit ratio {hit_ratio:.2f}")
    for key in ("serve_ph_n_cold", "serve_ph_n_batched",
                "serve_ph_n_warm_tau", "serve_ph_n_warm_points",
                "serve_ph_n_rejected", "serve_ph_store_bytes"):
        print(f"  {key} = {s.get(key, 0.0):.0f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", choices=("tokens", "ph"), default="tokens")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    # tokens workload
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--s-max", type=int, default=128)
    # ph workload
    ap.add_argument("--cloud-size", type=int, default=48)
    ap.add_argument("--tau", type=float, default=1.6)
    ap.add_argument("--arrivals", type=int, default=6,
                    help="points appended per point-arrival update")
    ap.add_argument("--update-fraction", type=float, default=0.5,
                    help="fraction of requests that are warm updates")
    ap.add_argument("--budget-bytes", type=int, default=None,
                    help="admission memory budget per reduction")
    ap.add_argument("--store-budget-bytes", type=int, default=None,
                    help="per-tenant cached-state budget")
    ap.add_argument("--max-batch-clouds", type=int, default=8)
    ap.add_argument("--landmark-cap", type=int, default=None)
    ap.add_argument("--reduce-engine", default="single",
                    choices=("single", "batch", "packed"))
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--n-shards", type=int, default=None)
    args = ap.parse_args()
    if args.workload == "tokens":
        run_tokens(args)
    else:
        run_ph(args)


if __name__ == "__main__":
    main()

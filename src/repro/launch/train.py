"""End-to-end training driver.

Runs any registered architecture (full or ``--reduced``) on the local
device(s): data pipeline -> sharded train state -> jitted microbatched step
-> async checkpointing -> metrics, with optional TDA monitoring (the paper's
technique applied to the model's own hidden states: persistence diagrams of
the final-layer activation point cloud, logged every ``--tda-every`` steps).

On CPU this trains reduced configs end-to-end (examples/train_lm.py drives a
~27M model a few hundred steps and asserts the loss drops); on a real TPU
mesh the same file is the production entry point — the mesh/sharding plumbing
is identical to the dry-run's (launch/specs.py).

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 200 --batch 32 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.tokens import ShardedTokenStream
from repro.dist.sharding import (activation_rules, batch_specs,
                                 bind_activation_rules, shard_params,
                                 shardings_from_specs)
from repro.launch.mesh import make_mesh
from repro.models.config import ModelConfig
from repro.obs.trace import stopwatch
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.train_step import (TrainState, init_train_state,
                                    make_train_step)


@dataclasses.dataclass
class TrainJob:
    cfg: ModelConfig
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 64
    n_micro: int = 1
    lr: float = 3e-4
    warmup: int = 20
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    tda_every: int = 0
    mesh_shape: Optional[tuple] = None       # e.g. (2, 2) on forced devices
    log_every: int = 10


def tda_monitor(params, cfg: ModelConfig, batch: Dict[str, np.ndarray]
                ) -> Dict[str, float]:
    """PH of the final hidden-state point cloud (Dory engine on the model's
    own representations) — H0/H1 Betti summary at the median pairwise scale."""
    from repro.core import compute_ph
    from repro.models.transformer import forward

    sub = {k: jnp.asarray(v[:4]) for k, v in batch.items()}
    if cfg.input_kind == "tokens":
        sub["tokens"] = sub["tokens"][:, :-1]
    logits, _ = forward(params, cfg, sub)
    # final hidden states ~ logits are too wide; use a random projection
    x = np.asarray(logits[..., :64], dtype=np.float64)
    pts = x.reshape(-1, x.shape[-1])[:256]
    res = compute_ph(points=pts, maxdim=1,
                     tau_max=float(np.quantile(
                         np.linalg.norm(pts[:1] - pts, axis=-1), 0.5)) + 1e-6)
    b = res.betti_at(res.stats.get("tau_med", 0.0))
    return {"tda_h0_pairs": float(len(res.diagrams[0])),
            "tda_h1_pairs": float(len(res.diagrams[1])),
            "tda_b0": float(b.get(0, 0))}


def run(job: TrainJob, restore: bool = False) -> Dict[str, Any]:
    cfg = job.cfg
    opt = AdamW(lr=warmup_cosine(job.lr, job.warmup, max(job.steps, 2)))
    key = jax.random.PRNGKey(job.seed)

    mesh = None
    if job.mesh_shape is not None:
        axes = ("data", "model")[:len(job.mesh_shape)] \
            if len(job.mesh_shape) == 2 else ("pod", "data", "model")
        mesh = make_mesh(job.mesh_shape, axes)

    step_fn = make_train_step(
        cfg, opt, n_micro=job.n_micro,
        micro_batch_axes=(tuple(a for a in ("pod", "data")
                                if a in mesh.axis_names) if mesh else None))

    ckpt = Checkpointer(job.ckpt_dir) if job.ckpt_dir else None
    start_step = 0
    state = None

    if mesh is not None:
        rules = activation_rules(cfg, mesh)
        step_fn = bind_activation_rules(step_fn, rules)
        heads = {"q": cfg.n_heads, "kv": cfg.n_kv_heads}
        with mesh:
            state = init_train_state(cfg, opt, key)
            pspecs, _ = shard_params(state.params, mesh, fsdp=True,
                                     heads=heads)
            from repro.train.optimizer import AdamWState
            from jax.sharding import PartitionSpec as P
            sspecs = TrainState(params=pspecs, opt=AdamWState(
                step=P(), m=pspecs, v=pspecs))
            ssh = shardings_from_specs(sspecs, mesh)
            if restore and ckpt is not None and ckpt.latest_step() is not None:
                state, meta = ckpt.restore(state, shardings=ssh)
                start_step = int(meta.get("step", 0)) + 1
            else:
                state = jax.device_put(state, ssh)
            bspecs = batch_specs(
                {"tokens": jax.ShapeDtypeStruct(
                    (job.global_batch, job.seq_len + 1), jnp.int32)}, mesh)
            bsh = shardings_from_specs(bspecs, mesh)
            jstep = jax.jit(step_fn, in_shardings=(ssh, bsh),
                            out_shardings=(ssh, None), donate_argnums=(0,))
    else:
        state = init_train_state(cfg, opt, key)
        if restore and ckpt is not None and ckpt.latest_step() is not None:
            state, meta = ckpt.restore(state)
            start_step = int(meta.get("step", 0)) + 1
        jstep = jax.jit(step_fn, donate_argnums=(0,))

    stream = ShardedTokenStream(vocab=cfg.vocab_size,
                                global_batch=job.global_batch,
                                seq=job.seq_len + 1, seed=job.seed)
    history = []
    with stopwatch("train/steps") as sw_wall:
        for step in range(start_step, job.steps):
            batch_np = stream.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if mesh is not None:
                with mesh:
                    state, metrics = jstep(state, batch)
            else:
                state, metrics = jstep(state, batch)
            if step % job.log_every == 0 or step == job.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                if job.tda_every and step % job.tda_every == 0:
                    m.update(tda_monitor(state.params, cfg, batch_np))
                history.append(m)
                print(json.dumps({k: round(v, 5) if isinstance(v, float) else v
                                  for k, v in m.items()}))
            if ckpt is not None and step and step % job.ckpt_every == 0:
                ckpt.save_async(step, state, metadata={"step": step})
        if ckpt is not None:
            ckpt.save(job.steps - 1, state, metadata={"step": job.steps - 1})
            ckpt.wait()
    wall = sw_wall.elapsed
    return {"history": history, "state": state, "wall_s": wall,
            "final_loss": history[-1]["loss"] if history else float("nan")}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--tda-every", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=64,
                    help="reduced config width")
    ap.add_argument("--layers", type=int, default=2,
                    help="reduced config depth")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.layers, d_model=args.d_model,
                          n_heads=max(4, args.d_model // 32),
                          d_ff=args.d_model * 4)
    job = TrainJob(cfg=cfg, steps=args.steps, global_batch=args.batch,
                   seq_len=args.seq, n_micro=args.n_micro, lr=args.lr,
                   ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                   tda_every=args.tda_every)
    out = run(job, restore=args.restore)
    print(f"done: {args.steps} steps in {out['wall_s']:.1f}s, "
          f"final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()

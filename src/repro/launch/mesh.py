"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod, ICI).
Multi pod:  (pod=2, data=16, model=16) = 512 chips; ``pod`` is the DCN axis.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  The dry-run process forces 512 host devices; the
single-pod mesh then uses the first 256, so both meshes build in one process.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {dict(zip(axes, shape))}, have "
            f"{len(devices)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this)")
    return Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh over the first prod(shape) devices (tests/elastic)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    assert len(devices) >= n, (len(devices), shape)
    return Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_data_mesh(n_devices: int | None = None) -> Mesh:
    """1-D ``(data,)`` mesh over the first ``n_devices`` (default: all).

    The mesh shape ``repro.scale.shard`` and ``compute_ph(...,
    backend="tiled", mesh=...)`` expect for sharding the tile harvest; on a
    CPU host, virtual devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
    initializes — the 4-device CI job does this).
    """
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices for a (data={n},) mesh, "
                           f"have {len(devices)}")
    return Mesh(np.array(devices[:n]), ("data",))

"""Post-SPMD HLO analysis: trip-weighted FLOPs / HBM traffic / collective
link-bytes for the roofline.

Why not ``compiled.cost_analysis()`` alone?  XLA's HloCostAnalysis counts
every computation ONCE — a 28-layer ``lax.scan`` body or a 16-microbatch
accumulation loop is charged a single iteration, undercounting FLOPs by the
trip count (verified: qwen3 train_4k reports 26x fewer FLOPs than
6·N·D).  And it reports no collective traffic at all.  So we parse
``compiled.as_text()`` ourselves:

* **computations** are split on header lines; each op's RESULT shape is
  inline (operand shapes are not — they are resolved through a
  per-computation symbol table built from defining lines and parameters);
* **while** trip counts come from the backend_config
  ``known_trip_count`` (exact, set by the loop-simplifier), with the
  condition-constant heuristic as fallback;
* **flops**: ``dot`` = 2 · prod(result dims) · prod(lhs contracting dims)
  (+ convolution via the same formula over kernel dims); counted through
  fusion-called computations too;
* **HBM traffic**: post-fusion HLO materializes exactly one buffer per
  top-level op — traffic ≈ Σ (result bytes + operand bytes) over
  materializing ops (fusions, dots, copies, collectives, …);
  ``parameter / tuple / get-tuple-element / bitcast / constant`` are free,
  ops inside fused computations are VMEM-resident and charged nothing.
  Two TPU-target corrections on the CPU-backend artifact:
  - **in-place dynamic-update-slice**: a fusion containing a DUS aliases its
    big buffer operand and writes only the update region — charged
    2 x Σ(non-aliased operands), not the full buffer (XLA's
    InPlaceDynamicUpdateSliceFusion; without this the decode cache scan is
    overcharged ~30x);
  - **dtype-legalization converts**: the CPU backend upcasts bf16 dot
    operands to f32 and keeps full-precision copies (bf16 dots unsupported
    on CPU); a fusion whose root is a pure element-count-preserving convert
    is charged 0 — on the TPU target the MXU consumes bf16 directly and
    these copies do not exist;
* **collectives** are charged ring-algorithm per-device link bytes from the
  RESULT shape (R) and replica-group size N:
    all-reduce          2·R·(N-1)/N      (R = full buffer)
    all-gather          R·(N-1)/N        (R = gathered output)
    reduce-scatter      R·(N-1)          (R = scattered shard)
    all-to-all          R·(N-1)/N        (R = local buffer)
    collective-permute  R
  Groups whose device ids span pods (id // pod_size differs) are DCN
  traffic, the rest ICI.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*[a-z\d]*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,}{]+)\}\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,()TS]+)\]")
_TRIP_RE = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"')
_CONST_RE = re.compile(r"=\s*[su]\d+\[\]\s*constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")

# ops that do not materialize an HBM buffer
_FREE_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "iota", "partition-id", "replica-id",
}


def _first_word(rest: str) -> str:
    """Opcode of an op line: the token right before the first '(' that is
    not part of the result-shape text."""
    # strip the result shape(s): everything up to the last ']' or '}' before
    # the opcode.  Simplest robust approach: scan tokens from the end of the
    # shape prefix.
    m = re.match(r"^(?:\([^()]*\)|[a-z]+\d*[a-z\d]*\[[\d,]*\](?:\{[\d,]*\})?"
                 r"|\s|,|/\*[^*]*\*/)*([\w\-]+)\(", rest)
    return m.group(1) if m else ""


def shape_bytes(text: str) -> int:
    """Sum byte sizes of every shape literal in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> List[List[int]]:
    """All shape literals' dims in ``text`` (first = result for op lines)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append([int(d) for d in dims.split(",") if d])
    return out


def _ring_bytes(kind: str, result_bytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * result_bytes * (n - 1) / n
    if kind == "all-gather":
        return float(result_bytes) * (n - 1) / n
    if kind == "reduce-scatter":
        return float(result_bytes) * (n - 1)
    if kind == "all-to-all":
        return float(result_bytes) * (n - 1) / n
    if kind == "collective-broadcast":
        return float(result_bytes)
    return float(result_bytes)        # collective-permute


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_bytes: int
    result_dims: List[int]
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    symtab: Dict[str, _Op]
    whiles: List[Tuple[str, str, float]]     # (cond, body, trip)
    calls: List[str]                         # call/conditional edges
    fusion_calls: List[str]                  # fusion-called computations
    max_const: int = 0
    has_dus: bool = False                    # contains dynamic-update-slice
    root_opcode: str = ""
    root_elems: int = 0                      # element count of the root
    n_compute_ops: int = 0                   # non-layout/non-convert ops
    # parameter index -> bytes actually read when the parameter's only
    # consumers are (dynamic-)slice ops (scan xs/cache stacks: a fusion
    # reading stacked[i] must be charged the slice, not the stack)
    param_slice_bytes: Dict[int, int] = dataclasses.field(
        default_factory=dict)


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            if "->" in line and line.rstrip().endswith("{"):
                m = _COMP_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        comps[cur].append(line)
    return comps


def _group_info(line: str, pod_size: int) -> Tuple[int, bool]:
    """(replica group size, crosses_pod) for a collective op line."""
    gm = _GROUPS_RE.search(line)
    if gm:
        groups = gm.group(1).split("},{")
        first = [int(x) for x in groups[0].strip("{}").split(",") if x]
        n = len(first)
        crosses = any(
            len({int(x) // pod_size
                 for x in g.strip("{}").split(",") if x}) > 1
            for g in groups)
        return n, crosses
    gi = _GROUPS_IOTA_RE.search(line)
    if gi:
        n_groups, group_size = int(gi.group(1)), int(gi.group(2))
        # iota groups [G,N]<=[dims(perm)]: contiguous ids iff the iota is
        # untransposed; a group whose stride reaches across pod_size crosses.
        spec = gi.group(3)
        total = n_groups * group_size
        if "T" not in spec and "(" not in spec:
            # [G,N]<=[total] row-major: group g = [g*N, (g+1)*N)
            crosses = group_size > pod_size or (
                total > pod_size and group_size > 1 and
                (pod_size % group_size != 0))
        else:
            # transposed iota: elements of a group are strided by n_groups —
            # any multi-pod program with stride >= pod_size crosses
            crosses = total > pod_size
        return group_size, crosses
    return 1, False


def _parse_computation(name: str, lines: List[str],
                       pod_size: int) -> _Computation:
    comp = _Computation(name=name, ops=[], symtab={}, whiles=[], calls=[],
                        fusion_calls=[])
    for line in lines:
        for m in _CONST_RE.finditer(line):
            comp.max_const = max(comp.max_const, int(m.group(1)))
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        opname, rest = dm.groups()
        opcode = _first_word(rest)
        if not opcode:
            continue
        result_text = rest.split(opcode + "(")[0]
        dims = _shape_dims(result_text)
        op = _Op(name=opname, opcode=opcode,
                 result_bytes=shape_bytes(result_text),
                 result_dims=dims[0] if dims else [], line=line)
        comp.symtab[opname] = op
        comp.ops.append(op)
        if opcode == "dynamic-update-slice":
            comp.has_dus = True
        if opcode not in ("parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "convert", "broadcast",
                          "reshape", "copy", "transpose"):
            comp.n_compute_ops += 1
        if line.lstrip().startswith("ROOT "):
            comp.root_opcode = opcode
            n_el = 1
            for d in op.result_dims:
                n_el *= d
            comp.root_elems = n_el
        if opcode == "while":
            cm = re.search(r"condition=%?([\w.\-]+)", line)
            bm = re.search(r"body=%?([\w.\-]+)", line)
            tm = _TRIP_RE.search(line)
            trip = float(tm.group(1)) if tm else 0.0
            if cm and bm:
                comp.whiles.append((cm.group(1), bm.group(1), trip))
        elif opcode in ("call", "conditional", "async-start"):
            for cm in re.finditer(
                    r"(?:to_apply|branch_computations|called_computation)="
                    r"\{?%?([\w.\-]+)", line):
                comp.calls.append(cm.group(1))
        elif opcode == "fusion":
            cm = re.search(r"calls=%?([\w.\-]+)", line)
            if cm:
                comp.fusion_calls.append(cm.group(1))

    # slice-only parameter analysis (see param_slice_bytes)
    param_idx: Dict[str, int] = {}
    for op in comp.ops:
        if op.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", op.line)
            if pm:
                param_idx[op.name] = int(pm.group(1))
    for pname, pidx in param_idx.items():
        slice_bytes = None
        ok = True
        for op in comp.ops:
            if op.name == pname or f"%{pname}" not in op.line:
                continue
            if op.opcode in ("dynamic-slice", "slice", "gather"):
                slice_bytes = max(slice_bytes or 0, op.result_bytes)
            else:
                ok = False
                break
        if ok and slice_bytes is not None:
            comp.param_slice_bytes[pidx] = slice_bytes
    return comp


def _operand_names(line: str, opcode: str) -> List[str]:
    """Operand %names inside the op's parens (excluding attribute refs)."""
    idx = line.find(opcode + "(")
    if idx < 0:
        return []
    depth = 0
    start = idx + len(opcode)
    end = start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERANDS_RE.findall(line[start:end + 1])


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out_elems = 1
    for d in op.result_dims:
        out_elems *= d
    contract = 1
    cm = _CONTRACT_RE.search(op.line)
    operands = _operand_names(op.line, op.opcode)
    if cm and operands:
        lhs = comp.symtab.get(operands[0])
        if lhs is not None and lhs.result_dims:
            for di in cm.group(1).split(","):
                if di and int(di) < len(lhs.result_dims):
                    contract *= lhs.result_dims[int(di)]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class ModuleCosts:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))


def analyze_module(hlo: str, pod_size: int = 256) -> Dict[str, float]:
    """Trip-weighted per-device costs of a post-SPMD HLO module."""
    raw = _split_computations(hlo)
    comps = {n: _parse_computation(n, ls, pod_size) for n, ls in raw.items()}
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    costs = ModuleCosts()

    def visit(name: str, mult: float, in_fusion: bool,
              stack: Tuple[str, ...]):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        stack = stack + (name,)
        for op in comp.ops:
            if op.opcode == "dot":
                f = _dot_flops(op, comp) * mult
                costs.flops += f
                costs.counts["dot"] += mult
            elif op.opcode == "convolution":
                # charge like a dot: 2 * out * (in_ch * kernel_spatial)
                f = 2.0 * max(op.result_bytes // 4, 0) * mult
                costs.flops += f
                costs.counts["convolution"] += mult
            if in_fusion:
                continue
            if op.opcode in COLLECTIVES or (
                    op.opcode.endswith("-start") and
                    op.opcode[:-6] in COLLECTIVES):
                kind = op.opcode[:-6] if op.opcode.endswith("-start") \
                    else op.opcode
                n, crosses = _group_info(op.line, pod_size)
                b = _ring_bytes(kind, op.result_bytes, n) * mult
                costs.collective[kind] += b
                costs.collective["total"] += b
                costs.collective["dcn" if crosses else "ici"] += b
                costs.counts[kind] += mult
            if op.opcode in _FREE_OPS or op.opcode.endswith("-done") or \
                    op.opcode == "while":
                continue
            callee = None
            if op.opcode == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", op.line)
                callee = comps.get(cm.group(1)) if cm else None
            operand_bytes = []
            for i, on in enumerate(_operand_names(op.line, op.opcode)):
                o = comp.symtab.get(on)
                if o is None:
                    continue
                b = o.result_bytes
                if callee is not None and i in callee.param_slice_bytes:
                    b = min(b, callee.param_slice_bytes[i])
                operand_bytes.append(b)
            if op.opcode in ("dynamic-slice", "slice", "gather"):
                # reads only the slice (charged as result read + write)
                costs.traffic_bytes += 2.0 * op.result_bytes * mult
                continue
            in_place_dus = op.opcode == "dynamic-update-slice" or \
                (callee is not None and callee.has_dus)
            # pure layout/convert fusions: dtype-legalization shadows and
            # layout copies the TPU backend elides/fuses — charged 0
            dtype_legalize = op.opcode == "convert" or (
                callee is not None and callee.n_compute_ops == 0)
            if in_place_dus:
                # aliased buffer(s): only the update region moves.  Charge
                # 2x the sub-half-result operands (update read + slice
                # write); buffer-sized operands are aliased or shadows.
                half = op.result_bytes / 2
                traffic = 2.0 * sum(b for b in operand_bytes if b < half)
            elif dtype_legalize:
                traffic = 0.0
            else:
                traffic = op.result_bytes + sum(operand_bytes)
            costs.traffic_bytes += traffic * mult
        for callee in comp.calls:
            visit(callee, mult, in_fusion, stack)
        for callee in comp.fusion_calls:
            visit(callee, mult, True, stack)       # flops only
        for cond, body, trip in comp.whiles:
            t = trip if trip > 0 else max(
                1, comps.get(cond, _Computation(cond, [], {}, [], [], [])
                             ).max_const)
            visit(body, mult * t, in_fusion, stack)
            visit(cond, mult * t, in_fusion, stack)

    if entry is not None and entry in comps:
        visit(entry, 1.0, False, ())
    else:                                   # fallback: flat, unweighted
        for name in comps:
            visit(name, 1.0, False, ())

    out = {"flops": costs.flops, "traffic_bytes": costs.traffic_bytes}
    out.update({k: v for k, v in costs.collective.items()})
    out.setdefault("total", 0.0)
    out.setdefault("ici", 0.0)
    out.setdefault("dcn", 0.0)
    out.update({f"count_{k}": v for k, v in costs.counts.items()})
    return out


def analyze_collectives(hlo: str, pod_size: int = 256) -> Dict[str, float]:
    """Per-device collective link-bytes (compat wrapper on analyze_module)."""
    full = analyze_module(hlo, pod_size=pod_size)
    keep = tuple(COLLECTIVES) + ("total", "ici", "dcn")
    return {k: v for k, v in full.items()
            if k in keep or (k.startswith("count_") and
                             k[6:] in COLLECTIVES)}

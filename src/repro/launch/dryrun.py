# Multi-pod dry-run: these two lines MUST run before any other import —
# jax locks the device count on first backend init (task spec step 0).
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run every (architecture x input-shape x mesh) cell.

For each cell we ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` on the
production meshes — single-pod (data=16, model=16) = 256 chips and multi-pod
(pod=2, data=16, model=16) = 512 chips — and record:

* ``compiled.memory_analysis()``   — proves the cell fits 16 GB/chip HBM;
* ``compiled.cost_analysis()``     — per-device HLO FLOPs / bytes (verified
  empirically: XLA reports the post-SPMD per-device module);
* collective link-bytes            — parsed from ``compiled.as_text()`` by
  ``launch/hlo.py`` (ring-algorithm bytes, loop-trip weighted, ICI/DCN split);
* the three roofline terms         — compute / memory / collective seconds on
  TPU v5e constants (197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI, and an
  assumed 25 GB/s/chip DCN for the pod axis);
* MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference) and the
  useful-compute ratio MODEL_FLOPS / (HLO_FLOPs · chips).

Artifacts land in ``benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json``;
``benchmarks/roofline.py`` renders EXPERIMENTS.md §Roofline from them.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --sweep --mesh both
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

from repro.obs.trace import stopwatch

# TPU v5e roofline constants (task spec)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
DCN_BW = 25e9              # bytes/s per chip across pods (assumption, noted)

ARTIFACT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "benchmarks", "artifacts", "dryrun")


def roofline_terms(per_dev_flops: float, per_dev_bytes: float,
                   coll: Dict[str, float]) -> Dict[str, float]:
    ici = coll.get("ici", 0.0)
    dcn = coll.get("dcn", 0.0)
    return {
        "compute_s": per_dev_flops / PEAK_FLOPS,
        "memory_s": per_dev_bytes / HBM_BW,
        "collective_s": ici / ICI_BW + dcn / DCN_BW,
        "collective_ici_s": ici / ICI_BW,
        "collective_dcn_s": dcn / DCN_BW,
    }


def run_cell(arch: str, shape: str, mesh_kind: str,
             overrides: Optional[dict] = None,
             save_hlo: bool = False) -> Dict[str, Any]:
    """Lower + compile one cell; return the roofline record."""
    import jax
    from repro.configs import canonical, cells
    from repro.launch.hlo import analyze_module
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell

    arch = canonical(arch)
    cell_specs = cells(arch)
    spec = cell_specs[shape]
    rec: Dict[str, Any] = dict(arch=arch, shape=shape, mesh=mesh_kind,
                               overrides=overrides or {})
    if spec["skip"]:
        rec.update(status="skip", skip_reason=spec["skip_reason"])
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    pod_size = 256 if mesh_kind == "multi" else chips

    with stopwatch("dryrun/lower") as sw_lower:
        cell = build_cell(arch, shape, mesh, overrides=overrides)
        with mesh:
            jitted = jax.jit(cell.fn,
                             in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings,
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.args)
    with mesh, stopwatch("dryrun/compile") as sw_compile:
        compiled = lowered.compile()
    t_lower = sw_lower.elapsed
    t_compile = sw_compile.elapsed

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    # trip-weighted per-device costs from the post-SPMD HLO; XLA's
    # cost_analysis counts loop bodies once (see launch/hlo.py docstring)
    parsed = analyze_module(hlo_text, pod_size=pod_size)
    coll = {k: parsed.get(k, 0.0) for k in ("ici", "dcn", "total")}

    per_dev_flops = float(parsed["flops"])
    per_dev_bytes = float(parsed["traffic_bytes"])
    terms = roofline_terms(per_dev_flops, per_dev_bytes, coll)
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    model_flops = cell.meta["model_flops"]
    hlo_total_flops = per_dev_flops * chips
    bound_s = max(terms["compute_s"], terms["memory_s"],
                  terms["collective_s"])
    mfu_bound = (model_flops / PEAK_FLOPS / chips) / bound_s \
        if bound_s > 0 else 0.0

    rec.update(
        status="ok",
        kind=cell.kind,
        chips=chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            code_bytes=mem.generated_code_size_in_bytes,
            peak_bytes=(mem.argument_size_in_bytes
                        + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes
                        - mem.alias_size_in_bytes),
        ),
        cost=dict(per_device_flops=per_dev_flops,
                  per_device_bytes=per_dev_bytes,
                  total_flops=hlo_total_flops,
                  xla_unweighted_flops=float(cost.get("flops", 0.0)),
                  xla_unweighted_bytes=float(
                      cost.get("bytes accessed", 0.0))),
        collectives={k: v for k, v in parsed.items()
                     if not k.startswith(("flops", "traffic"))},
        roofline=dict(
            terms, dominant=dominant,
            model_flops=model_flops,
            useful_flop_ratio=(model_flops / hlo_total_flops
                               if hlo_total_flops else 0.0),
            mfu_upper_bound=mfu_bound),
        meta=dict(params=cell.meta["params"],
                  n_micro=cell.meta.get("n_micro"),
                  seq_len=cell.meta["seq_len"],
                  global_batch=cell.meta["global_batch"],
                  sharding_report=cell.meta.get("sharding_report", [])[:40]),
    )
    if save_hlo:
        rec["hlo_path"] = _artifact_path(arch, shape, mesh_kind,
                                         suffix=".hlo.txt")
        os.makedirs(os.path.dirname(rec["hlo_path"]), exist_ok=True)
        with open(rec["hlo_path"], "w") as f:
            f.write(hlo_text)
    return rec


PH_SHAPES = {
    # (columns per device, column width in keys, pivot-table entries)
    "ph_round_64k": dict(b_per_dev=256, width=64, n_pivots=2**20),
    "ph_round_wide": dict(b_per_dev=1024, width=128, n_pivots=2**22),
}


def run_ph_cell(shape: str, mesh_kind: str,
                overrides: Optional[dict] = None,
                save_hlo: bool = False) -> Dict[str, Any]:
    """Dry-run the paper's distributed serial-parallel reduction round —
    the cell most representative of the paper's technique (§Perf cell C).

    The PH engine uses a flat data view of the pod (all chips on the batch
    axis: the serial-parallel batch IS the parallelism); columns are padded
    sorted paired-index key arrays, the pivot table is replicated.
    """
    import jax
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import jax_engine as je
    from repro.launch.hlo import analyze_module

    p = dict(PH_SHAPES[shape])
    if overrides:
        p.update(overrides)
    devices = jax.devices()
    if mesh_kind == "multi":
        mesh = Mesh(np.array(devices[:512]).reshape(2, 256),
                    ("pod", "data"))
        chips, pod_size = 512, 256
    else:
        mesh = Mesh(np.array(devices[:256]).reshape(256,), ("data",))
        chips, pod_size = 256, 256

    b_total = p["b_per_dev"] * chips
    w, n_piv = p["width"], p["n_pivots"]
    round_fn = je.make_distributed_round(
        mesh, n_parallel_iters=p.get("n_parallel_iters", 8))
    cols = jax.ShapeDtypeStruct((b_total, w), np.int64)
    pivot_keys = jax.ShapeDtypeStruct((n_piv,), np.int64)
    pivot_cols = jax.ShapeDtypeStruct((n_piv, w), np.int64)

    with stopwatch("dryrun/lower") as sw_lower, mesh:
        lowered = jax.jit(round_fn).lower(cols, pivot_keys, pivot_cols)
    with mesh, stopwatch("dryrun/compile") as sw_compile:
        compiled = lowered.compile()
    t_lower = sw_lower.elapsed
    t_compile = sw_compile.elapsed
    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    parsed = analyze_module(hlo_text, pod_size=pod_size)
    coll = {k: parsed.get(k, 0.0) for k in ("ici", "dcn", "total")}
    terms = roofline_terms(parsed["flops"], parsed["traffic_bytes"], coll)
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    rec = dict(
        arch="dory_ph", shape=shape, mesh=mesh_kind, status="ok",
        kind="ph_round", chips=chips,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(argument_bytes=mem.argument_size_in_bytes,
                    output_bytes=mem.output_size_in_bytes,
                    temp_bytes=mem.temp_size_in_bytes,
                    alias_bytes=mem.alias_size_in_bytes,
                    code_bytes=mem.generated_code_size_in_bytes,
                    peak_bytes=(mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes)),
        cost=dict(per_device_flops=parsed["flops"],
                  per_device_bytes=parsed["traffic_bytes"]),
        collectives={k: v for k, v in parsed.items()
                     if not k.startswith(("flops", "traffic"))},
        roofline=dict(terms, dominant=dominant, model_flops=0.0,
                      useful_flop_ratio=0.0, mfu_upper_bound=0.0),
        meta=dict(b_per_dev=p["b_per_dev"], width=w, n_pivots=n_piv,
                  seq_len=0, global_batch=b_total, params={},
                  sharding_report=[]),
        overrides=overrides or {},
    )
    if save_hlo:
        rec["hlo_path"] = _artifact_path("dory_ph", shape, mesh_kind,
                                         suffix=".hlo.txt")
        os.makedirs(os.path.dirname(rec["hlo_path"]), exist_ok=True)
        with open(rec["hlo_path"], "w") as f:
            f.write(hlo_text)
    return rec


def _artifact_path(arch: str, shape: str, mesh_kind: str,
                   suffix: str = ".json") -> str:
    return os.path.join(ARTIFACT_DIR, mesh_kind, f"{arch}__{shape}{suffix}")


def save_record(rec: Dict[str, Any]) -> str:
    path = _artifact_path(rec["arch"], rec["shape"], rec["mesh"])
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def print_summary(rec: Dict[str, Any]) -> None:
    if rec["status"] == "skip":
        print(f"[SKIP] {rec['arch']} x {rec['shape']} ({rec['mesh']}): "
              f"{rec['skip_reason']}")
        return
    if rec["status"] != "ok":
        print(f"[FAIL] {rec['arch']} x {rec['shape']} ({rec['mesh']}): "
              f"{rec.get('error', '?')}")
        return
    m = rec["memory"]
    r = rec["roofline"]
    print(f"[ OK ] {rec['arch']} x {rec['shape']} ({rec['mesh']}, "
          f"{rec['chips']} chips) "
          f"lower {rec['lower_s']}s compile {rec['compile_s']}s | "
          f"per-dev peak {m['peak_bytes'] / 2**30:.2f} GiB | "
          f"compute {r['compute_s'] * 1e3:.2f} ms "
          f"memory {r['memory_s'] * 1e3:.2f} ms "
          f"collective {r['collective_s'] * 1e3:.2f} ms "
          f"-> {r['dominant'].replace('_s', '')}-bound | "
          f"useful-FLOP {r['useful_flop_ratio']:.2f} "
          f"MFU<= {r['mfu_upper_bound']:.2f}")


def _sweep(mesh_kinds, archs, shapes, jobs: int) -> int:
    """Run every cell in a subprocess (isolation: one OOM/crash cannot take
    down the sweep — the fault-tolerance story applied to the tooling)."""
    tasks = [(a, s, m) for m in mesh_kinds for a in archs for s in shapes]
    failures = 0
    running: list = []

    def reap(block: bool) -> int:
        nonlocal failures
        done = []
        for p, desc in running:
            if p.poll() is not None or block:
                p.wait()
                if p.returncode != 0:
                    failures += 1
                    print(f"[FAIL] {desc} (exit {p.returncode})")
                done.append((p, desc))
        for item in done:
            running.remove(item)
        return len(done)

    for arch, shape, mesh_kind in tasks:
        while len(running) >= jobs:
            if not reap(block=False):
                time.sleep(2)
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh_kind]
        running.append((subprocess.Popen(cmd), f"{arch} x {shape} "
                        f"({mesh_kind})"))
    while running:
        if not reap(block=False):
            time.sleep(2)
    return failures


def main() -> None:
    from repro.configs import ARCHS, SHAPES

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--sweep", action="store_true",
                    help="all (arch x shape) cells, one subprocess each")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (perf experiments)")
    args = ap.parse_args()

    mesh_kinds = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.sweep:
        archs = [args.arch] if args.arch else list(ARCHS)
        shapes = [args.shape] if args.shape else list(SHAPES)
        failures = _sweep(mesh_kinds, archs, shapes, args.jobs)
        print(f"sweep done, {failures} failures")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch/--shape or --sweep"
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    rc = 0
    for mesh_kind in mesh_kinds:
        try:
            if args.arch == "dory_ph":
                rec = run_ph_cell(args.shape, mesh_kind,
                                  overrides=overrides or None,
                                  save_hlo=args.save_hlo)
            else:
                rec = run_cell(args.arch, args.shape, mesh_kind,
                               overrides=overrides or None,
                               save_hlo=args.save_hlo)
        except Exception as e:  # noqa: BLE001 — record, report, nonzero exit
            rec = dict(arch=args.arch, shape=args.shape, mesh=mesh_kind,
                       status="error", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-4000:])
            rc = 1
        if not overrides:
            save_record(rec)
        print_summary(rec)
    sys.exit(rc)


if __name__ == "__main__":
    main()

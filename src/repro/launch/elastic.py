"""Fault tolerance primitives: heartbeat supervision, shard supervision for
the distributed reduction, speculative straggler reassignment.

This module is imported by ``repro.core.packed_reduce`` — the distributed
packed GF(2) driver wires :class:`ShardSupervisor` into its superstep
loop (see ``docs/resilience.md``): every live shard beats once per
superstep on a *deterministic superstep-indexed clock*, dead shards are
detected by beat timeout and their remaining batch queue is re-dealt to
survivors from the last exact commit sweep, and stragglers are sidelined
for a cooldown so the fused superstep stops synchronizing on the slowest
host.  At production scale DCN heartbeats and the cluster scheduler
replace the in-process clock; the recovery algebra (re-deal from the last
commit sweep, exact-by-construction replica staleness) is identical.

Import discipline: dependency-light (stdlib + numpy) — no jax, no
side effects.  Anything that forces device counts belongs in the caller's
environment, not here.
"""
import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Heartbeat:
    """Supervisor-side liveness table (host_id -> last beat time).

    ``beat``/``dead``/``stragglers`` accept explicit timestamps so callers
    with a deterministic clock (e.g. the reduction superstep counter) get
    reproducible failure detection; wall-clock is only a default."""
    timeout_s: float = 5.0
    beats: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host: int, t: Optional[float] = None):
        self.beats[host] = time.monotonic() if t is None else t

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.beats.items() if now - t > self.timeout_s]

    def stragglers(self, factor: float = 3.0,
                   now: Optional[float] = None) -> List[int]:
        """Hosts whose last beat lags the median by ``factor``x the median
        inter-beat gap (cheap, coordination-free detection)."""
        now = time.monotonic() if now is None else now
        if len(self.beats) < 2:
            return []
        lags = {h: now - t for h, t in self.beats.items()}
        med = float(np.median(list(lags.values())))
        return [h for h, lag in lags.items()
                if lag > factor * max(med, 1e-3) and lag > med]


def speculative_reassign(assignment: Dict[int, List[int]],
                         stragglers: Sequence[int]) -> Dict[int, int]:
    """Speculative-execution policy: each straggler's pending work items
    are duplicated onto the least-loaded non-straggling survivor (first
    finisher wins).  Mutates ``assignment`` in place and returns the
    ``straggler -> backup`` map.  Deterministic given its inputs."""
    backups: Dict[int, int] = {}
    lagging = set(stragglers)
    for s in sorted(lagging):
        load = {h: len(v) for h, v in assignment.items() if h not in lagging}
        if not load:
            break
        backup = min(load, key=lambda h: (load[h], h))
        backups[s] = backup
        assignment[backup] = assignment[backup] + assignment.get(s, [])
    return backups


@dataclasses.dataclass
class RecoveryPlan:
    """What the supervisor decided for one superstep: which shards died
    since the last check, which are straggling, and the ``active`` set the
    driver should deal batches to this superstep."""
    dead: List[int]
    stragglers: List[int]
    active: List[int]


class ShardSupervisor:
    """Heartbeat-driven shard supervision on a deterministic clock.

    The reduction driver owns the clock (its superstep counter) and calls
    :meth:`observe` once per superstep with each live shard's beat time;
    shards that miss ``timeout`` clock units are declared dead and removed
    from ``live`` permanently, stragglers (beat lag > ``factor`` x median)
    are sidelined from dealing for ``sideline`` supersteps but stay live.
    With every shard beating on time this is a no-op returning
    ``active == live``."""

    def __init__(self, n_shards: int, timeout: float = 1.5,
                 factor: float = 3.0, sideline: int = 1) -> None:
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.hb = Heartbeat(timeout_s=timeout)
        self.live: List[int] = list(range(n_shards))
        self.factor = factor
        self.sideline = sideline
        self._sidelined_until: Dict[int, float] = {}
        for k in self.live:
            self.hb.beat(k, t=0.0)

    def observe(self, now: float,
                beats: Optional[Dict[int, float]] = None) -> RecoveryPlan:
        """Record this superstep's beats (``shard -> beat time``; a live
        shard absent from ``beats`` did not beat) and return the plan."""
        for k, t in (beats or {}).items():
            if k in self.live:
                self.hb.beat(k, t=t)
        newly_dead = sorted(k for k in self.hb.dead(now=now)
                            if k in self.live)
        for k in newly_dead:
            self.live.remove(k)
            self.hb.beats.pop(k, None)
            self._sidelined_until.pop(k, None)
        lagging = sorted(k for k in self.hb.stragglers(factor=self.factor,
                                                       now=now)
                         if k in self.live)
        for k in lagging:
            self._sidelined_until[k] = now + self.sideline
        active = [k for k in self.live
                  if self._sidelined_until.get(k, -np.inf) <= now
                  or len(self.live) == 1]
        if not active:                    # never stall: someone must deal
            active = list(self.live)
        return RecoveryPlan(dead=newly_dead, stragglers=lagging,
                            active=active)

    def kill(self, shard: int) -> None:
        """Remove a shard immediately (used once death is confirmed by a
        path faster than beat timeout, e.g. a transport-level error)."""
        if shard in self.live:
            self.live.remove(shard)
            self.hb.beats.pop(shard, None)
            self._sidelined_until.pop(shard, None)

# Elastic-training demonstrator: force 8 host devices BEFORE any jax import
# so meshes can shrink/grow inside one CPU process (same trick as dryrun.py).
import os
if "--no-force-devices" not in __import__("sys").argv:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

"""Fault tolerance: heartbeat supervision, elastic re-meshing, straggler
mitigation — runnable end-to-end on CPU.

The scenario this module simulates (and ``tests/test_system.py`` asserts):

1. train a reduced model on a (data=4, model=2) mesh with async sharded
   checkpoints;
2. a "hardware failure" removes half the devices mid-run (the supervisor's
   heartbeat detects a dead host);
3. the supervisor rebuilds a (data=2, model=2) mesh from the survivors,
   restores the latest checkpoint **resharded onto the new mesh**
   (Checkpointer.restore with target shardings), reassigns the dead hosts'
   deterministic data shards (data/tokens.reassign_shards), and continues;
4. training resumes bit-exactly from the checkpointed step — the loss curve
   continues downward across the failure boundary.

At production scale the same three primitives (atomic sharded checkpoints,
reshard-on-restore, deterministic shard reassignment) are what elasticity
reduces to; DCN heartbeats and scheduler integration replace the in-process
supervisor.  Straggler mitigation uses the same reassignment path: a host
whose heartbeat lags gets its shard duplicated onto the fastest survivor
(speculative execution), and the first result wins — simulated in
``simulate_straggler``.
"""
import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Heartbeat:
    """Supervisor-side liveness table (host_id -> last beat time)."""
    timeout_s: float = 5.0
    beats: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host: int, t: Optional[float] = None):
        self.beats[host] = time.monotonic() if t is None else t

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.beats.items() if now - t > self.timeout_s]

    def stragglers(self, factor: float = 3.0,
                   now: Optional[float] = None) -> List[int]:
        """Hosts whose last beat lags the median by ``factor``x the median
        inter-beat gap (cheap, coordination-free detection)."""
        now = time.monotonic() if now is None else now
        if len(self.beats) < 2:
            return []
        lags = {h: now - t for h, t in self.beats.items()}
        med = float(np.median(list(lags.values())))
        return [h for h, lag in lags.items()
                if lag > factor * max(med, 1e-3) and lag > med]


def run_elastic_demo(steps_before: int = 6, steps_after: int = 6,
                     ckpt_dir: Optional[str] = None, arch: str = "qwen3-0.6b",
                     batch: int = 8, seq: int = 32) -> Dict:
    """The full failure->re-mesh->restore->continue cycle.  Returns the two
    loss histories + the reassignment map (asserted in tests)."""
    import jax
    from repro.checkpoint import Checkpointer
    from repro.configs import get_config
    from repro.data.tokens import reassign_shards
    from repro.launch.mesh import make_mesh
    from repro.launch.train import TrainJob, run

    assert len(jax.devices()) >= 8, "run under forced 8-device CPU"
    ckpt_dir = ckpt_dir or "/tmp/repro_elastic_ckpt"
    cfg = get_config(arch, reduced=True)

    # phase 1: (data=4, model=2), checkpoint every step
    job = TrainJob(cfg=cfg, steps=steps_before, global_batch=batch,
                   seq_len=seq, ckpt_dir=ckpt_dir, ckpt_every=1,
                   mesh_shape=(4, 2), log_every=1)
    out1 = run(job)

    # phase 2: "pod half dies" -> heartbeat flags hosts 2,3 dead
    hb = Heartbeat(timeout_s=0.5)
    now = time.monotonic()
    for h in range(4):
        hb.beat(h, now - (10.0 if h >= 2 else 0.0))
    dead = sorted(hb.dead(now))
    mapping = reassign_shards(4, dead)

    # phase 3: rebuild smaller mesh, restore resharded, continue
    job2 = TrainJob(cfg=cfg, steps=steps_before + steps_after,
                    global_batch=batch, seq_len=seq, ckpt_dir=ckpt_dir,
                    ckpt_every=10_000, mesh_shape=(2, 2), log_every=1)
    out2 = run(job2, restore=True)

    return {"pre": out1["history"], "post": out2["history"],
            "dead": dead, "reassignment": mapping,
            "final_loss": out2["final_loss"]}


def simulate_straggler(n_hosts: int = 4, slow_host: int = 2,
                       work_items: int = 16) -> Dict:
    """Speculative-execution policy: the straggler's pending shard is
    duplicated onto the least-loaded survivor; first finisher wins.
    Deterministic work items make the winner reproducible."""
    hb = Heartbeat(timeout_s=100.0)
    now = time.monotonic()
    for h in range(n_hosts):
        hb.beat(h, now - (2.0 if h == slow_host else 0.1))
    lagging = hb.stragglers(factor=3.0, now=now)
    assignment = {h: [i for i in range(work_items) if i % n_hosts == h]
                  for h in range(n_hosts)}
    backups = {}
    for s in lagging:
        load = {h: len(v) for h, v in assignment.items() if h not in lagging}
        backup = min(load, key=load.get)
        backups[s] = backup
        assignment[backup] = assignment[backup] + assignment[s]
    return {"stragglers": lagging, "backups": backups,
            "assignment": assignment}


if __name__ == "__main__":
    res = run_elastic_demo()
    print(f"dead hosts: {res['dead']}  reassignment: {res['reassignment']}")
    pre = res["pre"][-1]["loss"]
    post = res["post"][-1]["loss"]
    print(f"loss across failure boundary: {pre:.4f} -> {post:.4f}")
    print("straggler sim:", simulate_straggler())

"""Point-cloud generators for the paper's benchmark suite.

``o3`` and ``torus4`` follow the paper's published definitions exactly
(8192 random orthogonal 3x3 matrices in R^9; random samples of the Clifford
torus S^1 x S^1 in R^4).  ``dragon``/``fractal`` stand-ins are generated
shapes with comparable regimes (3-D surface scan-like cloud; self-similar
network distance matrix), since the original files ship with external repos.
The Hi-C pair mimics the paper's §6 workload: a genome-like folded curve
("control") whose loop anchors are released in the "auxin" variant.
"""
from __future__ import annotations

import numpy as np


def circle_points(n: int, noise: float = 0.0, seed: int = 0) -> np.ndarray:
    t = np.linspace(0, 2 * np.pi, n, endpoint=False)
    pts = np.stack([np.cos(t), np.sin(t)], axis=1)
    if noise:
        pts = pts + np.random.default_rng(seed).normal(scale=noise,
                                                       size=pts.shape)
    return pts


def two_circles(n: int = 20, separation: float = 6.0) -> np.ndarray:
    a = circle_points(n)
    b = circle_points(n) + np.array([separation, 0.0])
    return np.concatenate([a, b], axis=0)


def sphere_points(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 3))
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def clifford_torus(n: int, seed: int = 0, grid: bool = False) -> np.ndarray:
    """torus4 (paper Table 1): points on S^1 x S^1 in R^4, radius 1/sqrt(2)."""
    if grid:
        k = int(round(np.sqrt(n)))
        a, b = np.meshgrid(np.linspace(0, 2 * np.pi, k, endpoint=False),
                           np.linspace(0, 2 * np.pi, k, endpoint=False))
        a, b = a.ravel(), b.ravel()
    else:
        rng = np.random.default_rng(seed)
        a = rng.uniform(0, 2 * np.pi, n)
        b = rng.uniform(0, 2 * np.pi, n)
    return np.stack([np.cos(a), np.sin(a), np.cos(b), np.sin(b)],
                    axis=1) / np.sqrt(2)


def o3_points(n: int, seed: int = 0) -> np.ndarray:
    """o3 (paper Table 1): n random orthogonal 3x3 matrices, points in R^9."""
    rng = np.random.default_rng(seed)
    out = np.empty((n, 9))
    for i in range(n):
        q, r = np.linalg.qr(rng.normal(size=(3, 3)))
        q = q * np.sign(np.diag(r))
        out[i] = q.ravel()
    return out


def dragon_like(n: int, seed: int = 0) -> np.ndarray:
    """3-D surface-scan-like cloud (dragon stand-in): noisy torus knot tube."""
    rng = np.random.default_rng(seed)
    t = rng.uniform(0, 2 * np.pi, n)
    p, q = 2, 3
    r = np.cos(q * t) + 2.0
    base = np.stack([r * np.cos(p * t), r * np.sin(p * t), -np.sin(q * t)],
                    axis=1)
    return base + rng.normal(scale=0.08, size=base.shape)


def fractal_like(n: int = 512, seed: int = 0) -> np.ndarray:
    """Self-similar network distance matrix (fractal stand-in).

    Recursive block structure: distance = level at which two leaves split,
    scaled + jittered — returns a *distance matrix* like the paper's set.
    """
    rng = np.random.default_rng(seed)
    levels = int(np.ceil(np.log2(n)))
    idx = np.arange(n)
    d = np.zeros((n, n))
    for lvl in range(levels):
        blk = (idx >> lvl)
        same = blk[:, None] == blk[None, :]
        d = np.where(same, d, lvl + 1.0)
    d = d / levels
    jitter = rng.uniform(0, 0.02, size=(n, n))
    jitter = (jitter + jitter.T) / 2
    d = d + jitter
    np.fill_diagonal(d, 0.0)
    return d


def genome_like(n: int, n_loops: int, seed: int = 0,
                loop_strength: float = 0.95) -> np.ndarray:
    """Hi-C-like folded-polymer point cloud (paper §6 stand-in).

    A 3-D random-walk polymer ("chromatin fiber") with ``n_loops`` cohesin
    loop anchors: pairs of loci pulled spatially together.  The *control*
    condition keeps the anchors; *auxin* (cohesin degraded) uses
    ``loop_strength=0`` which releases them — PH should report fewer H1
    loops, reproducing Fig. 21's direction.
    """
    rng = np.random.default_rng(seed)
    steps = rng.normal(size=(n, 3))
    pts = np.cumsum(steps, axis=0) / np.sqrt(n)
    spacing = np.sqrt(3.0 / n)          # typical inter-locus distance
    anchors = np.sort(rng.choice(n - 8, size=n_loops, replace=False))
    spans = rng.integers(n // 16, n // 4, size=n_loops)
    for ai, sp in zip(anchors, spans):
        bi = min(ai + int(sp), n - 1)
        seg = pts[ai:bi + 1].copy()
        length = bi - ai
        if length < 8:
            continue
        # cohesin ring: anchors meet, the intervening fiber bulges into an
        # extended loop — blend the segment toward a circle whose
        # circumference matches the fiber's natural length (a real H1
        # feature with birth ~ spacing and death ~ loop radius)
        u = rng.normal(size=3)
        u /= np.linalg.norm(u)
        v = rng.normal(size=3)
        v -= v @ u * u
        v /= np.linalg.norm(v)
        r = length * spacing / (2 * np.pi)
        center = (seg[0] + seg[-1]) / 2
        theta = np.linspace(0.0, 2 * np.pi, length + 1)
        circle = center + r * (np.cos(theta)[:, None] * u
                               + np.sin(theta)[:, None] * v)
        new_seg = loop_strength * circle + (1 - loop_strength) * seg
        delta = new_seg[-1] - seg[-1]
        pts[ai:bi + 1] = new_seg
        pts[bi + 1:] += delta           # keep the downstream fiber attached
    return pts


def hic_pair(n: int, n_loops: int = 24, seed: int = 0):
    """(control, auxin) point-cloud pair for the Fig. 21 reproduction."""
    control = genome_like(n, n_loops, seed=seed, loop_strength=0.95)
    auxin = genome_like(n, n_loops, seed=seed, loop_strength=0.0)
    return control, auxin

"""Data substrates: point clouds (paper benchmarks) + LM token pipeline."""

"""Synthetic LM token pipeline: deterministic, host-sharded, learnable.

Sequences follow a noisy affine-recurrence over the vocab
(``x_{t+1} = (a x_t + b) mod V`` with per-sequence (a, b) from a small pool
and epsilon token noise), so a model must learn transition structure — loss
decreases measurably within a few hundred steps on a ~10-100M model (the
end-to-end example's acceptance check).

``ShardedTokenStream`` carves the global batch by (host_id, n_hosts) and is
deterministic in (seed, step): any host can recompute any step — this is the
data-side story for elastic restarts and straggler reassignment
(``reassign_shards``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List

import numpy as np

_POOL = [(5, 3), (7, 11), (13, 1), (17, 29)]


def synthetic_tokens(seed: int, step: int, batch: int, seq: int,
                     vocab: int, noise: float = 0.05) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    ab = rng.integers(0, len(_POOL), size=batch)
    a = np.array([_POOL[i][0] for i in ab])[:, None]
    b = np.array([_POOL[i][1] for i in ab])[:, None]
    x0 = rng.integers(0, vocab, size=(batch, 1))
    toks = np.empty((batch, seq), dtype=np.int32)
    toks[:, :1] = x0
    for t in range(1, seq):
        toks[:, t:t + 1] = (a * toks[:, t - 1:t] + b) % vocab
    flip = rng.random((batch, seq)) < noise
    toks[flip] = rng.integers(0, vocab, size=int(flip.sum()))
    return toks


@dataclasses.dataclass
class ShardedTokenStream:
    vocab: int
    global_batch: int
    seq: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    step: int = 0

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.n_hosts

    def batch_at(self, step: int, host_id=None) -> Dict[str, np.ndarray]:
        host_id = self.host_id if host_id is None else host_id
        full = synthetic_tokens(self.seed, step, self.global_batch,
                                self.seq, self.vocab)
        lo = host_id * self.local_batch
        return {"tokens": full[lo:lo + self.local_batch]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self.step)
            self.step += 1


def reassign_shards(n_hosts: int, failed: List[int]) -> Dict[int, List[int]]:
    """Deterministic straggler/failure reassignment: each failed host's batch
    shard goes to the surviving host with the fewest extra shards (stable
    round-robin) — every survivor computes the same mapping with no
    coordination."""
    alive = [h for h in range(n_hosts) if h not in set(failed)]
    if not alive:
        raise RuntimeError("no survivors")
    mapping = {h: [h] for h in alive}
    for i, f in enumerate(sorted(failed)):
        owner = alive[i % len(alive)]
        mapping[owner].append(f)
    return mapping

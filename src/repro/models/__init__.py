"""Model zoo: config, layers, attention, MoE, SSM blocks, assembly."""

"""Model configuration covering the ten assigned architectures.

One dataclass drives the whole zoo; family-specific sub-configs (MoE, MLA,
xLSTM, RG-LRU, enc-dec) are optional fields.  ``reduced()`` derives the
CPU-smoke-test variant (same family and block pattern, tiny widths).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_shared: int = 0             # always-on shared experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    first_dense_layers: int = 0   # leading dense-FFN layers (deepseek: 1)
    dense_d_ff: int = 0           # d_ff of those dense layers


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8          # 1 sLSTM per this many blocks (rest mLSTM)
    proj_factor: float = 2.0      # up-projection factor for mLSTM
    conv_width: int = 4
    chunk: int = 64               # chunkwise-parallel chunk length


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: int = 0                # recurrence width (0 -> d_model)
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("rglru", "rglru", "local_attn")
    attn_window: int = 2048


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    act: str = "silu"
    norm_eps: float = 1e-6
    qk_norm: bool = False
    rope_kind: str = "rope"       # rope | mrope | none
    rope_theta: float = 1e6
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    attn_window: int = -1         # -1 = global
    global_every: int = 0         # gemma3: every k-th layer is global
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    enc_dec: bool = False         # whisper
    n_enc_layers: int = 0
    input_kind: str = "tokens"    # tokens | embeddings (vlm/audio stubs)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256  # TP divisibility padding (production std)
    sub_quadratic: bool = False   # eligible for long_500k (per task spec)
    z_loss: float = 1e-4
    remat: str = "none"           # none | full | dots  (activation ckpt)
    scan_seq_axis: bool = False   # sequence-parallel activation constraint

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def window_for_layer(self, i: int) -> int:
        if self.global_every and (i % self.global_every == self.global_every - 1):
            return -1
        return self.attn_window

    def reduced(self, n_layers: int = 2, d_model: int = 64, n_heads: int = 4,
                n_kv_heads: Optional[int] = None, d_ff: int = 128,
                vocab: int = 512) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kv = n_kv_heads if n_kv_heads is not None else max(
            1, n_heads * self.n_kv_heads // self.n_heads)
        changes = dict(
            name=self.name + "-reduced",
            n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=kv, d_ff=d_ff if self.d_ff else 0,
            vocab_size=vocab, head_dim=d_model // n_heads,
            vocab_pad_multiple=64, compute_dtype="float32",
        )
        if self.rope_kind == "mrope":
            # keep the 2:3:3 section ratio at the reduced head_dim
            half = (d_model // n_heads) // 2
            s1 = half // 4
            s2 = (half - s1) // 2
            changes["mrope_sections"] = (s1, s2, half - s1 - s2)
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_expert=32,
                n_shared=min(self.moe.n_shared, 1),
                dense_d_ff=64 if self.moe.first_dense_layers else 0)
        if self.mla:
            changes["mla"] = MLAConfig(kv_lora_rank=32, rope_head_dim=8,
                                       nope_head_dim=16, v_head_dim=16)
        if self.xlstm:
            changes["xlstm"] = dataclasses.replace(
                self.xlstm, slstm_every=2, chunk=8)
            changes["n_layers"] = 4
        if self.rglru:
            changes["rglru"] = dataclasses.replace(
                self.rglru, d_rnn=d_model, attn_window=16)
            changes["n_layers"] = 3
        if self.enc_dec:
            changes["n_enc_layers"] = 2
        if self.global_every:
            changes["attn_window"] = 8
            changes["global_every"] = 2
        return dataclasses.replace(self, **changes)

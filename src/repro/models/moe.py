"""Mixture-of-Experts FFN: top-k routing + capacity-grouped expert GEMMs.

Dispatch is sort-based (MaxText/megablocks style): tokens are ranked within
their routed expert, dropped beyond capacity C = ceil(T*k/E * cap_factor),
gathered into a dense (E, C, d) buffer, run through batched expert GEMMs
('ecd,edf->ecf'), and combined back weighted by router probabilities.  Total
GEMM FLOPs = E*C*3*d*f ≈ active-expert FLOPs — honest for the roofline,
unlike dense all-expert dispatch.  Under EP the expert axis shards over
``model``; XLA inserts the all-to-all-equivalent collectives from the
sharding of the (E, C, d) buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import bound_axis, bound_mesh, constrain

from .config import ModelConfig
from .layers import dense_init


def moe_params(key, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_expert),
                             fan_in=d, dtype=cfg.pdtype),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_expert),
                           fan_in=d, dtype=cfg.pdtype),
        "w_down": dense_init(ks[3], (m.n_experts, m.d_expert, d),
                             fan_in=m.d_expert, dtype=cfg.pdtype),
    }
    if m.n_shared:
        p["shared_gate"] = dense_init(ks[4], (d, m.n_shared * m.d_expert),
                                      dtype=cfg.pdtype)
        k5, k6 = jax.random.split(ks[4])
        p["shared_up"] = dense_init(k5, (d, m.n_shared * m.d_expert),
                                    dtype=cfg.pdtype)
        p["shared_down"] = dense_init(k6, (m.n_shared * m.d_expert, d),
                                      fan_in=m.n_shared * m.d_expert,
                                      dtype=cfg.pdtype)
    return p


def _moe_a2a(xf, top_e, top_p, params, cfg: ModelConfig, mesh, dp_axes):
    """Explicit expert-parallel dispatch under shard_map (§Perf cell B
    iteration 4).

    GSPMD's scatter/gather partitioning moved dispatch payloads via
    replicate+all-reduce / all-gather (2.2 TB/step/device on deepseek
    train_4k).  Here each device routes its own token shard: local
    capacity-grouping -> ``lax.all_to_all`` over the ``model`` (expert)
    axis -> local expert GEMMs -> all_to_all back -> local combine.  Every
    token's hidden vector crosses the expert axis exactly once each way —
    the textbook MoE dispatch (DeepSpeed/MaxText).  Expert weights enter
    replicated-over-data (the shard_map boundary performs the ZeRO
    all-gather of the FSDP shards).
    """
    m = cfg.moe
    t, d = xf.shape
    tp = mesh.shape["model"]
    e_loc = m.n_experts // tp
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    # tokens shard over dp (+ model when they divide it — decode batches
    # often don't); either way the a2a runs over the expert (model) axis
    if t % (dp * tp) == 0:
        tok_axes = tuple(dp_axes) + ("model",)
        t_loc = t // (dp * tp)
    else:
        tok_axes = tuple(dp_axes)
        t_loc = t // dp
    c_src = int(max(4, np.ceil(t_loc * m.top_k * m.capacity_factor
                               / m.n_experts)))
    n_slots = m.n_experts * c_src

    def local_fn(xf_l, te_l, tp_l, wg, wu, wd):
        T = xf_l.shape[0]
        k = te_l.shape[1]
        flat_e = te_l.reshape(-1)
        flat_p = tp_l.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        order = jnp.argsort(flat_e, stable=True)
        e_sorted = flat_e[order]
        counts = jnp.zeros((m.n_experts,), jnp.int32).at[e_sorted].add(1)
        starts = jnp.concatenate([
            jnp.zeros(1, jnp.int32),
            jnp.cumsum(counts)[:-1].astype(jnp.int32)])
        rank_sorted = jnp.arange(T * k, dtype=jnp.int32) - starts[e_sorted]
        rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
        keep = rank < c_src
        slot = jnp.where(keep, flat_e * c_src + rank, n_slots)
        slot_tok = jnp.full((n_slots + 1,), T, jnp.int32).at[slot].set(
            flat_tok)
        xf_pad = jnp.concatenate([xf_l, jnp.zeros((1, d), xf_l.dtype)])
        sbuf = xf_pad[jnp.minimum(slot_tok[:-1], T)]
        sbuf = sbuf.reshape(tp, e_loc, c_src, d)        # dest-major chunks
        rbuf = jax.lax.all_to_all(sbuf, "model", split_axis=0,
                                  concat_axis=0)        # (src, e_loc, c, d)
        rb = jnp.moveaxis(rbuf, 0, 1).reshape(e_loc, tp * c_src, d)
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", rb, wg))
        up = jnp.einsum("ecd,edf->ecf", rb, wu)
        oe = jnp.einsum("ecf,efd->ecd", gate * up, wd)
        ob = jnp.moveaxis(oe.reshape(e_loc, tp, c_src, d), 1, 0)
        back = jax.lax.all_to_all(ob, "model", split_axis=0, concat_axis=0)
        out_flat = back.reshape(n_slots, d)             # expert-major slots
        gathered = jnp.where(keep[:, None],
                             out_flat[jnp.clip(slot, 0, n_slots - 1)], 0.0)
        return (gathered.reshape(T, k, d)
                * flat_p.reshape(T, k, 1).astype(xf_l.dtype)).sum(axis=1)

    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(tok_axes, None), P(tok_axes, None), P(tok_axes, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(tok_axes, None),
        check_vma=False)
    cdt = cfg.cdtype
    return fn(xf, top_e, top_p, params["w_gate"].astype(cdt),
              params["w_up"].astype(cdt), params["w_down"].astype(cdt))


def moe_apply(params, cfg: ModelConfig, x):
    """x: (B, S, d) -> (B, S, d), plus router aux loss (scalar)."""
    m = cfg.moe
    cdt = cfg.cdtype
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d).astype(cdt)

    logits = xf.astype(jnp.float32) @ params["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)                # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((m.n_experts,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (t * m.top_k))
    aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight

    # explicit all-to-all dispatch when a production mesh is bound and the
    # token/expert counts divide it; else the GSPMD scatter/gather path
    mesh = bound_mesh()
    if mesh is not None and bound_axis("expert") == "model":
        batch_axes = bound_axis("batch") or ()
        dp_axes = (batch_axes,) if isinstance(batch_axes, str) \
            else tuple(batch_axes)
        dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes \
            else 1
        if dp > 1 and t % dp == 0:
            combined = _moe_a2a(xf, top_e, top_p, params, cfg, mesh,
                                dp_axes)
            if m.n_shared:
                g = jax.nn.silu(xf @ params["shared_gate"].astype(cdt))
                u = xf @ params["shared_up"].astype(cdt)
                combined = combined + (g * u) @ params["shared_down"] \
                    .astype(cdt)
            return combined.reshape(b, s, d), aux

    capacity = int(max(1, (t * m.top_k * m.capacity_factor) // m.n_experts))
    flat_e = top_e.reshape(-1)                                  # (T*k,)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), m.top_k)

    # rank within expert via sorted segments
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    seg_start = jnp.zeros((m.n_experts,), jnp.int32).at[e_sorted].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(seg_start)[:-1].astype(jnp.int32)])
    rank_sorted = jnp.arange(t * m.top_k, dtype=jnp.int32) - starts[e_sorted]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)

    keep = rank < capacity
    n_slots = m.n_experts * capacity
    slot = jnp.where(keep, flat_e * capacity + rank, n_slots)
    # dispatch = int32 scatter + payload gather.  Scattering the (T·k, d)
    # payloads directly made GSPMD replicate-and-all-reduce whole (E, C, d)
    # buffers (0.64 TB/step/device on deepseek train_4k); scattering 4-byte
    # token ids and gathering the payload moves 1000x less through the
    # scatter path (§Perf cell B iteration 3).
    slot_tok = jnp.full((n_slots + 1,), t, jnp.int32).at[slot].set(
        flat_tok.astype(jnp.int32))
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), cdt)])
    buf = xf_pad[jnp.minimum(slot_tok[:-1], t)]           # (E*C, d) gather
    # expert-sharded dispatch buffer (EP): without the constraint the SPMD
    # partitioner ran every expert GEMM with a sharded *contraction* and
    # all-reduced whole (E, C, d) buffers per layer — 2.3 TB/step/device on
    # deepseek train_4k (EXPERIMENTS.md §Perf cell B)
    buf = constrain(buf.reshape(m.n_experts, capacity, d),
                    "expert", "capacity", None)

    # ZeRO-style: all-gather the FSDP-sharded expert weights at use (a few
    # 10s of MB) instead of letting the partitioner run the GEMMs with a
    # sharded contraction and all-reduce (E, C, •) activations (100s of MB
    # x fwd/remat/bwd — §Perf cell B iteration 2)
    w_gate = constrain(params["w_gate"].astype(cdt), "expert", None, None)
    w_up = constrain(params["w_up"].astype(cdt), "expert", None, None)
    w_down = constrain(params["w_down"].astype(cdt), "expert", None, None)
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    up = jnp.einsum("ecd,edf->ecf", buf, w_up)
    out_e = jnp.einsum("ecf,efd->ecd", gate * up, w_down)
    out_e = constrain(out_e, "expert", "capacity", None)
    out_flat = out_e.reshape(n_slots, d)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.clip(slot, 0, n_slots - 1)],
                         0.0)
    # combine is a pure reshape+reduce: flat_tok = repeat(arange(t), k), so
    # entry (t_i, j) of the (t, k, d) view IS token t_i's j-th expert output
    # — the previous scatter-add here was another replicate+all-reduce
    combined = (gathered.reshape(t, m.top_k, d)
                * flat_p.reshape(t, m.top_k, 1).astype(cdt)).sum(axis=1)
    combined = constrain(combined.astype(cdt), "tokens", None)

    if m.n_shared:
        g = jax.nn.silu(xf @ params["shared_gate"].astype(cdt))
        u = xf @ params["shared_up"].astype(cdt)
        combined = combined + (g * u) @ params["shared_down"].astype(cdt)

    return combined.reshape(b, s, d), aux

"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and RG-LRU (RecurrentGemma).

TPU-native forms:
* **mLSTM** uses the *chunkwise-parallel* formulation: within a chunk the
  matrix-memory recurrence is an intra-chunk decay-masked attention
  (MXU einsums); across chunks a (nh, hd, hd) state is carried by
  ``lax.scan``.  Log-sigmoid forget gates keep every decay factor <= 1, so
  the chunkwise log-space algebra never overflows (input gate clipped).
* **sLSTM** keeps the paper's scalar-memory recurrence with block-diagonal
  per-head recurrent weights; sequential ``lax.scan`` over time (this block
  appears 1-in-8, so the serial span is small).
* **RG-LRU** is a per-channel gated linear recurrence — an
  ``associative_scan`` (log-depth on TPU), with the Griffin block structure
  (conv + gated branch) around it.

All three expose (sequence-apply, single-step-decode) pairs; decode states
are the serving caches — O(1) per token, which is why these families run the
``long_500k`` cell (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, rmsnorm, rmsnorm_params

I_CLIP = 5.0


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B, S, d); w: (cw, d).
    state: (B, cw-1, d) trailing inputs for decode."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(cw))
    new_state = xp[:, -(cw - 1):, :] if cw > 1 else pad
    return out, new_state


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_params(key, cfg: ModelConfig):
    d = cfg.d_model
    xc = cfg.xlstm
    di = int(d * xc.proj_factor)
    nh = cfg.n_heads
    ks = jax.random.split(key, 10)
    return {
        "norm": rmsnorm_params(d, cfg.pdtype),
        "w_up": dense_init(ks[0], (d, 2 * di), dtype=cfg.pdtype),
        "conv_w": dense_init(ks[1], (xc.conv_width, di), fan_in=xc.conv_width,
                             dtype=cfg.pdtype),
        "wq": dense_init(ks[2], (di, di), dtype=cfg.pdtype),
        "wk": dense_init(ks[3], (di, di), dtype=cfg.pdtype),
        "wv": dense_init(ks[4], (di, di), dtype=cfg.pdtype),
        "w_i": dense_init(ks[5], (di, nh), dtype=jnp.float32),
        "w_f": dense_init(ks[6], (di, nh), dtype=jnp.float32),
        "b_f": jnp.full((nh,), 3.0, dtype=jnp.float32),   # open forget gates
        "out_norm": rmsnorm_params(di, cfg.pdtype),
        "w_down": dense_init(ks[7], (di, d), fan_in=di, dtype=cfg.pdtype),
    }


def _mlstm_chunk(q, k, v, log_i, log_f, state):
    """One chunk. q,k,v: (B, L, nh, hd); log_i/log_f: (B, L, nh).
    state: (C (B,nh,hd,hd), n (B,nh,hd)).  Returns (h, new_state)."""
    b, L, nh, hd = q.shape
    C_prev, n_prev = state
    F = jnp.cumsum(log_f, axis=1)                     # (B, L, nh), <= 0
    # intra-chunk decay matrix D[i,j] = exp(F_i - F_j + log_i_j), j <= i
    Fi = F[:, :, None, :]
    Fj = F[:, None, :, :]
    logD = Fi - Fj + log_i[:, None, :, :]
    mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, :, :, None]
    D = jnp.where(mask, jnp.exp(jnp.minimum(logD, 30.0)), 0.0)  # (B,i,j,nh)
    scores = jnp.einsum("bihd,bjhd->bijh", q, k) / jnp.sqrt(jnp.float32(hd))
    sd = scores * D
    h_intra = jnp.einsum("bijh,bjhd->bihd", sd, v)
    n_intra = jnp.einsum("bijh,bjhd->bihd", sd, k)
    # inter-chunk contribution
    decay_q = jnp.exp(F)[..., None]                   # (B, L, nh, 1)
    h_inter = jnp.einsum("bihd,bhde->bihe", q * decay_q, C_prev)
    n_inter = jnp.einsum("bihd,bhd->bih", q * decay_q, n_prev)   # (B,L,nh)
    den = jnp.abs(jnp.einsum("bihd,bihd->bih", q, n_intra)
                  + n_inter)[..., None]
    h = (h_intra + h_inter) / jnp.maximum(den, 1.0)
    # state update
    F_L = F[:, -1:, :]                                # (B, 1, nh)
    decay_k = jnp.exp(jnp.minimum(F_L - F + log_i, 30.0))[..., None]
    C_new = jnp.exp(F_L[:, 0, :, None, None]) * C_prev + jnp.einsum(
        "bjhd,bjhe->bhde", k * decay_k, v)
    n_new = jnp.exp(F_L[:, 0, :, None]) * n_prev + jnp.sum(k * decay_k, axis=1)
    return h, (C_new, n_new)


def mlstm_apply(params, cfg: ModelConfig, x, cache=None):
    """Sequence (chunkwise) or decode-step (cache given) mLSTM block."""
    cdt = cfg.cdtype
    xc = cfg.xlstm
    b, s, d = x.shape
    di = int(d * xc.proj_factor)
    nh = cfg.n_heads
    hd = di // nh
    res = x
    xn = rmsnorm(params["norm"], x.astype(cdt), cfg.norm_eps)
    up = xn @ params["w_up"].astype(cdt)
    xm, z = jnp.split(up, 2, axis=-1)
    conv_state = None if cache is None else cache[2]
    xc_out, new_conv = _causal_conv(xm, params["conv_w"].astype(cdt),
                                    conv_state)
    xc_act = jax.nn.silu(xc_out)
    q = (xc_act @ params["wq"].astype(cdt)).reshape(b, s, nh, hd)
    k = (xc_act @ params["wk"].astype(cdt)).reshape(b, s, nh, hd)
    v = (xm @ params["wv"].astype(cdt)).reshape(b, s, nh, hd)
    xf = xm.astype(jnp.float32)
    log_i = jnp.minimum(xf @ params["w_i"], I_CLIP)
    log_f = jax.nn.log_sigmoid(xf @ params["w_f"] + params["b_f"])

    q32, k32, v32 = (t.astype(jnp.float32) for t in (q, k, v))
    if cache is None:
        L = min(xc.chunk, s)
        assert s % L == 0, (s, L)
        nchunk = s // L
        def body(state, inp):
            qc, kc, vc, lic, lfc = inp
            h, state = _mlstm_chunk(qc, kc, vc, lic, lfc, state)
            return state, h
        reshape = lambda t: jnp.moveaxis(
            t.reshape(b, nchunk, L, *t.shape[2:]), 1, 0)
        C0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, nh, hd), jnp.float32)
        state, hs = jax.lax.scan(
            body, (C0, n0),
            (reshape(q32), reshape(k32), reshape(v32),
             reshape(log_i), reshape(log_f)))
        h = jnp.moveaxis(hs, 0, 1).reshape(b, s, nh, hd)
        new_cache = (*state, new_conv)
    else:
        C_prev, n_prev = cache[0], cache[1]
        i_t = jnp.exp(log_i[:, 0])                     # (B, nh)
        f_t = jnp.exp(log_f[:, 0])
        kv = jnp.einsum("bhd,bhe->bhde", k32[:, 0], v32[:, 0])
        C_new = f_t[..., None, None] * C_prev + i_t[..., None, None] * kv
        n_new = f_t[..., None] * n_prev + i_t[..., None] * k32[:, 0]
        num = jnp.einsum("bhd,bhde->bhe", q32[:, 0], C_new)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", q32[:, 0], n_new))[..., None]
        h = (num / jnp.maximum(den, 1.0))[:, None].reshape(b, s, nh, hd)
        new_cache = (C_new, n_new, new_conv)

    h = h.reshape(b, s, di).astype(cdt)
    h = rmsnorm(params["out_norm"], h, cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ params["w_down"].astype(cdt)
    return res + out.astype(res.dtype), new_cache


def mlstm_init_cache(cfg: ModelConfig, batch: int):
    xc = cfg.xlstm
    di = int(cfg.d_model * xc.proj_factor)
    nh = cfg.n_heads
    hd = di // nh
    return (jnp.zeros((batch, nh, hd, hd), jnp.float32),
            jnp.zeros((batch, nh, hd), jnp.float32),
            jnp.zeros((batch, xc.conv_width - 1, di), cfg.cdtype))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_params(key, cfg: ModelConfig):
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    ks = jax.random.split(key, 4)
    return {
        "norm": rmsnorm_params(d, cfg.pdtype),
        "w": dense_init(ks[0], (d, 4 * d), dtype=cfg.pdtype),
        "r": dense_init(ks[1], (nh, hd, 4 * hd), fan_in=hd, dtype=cfg.pdtype),
        "b": jnp.zeros((4 * d,), dtype=jnp.float32),
        "w_out": dense_init(ks[2], (d, d), dtype=cfg.pdtype),
    }


def _slstm_cell(params_r, gates_x, state, nh, hd):
    """gates_x: (B, 4d) precomputed W x_t + b; state: (c, n, h) each (B,nh,hd)."""
    c, n, h = state
    rec = jnp.einsum("bhd,hdg->bhg", h, params_r)      # (B, nh, 4hd)
    g = gates_x.reshape(-1, nh, 4 * hd) + rec
    i_r, f_r, z_r, o_r = jnp.split(g, 4, axis=-1)
    i = jnp.exp(jnp.minimum(i_r, I_CLIP))
    f = jax.nn.sigmoid(f_r + 1.0)
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    c = f * c + i * z
    n = f * n + i
    h = o * (c / jnp.maximum(jnp.abs(n), 1.0))
    return c, n, h


def slstm_apply(params, cfg: ModelConfig, x, cache=None):
    cdt = cfg.cdtype
    b, s, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    res = x
    xn = rmsnorm(params["norm"], x.astype(cdt), cfg.norm_eps)
    gates_x = (xn @ params["w"].astype(cdt)).astype(jnp.float32) + params["b"]
    if cache is None:
        state = tuple(jnp.zeros((b, nh, hd), jnp.float32) for _ in range(3))
    else:
        state = cache
    r32 = params["r"].astype(jnp.float32)

    if s == 1:
        state = _slstm_cell(r32, gates_x[:, 0], state, nh, hd)
        hs = state[2][:, None]
    else:
        def body(st, gx):
            st = _slstm_cell(r32, gx, st, nh, hd)
            return st, st[2]
        state, hs = jax.lax.scan(body, state, jnp.moveaxis(gates_x, 0, 1))
        hs = jnp.moveaxis(hs, 0, 1)
    out = hs.reshape(b, s, d).astype(cdt) @ params["w_out"].astype(cdt)
    return res + out.astype(res.dtype), state


def slstm_init_cache(cfg: ModelConfig, batch: int):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    return tuple(jnp.zeros((batch, nh, hd), jnp.float32) for _ in range(3))


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

def rglru_params(key, cfg: ModelConfig):
    d = cfg.d_model
    dr = cfg.rglru.d_rnn or d
    ks = jax.random.split(key, 7)
    return {
        "norm": rmsnorm_params(d, cfg.pdtype),
        "w_x": dense_init(ks[0], (d, dr), dtype=cfg.pdtype),
        "w_gate": dense_init(ks[1], (d, dr), dtype=cfg.pdtype),
        "conv_w": dense_init(ks[2], (cfg.rglru.conv_width, dr),
                             fan_in=cfg.rglru.conv_width, dtype=cfg.pdtype),
        "w_a": dense_init(ks[3], (dr, dr), dtype=jnp.float32),
        "w_i": dense_init(ks[4], (dr, dr), dtype=jnp.float32),
        "lam": jnp.full((dr,), 2.0, dtype=jnp.float32),  # sigmoid(2)≈0.88
        "w_down": dense_init(ks[5], (dr, d), fan_in=dr, dtype=cfg.pdtype),
    }


def rglru_apply(params, cfg: ModelConfig, x, cache=None):
    """Griffin recurrent block: conv + RG-LRU branch gated by GeLU branch."""
    cdt = cfg.cdtype
    b, s, d = x.shape
    res = x
    xn = rmsnorm(params["norm"], x.astype(cdt), cfg.norm_eps)
    branch = xn @ params["w_x"].astype(cdt)
    gate = jax.nn.gelu(xn @ params["w_gate"].astype(cdt))
    conv_state = None if cache is None else cache[1]
    u, new_conv = _causal_conv(branch, params["conv_w"].astype(cdt),
                               conv_state)
    uf = u.astype(jnp.float32)
    c = 8.0
    log_a_max = c * jax.nn.log_sigmoid(params["lam"])        # (dr,), < 0
    r = jax.nn.sigmoid(uf @ params["w_a"])
    i = jax.nn.sigmoid(uf @ params["w_i"])
    log_a = r * log_a_max[None, None, :]
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-8)) * (i * uf)

    if cache is None:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        a_sc, h = jax.lax.associative_scan(combine, (a, gated_x), axis=1)
        h_last = h[:, -1]
        new_cache = (h_last, new_conv)
    else:
        h_prev = cache[0]
        h = a[:, 0] * h_prev + gated_x[:, 0]
        h_last = h
        h = h[:, None]
        new_cache = (h_last, new_conv)

    out = (h.astype(cdt) * gate) @ params["w_down"].astype(cdt)
    return res + out.astype(res.dtype), new_cache


def rglru_init_cache(cfg: ModelConfig, batch: int):
    dr = cfg.rglru.d_rnn or cfg.d_model
    return (jnp.zeros((batch, dr), jnp.float32),
            jnp.zeros((batch, cfg.rglru.conv_width - 1, dr), cfg.cdtype))

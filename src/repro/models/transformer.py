"""Model assembly: layer-group plans, scan-over-layers, forward/decode.

Every architecture is a sequence of *groups*; a group is a superblock
(ordered tuple of block kinds) scanned ``repeats`` times with stacked
params — so HLO size stays O(superblock), not O(depth) (granite-34b's 88
layers lower as one scan).  Same-shape heterogeneity (gemma3's 5:1
local:global windows) rides through scan ``xs`` as a per-repeat window
scalar; different-shape heterogeneity (xLSTM's 7 mLSTM + 1 sLSTM,
RecurrentGemma's 2 RG-LRU + 1 local-attn) becomes multi-part superblocks.

Three entry points (the dry-run lowers all three):
* ``forward``      — tokens/embeddings -> logits (+ MoE aux), training path;
* ``prefill``      — forward that also returns per-layer caches;
* ``decode_step``  — one token against a fixed-capacity cache (serving).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn
from . import ssm
from .config import ModelConfig
from .layers import (dense_init, embed, embed_params, mlp, mlp_params,
                     rmsnorm, rmsnorm_params, sinusoidal_positions, unembed)
from .moe import moe_apply, moe_params


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    name: str
    parts: Tuple[Tuple[str, int], ...]      # ((kind, count), ...)
    repeats: int
    windows: Optional[np.ndarray] = None    # (repeats, n_instances) int32
    d_ff_override: int = 0

    @property
    def instances(self) -> List[Tuple[str, int]]:
        out = []
        for kind, count in self.parts:
            for j in range(count):
                out.append((kind, len(out)))
        return out


def build_plan(cfg: ModelConfig) -> List[GroupSpec]:
    if cfg.xlstm is not None:
        se = cfg.xlstm.slstm_every
        reps = cfg.n_layers // se
        return [GroupSpec("xlstm", (("mlstm", se - 1), ("slstm", 1)), reps)]
    if cfg.rglru is not None:
        pat = cfg.rglru.block_pattern
        plen = len(pat)
        reps = cfg.n_layers // plen
        rem = cfg.n_layers - reps * plen
        parts = tuple((k, 1) for k in pat)
        win = np.full((reps, plen), -1, dtype=np.int32)
        for i, k in enumerate(pat):
            if k == "local_attn":
                win[:, i] = cfg.rglru.attn_window
        groups = [GroupSpec("griffin", parts, reps, windows=win)]
        if rem:
            groups.append(GroupSpec(
                "griffin_rem", tuple((pat[i], 1) for i in range(rem)), 1,
                windows=np.full((1, rem), -1, dtype=np.int32)))
        return groups
    if cfg.enc_dec:
        return [GroupSpec("encoder", (("enc_attn_mlp", 1),), cfg.n_enc_layers),
                GroupSpec("decoder", (("dec_attn_mlp", 1),), cfg.n_layers)]
    mixer = "mla" if cfg.mla is not None else "attn"
    ffn = "moe" if cfg.moe is not None else "mlp"
    groups = []
    start = 0
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        groups.append(GroupSpec(
            "dense_head", ((f"{mixer}_mlp", 1),), cfg.moe.first_dense_layers,
            d_ff_override=cfg.moe.dense_d_ff))
        start = cfg.moe.first_dense_layers
    n = cfg.n_layers - start
    win = np.array([[cfg.window_for_layer(start + i)] for i in range(n)],
                   dtype=np.int32)
    groups.append(GroupSpec("blocks", ((f"{mixer}_{ffn}", 1),), n,
                            windows=win))
    return groups


# ---------------------------------------------------------------------------
# per-kind param init / apply / cache init
# ---------------------------------------------------------------------------

def _block_params(key, kind: str, cfg: ModelConfig, d_ff_override: int = 0):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if kind.startswith("attn") or kind.endswith("attn_mlp") or \
            kind.startswith("mla") or kind == "local_attn":
        p["ln1"] = rmsnorm_params(cfg.d_model, cfg.pdtype)
        if kind.startswith("mla"):
            p["attn"] = attn.mla_params(ks[0], cfg)
        else:
            p["attn"] = attn.attn_params(ks[0], cfg)
        if kind == "dec_attn_mlp":
            p["ln_cross"] = rmsnorm_params(cfg.d_model, cfg.pdtype)
            p["cross"] = attn.cross_attn_params(ks[2], cfg)
        if kind.endswith("_moe"):
            p["ln2"] = rmsnorm_params(cfg.d_model, cfg.pdtype)
            p["ffn"] = moe_params(ks[1], cfg)
        elif kind == "local_attn" and cfg.d_ff == 0:
            pass
        else:
            d_ff = d_ff_override or cfg.d_ff
            p["ln2"] = rmsnorm_params(cfg.d_model, cfg.pdtype)
            p["ffn"] = mlp_params(ks[1], cfg.d_model, d_ff, cfg.pdtype,
                                  cfg.act)
        return p
    if kind == "mlstm":
        return ssm.mlstm_params(key, cfg)
    if kind == "slstm":
        return ssm.slstm_params(key, cfg)
    if kind == "rglru":
        p = ssm.rglru_params(key, cfg)
        if cfg.d_ff:
            p["ln2"] = rmsnorm_params(cfg.d_model, cfg.pdtype)
            p["ffn"] = mlp_params(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype,
                                  cfg.act)
        return p
    raise ValueError(f"unknown block kind {kind}")


def _block_cache(kind: str, cfg: ModelConfig, batch: int, s_max: int):
    if kind in ("mlstm",):
        return ssm.mlstm_init_cache(cfg, batch)
    if kind == "slstm":
        return ssm.slstm_init_cache(cfg, batch)
    if kind == "rglru":
        return ssm.rglru_init_cache(cfg, batch)
    if kind.startswith("mla"):
        m = cfg.mla
        return (jnp.zeros((batch, s_max, m.kv_lora_rank), cfg.cdtype),
                jnp.zeros((batch, s_max, m.rope_head_dim), cfg.cdtype))
    kv = (jnp.zeros((batch, s_max, cfg.n_kv_heads, cfg.head_dim_),
                    cfg.cdtype),
          jnp.zeros((batch, s_max, cfg.n_kv_heads, cfg.head_dim_),
                    cfg.cdtype))
    if kind == "dec_attn_mlp":
        # + cross-attention K/V, computed once at prefill (enc length ==
        # the decode cache length in the whisper cells: S_enc = S_dec)
        return kv + (jnp.zeros((batch, s_max, cfg.n_heads, cfg.head_dim_),
                               cfg.cdtype),
                     jnp.zeros((batch, s_max, cfg.n_heads, cfg.head_dim_),
                               cfg.cdtype))
    return kv


def _apply_block(kind: str, params, cfg: ModelConfig, x, ctx,
                 window, cache=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("mlstm", "slstm", "rglru") or kind == "rglru":
        if kind == "mlstm":
            x, new_cache = ssm.mlstm_apply(params, cfg, x, cache)
        elif kind == "slstm":
            x, new_cache = ssm.slstm_apply(params, cfg, x, cache)
        else:
            x, new_cache = ssm.rglru_apply(params, cfg, x, cache)
            if "ffn" in params:
                h = rmsnorm(params["ln2"], x.astype(cfg.cdtype), cfg.norm_eps)
                x = x + mlp(params["ffn"], h, cfg.act, cfg.cdtype
                            ).astype(x.dtype)
        return x, new_cache, aux

    causal = kind != "enc_attn_mlp"
    h = rmsnorm(params["ln1"], x.astype(cfg.cdtype), cfg.norm_eps)
    self_cache = cache
    cross_cache = None
    if kind == "dec_attn_mlp" and cache is not None:
        self_cache, cross_cache = cache[:2], cache[2:]
    if kind.startswith("mla"):
        a_out, new_cache = attn.mla_apply(
            params["attn"], cfg, h, ctx["positions"], window,
            cache=self_cache, cache_pos=ctx.get("cache_pos"))
    else:
        a_out, new_cache = attn.attention_apply(
            params["attn"], cfg, h, ctx["positions"], window,
            cache=self_cache, cache_pos=ctx.get("cache_pos"),
            positions3=ctx.get("positions3"), causal=causal)
    x = x + a_out.astype(x.dtype)
    if kind == "dec_attn_mlp":
        h = rmsnorm(params["ln_cross"], x.astype(cfg.cdtype), cfg.norm_eps)
        c_out, cross_kv = attn.cross_attention_apply(
            params["cross"], cfg, h, ctx["enc_out"], kv_cache=cross_cache)
        x = x + c_out.astype(x.dtype)
        new_cache = tuple(new_cache) + tuple(cross_kv)
    if "ffn" in params:
        h = rmsnorm(params["ln2"], x.astype(cfg.cdtype), cfg.norm_eps)
        if kind.endswith("_moe"):
            f_out, aux = moe_apply(params["ffn"], cfg, h)
        else:
            f_out = mlp(params["ffn"], h, cfg.act, cfg.cdtype)
        x = x + f_out.astype(x.dtype)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# model init / apply
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    plan = build_plan(cfg)
    keys = jax.random.split(key, len(plan) + 3)
    params: Dict[str, Any] = {
        "embed": embed_params(keys[0], cfg.padded_vocab, cfg.d_model,
                              cfg.pdtype),
        "final_norm": rmsnorm_params(cfg.d_model, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "table": dense_init(keys[1], (cfg.padded_vocab, cfg.d_model),
                                dtype=cfg.pdtype)}
    if cfg.enc_dec:
        params["enc_final_norm"] = rmsnorm_params(cfg.d_model, cfg.pdtype)
    groups = []
    for gi, g in enumerate(plan):
        gkey = jax.random.fold_in(keys[2], gi)
        inst_params = {}
        for kind, idx in g.instances:
            ikey = jax.random.fold_in(gkey, idx)
            stacked = jax.vmap(
                lambda k: _block_params(k, kind, cfg, g.d_ff_override)
            )(jax.random.split(ikey, g.repeats))
            inst_params[f"{kind}_{idx}"] = stacked
        groups.append(inst_params)
    params["groups"] = groups
    return params


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    plan = build_plan(cfg)
    caches = []
    for g in plan:
        if g.name == "encoder":
            caches.append({})       # encoder has no decode cache
            continue
        inst = {}
        for kind, idx in g.instances:
            one = _block_cache(kind, cfg, batch, s_max)
            inst[f"{kind}_{idx}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (g.repeats,) + x.shape).copy(), one)
        caches.append(inst)
    return caches


def _run_group(g: GroupSpec, gparams, cfg, x, ctx, caches=None):
    """Scan the group's superblock over its repeats (+remat policy)."""
    windows = g.windows if g.windows is not None else \
        np.full((g.repeats, len(g.instances)), -1, dtype=np.int32)
    win_xs = jnp.asarray(windows, jnp.int32)

    def body_inner(x, aux, params_r, win_r, cache_r):
        new_cache_r = {}
        for kind, idx in g.instances:
            key = f"{kind}_{idx}"
            c = None if cache_r is None else cache_r[key]
            x, nc, a = _apply_block(kind, params_r[key], cfg, x, ctx,
                                    win_r[idx], c)
            new_cache_r[key] = nc
            aux = aux + a
        return x, aux, new_cache_r

    if cfg.remat == "full":
        body_inner = jax.checkpoint(
            body_inner, policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        body_inner = jax.checkpoint(
            body_inner,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    def body(carry, xs):
        x, aux = carry
        params_r, win_r, cache_r = xs
        x, aux, new_cache_r = body_inner(x, aux, params_r, win_r, cache_r)
        return (x, aux), new_cache_r

    aux0 = jnp.zeros((), jnp.float32)
    xs = (gparams, win_xs, caches)
    (x, aux), new_caches = jax.lax.scan(body, (x, aux0), xs)
    return x, aux, new_caches


def forward(params, cfg: ModelConfig, batch, return_caches: bool = False):
    """Training/prefill forward.  batch keys: tokens | embeds, positions,
    positions3 (mrope), enc_embeds (enc-dec/audio)."""
    plan = build_plan(cfg)
    if cfg.input_kind == "tokens":
        x = embed(params["embed"], batch["tokens"]).astype(cfg.cdtype)
    else:
        x = batch["embeds"].astype(cfg.cdtype)
    positions = batch.get("positions")
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    positions3 = batch.get("positions3")
    if cfg.rope_kind == "mrope" and positions3 is None:
        positions3 = jnp.broadcast_to(positions[None], (3, b, s))
    ctx = {"positions": positions, "positions3": positions3}

    enc_out = None
    if cfg.enc_dec:
        e = batch["enc_embeds"].astype(cfg.cdtype)
        e = e + sinusoidal_positions(e.shape[1], cfg.d_model
                                     ).astype(cfg.cdtype)[None]
        ectx = {"positions": jnp.broadcast_to(
            jnp.arange(e.shape[1], dtype=jnp.int32), e.shape[:2])}
        for gi, g in enumerate(plan):
            if g.name != "encoder":
                continue
            e, _, _ = _run_group(g, params["groups"][gi], cfg, e, ectx)
        enc_out = rmsnorm(params["enc_final_norm"], e, cfg.norm_eps)
        ctx["enc_out"] = enc_out
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model
                                     ).astype(cfg.cdtype)[None]

    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    for gi, g in enumerate(plan):
        if g.name == "encoder":
            caches.append({})
            continue
        x, aux, cache = _run_group(g, params["groups"][gi], cfg, x, ctx)
        aux_total = aux_total + aux
        caches.append(cache)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = unembed(head, x, cfg.cdtype).astype(jnp.float32)
    if return_caches:
        return logits, aux_total, {"layers": caches, "enc_out": enc_out}
    return logits, aux_total


def make_cache(cfg: ModelConfig, batch: int, s_max: int, enc_out=None):
    return {"layers": init_cache(cfg, batch, s_max), "enc_out": enc_out}


def _sinusoidal_at(pos, d_model: int):
    """Sinusoidal position embedding at a traced position. -> (d_model,)"""
    half = d_model // 2
    div = jnp.exp(jnp.arange(half, dtype=jnp.float32)
                  * (-jnp.log(10000.0) / d_model) * 2.0)
    ang = jnp.asarray(pos, jnp.float32) * div
    pe = jnp.zeros((d_model,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang))
    pe = pe.at[1::2].set(jnp.cos(ang))
    return pe


def decode_step(params, cfg: ModelConfig, cache, batch):
    """One-token serving step.  batch: tokens (B, 1) | embeds (B, 1, d),
    cache_pos scalar int32, enc_out for enc-dec.  Returns (logits, cache)."""
    plan = build_plan(cfg)
    if cfg.input_kind == "tokens":
        x = embed(params["embed"], batch["tokens"]).astype(cfg.cdtype)
    else:
        x = batch["embeds"].astype(cfg.cdtype)
    pos = batch["cache_pos"]
    b = x.shape[0]
    positions = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32)[None, None], (b, 1))
    enc_out = batch.get("enc_out")
    if enc_out is None:
        enc_out = cache.get("enc_out")
    positions3 = batch.get("positions3")
    if cfg.rope_kind == "mrope" and positions3 is None:
        positions3 = jnp.broadcast_to(positions[None], (3, b, 1))
    ctx = {"positions": positions, "cache_pos": pos,
           "positions3": positions3,
           "enc_out": enc_out}
    if cfg.enc_dec:
        x = x + _sinusoidal_at(pos, cfg.d_model).astype(cfg.cdtype)[None, None]
    aux = jnp.zeros((), jnp.float32)
    new_layers = []
    for gi, g in enumerate(plan):
        if g.name == "encoder":
            new_layers.append({})
            continue
        x, a, nc = _run_group(g, params["groups"][gi], cfg, x, ctx,
                              caches=cache["layers"][gi])
        new_layers.append(nc)
        aux = aux + a
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = unembed(head, x, cfg.cdtype).astype(jnp.float32)
    return logits, {"layers": new_layers, "enc_out": cache.get("enc_out")}


def count_params(params) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(params)))

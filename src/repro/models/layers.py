"""Shared neural layers: norms, MLPs, embeddings, rotary position encodings."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain


def dense_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = (1.0 / max(fan_in, 1)) ** 0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def rmsnorm_params(d, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dt)


def mlp_params(key, d_model, d_ff, dtype, act="silu"):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), fan_in=d_ff, dtype=dtype),
    }
    if act in ("silu", "swiglu"):
        p["w_gate"] = dense_init(k2, (d_model, d_ff), dtype=dtype)
    return p


def mlp(params, x, act="silu", cdtype=jnp.bfloat16):
    x = x.astype(cdtype)
    up = x @ params["w_up"].astype(cdtype)
    if "w_gate" in params:
        gate = jax.nn.silu(x @ params["w_gate"].astype(cdtype))
        h = gate * up
    else:
        h = jax.nn.gelu(up)
    h = constrain(h, "batch", None, "mlp")
    return constrain(h @ params["w_down"].astype(cdtype),
                     "batch", None, None)


def embed_params(key, vocab, d_model, dtype):
    return {"table": dense_init(key, (vocab, d_model), fan_in=1, dtype=dtype)}


def embed(params, ids):
    return constrain(params["table"][ids], "batch", None, None)


def unembed(params, x, cdtype=jnp.bfloat16):
    # 1/sqrt(d) keeps initial logits O(1) under tied N(0,1) embeddings
    # (initial CE ~= log V instead of ~7x that; examples/train_lm.py relies
    # on the first few hundred steps being in the learnable regime)
    d = x.shape[-1]
    logits = x.astype(cdtype) @ params["table"].astype(cdtype).T
    return constrain(logits * (1.0 / d ** 0.5), "batch", None, "vocab")


# ---------------------------------------------------------------------------
# Rotary position encodings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e6) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray,
                sections: Tuple[int, int, int], theta: float = 1e6
                ) -> jnp.ndarray:
    """Multimodal RoPE (qwen2-vl): x (B, S, H, D); positions3 (3, B, S).

    The D/2 frequency lanes are partitioned into (temporal, height, width)
    sections; each section rotates by its own position grid.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    sec = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)])
    assert sec.shape[0] == d // 2, (sections, d)
    # lane l rotates by positions3[sec[l]] (temporal / height / width grid)
    pos = positions3.astype(jnp.float32)               # (3, B, S)
    lane_pos = pos[sec, :, :]                          # (D/2, B, S)
    ang = jnp.moveaxis(lane_pos, 0, -1) * freqs        # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d_model, 2, dtype=jnp.float32)
                  * (-jnp.log(10000.0) / d_model))
    pe = jnp.zeros((seq, d_model), dtype=jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe

"""Attention blocks: GQA/MQA with qk-norm + RoPE/M-RoPE variants, sliding
windows, DeepSeek-style MLA (compressed KV), cross-attention, and the
prefill/decode KV-cache paths.

Masking is data-driven (per-layer window scalar; -1 = global) so
heterogeneous local/global stacks (gemma3, recurrentgemma) scan over a single
homogeneous param group.  The Pallas flash kernel handles the same masks on
TPU; the jnp path here is what the dry-run lowers (see kernels/ops.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

from .config import ModelConfig
from .layers import apply_mrope, apply_rope, dense_init, rmsnorm, rmsnorm_params

NEG_INF = -2.0e38


def attn_params(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype=cfg.pdtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype=cfg.pdtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype=cfg.pdtype),
        "wo": dense_init(ks[3], (h * hd, d), fan_in=h * hd, dtype=cfg.pdtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_params(hd, cfg.pdtype)
        p["k_norm"] = rmsnorm_params(hd, cfg.pdtype)
    return p


def mla_params(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    m = cfg.mla
    ks = jax.random.split(key, 6)
    qk_head = m.nope_head_dim + m.rope_head_dim
    return {
        "wq": dense_init(ks[0], (d, h * qk_head), dtype=cfg.pdtype),
        "w_dkv": dense_init(ks[1], (d, m.kv_lora_rank), dtype=cfg.pdtype),
        "w_krope": dense_init(ks[2], (d, m.rope_head_dim), dtype=cfg.pdtype),
        "w_uk": dense_init(ks[3], (m.kv_lora_rank, h * m.nope_head_dim),
                           fan_in=m.kv_lora_rank, dtype=cfg.pdtype),
        "w_uv": dense_init(ks[4], (m.kv_lora_rank, h * m.v_head_dim),
                           fan_in=m.kv_lora_rank, dtype=cfg.pdtype),
        "wo": dense_init(ks[5], (h * m.v_head_dim, d),
                         fan_in=h * m.v_head_dim, dtype=cfg.pdtype),
        "kv_norm": rmsnorm_params(m.kv_lora_rank, cfg.pdtype),
    }


def _mask_bias(q_pos, k_pos, window, causal=True):
    """(.., Sq, Sk) additive bias from positions; window traced scalar."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(diff.shape, dtype=bool)
    if causal:
        ok &= diff >= 0
    ok &= (window < 0) | (diff < jnp.maximum(window, 1))
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias):
    """q: (B,Sq,H,D) k/v: (B,Sk,KV,D').

    Two GQA layouts, chosen by phase:

    * **train/prefill** (Sq > 1): KV is broadcast to the full head count
      instead of reshape-grouping q into (kv, group) — the 5-D grouped
      einsum defeats SPMD propagation whenever kv_heads doesn't divide the
      model axis (involuntary full rematerialization — a 451 GB/step
      collective on qwen3 train_4k before this change).  The repeat is free
      under TP: KV is small and each device slices only its own heads.
    * **decode** (Sq == 1): grouped einsum against the *sequence-sharded*
      cache — no repeat (3x cache-traffic saving), heads unsharded, softmax
      and context reduce over the sharded KV axis (XLA inserts the small
      per-token all-reduces).  See EXPERIMENTS.md §Perf.
    """
    b, sq, h, dq = q.shape
    kvh = k.shape[2]
    # bf16 operands + f32 accumulation (preferred_element_type) — upcasting
    # K/V to f32 made XLA keep a full f32 copy of the decode cache in the
    # layer-scan carry and reconvert the whole stack every iteration
    # (~160 GB/step of traffic on decode_32k; EXPERIMENTS.md §Perf).
    f32 = jnp.float32
    scale = 1.0 / jnp.sqrt(f32(dq))
    if sq == 1 and kvh != h:
        g = h // kvh
        qg = q.reshape(b, sq, kvh, g, dq)
        k = constrain(k, "batch", "kv_seq", None, None)
        v = constrain(v, "batch", "kv_seq", None, None)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                            preferred_element_type=f32) * scale
        scores = scores + bias[:, None, None, :, :]
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                         preferred_element_type=f32)
        return out.reshape(b, sq, h, v.shape[-1])
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    k = constrain(k, "batch", "kv_seq", "heads", None)
    v = constrain(v, "batch", "kv_seq", "heads", None)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=f32) * scale
    scores = scores + bias[:, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=f32)
    return constrain(out, "batch", None, "heads", None)


def _q_chunk(sq: int) -> int:
    """Query-block size for chunked attention (0 = unchunked).

    Full-bias SDPA materializes (B, KV, G, Sq, Sk) fp32 scores — 4.3 GB per
    (b, h) pair at 32k — so any long-sequence cell must bound live scores to
    one query block.  This is the jnp analogue of the Pallas flash kernel's
    grid dimension (kernels/flash_attention.py); XLA sees a ``lax.scan`` and
    keeps only one block's scores live (remat-friendly, and the memory term
    in the roofline reflects it)."""
    if sq <= 2048:
        return 0
    return 1024 if sq <= 8192 else 512


def _sdpa_masked(q, k, v, q_pos, k_pos, window, causal=True, valid=None):
    """Mask-from-positions SDPA with automatic query chunking.

    q: (B,Sq,H,D); k/v: (B,Sk,KV,D'); q_pos: (B,Sq); k_pos: (B,Sk) or (Sk,).
    ``valid`` optionally masks out unwritten cache slots (Sk,)."""
    sq = q.shape[1]
    bq = _q_chunk(sq)

    def bias_for(qp):
        bias = _mask_bias(qp, k_pos, window, causal=causal)
        if valid is not None:
            bias = jnp.where(valid[None, None, :], bias, NEG_INF)
        return bias

    if bq == 0 or sq % bq != 0:
        return _sdpa(q, k, v, bias_for(q_pos))

    b, _, h, dq = q.shape
    nb = sq // bq
    qs = jnp.moveaxis(q.reshape(b, nb, bq, h, dq), 1, 0)
    qps = jnp.moveaxis(q_pos.reshape(b, nb, bq), 1, 0)

    def body(carry, xs):
        qb, qpb = xs
        return carry, _sdpa(qb, k, v, bias_for(qpb))

    _, outs = jax.lax.scan(body, 0, (qs, qps))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, v.shape[-1])


def attention_apply(params, cfg: ModelConfig, x, positions, window,
                    cache: Optional[Tuple] = None, cache_pos=None,
                    positions3=None, causal: bool = True):
    """Standard GQA attention.  If ``cache`` is given: decode step — x is
    (B, 1, d), cache=(K, V) with capacity S_max, write at ``cache_pos``."""
    cdt = cfg.cdtype
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    x = x.astype(cdt)
    q = (x @ params["wq"].astype(cdt)).reshape(b, s, h, hd)
    k = (x @ params["wk"].astype(cdt)).reshape(b, s, kv, hd)
    v = (x @ params["wv"].astype(cdt)).reshape(b, s, kv, hd)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if cfg.rope_kind == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        q = apply_mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)

    if cache is None:
        out = _sdpa_masked(q, k, v, positions, positions, window,
                           causal=causal)
        new_cache = (k, v)
    else:
        ck, cv = cache
        # index dtypes must match exactly (int32 even under enabled x64)
        z = jnp.int32(0)
        pos = jnp.asarray(cache_pos, jnp.int32)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (z, pos, z, z))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (z, pos, z, z))
        s_max = ck.shape[1]
        k_pos = jnp.arange(s_max, dtype=jnp.int32)              # (S_max,)
        diff = cache_pos - k_pos
        ok = diff >= 0                                          # causal/valid
        ok &= (window < 0) | (diff < jnp.maximum(window, 1))
        bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
        bias = jnp.broadcast_to(bias[None, None, :], (b, s, s_max))
        out = _sdpa(q, ck.astype(cdt), cv.astype(cdt), bias)
        new_cache = (ck, cv)
    out = out.reshape(b, s, h * hd).astype(cdt)
    out = constrain(out @ params["wo"].astype(cdt), "batch", None, None)
    return out, new_cache


def mla_apply(params, cfg: ModelConfig, x, positions, window,
              cache: Optional[Tuple] = None, cache_pos=None):
    """DeepSeek-V2 Multi-head Latent Attention: KV compressed to
    ``kv_lora_rank`` (+ shared rotary key head); the cache stores only the
    latent c_kv and k_rope — the paper's KV-memory saving."""
    cdt = cfg.cdtype
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    x = x.astype(cdt)
    q = (x @ params["wq"].astype(cdt)).reshape(
        b, s, h, m.nope_head_dim + m.rope_head_dim)
    q = constrain(q, "batch", None, "heads", None)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"].astype(cdt),
                   cfg.norm_eps)                                   # (B,S,r)
    k_rope = apply_rope((x @ params["w_krope"].astype(cdt))[:, :, None, :],
                        positions, cfg.rope_theta)                 # (B,S,1,dr)

    if cache is not None:
        # ---- absorbed decode (DeepSeek's production form) ----
        # Scores are computed directly against the latent cache:
        #   q·K^T = (q_nope W_uk^T)·c^T  and  out = (P·c) W_uv.
        # Up-projecting the whole 32k cache per token costs
        # O(S·r·h·(n+v)) FLOPs + a full-cache reshard per layer (the
        # dry-run measured 2.18 s/token of collectives on decode_32k);
        # the absorbed form touches the latent once — O(S·r·h).
        c_cache, kr_cache = cache
        z = jnp.int32(0)
        pos = jnp.asarray(cache_pos, jnp.int32)
        c_cache = jax.lax.dynamic_update_slice(
            c_cache, c_kv.astype(c_cache.dtype), (z, pos, z))
        kr_cache = jax.lax.dynamic_update_slice(
            kr_cache, k_rope[:, :, 0, :].astype(kr_cache.dtype),
            (z, pos, z))
        c_all = constrain(c_cache.astype(cdt), "batch", "kv_seq", None)
        kr_all = constrain(kr_cache.astype(cdt), "batch", "kv_seq", None)
        s_k = c_all.shape[1]
        f32 = jnp.float32
        r = m.kv_lora_rank
        w_uk_h = params["w_uk"].astype(cdt).reshape(r, h, m.nope_head_dim)
        q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk_h)   # (B,1,h,r)
        s_nope = jnp.einsum("bqhr,bkr->bhqk", q_lat, c_all,
                            preferred_element_type=f32)
        s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, kr_all,
                            preferred_element_type=f32)
        scale = 1.0 / jnp.sqrt(f32(m.nope_head_dim + m.rope_head_dim))
        scores = (s_nope + s_rope) * scale
        k_idx = jnp.arange(s_k, dtype=jnp.int32)
        ok = k_idx <= pos
        ok &= (window < 0) | (pos - k_idx < jnp.maximum(window, 1))
        scores = jnp.where(ok[None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhqk,bkr->bqhr", probs.astype(cdt), c_all,
                             preferred_element_type=f32)      # (B,1,h,r)
        w_uv_h = params["w_uv"].astype(cdt).reshape(r, h, m.v_head_dim)
        out = jnp.einsum("bqhr,rhv->bqhv", out_lat.astype(cdt), w_uv_h)
        out = out.reshape(b, s, h * m.v_head_dim).astype(cdt)
        out = constrain(out @ params["wo"].astype(cdt), "batch", None, None)
        return out, (c_cache, kr_cache)

    c_all, kr_all = c_kv, k_rope
    s_k = s
    k_pos = positions
    new_cache = (c_kv, k_rope[:, :, 0, :])

    k_nope = constrain((c_all @ params["w_uk"].astype(cdt)).reshape(
        b, s_k, h, m.nope_head_dim), "batch", None, "heads", None)
    val = constrain((c_all @ params["w_uv"].astype(cdt)).reshape(
        b, s_k, h, m.v_head_dim), "batch", None, "heads", None)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all, (b, s_k, h, m.rope_head_dim))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = _sdpa_masked(q_full, k_full, val, positions, k_pos, window,
                       causal=True)
    out = out.reshape(b, s, h * m.v_head_dim).astype(cdt)
    out = constrain(out @ params["wo"].astype(cdt), "batch", None, None)
    return out, new_cache


def cross_attn_params(key, cfg: ModelConfig):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd), dtype=cfg.pdtype),
        "wk": dense_init(ks[1], (d, h * hd), dtype=cfg.pdtype),
        "wv": dense_init(ks[2], (d, h * hd), dtype=cfg.pdtype),
        "wo": dense_init(ks[3], (h * hd, d), fan_in=h * hd, dtype=cfg.pdtype),
    }


def cross_attention_apply(params, cfg: ModelConfig, x, enc_out,
                          kv_cache: Optional[Tuple] = None):
    """Decoder->encoder cross attention (whisper); enc_out: (B, Se, d).

    Query-chunked like self-attention (_sdpa_masked): unchunked 16k x 16k
    cross scores put whisper prefill_32k at 50 GiB/device in the dry-run.

    ``kv_cache=(xk, xv)`` serves decode: cross K/V are computed once at
    prefill and cached — recomputing them from the full encoder output
    every token cost 2·Se·d² FLOPs per layer per token (the whisper
    decode_32k cell's dominant term before this).  Returns
    (out, (xk, xv))."""
    cdt = cfg.cdtype
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    x = x.astype(cdt)
    q = (x @ params["wq"].astype(cdt)).reshape(b, s, h, hd)
    q = constrain(q, "batch", None, "heads", None)
    if kv_cache is not None:
        k, v = kv_cache
        k = k.astype(cdt)
        v = v.astype(cdt)
    else:
        e = enc_out.astype(cdt)
        se = enc_out.shape[1]
        k = (e @ params["wk"].astype(cdt)).reshape(b, se, h, hd)
        v = (e @ params["wv"].astype(cdt)).reshape(b, se, h, hd)
    se = k.shape[1]
    k = constrain(k, "batch", "kv_seq", "heads", None)
    v = constrain(v, "batch", "kv_seq", "heads", None)
    q_pos = jnp.zeros((b, s), dtype=jnp.int32)
    k_pos = jnp.zeros((se,), dtype=jnp.int32)
    out = _sdpa_masked(q, k, v, q_pos, k_pos, jnp.int32(-1), causal=False)
    out = out.reshape(b, s, h * hd).astype(cdt)
    out = constrain(out @ params["wo"].astype(cdt), "batch", None, None)
    return out, (k, v)

from .steps import (extend_cache, make_decode_step, make_prefill_step,
                    sample_greedy, sample_temperature)
from .engine import ServeEngine, Request
from .ph import (AdmissionDecision, PHRequest, PHResponse, PHServeEngine,
                 fingerprint_points)

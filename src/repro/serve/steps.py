"""Serving steps: prefill, cache extension, one-token decode, sampling.

``make_prefill_step`` / ``make_decode_step`` build the jittable functions the
launcher shards (these are exactly what the ``prefill_*`` / ``decode_*`` /
``long_*`` dry-run cells lower).  ``extend_cache`` turns a prefill cache
(KV length = prompt length) into a fixed-capacity decode cache (KV length =
``s_max``) — attention/MLA caches are seq-padded, recurrent states (mLSTM /
sLSTM / RG-LRU / conv) pass through, because prefill already left them at the
post-prompt state (O(1) decode state is why SSM/hybrid archs run long_500k).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, forward


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """prefill_step(params, batch) -> (logits, cache_dict).

    batch: tokens (B, S) | embeds (B, S, d) (+ positions3 / enc_embeds)."""

    def prefill_step(params, batch):
        logits, _aux, caches = forward(params, cfg, batch, return_caches=True)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """decode_fn(params, cache, batch) -> (logits, new_cache).

    batch: tokens (B, 1) | embeds (B, 1, d), cache_pos scalar int32."""

    def decode_fn(params, cache, batch):
        return decode_step(params, cfg, cache, batch)

    return decode_fn


def _pad_seq_axis(x: jnp.ndarray, axis: int, s_max: int) -> jnp.ndarray:
    pad = s_max - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


_SEQ_CACHE_KINDS = ("attn", "mla", "local_attn", "dec", "enc")


def extend_cache(cfg: ModelConfig, prefill_cache: Dict[str, Any],
                 prompt_len: int, s_max: int) -> Dict[str, Any]:
    """Pad every seq-bearing cache leaf from ``prompt_len`` to ``s_max``.

    Only attention-family blocks carry a sequence axis; recurrent states
    (mLSTM/sLSTM/RG-LRU) pass through untouched — they are matched by their
    block-kind key, NOT by shape (a recurrent state dim that happens to
    equal prompt_len must not be padded)."""

    def fix(path, leaf):
        keys = [getattr(k, "key", "") for k in path]
        is_attn = any(str(k).startswith(_SEQ_CACHE_KINDS) for k in keys)
        if not is_attn:
            return leaf
        # decoder cross-attention K/V (tuple slots 2/3 under a "dec" key)
        # keep the encoder length — zero-padding keys would leak softmax
        # mass; only the *self*-attention slots grow to decode capacity
        is_dec = any(str(k).startswith("dec") for k in keys)
        idx = next((getattr(k, "idx", None) for k in reversed(path)
                    if hasattr(k, "idx")), None)
        if is_dec and idx is not None and idx >= 2:
            return leaf
        # stacked attention leaves: (repeats, B, S, ...) — S is axis >= 2
        for ax in range(2, leaf.ndim):
            if leaf.shape[ax] == prompt_len and prompt_len != s_max:
                return _pad_seq_axis(leaf, ax, s_max)
        return leaf

    layers = jax.tree_util.tree_map_with_path(fix, prefill_cache["layers"])
    return {"layers": layers, "enc_out": prefill_cache.get("enc_out")}


def sample_greedy(logits: jnp.ndarray) -> jnp.ndarray:
    """(B, 1, V) -> (B, 1) int32."""
    return jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)


def sample_temperature(logits: jnp.ndarray, key, temperature: float = 1.0
                       ) -> jnp.ndarray:
    scaled = logits[:, -1, :] / jnp.maximum(temperature, 1e-6)
    out = jax.random.categorical(key, scaled, axis=-1)
    return out[:, None].astype(jnp.int32)

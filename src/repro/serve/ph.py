"""PH-as-a-service: batched, cache-warm topology serving (tentpole, ISSUE 9).

``PHServeEngine`` turns the reduction stack into a request/response service
for many small-to-medium point clouds, reusing every piece of the paper's
memory story instead of re-deriving it per request:

* **Admission control** — each request passes through the
  ``(3n + 12 n_e) * 4``-byte account (:func:`repro.scale.budget
  .estimate_tau_max`): the requested ``tau_max`` is *clamped* to what the
  service's ``memory_budget_bytes`` affords, and requests whose ``O(n)``
  part alone overflows are rejected with a reproducible
  :class:`AdmissionDecision` (the decision is a pure function of
  ``(points, budget, seed)``, so a rejection can be re-derived offline from
  the logged account).
* **Dataset cache** — landmarks, filtrations and reduction checkpoints
  (:class:`repro.core.resume.ReductionCheckpoint`) are cached per
  ``(tenant, dataset)`` keyed by a content fingerprint, with per-tenant
  ``store_budget_bytes`` isolation enforced by LRU whole-dataset eviction.
* **Warm starts** — a request that *extends* a cached dataset is served
  incrementally: tau growth skips every previously committed pair
  (:func:`~repro.core.resume.warm_tau_growth`), point arrival replays from
  the recorded V-expansions (:func:`~repro.core.resume.warm_point_arrival`).
  Both are bit-identical to a cold reduction (the metamorphic property
  ``tests/test_serve_ph.py`` pins down).
* **Union batching** — cold requests drained in one :meth:`step` are packed
  into a single block-diagonal reduction
  (:func:`~repro.core.resume.batched_cold_reduce`), amortizing engine
  dispatch across clouds with *exact* per-cloud results.
* **Graceful degradation** (ISSUE 10) — per-request deadlines, bounded
  cold-retry with deterministic backoff
  (:func:`repro.resilience.faults.retry_with_backoff`), a circuit breaker
  per ``(tenant, dataset)``, and load shedding under queue/overload
  pressure.  A degraded request is served with clamped ``tau`` / lowered
  ``maxdim`` and the response says so explicitly
  (``PHResponse.degraded`` + ``degraded_reason``) — degradation is never
  silent and never an exception.

Everything is deterministic given ``(seed, arrival order)`` and instrumented
through the ``serve_ph_*`` names in the :mod:`repro.obs.metrics` schema;
``benchmarks/serve_bench.py`` turns those counters into the
``BENCH_serve.json`` CI gate.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.filtration import Filtration, build_filtration
from repro.core.resume import (ReductionCheckpoint, batched_cold_reduce,
                               canonical_diagram, cold_reduce, make_reducer,
                               warm_point_arrival, warm_tau_growth)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import span, stopwatch
from repro.resilience.faults import (TransientFault, active_injector,
                                     retry_with_backoff)
from repro.scale.budget import (account_bytes, estimate_tau_max,
                                maxmin_landmarks, sample_pair_lengths)


def fingerprint_points(points: np.ndarray) -> str:
    """Content fingerprint of a point cloud (shape + dtype + raw bytes)."""
    p = np.ascontiguousarray(points)
    h = hashlib.sha256()
    h.update(str(p.shape).encode())
    h.update(str(p.dtype).encode())
    h.update(p.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class PHRequest:
    uid: int
    points: np.ndarray
    tau_max: float = np.inf
    tenant: str = "default"
    dataset: Optional[str] = None   # default: content-addressed by fingerprint
    maxdim: int = 2
    deadline_s: Optional[float] = None   # None: engine default_deadline_s


@dataclasses.dataclass
class AdmissionDecision:
    """The reproducible memory account behind an admit/reject/clamp.

    ``predicted_bytes = account_bytes(n, n_e_est)`` at the granted tau; the
    estimate is a pure function of ``(points, budget, n_samples, seed)``,
    so replaying :meth:`PHServeEngine.admission_account` on the logged
    inputs reproduces the decision bit-for-bit.
    """
    uid: int
    tenant: str
    n: int
    requested_tau: float
    granted_tau: float
    n_e_est: int
    predicted_bytes: int
    budget_bytes: Optional[int]
    admitted: bool
    reason: str


@dataclasses.dataclass
class PHResponse:
    uid: int
    tenant: str
    dataset: str
    admitted: bool
    path: str        # rejected|hit|cold|batched|warm_tau|warm_points|degraded
    granted_tau: float
    diagrams: Optional[Dict[int, np.ndarray]]
    admission: AdmissionDecision
    cached: bool = False            # checkpoint retained for future warm starts
    n_landmarks: Optional[int] = None
    cover_radius: Optional[float] = None
    latency_s: float = 0.0
    degraded: bool = False          # served under a brown-out contract
    degraded_reason: str = ""       # deadline|overload|queue_depth|circuit_open|cold_failed


@dataclasses.dataclass
class _CacheEntry:
    fingerprint: str
    n: int
    tau: float
    maxdim: int
    filtration: Filtration
    checkpoint: ReductionCheckpoint
    diagrams: Dict[int, np.ndarray]
    seq: int                        # LRU clock
    landmarks: Optional[np.ndarray] = None
    cover_radius: Optional[float] = None

    def nbytes(self) -> int:
        f = self.filtration
        filt_bytes = int(f.edges.nbytes + f.edge_len.nbytes
                         + f.nbr_vtx.nbytes + f.nbr_vtx_ord.nbytes
                         + f.nbr_edge_ord.nbytes + f.nbr_edge_vtx.nbytes
                         + f.degree.nbytes)
        diag_bytes = int(sum(d.nbytes for d in self.diagrams.values()))
        lm_bytes = int(self.landmarks.nbytes) if self.landmarks is not None \
            else 0
        return self.checkpoint.nbytes() + filt_bytes + diag_bytes + lm_bytes


class PHServeEngine:
    """Admission-controlled, cache-warm PH serving (module docstring).

    ``memory_budget_bytes`` is the *per-reduction* account that admission
    inverts into a tau cap; ``store_budget_bytes`` is the *per-tenant*
    cache residency cap (checkpoints + filtrations + landmarks), enforced
    by LRU whole-dataset eviction.  ``reducer_opts`` go to
    :func:`repro.core.resume.make_reducer` — ``engine`` may be ``single``,
    ``batch`` or ``packed`` (optionally sharded with ``n_shards``).

    Degradation knobs (``docs/resilience.md``): ``default_deadline_s``
    compares a cold request against the EWMA of observed cold latency and
    serves a clamped result when it cannot meet the deadline;
    ``max_cold_retries`` bounds re-attempts of a failed cold reduction
    (deterministic backoff, ``retry_base_s``); ``breaker_threshold``
    consecutive failures open a per-``(tenant, dataset)`` circuit for
    ``breaker_cooldown_steps`` engine steps; ``shed_queue_depth`` sheds
    drained requests beyond that depth onto the degraded contract
    (``tau * degrade_tau_factor`` when finite, ``maxdim`` clamped to
    ``degrade_maxdim``).  Degraded responses are never cached.
    """

    def __init__(self,
                 memory_budget_bytes: Optional[int] = None,
                 store_budget_bytes: Optional[int] = None,
                 max_batch_clouds: int = 8,
                 landmark_cap: Optional[int] = None,
                 n_admission_samples: int = 4096,
                 seed: int = 0,
                 default_deadline_s: Optional[float] = None,
                 max_cold_retries: int = 2,
                 retry_base_s: float = 1e-3,
                 breaker_threshold: int = 3,
                 breaker_cooldown_steps: int = 2,
                 shed_queue_depth: Optional[int] = None,
                 degrade_tau_factor: float = 0.5,
                 degrade_maxdim: int = 1,
                 **reducer_opts):
        reducer_opts.setdefault("engine", "single")
        reducer_opts.setdefault("mode", "implicit")
        self.memory_budget_bytes = memory_budget_bytes
        self.store_budget_bytes = store_budget_bytes
        self.max_batch_clouds = int(max_batch_clouds)
        self.landmark_cap = landmark_cap
        self.n_admission_samples = int(n_admission_samples)
        self.seed = int(seed)
        self.reducer_opts = dict(reducer_opts)
        self._reducer = make_reducer(**reducer_opts)
        self.queue: List[PHRequest] = []
        self.done: Dict[int, PHResponse] = {}
        self.admission_log: List[AdmissionDecision] = []
        self._cache: Dict[Tuple[str, str], _CacheEntry] = {}
        self._seq = 0
        self.metrics = MetricsRegistry()
        # -- resilience / degradation state --------------------------------
        self.default_deadline_s = default_deadline_s
        self.max_cold_retries = int(max_cold_retries)
        self.retry_base_s = float(retry_base_s)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_steps = int(breaker_cooldown_steps)
        self.shed_queue_depth = shed_queue_depth
        self.degrade_tau_factor = float(degrade_tau_factor)
        self.degrade_maxdim = int(degrade_maxdim)
        self._step_idx = 0
        # (tenant, dataset) -> {"failures": consecutive, "open_until": step}
        self._breakers: Dict[Tuple[str, str], Dict[str, int]] = {}
        self._cold_ewma: Optional[float] = None   # observed cold latency/req
        self._pinned: set = set()     # keys served this step: LRU-immune
        self._degraded: Dict[int, str] = {}   # uid -> degrade reason

    # -- admission ------------------------------------------------------
    def admission_account(self, points: np.ndarray, requested_tau: float,
                          uid: int = -1, tenant: str = "default"
                          ) -> AdmissionDecision:
        """The memory account for one request; pure given engine config."""
        n = int(points.shape[0])
        total_pairs = n * (n - 1) // 2
        budget = self.memory_budget_bytes
        if budget is None:
            granted = float(requested_tau)
            n_e_est = self._estimate_edges(points, granted, total_pairs)
            return AdmissionDecision(
                uid=uid, tenant=tenant, n=n, requested_tau=requested_tau,
                granted_tau=granted, n_e_est=n_e_est,
                predicted_bytes=account_bytes(n, n_e_est), budget_bytes=None,
                admitted=True, reason="no budget configured")
        try:
            tau_cap = estimate_tau_max(
                points, budget, n_samples=self.n_admission_samples,
                seed=self.seed)
        except ValueError as e:
            return AdmissionDecision(
                uid=uid, tenant=tenant, n=n, requested_tau=requested_tau,
                granted_tau=0.0, n_e_est=0,
                predicted_bytes=account_bytes(n, 0), budget_bytes=budget,
                admitted=False, reason=str(e))
        granted = float(min(requested_tau, tau_cap))
        n_e_est = self._estimate_edges(points, granted, total_pairs)
        clamped = granted < requested_tau
        return AdmissionDecision(
            uid=uid, tenant=tenant, n=n, requested_tau=requested_tau,
            granted_tau=granted, n_e_est=n_e_est,
            predicted_bytes=account_bytes(n, n_e_est), budget_bytes=budget,
            admitted=True,
            reason=f"tau clamped to budget cap {tau_cap:.6g}" if clamped
            else "within budget")

    def _estimate_edges(self, points: np.ndarray, tau: float,
                        total_pairs: int) -> int:
        if total_pairs == 0:
            return 0
        if not np.isfinite(tau):
            return total_pairs
        lens = sample_pair_lengths(points, n_samples=self.n_admission_samples,
                                   seed=self.seed)
        if lens.size == 0:
            return 0
        return int(round(float(np.mean(lens <= tau)) * total_pairs))

    # -- cache / tenancy ------------------------------------------------
    def tenant_bytes(self) -> Dict[str, int]:
        """Resident cache bytes per tenant (the isolation invariant)."""
        out: Dict[str, int] = {}
        for (tenant, _), e in self._cache.items():
            out[tenant] = out.get(tenant, 0) + e.nbytes()
        return out

    def _touch(self, entry: _CacheEntry) -> None:
        self._seq += 1
        entry.seq = self._seq

    def _store(self, tenant: str, dataset: str, entry: _CacheEntry) -> bool:
        """Insert under the tenant budget; LRU-evict whole datasets.

        Entries already served this step are *pinned* (``self._pinned``) —
        eviction must never reclaim a dataset that was warmed moments ago
        in the same drain (the warm result would be produced and then
        immediately thrown away, and a same-step repeat would go cold).
        When the only candidates are pinned, the *incoming* entry is
        dropped instead, preserving the tenant-byte invariant."""
        self._touch(entry)
        key = (tenant, dataset)
        budget = self.store_budget_bytes
        if budget is not None and entry.nbytes() > budget:
            self._cache.pop(key, None)   # stale state must not linger
            self._set_store_gauge()
            return False
        self._cache[key] = entry
        if budget is not None:
            while True:
                total = sum(e.nbytes() for (t, _), e in self._cache.items()
                            if t == tenant)
                if total <= budget:
                    break
                victims = [(e.seq, k) for k, e in self._cache.items()
                           if k[0] == tenant and k != key
                           and k not in self._pinned]
                if not victims:
                    # over budget with only pinned survivors: sacrifice the
                    # incoming entry rather than a just-served one
                    self._cache.pop(key, None)
                    break
                _, victim = min(victims)
                del self._cache[victim]
                self.metrics.counter("serve_ph_n_evictions").inc()
        self._set_store_gauge()
        return key in self._cache

    def _set_store_gauge(self) -> None:
        self.metrics.gauge("serve_ph_store_bytes").set(
            sum(e.nbytes() for e in self._cache.values()))

    # -- request lifecycle ----------------------------------------------
    def submit(self, req: PHRequest) -> None:
        self.queue.append(req)
        self.metrics.counter("serve_ph_n_requests").inc()

    def _classify(self, req: PHRequest, dataset: str, fp: str,
                  points: np.ndarray, granted_tau: float
                  ) -> Tuple[str, Optional[_CacheEntry]]:
        """hit | warm_tau | warm_points | cold, against the tenant cache."""
        entry = self._cache.get((req.tenant, dataset))
        if entry is None or entry.maxdim != req.maxdim:
            return "cold", None
        if entry.fingerprint == fp:
            if granted_tau == entry.tau:
                return "hit", entry
            if granted_tau > entry.tau:
                return "warm_tau", entry
            return "cold", None      # tau shrink: not an extension
        # prefix growth: cached cloud is a prefix of the new one
        n_old = entry.n
        if points.shape[0] > n_old and granted_tau >= entry.tau \
                and entry.landmarks is None \
                and fingerprint_points(points[:n_old]) == entry.fingerprint:
            return "warm_points", entry
        return "cold", None

    def step(self) -> int:
        """Drain the queue once: admit, serve warm paths, batch the colds.

        Returns the number of requests completed this step.
        """
        self._step_idx += 1
        self._pinned = set()
        if not self.queue:
            self.metrics.gauge("serve_ph_queue_depth").set(0)
            return 0
        overload = False
        inj = active_injector()
        if inj is not None and inj.fire("serve.step", index=self._step_idx,
                                        kinds=("overload",)):
            overload = True
        pending, self.queue = self.queue, []
        self.metrics.gauge("serve_ph_queue_depth").set(len(pending))
        colds: List[Tuple[PHRequest, str, str, np.ndarray, AdmissionDecision,
                          Optional[np.ndarray], Optional[float]]] = []
        n_done = 0
        for i, req in enumerate(pending):
            shed = overload or (self.shed_queue_depth is not None
                                and i >= self.shed_queue_depth)
            if shed:
                self.metrics.counter("serve_ph_n_shed").inc()
                req = self._degrade(req, "overload" if overload
                                    else "queue_depth")
            with stopwatch("serve_ph/request") as sw:
                out = self._serve_or_defer(req, colds)
            if out is not None:
                out.latency_s = sw.elapsed
                self._finish(out)
                n_done += 1
        n_done += self._run_cold_batches(colds)
        self._set_store_gauge()
        return n_done

    def run(self, max_steps: int = 10_000) -> Dict[int, PHResponse]:
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1
        return self.done

    def _finish(self, resp: PHResponse) -> None:
        if resp.degraded:
            self.metrics.counter("serve_ph_n_degraded").inc()
        self.done[resp.uid] = resp
        self.metrics.histogram("serve_ph_latency_s").observe(resp.latency_s)

    # -- degradation -----------------------------------------------------
    def _degrade(self, req: PHRequest, reason: str) -> PHRequest:
        """Clamp a request onto the brown-out contract and record why.

        The recorded reason is surfaced on the eventual response
        (``degraded=True``) no matter which path serves it — degradation
        is explicit, never silent."""
        self._degraded[req.uid] = reason
        tau = float(req.tau_max)
        if np.isfinite(tau):
            tau *= self.degrade_tau_factor
        return dataclasses.replace(
            req, tau_max=tau, maxdim=min(req.maxdim, self.degrade_maxdim))

    def _breaker_failure(self, key: Tuple[str, str]) -> None:
        rec = self._breakers.setdefault(key, {"failures": 0, "open_until": 0})
        rec["failures"] += 1
        if rec["failures"] >= self.breaker_threshold:
            rec["open_until"] = self._step_idx + self.breaker_cooldown_steps
            rec["failures"] = 0

    def _breaker_success(self, key: Tuple[str, str]) -> None:
        rec = self._breakers.get(key)
        if rec is not None:
            rec["failures"] = 0

    def _breaker_open(self, key: Tuple[str, str]) -> bool:
        rec = self._breakers.get(key)
        return rec is not None and self._step_idx <= rec["open_until"]

    def _serve_or_defer(self, req: PHRequest, colds: list
                        ) -> Optional[PHResponse]:
        """Serve a request on the hit/warm path, or defer it to the cold
        batch.  Returns ``None`` exactly when deferred."""
        points = np.asarray(req.points, dtype=np.float64)
        lm_idx: Optional[np.ndarray] = None
        lm_radius: Optional[float] = None
        full_fp = fingerprint_points(points)
        if self.landmark_cap is not None \
                and points.shape[0] > self.landmark_cap:
            cached = self._cache.get(
                (req.tenant, req.dataset or full_fp))
            if cached is not None and cached.fingerprint == full_fp \
                    and cached.landmarks is not None:
                lm_idx, lm_radius = cached.landmarks, cached.cover_radius
            else:
                with span("serve_ph/landmarks", n=int(points.shape[0]),
                          k=int(self.landmark_cap)):
                    lm_idx, lm_radius = maxmin_landmarks(
                        points, self.landmark_cap, seed=self.seed)
            served = points[lm_idx]
        else:
            served = points
        decision = self.admission_account(served, float(req.tau_max),
                                          uid=req.uid, tenant=req.tenant)
        self.admission_log.append(decision)
        if not decision.admitted:
            self.metrics.counter("serve_ph_n_rejected").inc()
            self._degraded.pop(req.uid, None)
            dataset = req.dataset or full_fp
            return PHResponse(
                uid=req.uid, tenant=req.tenant, dataset=dataset,
                admitted=False, path="rejected",
                granted_tau=decision.granted_tau, diagrams=None,
                admission=decision)
        self.metrics.counter("serve_ph_n_admitted").inc()
        dataset = req.dataset or full_fp
        granted = decision.granted_tau
        if self._breaker_open((req.tenant, dataset)):
            # repeated cold failures opened the circuit: fail fast with an
            # explicit degraded response instead of burning another attempt
            self.metrics.counter("serve_ph_n_circuit_open").inc()
            self._degraded.pop(req.uid, None)
            return PHResponse(
                uid=req.uid, tenant=req.tenant, dataset=dataset,
                admitted=True, path="degraded", granted_tau=granted,
                diagrams=None, admission=decision, degraded=True,
                degraded_reason="circuit_open")
        # identity of the *served* cloud: landmarked requests cache under
        # the full cloud's fingerprint so repeats reuse the landmark set
        fp = full_fp
        kind, entry = self._classify(req, dataset, fp, points, granted)
        deadline = req.deadline_s if req.deadline_s is not None \
            else self.default_deadline_s
        if kind == "cold" and deadline is not None \
                and self._cold_ewma is not None \
                and self._cold_ewma > deadline:
            # a cold reduction is predicted to blow the deadline: serve the
            # clamped contract instead (may even turn the request warm)
            self.metrics.counter("serve_ph_n_deadline_degraded").inc()
            req = self._degrade(req, "deadline")
            granted = min(granted, float(req.tau_max))
            decision = dataclasses.replace(decision, granted_tau=granted)
            kind, entry = self._classify(req, dataset, fp, points, granted)
        if kind == "hit":
            self.metrics.counter("serve_ph_n_cache_hits").inc()
            self._touch(entry)
            self._pinned.add((req.tenant, dataset))
            self._breaker_success((req.tenant, dataset))
            reason = self._degraded.pop(req.uid, "")
            return PHResponse(
                uid=req.uid, tenant=req.tenant, dataset=dataset,
                admitted=True, path="hit", granted_tau=granted,
                diagrams=dict(entry.diagrams), admission=decision,
                cached=True, n_landmarks=_lm_n(entry.landmarks),
                cover_radius=entry.cover_radius,
                degraded=bool(reason), degraded_reason=reason)
        if kind == "warm_tau":
            self.metrics.counter("serve_ph_n_cache_hits").inc()
            self.metrics.counter("serve_ph_n_warm_tau").inc()
            with span("serve_ph/warm_tau", uid=req.uid):
                filt = build_filtration(points=served, tau_max=granted)
                diagrams, ckpt = warm_tau_growth(
                    filt, entry.checkpoint, reducer=self._reducer)
            return self._respond(req, dataset, fp, served, granted, filt,
                                 diagrams, ckpt, decision, "warm_tau",
                                 lm_idx, lm_radius)
        if kind == "warm_points":
            self.metrics.counter("serve_ph_n_cache_hits").inc()
            self.metrics.counter("serve_ph_n_warm_points").inc()
            with span("serve_ph/warm_points", uid=req.uid):
                filt = build_filtration(points=served, tau_max=granted)
                diagrams, ckpt = warm_point_arrival(
                    filt, entry.checkpoint, reducer=self._reducer)
            return self._respond(req, dataset, fp, served, granted, filt,
                                 diagrams, ckpt, decision, "warm_points",
                                 lm_idx, lm_radius)
        self.metrics.counter("serve_ph_n_cache_misses").inc()
        colds.append((req, dataset, fp, served, decision, lm_idx, lm_radius))
        return None

    def _respond(self, req, dataset, fp, served, granted, filt, diagrams,
                 ckpt, decision, path, lm_idx, lm_radius) -> PHResponse:
        diagrams = {d: canonical_diagram(v) for d, v in diagrams.items()}
        self._breaker_success((req.tenant, dataset))
        reason = self._degraded.pop(req.uid, "")
        if reason:
            # degraded (clamped) results are served but never cached — a
            # brown-out must not evict full-fidelity datasets or masquerade
            # as one on a later classify
            cached = False
        else:
            # n is the identity-bearing cloud size: the *full* cloud
            # (prefix checks and fingerprints run against it), not the
            # landmark subset
            entry = _CacheEntry(
                fingerprint=fp, n=int(np.asarray(req.points).shape[0]),
                tau=granted, maxdim=req.maxdim, filtration=filt,
                checkpoint=ckpt, diagrams=diagrams, seq=0,
                landmarks=np.asarray(lm_idx) if lm_idx is not None else None,
                cover_radius=lm_radius)
            cached = self._store(req.tenant, dataset, entry)
            if cached:
                self._pinned.add((req.tenant, dataset))
        return PHResponse(
            uid=req.uid, tenant=req.tenant, dataset=dataset, admitted=True,
            path=path, granted_tau=granted, diagrams=dict(diagrams),
            admission=decision, cached=cached, n_landmarks=_lm_n(lm_idx),
            cover_radius=lm_radius, degraded=bool(reason),
            degraded_reason=reason)

    def _run_cold_batches(self, colds: list) -> int:
        """Pack drained cold requests into union reductions, chunked to
        ``max_batch_clouds``; per-cloud results are exact (resume module)."""
        n_done = 0
        by_dim: Dict[int, list] = {}
        for item in colds:
            by_dim.setdefault(item[0].maxdim, []).append(item)
        for maxdim, group in sorted(by_dim.items()):
            for s in range(0, len(group), self.max_batch_clouds):
                chunk = group[s:s + self.max_batch_clouds]
                n_done += self._serve_cold_chunk(chunk, maxdim)
        return n_done

    def _serve_cold_chunk(self, chunk: list, maxdim: int) -> int:
        inj = active_injector()
        batched = len(chunk) > 1

        def attempt(a: int):
            if inj is not None and inj.fire(
                    "serve.step", index=self._step_idx,
                    kinds=("fail_reduce",), attempt=a):
                raise TransientFault("injected cold-reduction failure")
            filts = [build_filtration(points=served,
                                      tau_max=dec.granted_tau)
                     for (_, _, _, served, dec, _, _) in chunk]
            with span("serve_ph/reduce", n_clouds=len(chunk),
                      batched=batched):
                return filts, batched_cold_reduce(filts, maxdim=maxdim,
                                                  reducer=self._reducer)

        def note_retry(a, err, delay_s):
            self.metrics.counter("serve_ph_n_cold_retries").inc()

        with stopwatch("serve_ph/cold_chunk") as sw:
            try:
                filts, results = retry_with_backoff(
                    attempt, attempts=1 + self.max_cold_retries,
                    base_s=self.retry_base_s,
                    seed=self.seed ^ (self._step_idx << 4),
                    sleep=None, on_retry=note_retry)
            except TransientFault:
                results = None
        if results is None:
            # retry budget spent: every request in the chunk gets an
            # explicit degraded response and counts against its circuit
            for (req, dataset, fp, served, dec, lm_idx, lm_radius) in chunk:
                self._breaker_failure((req.tenant, dataset))
                self._degraded.pop(req.uid, None)
                self._finish(PHResponse(
                    uid=req.uid, tenant=req.tenant, dataset=dataset,
                    admitted=True, path="degraded",
                    granted_tau=dec.granted_tau, diagrams=None,
                    admission=dec, degraded=True,
                    degraded_reason="cold_failed",
                    latency_s=sw.elapsed / len(chunk)))
            return len(chunk)
        if batched:
            self.metrics.counter("serve_ph_n_batches").inc()
            self.metrics.counter("serve_ph_n_batched").inc(len(chunk))
            self.metrics.histogram("serve_ph_batch_clouds").observe(
                len(chunk))
        per_req = sw.elapsed / len(chunk)
        # EWMA of cold latency feeds the deadline-degrade predictor
        self._cold_ewma = per_req if self._cold_ewma is None \
            else 0.3 * per_req + 0.7 * self._cold_ewma
        for (req, dataset, fp, served, dec, lm_idx, lm_radius), filt, \
                (diagrams, ckpt) in zip(chunk, filts, results):
            self.metrics.counter("serve_ph_n_cold").inc()
            resp = self._respond(req, dataset, fp, served, dec.granted_tau,
                                 filt, diagrams, ckpt, dec,
                                 "batched" if batched else "cold",
                                 lm_idx, lm_radius)
            resp.latency_s = per_req
            self._finish(resp)
        return len(chunk)

    def stats(self) -> Dict[str, float]:
        """Serving counters through the typed registry (``serve_ph_*``)."""
        return self.metrics.as_stats()


def _lm_n(lm_idx) -> Optional[int]:
    return None if lm_idx is None else int(len(lm_idx))

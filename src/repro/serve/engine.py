"""Batched serving engine: fixed-slot continuous batching over a shared
fixed-capacity KV cache.

``ServeEngine`` keeps ``max_batch`` request slots.  New requests are padded
to ``prompt_len`` and prefilled as a batch; decode then advances *all* active
slots one token per ``step()`` (one jitted ``decode_step`` call — the
batched-requests serving story).  Finished slots (EOS or ``max_new``) are
vacated and refilled from the queue; per-slot generated tokens stream back on
completion.  Everything is deterministic given (seed, arrival order).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_params, make_cache
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import span

from .steps import extend_cache, make_decode_step, make_prefill_step, \
    sample_greedy


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S_prompt,) int32
    max_new: int = 32
    eos_id: int = -1                # -1 = never
    generated: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params=None, max_batch: int = 8,
                 prompt_len: int = 32, s_max: int = 128, seed: int = 0):
        assert cfg.input_kind == "tokens", "engine serves token models"
        self.cfg = cfg
        self.max_batch = max_batch
        self.prompt_len = prompt_len
        self.s_max = s_max
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else init_params(cfg, key)
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_decode_step(cfg))
        self.queue: List[Request] = []
        self.done: Dict[int, List[int]] = {}
        self._slots: List[Optional[Request]] = [None] * max_batch
        self._pos = np.zeros(max_batch, dtype=np.int32)      # next write pos
        self._cache = None
        self._last_tok = np.zeros((max_batch, 1), dtype=np.int32)
        self.metrics = MetricsRegistry()

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.generated = []
        self.queue.append(req)

    def _admit(self):
        """Fill slots from the queue; batch-prefill the newcomers.

        Admission is *epoch* batching: slots refill only when the whole
        batch has drained, because every slot shares one scalar
        ``cache_pos`` (per-slot positions are the continuous-batching
        extension, tracked in DESIGN.md future work)."""
        if any(s is not None for s in self._slots):
            return
        new_idx = [i for i, s in enumerate(self._slots) if s is None]
        if not new_idx or not self.queue:
            return
        admitted = []
        for i in new_idx:
            if not self.queue:
                break
            self._slots[i] = self.queue.pop(0)
            admitted.append(i)

        toks = np.zeros((self.max_batch, self.prompt_len), dtype=np.int32)
        for i in admitted:
            p = self._slots[i].prompt[-self.prompt_len:]
            toks[i, -len(p):] = p                     # left-pad into the slot
        with span("serve/prefill", n_admitted=len(admitted)):
            logits, caches = self._prefill(self.params,
                                           {"tokens": jnp.asarray(toks)})
        self.metrics.counter("serve_n_prefills").inc()
        # the whole batch drained before admission, so the cache is replaced
        self._cache = extend_cache(self.cfg, caches, self.prompt_len,
                                   self.s_max)
        nxt = np.asarray(sample_greedy(logits))
        for i in admitted:
            self._pos[i] = self.prefill_written = self.prompt_len
            self._last_tok[i] = nxt[i]
            self._slots[i].generated.append(int(nxt[i, 0]))

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit + advance every active slot one token.  Returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return 0
        # all slots share cache_pos; slots are admitted at the same prompt
        # length so positions stay aligned (fixed-slot batching)
        pos = int(self._pos[active[0]])
        with span("serve/decode", n_active=len(active), cache_pos=pos):
            logits, self._cache = self._decode(
                self.params, self._cache,
                {"tokens": jnp.asarray(self._last_tok),
                 "cache_pos": jnp.int32(pos)})
        nxt = np.asarray(sample_greedy(logits))
        self.metrics.counter("serve_n_decode_steps").inc()
        self.metrics.counter("serve_n_tokens").inc(len(active))
        for i in active:
            req = self._slots[i]
            tok = int(nxt[i, 0])
            req.generated.append(tok)
            self._last_tok[i] = nxt[i]
            self._pos[i] += 1
            hit_eos = tok == req.eos_id
            full = len(req.generated) >= req.max_new or \
                self._pos[i] + 1 >= self.s_max
            if hit_eos or full:
                self.done[req.uid] = req.generated
                self._slots[i] = None
                self.metrics.counter("serve_n_completed").inc()
                self.metrics.histogram("serve_tokens_per_request").observe(
                    len(req.generated))
        return sum(s is not None for s in self._slots)

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while (self.queue or any(s is not None for s in self._slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.done

    def stats(self) -> Dict[str, float]:
        """Serving counters through the typed registry
        (``serve_*`` names in the :mod:`repro.obs.metrics` schema)."""
        return self.metrics.as_stats()

"""repro.resilience — deterministic fault injection + recovery primitives.

See ``docs/resilience.md`` for the fault model, the recovery line in the
distributed reduction, and the serving degradation contract."""
from .faults import (  # noqa: F401
    SITES,
    CheckpointCorruption,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    TransientFault,
    WireCorruption,
    active_injector,
    backoff_delays,
    corrupt_payload,
    flip_bit,
    inject,
    retry_with_backoff,
)

__all__ = [
    "SITES",
    "CheckpointCorruption",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "TransientFault",
    "WireCorruption",
    "active_injector",
    "backoff_delays",
    "corrupt_payload",
    "flip_bit",
    "inject",
    "retry_with_backoff",
]

"""Deterministic fault injection for the PH pipeline.

Long-running distributed reductions meet shard loss, dropped or corrupt
pivot-exchange payloads, straggling hosts, and bit-rotted checkpoints as
routine events.  This module gives every recovery path in the repo a
*seeded, fully deterministic* adversary so that exactness under failure
("diagrams bit-identical to the fault-free run") is a CI-gated property
rather than a hope.

The model is a :class:`FaultPlan` — an ordered list of :class:`FaultSpec`
records, each naming an *injection point* (a ``site``), a fault ``kind``,
and a deterministic trigger (occurrence index at that site, optionally a
shard id).  A :class:`FaultInjector` is armed over a region of code with
the :func:`inject` context manager (same active-object pattern as
``repro.analyze.invariants.active_sanitizer``); instrumented sites call
:func:`active_injector` and, when an injector is live, ``fire(site, ...)``
with their local context.  With no injector armed the cost is one ``None``
check per site.

Injection points threaded through the pipeline:

===================  =========================================================
site                 instrumented where / supported kinds
===================  =========================================================
``harvest.tile``     ``scale/tiles.py`` per-tile edge harvest —
                     ``fail_tile`` (transient, retried)
``reduce.superstep`` ``core/packed_reduce.py`` superstep loop —
                     ``kill_shard`` (``when="start"|"mid"``), ``slow_shard``
``exchange.wire``    the pivot-exchange transport — ``drop``, ``corrupt``,
                     ``delay`` (per payload delivery attempt)
``resume.load``      ``ReductionCheckpoint.load`` — ``bitflip``, ``truncate``
``serve.step``       ``serve/ph.py`` engine step — ``fail_reduce``,
                     ``overload``
===================  =========================================================

Every random choice (which bit to flip, jitter in a backoff schedule)
derives from ``np.random.default_rng(seed)`` so an identical plan replays
an identical failure history; :meth:`FaultPlan.random` fuzzes plans that
are themselves reproducible from their seed.
"""
from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

SITES: Tuple[str, ...] = (
    "harvest.tile",
    "reduce.superstep",
    "exchange.wire",
    "resume.load",
    "serve.step",
)

# kinds legal per site (validated at FaultSpec construction so a typo'd
# plan fails loudly instead of silently never firing)
_KINDS: Dict[str, Tuple[str, ...]] = {
    "harvest.tile": ("fail_tile",),
    "reduce.superstep": ("kill_shard", "slow_shard"),
    "exchange.wire": ("drop", "corrupt", "delay"),
    "resume.load": ("bitflip", "truncate"),
    "serve.step": ("fail_reduce", "overload"),
}


class InjectedFault(RuntimeError):
    """Base class for errors raised by an armed :class:`FaultInjector`."""


class TransientFault(InjectedFault):
    """A retryable failure (lost tile computation, flaky cold reduction).

    Recovery paths catch exactly this (never bare ``except``) and retry
    under :func:`retry_with_backoff`; anything else propagates."""


class WireCorruption(ValueError):
    """A pivot-exchange payload failed checksum/shape validation.

    Subclasses ``ValueError`` so pre-existing callers that guarded decode
    with ``except ValueError`` keep working."""


class CheckpointCorruption(ValueError):
    """A checkpoint failed its integrity check (hash, version, truncation).

    Raised by ``ReductionCheckpoint.load`` and
    ``checkpoint.Checkpointer.restore`` — callers fall back to an older
    step or a cold reduction, never to silently wrong state."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: fire ``kind`` at ``site`` when the site's
    occurrence counter hits ``at`` (and the shard matches, if given).

    ``times`` consecutive matching occurrences are affected — e.g. a
    ``drop`` with ``times=2`` kills the first two delivery attempts of a
    payload and lets the third through, exercising bounded retry.
    ``params`` carries kind-specific knobs (``when`` for ``kill_shard``,
    ``lag``/``duration`` for ``slow_shard``, ``bit`` for ``corrupt`` /
    ``bitflip``) as a hashable tuple of pairs."""

    site: str
    kind: str
    at: Optional[int] = None
    shard: Optional[int] = None
    times: int = 1
    params: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self) -> None:
        if self.site not in SITES:
            raise ValueError(f"unknown injection site {self.site!r}; "
                             f"sites: {SITES}")
        if self.kind not in _KINDS[self.site]:
            raise ValueError(f"kind {self.kind!r} not legal at {self.site!r}; "
                             f"legal: {_KINDS[self.site]}")
        if self.times < 1:
            raise ValueError("times must be >= 1")

    def param(self, key: str, default: Any = None) -> Any:
        return dict(self.params).get(key, default)

    def matches(self, site: str, index: Optional[int],
                shard: Optional[int]) -> bool:
        if site != self.site:
            return False
        if self.at is not None and index != self.at:
            return False
        if self.shard is not None and shard != self.shard:
            return False
        return True


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, ordered fault schedule.  Frozen + hashable so two plans
    built from the same seed compare equal (asserted by the determinism
    fuzz in ``tests/test_resilience.py``)."""

    specs: Tuple[FaultSpec, ...] = ()
    seed: int = 0

    @classmethod
    def of(cls, *specs: FaultSpec, seed: int = 0) -> "FaultPlan":
        return cls(specs=tuple(specs), seed=seed)

    @classmethod
    def random(cls, seed: int, n_faults: int = 4,
               sites: Sequence[str] = SITES,
               max_index: int = 8, max_shard: int = 4) -> "FaultPlan":
        """A reproducible random plan: same ``seed`` -> identical specs."""
        rng = np.random.default_rng(seed)
        specs = []
        sites = tuple(sites)
        for _ in range(int(n_faults)):
            site = sites[int(rng.integers(len(sites)))]
            kinds = _KINDS[site]
            kind = kinds[int(rng.integers(len(kinds)))]
            params: Tuple[Tuple[str, Any], ...] = ()
            if kind == "kill_shard":
                params = (("when", ("start", "mid")[int(rng.integers(2))]),)
            elif kind == "slow_shard":
                params = (("lag", float(rng.integers(1, 4))),
                          ("duration", int(rng.integers(1, 3))))
            elif kind in ("corrupt", "bitflip"):
                params = (("bit", int(rng.integers(0, 256))),)
            elif kind == "delay":
                params = (("delay_s", float(rng.uniform(1e-4, 1e-2))),)
            shard = (int(rng.integers(max_shard))
                     if site in ("reduce.superstep", "exchange.wire") else None)
            specs.append(FaultSpec(
                site=site, kind=kind, at=int(rng.integers(1, max_index + 1)),
                shard=shard, times=int(rng.integers(1, 3)), params=params))
        return cls(specs=tuple(specs), seed=seed)


class FaultInjector:
    """Replays a :class:`FaultPlan` against instrumented sites.

    Each call to :meth:`fire` advances nothing by itself — the *caller*
    supplies the occurrence index (superstep number, exchange round,
    tile ordinal, engine step), so firing is a pure function of pipeline
    progress and the plan, never of wall-clock time.  Per-spec remaining
    ``times`` budgets and a structured ``fired`` log are the only state."""

    def __init__(self, plan: FaultPlan) -> None:
        self.plan = plan
        self._remaining: List[int] = [s.times for s in plan.specs]
        self.fired: List[Dict[str, Any]] = []
        self.rng = np.random.default_rng(plan.seed)

    def fire(self, site: str, index: Optional[int] = None,
             shard: Optional[int] = None,
             kinds: Optional[Tuple[str, ...]] = None,
             **ctx: Any) -> List[FaultSpec]:
        """Return the specs triggering at this site occurrence (may be
        empty), consuming one unit of each spec's ``times`` budget.

        ``kinds`` restricts which fault kinds this call site can consume —
        two instrumented sites sharing one injection point (e.g. the serve
        step loop handles ``overload``, its cold-reduction attempt handles
        ``fail_reduce``) each fire with their own filter so neither burns
        the other's budget."""
        hits: List[FaultSpec] = []
        for i, spec in enumerate(self.plan.specs):
            if kinds is not None and spec.kind not in kinds:
                continue
            if self._remaining[i] > 0 and spec.matches(site, index, shard):
                self._remaining[i] -= 1
                hits.append(spec)
                self.fired.append({"site": site, "kind": spec.kind,
                                   "index": index, "shard": shard, **ctx})
        return hits

    def n_fired(self, site: Optional[str] = None,
                kind: Optional[str] = None) -> int:
        return sum(1 for f in self.fired
                   if (site is None or f["site"] == site)
                   and (kind is None or f["kind"] == kind))

    def exhausted(self) -> bool:
        """True once every spec has spent its full ``times`` budget."""
        return all(r == 0 for r in self._remaining)


_ACTIVE: Optional[FaultInjector] = None


def active_injector() -> Optional[FaultInjector]:
    """The injector armed by the innermost :func:`inject`, or ``None``."""
    return _ACTIVE


@contextmanager
def inject(plan: Optional[FaultPlan]) -> Iterator[Optional[FaultInjector]]:
    """Arm a fault plan for the duration of the block::

        with inject(FaultPlan.of(FaultSpec("reduce.superstep",
                                           "kill_shard", at=2, shard=1))) as inj:
            res = compute_ph(points, engine="packed", n_shards=4)

    ``inject(None)`` is a no-op (yields ``None``) so callers can thread an
    optional plan without branching."""
    global _ACTIVE
    if plan is None:
        yield None
        return
    previous = _ACTIVE
    _ACTIVE = FaultInjector(plan)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = previous


# ---------------------------------------------------------------------------
# deterministic backoff + retry — the blessed alternative the
# ``retry-without-backoff`` lint rule points offenders at
# ---------------------------------------------------------------------------

def backoff_delays(attempts: int, base_s: float = 1e-3, factor: float = 2.0,
                   jitter: float = 0.5, seed: int = 0) -> np.ndarray:
    """Exponential backoff schedule with deterministic jitter.

    ``delay[a] = base_s * factor**a * (1 + jitter * u_a)`` with ``u_a``
    drawn from ``default_rng(seed)`` — two calls with the same arguments
    return bit-identical schedules, so a retried recovery replays exactly."""
    if attempts <= 0:
        return np.zeros(0, dtype=np.float64)
    rng = np.random.default_rng(seed)
    u = rng.random(attempts)
    return base_s * factor ** np.arange(attempts) * (1.0 + jitter * u)


def retry_with_backoff(fn: Callable[[int], Any], attempts: int = 3,
                       base_s: float = 1e-3, factor: float = 2.0,
                       jitter: float = 0.5, seed: int = 0,
                       retry_on: Tuple[type, ...] = (TransientFault,),
                       sleep: Optional[Callable[[float], None]] = time.sleep,
                       on_retry: Optional[Callable[[int, BaseException, float],
                                                   None]] = None) -> Any:
    """Call ``fn(attempt)`` up to ``attempts`` times, sleeping the
    deterministic :func:`backoff_delays` schedule between failures.

    Only exceptions in ``retry_on`` are retried; the last one re-raises
    once the budget is spent.  ``sleep=None`` accounts the schedule
    without blocking (host-simulated transports); ``on_retry`` observes
    ``(attempt, error, scheduled_delay_s)`` for metrics."""
    if attempts < 1:
        raise ValueError("attempts must be >= 1")
    delays = backoff_delays(attempts - 1, base_s=base_s, factor=factor,
                            jitter=jitter, seed=seed)
    for a in range(attempts):
        try:
            return fn(a)
        except retry_on as e:  # noqa: PERF203 - retry loop by design
            if a == attempts - 1:
                raise
            delay = float(delays[a])
            if on_retry is not None:
                on_retry(a, e, delay)
            if sleep is not None and delay > 0.0:
                sleep(delay)
    raise AssertionError("unreachable")


# ---------------------------------------------------------------------------
# deterministic corruption helpers
# ---------------------------------------------------------------------------

def flip_bit(buf: bytes, bit: int) -> bytes:
    """Return ``buf`` with one bit flipped (``bit`` taken mod the length)."""
    if len(buf) == 0:
        return buf
    bit = int(bit) % (len(buf) * 8)
    out = bytearray(buf)
    out[bit // 8] ^= 1 << (bit % 8)
    return bytes(out)


def corrupt_payload(payload: np.ndarray, bit: int) -> np.ndarray:
    """Bit-flip a wire payload (uint32 words) deterministically."""
    raw = flip_bit(np.ascontiguousarray(payload, dtype=np.uint32).tobytes(),
                   bit)
    return np.frombuffer(raw, dtype=np.uint32).copy()

"""repro: Dory-JAX — persistent homology at scale + multi-pod LM framework."""
__version__ = "1.0.0"

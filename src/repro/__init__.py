"""repro: Dory-JAX — persistent homology at scale + multi-pod LM framework."""
__version__ = "1.0.0"

import jax as _jax

if not hasattr(_jax, "shard_map"):
    # jax < 0.5 ships shard_map under jax.experimental with the old
    # ``check_rep`` kwarg; call sites in this repo use the stable
    # ``jax.shard_map(..., check_vma=...)`` API, so bridge it here.
    from jax.experimental.shard_map import shard_map as _shard_map

    def _shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=None,
                          check_rep=None, **kwargs):
        if check_rep is None:
            check_rep = True if check_vma is None else check_vma
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_rep, **kwargs)

    _jax.shard_map = _shard_map_compat

# Old jax (< ~0.5) Compiled.cost_analysis() returned a one-element list per
# executable; newer jax returns the dict directly and call sites in this
# repo (launch/dryrun and the test contract, which calls the method on the
# Compiled object itself) index it as a dict.  The unwrap is idempotent —
# on a dict-returning jax it never fires — and guarded so a jax refactor
# that moves the class degrades to a no-op instead of an import error.
try:
    from jax._src import stages as _stages

    if not getattr(_stages.Compiled.cost_analysis, "_repro_compat", False):
        _orig_cost_analysis = _stages.Compiled.cost_analysis

        def _cost_analysis_compat(self):
            out = _orig_cost_analysis(self)
            if isinstance(out, list) and len(out) == 1:
                return out[0]
            return out

        _cost_analysis_compat._repro_compat = True
        _stages.Compiled.cost_analysis = _cost_analysis_compat
except (ImportError, AttributeError):
    pass

del _jax

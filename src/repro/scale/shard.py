"""Sharded tiled filtration harvest over the ``data`` mesh axis.

``tiles.py`` streams the ``(tile_m, tile_n)`` block grid serially, so wall
time on million-point clouds is bounded by one device even when the paper's
``(3n + 12 n_e) * 4``-byte account already fits.  This module partitions the
upper-triangular tile grid **round-robin** across the ``data`` mesh axis and
harvests all shards' tiles concurrently — the distributed-reduction route
past the single-device wall (cf. DIPHA's spectral-sequence distribution,
arXiv:1310.0710).

Execution model (one *round* = one tile per device):

* the FLOP-dominant f32 candidate tiles are computed **on device** under
  ``jax.shard_map``: point blocks for the round are stacked on a leading
  axis sharded over ``data`` (specs from ``repro.dist.sharding.tile_specs``)
  and each device runs the Pallas ``pairwise_sq_dists`` kernel on its own
  block pair — no cross-device communication inside a round;
* the round's stacked f32 output is gathered back to the host (this is the
  ``gather_bytes`` transient in :class:`~repro.scale.tiles.TileStats`),
  where each tile's candidates get the exact f64 re-measure
  (``pair_sq_dists``) and become a per-shard COO fragment — COO fragments
  are variably sized, which is exactly what cannot live under ``jit``;
* fragments from all shards merge through the single canonical
  ``(length, i, j)`` lexsort (``merge_edge_chunks``).

The ``numpy`` backend shards the same tile partition on the host (no mesh
required — ``n_shards`` alone reproduces any device count's work split),
which is what the bit-identity tests sweep.

**Bit-identity is structural, not numeric luck**: every unordered pair
(i < j) lives in exactly one tile, every tile in exactly one shard, each
tile's exact lengths come from the same fixed-order f64 kernels as the
serial and dense paths, and the final lexsort is a total order — so the
sorted edge list (and hence the whole :class:`Filtration`) is bit-identical
for every device count, including 1 and the serial/dense builders.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from ..core.filtration import filtration_from_edges
from ..obs.trace import span
from .tiles import (DEFAULT_TILE, TileStats, _f32_dists_threshold,
                    _f32_threshold, _refine_f32_dists_tile, _refine_f32_tile,
                    _resolve_backend, iter_tile_edges, merge_edge_chunks,
                    tile_grid)

__all__ = ["build_filtration_sharded", "harvest_edges_sharded",
           "partition_tiles", "shard_of_mesh"]


def partition_tiles(n: int, tile_m: int, tile_n: int,
                    n_shards: int) -> List[List[Tuple[int, int]]]:
    """Round-robin partition of the upper-triangular tile grid.

    Tile ``t`` (row-major :func:`~repro.scale.tiles.tile_grid` order) goes to
    shard ``t % n_shards``; consecutive grid tiles land on different shards,
    which balances the diagonal tiles (cheaper: half masked out) across
    devices instead of clustering them on one.  Every tile appears in
    exactly one shard — the disjoint-cover invariant the bit-identity
    guarantee rests on.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    tiles = tile_grid(n, tile_m, tile_n)
    return [tiles[k::n_shards] for k in range(n_shards)]


def shard_of_mesh(mesh) -> Tuple[str, int]:
    """(axis name, size) of the mesh axis tiles shard over (the data axis)."""
    from ..dist.sharding import tile_specs

    _, _, axis = tile_specs(mesh)
    return axis, int(mesh.shape[axis])


def _harvest_shards_host(points, dists, shards, tau_max, tile_m, tile_n,
                         backend, interpret, stats, chunks):
    """Host-partitioned harvest: each shard's tile list replayed through
    the serial :func:`iter_tile_edges` dispatch (exact-f64 numpy, or the
    pallas f32-candidate/f64-refine path when that backend was requested
    without a mesh) — one per-tile implementation, so the serial-vs-sharded
    bit-identity contract cannot drift.  Fragment bytes tracked per shard.
    """
    ii, jj, ll = chunks
    for k, shard in enumerate(shards):
        shard_bytes = 0
        # the host simulation replays shards back-to-back; lane attribution
        # renders them as the parallel device tracks a mesh would run
        with span("harvest/shard", lane=k, n_tiles=len(shard)):
            for iu, ju, lens in iter_tile_edges(points=points, dists=dists,
                                                tau_max=tau_max,
                                                tile_m=tile_m, tile_n=tile_n,
                                                backend=backend,
                                                interpret=interpret,
                                                stats=stats, tiles=shard):
                ii.append(iu.astype(np.int64))
                jj.append(ju.astype(np.int64))
                ll.append(lens)
                shard_bytes += ii[-1].nbytes + jj[-1].nbytes + ll[-1].nbytes
        if stats is not None:
            stats.shard_peak_harvest_bytes = max(
                stats.shard_peak_harvest_bytes, shard_bytes)


def _candidate_round_fn(x, y, interpret=None):
    """Per-device body of one points-harvest round: ``(1, tile_m, d)`` x
    ``(1, tile_n, d)`` blocks -> the ``(1, tm, tn)`` f32 candidate tile.
    No collectives by design — each device's tile is independent; kept at
    module level (closed only over static config) so
    ``repro.analyze.collectives`` can trace and pin that schedule."""
    from ..kernels.pairwise_dist import pairwise_sq_dists

    return pairwise_sq_dists(x[0], y[0], interpret=interpret)[None]


def _dists_round_fn(t, thr32):
    """Per-device body of one dists-harvest round: threshold the device's
    own f32 tile; only the 1-byte candidate mask gathers back.  Module
    level for the same static-traceability reason as
    :func:`_candidate_round_fn`."""
    return (t[0] <= thr32)[None]


def _harvest_shards_device(points, sq, shards, tau_max, tile_m, tile_n,
                           mesh, interpret, stats, chunks):
    """Device rounds under ``shard_map``: one f32 candidate tile per device
    per round, exact f64 refine + COO extraction on the host."""
    import functools

    import jax
    import jax.numpy as jnp

    from ..dist.sharding import tile_specs

    n, d = points.shape
    n_shards = len(shards)
    thr32 = _f32_threshold(points, sq, tau_max)
    pts32 = np.asarray(points, dtype=np.float32)
    in_specs, out_specs, _ = tile_specs(mesh)

    sharded = jax.shard_map(
        functools.partial(_candidate_round_fn, interpret=interpret),
        mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)

    ii, jj, ll = chunks
    shard_bytes = [0] * n_shards
    xs = np.zeros((n_shards, tile_m, d), dtype=np.float32)
    ys = np.zeros((n_shards, tile_n, d), dtype=np.float32)
    n_rounds = max(len(s) for s in shards)
    for r in range(n_rounds):
        live = []
        xs[:] = 0.0
        ys[:] = 0.0
        for k, shard in enumerate(shards):
            if r >= len(shard):
                continue            # exhausted shard recomputes a zero block
            si, sj = shard[r]
            ei, ej = min(si + tile_m, n), min(sj + tile_n, n)
            xs[k, :ei - si] = pts32[si:ei]
            ys[k, :ej - sj] = pts32[sj:ej]
            live.append((k, si, ei, sj, ej))
        with span("harvest/round", round=r, n_live=len(live)):
            # analyze: allow[host-sync] one round gather per tile wave is the harvest schedule (gather_bytes transient)
            d2 = np.asarray(sharded(jnp.asarray(xs), jnp.asarray(ys)))
        if stats is not None:
            stats.gather_bytes = max(stats.gather_bytes,
                                     d2.nbytes + xs.nbytes + ys.nbytes)
        for k, si, ei, sj, ej in live:
            if stats is not None:
                stats.tiles_visited += 1
            with span("harvest/refine", lane=k, round=r, tile=f"{si},{sj}"):
                # crop to the real extent first: zero-padded rows fabricate
                # origin distances that must never reach the threshold test
                iu, ju, lens = _refine_f32_tile(
                    d2[k, :ei - si, :ej - sj], points, sq, si, ei, sj, ej,
                    tau_max, thr32, stats)
            ii.append(iu.astype(np.int64))
            jj.append(ju.astype(np.int64))
            ll.append(lens)
            shard_bytes[k] += ii[-1].nbytes + jj[-1].nbytes + ll[-1].nbytes
    if stats is not None:
        stats.shard_peak_harvest_bytes = max(stats.shard_peak_harvest_bytes,
                                             max(shard_bytes, default=0))


def _harvest_shards_device_dists(dists, shards, tau_max, tile_m, tile_n,
                                 mesh, stats, chunks):
    """Device rounds for a precomputed distance matrix: each device
    thresholds its own f32 tile under ``shard_map`` (the gathered per-round
    transient is the 1-byte candidate mask, a quarter of the f32 tile), and
    the host re-measures candidates straight from the exact f64 matrix."""
    import functools

    import jax
    import jax.numpy as jnp

    from ..dist.sharding import tile_specs

    n = dists.shape[0]
    n_shards = len(shards)
    thr32 = _f32_dists_threshold(tau_max)
    _, spec, _ = tile_specs(mesh)

    sharded = jax.shard_map(
        functools.partial(_dists_round_fn, thr32=thr32),
        mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False)

    ii, jj, ll = chunks
    shard_bytes = [0] * n_shards
    buf = np.zeros((n_shards, tile_m, tile_n), dtype=np.float32)
    n_rounds = max(len(s) for s in shards)
    for r in range(n_rounds):
        live = []
        buf[:] = np.inf   # exhausted-shard padding must never pass thr32
        for k, shard in enumerate(shards):
            if r >= len(shard):
                continue
            si, sj = shard[r]
            ei, ej = min(si + tile_m, n), min(sj + tile_n, n)
            buf[k, :ei - si, :ej - sj] = dists[si:ei, sj:ej]
            live.append((k, si, ei, sj, ej))
        with span("harvest/round", round=r, n_live=len(live)):
            # analyze: allow[host-sync] the per-round candidate-mask gather is the schedule; the f64 re-measure needs it on host
            cand = np.asarray(sharded(jnp.asarray(buf)))
        if stats is not None:
            stats.gather_bytes = max(stats.gather_bytes,
                                     cand.nbytes + buf.nbytes)
        for k, si, ei, sj, ej in live:
            if stats is not None:
                stats.tiles_visited += 1
            with span("harvest/refine", lane=k, round=r, tile=f"{si},{sj}"):
                # crop to the real extent first: the inf padding is masked
                # out by construction, the crop keeps the index math honest
                iu, ju, lens = _refine_f32_dists_tile(
                    cand[k, :ei - si, :ej - sj], dists, si, ei, sj, ej,
                    tau_max, stats)
            ii.append(iu.astype(np.int64))
            jj.append(ju.astype(np.int64))
            ll.append(lens)
            shard_bytes[k] += ii[-1].nbytes + jj[-1].nbytes + ll[-1].nbytes
    if stats is not None:
        stats.shard_peak_harvest_bytes = max(stats.shard_peak_harvest_bytes,
                                             max(shard_bytes, default=0))


def harvest_edges_sharded(
    points: Optional[np.ndarray] = None,
    dists: Optional[np.ndarray] = None,
    tau_max: float = np.inf,
    tile_m: int = DEFAULT_TILE,
    tile_n: int = DEFAULT_TILE,
    mesh=None,
    n_shards: Optional[int] = None,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    stats: Optional[TileStats] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sharded harvest: all permissible edges as one canonical sorted list.

    Bit-identical to :func:`~repro.scale.tiles.harvest_edges` (and the dense
    upper triangle) for every shard/device count.  Exactly one of ``mesh``
    (its data axis fixes the shard count, and the ``pallas`` backend runs
    rounds under ``shard_map``) or ``n_shards`` (host-partitioned execution,
    no devices needed) is typically given; both default to 1 shard.

    A ``dists`` matrix rides the device rounds too when a mesh is given:
    each device thresholds its own f32 tile of the matrix and only the
    candidate mask gathers back (``_harvest_shards_device_dists``), with
    the exact f64 re-measure read straight from the matrix on the host.
    Without a mesh — or with the ``numpy`` backend — ``dists`` harvests on
    the host, reproducing the multi-device *work split* (and its
    per-device :class:`TileStats` accounting) without device transfers.
    """
    if (points is None) == (dists is None):
        raise ValueError("provide exactly one of points or dists")
    if mesh is not None:
        axis, mesh_shards = shard_of_mesh(mesh)
        if n_shards is not None and int(n_shards) != mesh_shards:
            raise ValueError(
                f"n_shards={n_shards} disagrees with the mesh's "
                f"{axis}-axis size {mesh_shards}; pass only one of them")
        n_shards = mesh_shards
        if stats is not None:
            stats.mesh_axis = axis
    n_shards = 1 if n_shards is None else int(n_shards)
    if mesh is not None and backend in ("auto", "pallas"):
        # a mesh asks for device execution: "auto" means the shard_map path
        # (interpret-mode pallas off-TPU), not the host split the serial
        # resolver would pick on CPU — for points and dists inputs alike
        backend = "pallas"
    elif points is not None:
        backend = _resolve_backend(backend)
    else:
        backend = "numpy"

    if dists is not None:
        dists = np.asarray(dists)
        n = dists.shape[0]
        if dists.shape != (n, n):
            raise ValueError(f"dists must be square, got {dists.shape}")
        points = sq = None
    else:
        points = np.asarray(points, dtype=np.float64)
        n = points.shape[0]
        sq = np.sum(points * points, axis=1)

    if stats is not None:
        stats.n = n
        stats.tile_m, stats.tile_n = tile_m, tile_n
        stats.backend = backend
        stats.n_shards = n_shards

    shards = partition_tiles(n, tile_m, tile_n, n_shards)
    chunks: Tuple[list, list, list] = ([], [], [])
    if backend == "pallas" and mesh is not None and points is not None:
        _harvest_shards_device(points, sq, shards, tau_max, tile_m, tile_n,
                               mesh, interpret, stats, chunks)
    elif backend == "pallas" and mesh is not None and dists is not None:
        _harvest_shards_device_dists(dists, shards, tau_max, tile_m, tile_n,
                                     mesh, stats, chunks)
    else:
        _harvest_shards_host(points, dists, shards, tau_max,
                             tile_m, tile_n, backend, interpret, stats,
                             chunks)
    return merge_edge_chunks(*chunks, stats=stats)


def build_filtration_sharded(
    points: Optional[np.ndarray] = None,
    dists: Optional[np.ndarray] = None,
    tau_max: float = np.inf,
    tile_m: int = DEFAULT_TILE,
    tile_n: int = DEFAULT_TILE,
    mesh=None,
    n_shards: Optional[int] = None,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    with_dense_order: bool = False,
    return_stats: bool = False,
):
    """Mesh-sharded streamed :class:`Filtration` build.

    The multi-device form of
    :func:`~repro.scale.tiles.build_filtration_tiled`: output is
    bit-identical to it (and to dense ``build_filtration``) for every device
    count; wall time scales with the data-axis size; per-device peak memory
    is one tile + the round gather + this device's fragment share — see
    :meth:`TileStats.per_device_peak_bytes` and
    ``scale.budget.tile_transient_bytes``.

    Returns ``filt`` or ``(filt, TileStats)`` with ``return_stats``.
    """
    stats = TileStats()
    iu, ju, lens = harvest_edges_sharded(
        points=points, dists=dists, tau_max=tau_max, tile_m=tile_m,
        tile_n=tile_n, mesh=mesh, n_shards=n_shards, backend=backend,
        interpret=interpret, stats=stats)
    filt = filtration_from_edges(stats.n, iu, ju, lens, tau_max,
                                 presorted=True,
                                 with_dense_order=with_dense_order)
    stats.base_memory_bytes = filt.base_memory_bytes()
    if return_stats:
        return filt, stats
    return filt

"""Streaming tiled filtration construction (million-point path, paper §5-6).

``build_filtration`` materializes a dense ``(n, n)`` float64 distance matrix,
which hard-caps the repo at a few thousand points — the exact barrier the
paper removes.  This module constructs the *same* sparse :class:`Filtration`
without ever holding an ``O(n^2)`` array:

* the distance matrix is computed tile-by-tile over ``(tile_m, tile_n)``
  blocks (numpy host path, or the Pallas ``pairwise_sq_dists`` TPU kernel);
* each tile is thresholded against ``tau_max`` in place and the surviving
  ``(i, j, length)`` triplets are harvested as COO chunks;
* chunks are merged into the globally sorted canonical edge list
  (``(length, i, j)`` lexicographic) and handed to
  ``filtration_from_edges`` — total extra memory is one tile plus
  ``O(n + n_e)``, never ``O(n^2)``.

Bit-identity with the dense path is guaranteed, not hoped for: both paths
compute distances with the fixed-order ``cross_term`` / ``block_sq_dists``
kernels from ``core.filtration`` (BLAS matmul changes accumulation order with
operand shape, so it could not provide this invariant).  The Pallas backend
computes tiles in float32 as a *candidate filter* only — candidates within a
conservative error margin of ``tau_max`` are re-measured exactly in float64
(``pair_sq_dists``) on the sparse candidate set, so its output is also
bit-identical to the dense build.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from ..obs.trace import span
from ..core.filtration import (Filtration, block_sq_dists,
                               filtration_from_edges, pair_sq_dists)
from ..resilience.faults import (TransientFault, active_injector,
                                 retry_with_backoff)

DEFAULT_TILE = 2048


@dataclasses.dataclass
class TileStats:
    """Accounting for one streamed build (benchmarks assert against this).

    For sharded builds (``repro.scale.shard``) the per-tile fields describe
    *one device*: ``peak_tile_bytes`` is the largest tile resident on any
    single device, ``gather_bytes`` the stacked per-round transfer transient,
    and ``shard_peak_harvest_bytes`` the largest per-device COO fragment set
    held before the host merge.  ``n_shards == 1`` for serial builds.
    """

    n: int = 0
    n_e: int = 0
    tile_m: int = 0
    tile_n: int = 0
    backend: str = "numpy"
    tiles_visited: int = 0
    candidate_pairs: int = 0      # pallas path: f32 candidates refined in f64
    peak_tile_bytes: int = 0      # largest per-tile scratch (per device)
    harvest_bytes: int = 0        # final sorted COO triplet arrays
    merge_peak_bytes: int = 0     # worst transient during concat + lexsort
    base_memory_bytes: int = 0    # paper (3n + 12 n_e) * 4 for the result
    n_shards: int = 1             # devices/shards the tile grid was split over
    mesh_axis: str = ""           # mesh axis name for device-sharded builds
    gather_bytes: int = 0         # sharded: stacked f32 round in/out transient
    shard_peak_harvest_bytes: int = 0   # largest per-shard fragment set
    tile_retries: int = 0         # injected/transient tile failures retried

    def peak_extra_bytes(self) -> int:
        """Peak transient memory of the build: one tile + the merge worst case
        (chunks + concat copy, then sort index + permuted copies)."""
        return self.peak_tile_bytes + max(self.merge_peak_bytes,
                                          self.harvest_bytes)

    def per_device_base_bytes(self) -> int:
        """Per-device share of the paper's ``(3n + 12 n_e) * 4`` account.

        The ``3n`` vertex arrays are duplicated on every device; the
        ``12 n_e`` edge arrays split ~evenly across shards (ceiling share).
        """
        shards = max(1, self.n_shards)
        ne_share = -(-self.n_e // shards)
        return (3 * self.n + 12 * ne_share) * 4

    def per_device_peak_bytes(self) -> int:
        """Peak per-device transient of a sharded harvest: the resident tile
        scratch plus the round gather stack plus this device's un-merged COO
        fragments.  ``scale.budget.tile_transient_bytes`` a-priori bounds
        the first two terms only (``peak_tile_bytes + gather_bytes``); the
        fragment term rides the edge share of the
        :meth:`per_device_base_bytes` account instead."""
        return (self.peak_tile_bytes + self.gather_bytes
                + self.shard_peak_harvest_bytes)


def _resolve_backend(backend: str) -> str:
    if backend == "auto":
        try:
            import jax
            return "pallas" if jax.default_backend() == "tpu" else "numpy"
        except ImportError:
            return "numpy"
    if backend not in ("numpy", "pallas"):
        raise ValueError(f"unknown tile backend {backend!r}")
    return backend


def _f32_margin(sq_max: float, d: int) -> float:
    """Upper bound on |d2_f32 - d2_f64| for the Pallas candidate filter.

    Input rounding to f32 plus the f32 Gram accumulation each contribute
    O(eps32) per term; 8 * (d + 4) terms is a deliberately loose constant —
    a too-wide margin only means a few extra candidates get the exact f64
    re-measure, never a missed edge.
    """
    eps32 = float(np.finfo(np.float32).eps)
    return 8.0 * (d + 4) * eps32 * max(sq_max, 1.0) * 4.0


def tile_grid(n: int, tile_m: int, tile_n: int) -> list:
    """Row-major list of upper-triangular tile origins ``(si, sj)``.

    A tile is listed iff it intersects the strict upper triangle
    (``si < min(sj + tile_n, n) - 1``); every unordered pair (i < j) lives in
    exactly one listed tile — the one indexed by ``(i // tile_m,
    j // tile_n)`` — so per-tile harvests are disjoint and their union is
    exactly the dense path's thresholded upper triangle.
    """
    return [(si, sj)
            for si in range(0, n, tile_m)
            for sj in range(0, n, tile_n)
            if si < min(sj + tile_n, n) - 1]


def _upper_mask(si: int, ei: int, sj: int, ej: int) -> Optional[np.ndarray]:
    """i<j mask for a diagonal-crossing tile; None when fully above (the
    vast majority for large n, which then needs no mask at all)."""
    if ei - 1 < sj:
        return None
    return np.arange(si, ei)[:, None] < np.arange(sj, ej)[None, :]


def _harvest_masked_tile(lens_tile: np.ndarray, si: int, sj: int,
                         tau_max: float, upper: Optional[np.ndarray],
                         stats: Optional[TileStats]
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Threshold one exact-f64 length tile and emit its COO chunk."""
    mask = lens_tile <= tau_max
    if upper is not None:
        mask &= upper
    if stats is not None:
        stats.peak_tile_bytes = max(
            stats.peak_tile_bytes, lens_tile.nbytes + mask.nbytes
            + (0 if upper is None else upper.nbytes))
    ri, rj = np.nonzero(mask)
    return si + ri, sj + rj, lens_tile[ri, rj]


def _harvest_points_tile(points: np.ndarray, sq: np.ndarray,
                         si: int, ei: int, sj: int, ej: int, tau_max: float,
                         stats: Optional[TileStats]
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """numpy host path: exact f64 tile via the fixed-order kernels."""
    d2 = block_sq_dists(points[si:ei], points[sj:ej], sq[si:ei], sq[sj:ej])
    lens_tile = np.sqrt(d2, out=d2)
    return _harvest_masked_tile(lens_tile, si, sj, tau_max,
                                _upper_mask(si, ei, sj, ej), stats)


def _refine_f32_tile(d2_32: np.ndarray, points: np.ndarray, sq: np.ndarray,
                     si: int, ei: int, sj: int, ej: int,
                     tau_max: float, thr32: np.float32,
                     stats: Optional[TileStats]
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """f32 candidate filter + exact f64 re-measure for one tile.

    ``d2_32`` is the tile's f32 squared distances (already cropped to the
    real ``(ei - si, ej - sj)`` extent).  Candidates within the conservative
    ``thr32`` margin are re-measured in f64 on the sparse candidate set, so
    the output is bit-identical to the numpy tile regardless of which device
    (or how many devices) produced ``d2_32``.
    """
    upper = _upper_mask(si, ei, sj, ej)
    cand = d2_32 <= thr32
    if upper is not None:
        cand &= upper
    if stats is not None:
        stats.peak_tile_bytes = max(
            stats.peak_tile_bytes, d2_32.nbytes + cand.nbytes
            + (0 if upper is None else upper.nbytes))
    ri, rj = np.nonzero(cand)
    iu, ju = si + ri, sj + rj
    lens = np.sqrt(pair_sq_dists(points, iu, ju, sq))
    if stats is not None:
        stats.candidate_pairs += int(iu.size)
    keep = lens <= tau_max
    return iu[keep], ju[keep], lens[keep]


def _f32_threshold(points: np.ndarray, sq: np.ndarray,
                   tau_max: float) -> np.float32:
    """Margin-widened f32 candidate threshold for the whole cloud."""
    n = points.shape[0]
    margin = _f32_margin(float(sq.max()) if n else 1.0, points.shape[1])
    return np.float32(tau_max * tau_max + margin) \
        if np.isfinite(tau_max) else np.float32(np.inf)


def _f32_dists_threshold(tau_max: float) -> np.float32:
    """Conservative f32 candidate threshold for a precomputed *length*
    matrix: casting a length to f32 perturbs it by at most eps32/2
    relative, so a 4-eps margin can only add candidates (each re-measured
    against the exact f64 entry), never drop a true edge."""
    if not np.isfinite(tau_max):
        return np.float32(np.inf)
    eps32 = float(np.finfo(np.float32).eps)
    return np.float32(tau_max + 4.0 * eps32 * max(tau_max, 1.0))


def _refine_f32_dists_tile(cand: np.ndarray, dists: np.ndarray,
                           si: int, ei: int, sj: int, ej: int,
                           tau_max: float, stats: Optional[TileStats]
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Exact f64 re-measure of one device-filtered dists tile.

    ``cand`` is the tile's f32 candidate mask (already cropped to the real
    ``(ei - si, ej - sj)`` extent) computed on device against
    :func:`_f32_dists_threshold`; the exact lengths come straight from the
    f64 matrix, so the output is bit-identical to the host dists tile for
    any device count.
    """
    upper = _upper_mask(si, ei, sj, ej)
    if upper is not None:
        cand = cand & upper
    if stats is not None:
        stats.peak_tile_bytes = max(
            stats.peak_tile_bytes,
            2 * cand.nbytes + (0 if upper is None else upper.nbytes))
    ri, rj = np.nonzero(cand)
    iu, ju = si + ri, sj + rj
    lens = np.asarray(dists[iu, ju], dtype=np.float64)
    if stats is not None:
        stats.candidate_pairs += int(iu.size)
    keep = lens <= tau_max
    return iu[keep], ju[keep], lens[keep]


def iter_tile_edges(
    points: Optional[np.ndarray] = None,
    dists: Optional[np.ndarray] = None,
    tau_max: float = np.inf,
    tile_m: int = DEFAULT_TILE,
    tile_n: int = DEFAULT_TILE,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    stats: Optional[TileStats] = None,
    tiles: Optional[list] = None,
) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Yield COO edge chunks ``(iu, ju, lens)`` per tile, ``i < j`` only.

    Tiles stream serially in :func:`tile_grid` order — or in the explicit
    ``tiles`` list of ``(si, sj)`` origins, which is how ``scale.shard``
    replays one shard's partition through this exact dispatch (keeping the
    serial and sharded per-tile code paths literally the same).  Chunks are
    disjoint and their union over a full grid is exactly the dense path's
    thresholded upper triangle.
    """
    if (points is None) == (dists is None):
        raise ValueError("provide exactly one of points or dists")
    backend = _resolve_backend(backend) if points is not None else "numpy"
    if stats is not None:
        stats.tile_m, stats.tile_n, stats.backend = tile_m, tile_n, backend

    if dists is not None:
        dists = np.asarray(dists)
        n = dists.shape[0]
        if dists.shape != (n, n):
            raise ValueError(f"dists must be square, got {dists.shape}")
    else:
        points = np.asarray(points, dtype=np.float64)
        n = points.shape[0]
        sq = np.sum(points * points, axis=1)
        if backend == "pallas":
            import jax.numpy as jnp

            from ..kernels.pairwise_dist import pairwise_sq_dists
            pts32 = jnp.asarray(points, dtype=jnp.float32)
            thr32 = _f32_threshold(points, sq, tau_max)
    if stats is not None:
        stats.n = n

    if tiles is None:
        tiles = tile_grid(n, tile_m, tile_n)
    inj = active_injector()
    for tile_ord, (si, sj) in enumerate(tiles):
        ei, ej = min(si + tile_m, n), min(sj + tile_n, n)
        if stats is not None:
            stats.tiles_visited += 1

        # the chunk is computed under its span and only then yielded, so
        # consumer work between tiles is never attributed to the harvest
        def compute_tile(attempt: int, tile_ord=tile_ord,
                         si=si, sj=sj, ei=ei, ej=ej):
            # a lost tile computation (preempted device, evicted host) is
            # transient: the tile is a pure function of its origin, so the
            # retry re-harvests identical bits
            if inj is not None and inj.fire("harvest.tile", index=tile_ord,
                                            kinds=("fail_tile",)):
                raise TransientFault(
                    f"injected tile failure at ({si},{sj})")
            if dists is not None:
                with span("harvest/tile", tile=f"{si},{sj}",
                          backend="dists"):
                    lens_tile = np.asarray(dists[si:ei, sj:ej],
                                           dtype=np.float64)
                    return _harvest_masked_tile(lens_tile, si, sj, tau_max,
                                                _upper_mask(si, ei, sj, ej),
                                                stats)
            if backend == "pallas":
                with span("harvest/tile", tile=f"{si},{sj}",
                          backend="pallas"):
                    # analyze: allow[host-sync] one gather per tile is the streaming contract; the f64 refine consumes it on host
                    d2_32 = np.asarray(pairwise_sq_dists(
                        pts32[si:ei], pts32[sj:ej], interpret=interpret))
                    return _refine_f32_tile(d2_32, points, sq, si, ei,
                                            sj, ej, tau_max, thr32, stats)
            with span("harvest/tile", tile=f"{si},{sj}", backend="numpy"):
                return _harvest_points_tile(points, sq, si, ei, sj, ej,
                                            tau_max, stats)

        if inj is None:
            chunk = compute_tile(0)
        else:
            def note_retry(a, err, delay_s):
                if stats is not None:
                    stats.tile_retries += 1
            chunk = retry_with_backoff(compute_tile, attempts=3,
                                       base_s=1e-4, seed=tile_ord,
                                       sleep=None, on_retry=note_retry)
        yield chunk


def harvest_edges(
    points: Optional[np.ndarray] = None,
    dists: Optional[np.ndarray] = None,
    tau_max: float = np.inf,
    tile_m: int = DEFAULT_TILE,
    tile_n: int = DEFAULT_TILE,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    stats: Optional[TileStats] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All permissible edges as one globally sorted COO list.

    Chunks stream out of :func:`iter_tile_edges` and merge through
    :func:`merge_edge_chunks` into the canonical ``(length, i, j)`` order —
    the same the dense builder uses, so downstream structures match bit for
    bit.  See :func:`repro.scale.shard.harvest_edges_sharded` for the
    multi-device form.
    """
    ii, jj, ll = [], [], []
    for iu, ju, lens in iter_tile_edges(points=points, dists=dists,
                                        tau_max=tau_max, tile_m=tile_m,
                                        tile_n=tile_n, backend=backend,
                                        interpret=interpret, stats=stats):
        ii.append(iu.astype(np.int64))
        jj.append(ju.astype(np.int64))
        ll.append(lens)
    return merge_edge_chunks(ii, jj, ll, stats=stats)


def merge_edge_chunks(
    ii: list, jj: list, ll: list, stats: Optional[TileStats] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Merge per-tile COO chunk lists into the canonical sorted edge list.

    The single ``(length, i, j)`` lexsort is a total order over pairs, so
    the result is independent of chunk arrival order — serial tile streams
    and sharded per-device fragments merge to identical bits.  Consumes the
    input lists (chunks are released as each concatenation lands) so the
    transient peak is chunks + one concat copy, then sort index + permuted
    copies — recorded honestly in ``TileStats.merge_peak_bytes``.
    """
    chunk_bytes = sum(a.nbytes + b.nbytes + c.nbytes
                      for a, b, c in zip(ii, jj, ll))
    with span("harvest/merge", n_chunks=len(ll)):
        iu = np.concatenate(ii) if ii else np.zeros(0, dtype=np.int64)
        ii.clear()
        ju = np.concatenate(jj) if jj else np.zeros(0, dtype=np.int64)
        jj.clear()
        lens = np.concatenate(ll) if ll else np.zeros(0)
        ll.clear()
        srt = np.lexsort((ju, iu, lens))
        iu, ju, lens = iu[srt], ju[srt], lens[srt]
    if stats is not None:
        stats.n_e = int(lens.size)
        stats.harvest_bytes = int(iu.nbytes + ju.nbytes + lens.nbytes)
        # worst transient: all chunks + the first concat copy alive together,
        # vs. final arrays + lexsort index + one permuted copy in flight
        stats.merge_peak_bytes = max(chunk_bytes + iu.nbytes,
                                     stats.harvest_bytes + srt.nbytes
                                     + iu.nbytes)
    return iu, ju, lens


def build_filtration_tiled(
    points: Optional[np.ndarray] = None,
    dists: Optional[np.ndarray] = None,
    tau_max: float = np.inf,
    tile_m: int = DEFAULT_TILE,
    tile_n: int = DEFAULT_TILE,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    with_dense_order: bool = False,
    return_stats: bool = False,
):
    """Streamed :class:`Filtration` build — never allocates ``(n, n)``.

    Output is bit-identical (edges, orders, lengths, neighborhoods) to
    ``build_filtration`` on the same input, but peak memory is one
    ``(tile_m, tile_n)`` tile plus ``O(n + n_e)``.  ``with_dense_order``
    defaults to False so the result runs the order-free sparse Dory path;
    flipping it restores DoryNS semantics (and the O(n^2) table).

    Returns ``filt`` or ``(filt, TileStats)`` with ``return_stats``.
    """
    stats = TileStats()
    iu, ju, lens = harvest_edges(points=points, dists=dists, tau_max=tau_max,
                                 tile_m=tile_m, tile_n=tile_n,
                                 backend=backend, interpret=interpret,
                                 stats=stats)
    filt = filtration_from_edges(stats.n, iu, ju, lens, tau_max,
                                 presorted=True,
                                 with_dense_order=with_dense_order)
    stats.base_memory_bytes = filt.base_memory_bytes()
    if return_stats:
        return filt, stats
    return filt

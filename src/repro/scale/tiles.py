"""Streaming tiled filtration construction (million-point path, paper §5-6).

``build_filtration`` materializes a dense ``(n, n)`` float64 distance matrix,
which hard-caps the repo at a few thousand points — the exact barrier the
paper removes.  This module constructs the *same* sparse :class:`Filtration`
without ever holding an ``O(n^2)`` array:

* the distance matrix is computed tile-by-tile over ``(tile_m, tile_n)``
  blocks (numpy host path, or the Pallas ``pairwise_sq_dists`` TPU kernel);
* each tile is thresholded against ``tau_max`` in place and the surviving
  ``(i, j, length)`` triplets are harvested as COO chunks;
* chunks are merged into the globally sorted canonical edge list
  (``(length, i, j)`` lexicographic) and handed to
  ``filtration_from_edges`` — total extra memory is one tile plus
  ``O(n + n_e)``, never ``O(n^2)``.

Bit-identity with the dense path is guaranteed, not hoped for: both paths
compute distances with the fixed-order ``cross_term`` / ``block_sq_dists``
kernels from ``core.filtration`` (BLAS matmul changes accumulation order with
operand shape, so it could not provide this invariant).  The Pallas backend
computes tiles in float32 as a *candidate filter* only — candidates within a
conservative error margin of ``tau_max`` are re-measured exactly in float64
(``pair_sq_dists``) on the sparse candidate set, so its output is also
bit-identical to the dense build.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from ..core.filtration import (Filtration, block_sq_dists,
                               filtration_from_edges, pair_sq_dists)

DEFAULT_TILE = 2048


@dataclasses.dataclass
class TileStats:
    """Accounting for one streamed build (benchmarks assert against this)."""

    n: int = 0
    n_e: int = 0
    tile_m: int = 0
    tile_n: int = 0
    backend: str = "numpy"
    tiles_visited: int = 0
    candidate_pairs: int = 0      # pallas path: f32 candidates refined in f64
    peak_tile_bytes: int = 0      # largest per-tile scratch
    harvest_bytes: int = 0        # final sorted COO triplet arrays
    merge_peak_bytes: int = 0     # worst transient during concat + lexsort
    base_memory_bytes: int = 0    # paper (3n + 12 n_e) * 4 for the result

    def peak_extra_bytes(self) -> int:
        """Peak transient memory of the build: one tile + the merge worst case
        (chunks + concat copy, then sort index + permuted copies)."""
        return self.peak_tile_bytes + max(self.merge_peak_bytes,
                                          self.harvest_bytes)


def _resolve_backend(backend: str) -> str:
    if backend == "auto":
        try:
            import jax
            return "pallas" if jax.default_backend() == "tpu" else "numpy"
        except ImportError:
            return "numpy"
    if backend not in ("numpy", "pallas"):
        raise ValueError(f"unknown tile backend {backend!r}")
    return backend


def _f32_margin(sq_max: float, d: int) -> float:
    """Upper bound on |d2_f32 - d2_f64| for the Pallas candidate filter.

    Input rounding to f32 plus the f32 Gram accumulation each contribute
    O(eps32) per term; 8 * (d + 4) terms is a deliberately loose constant —
    a too-wide margin only means a few extra candidates get the exact f64
    re-measure, never a missed edge.
    """
    eps32 = float(np.finfo(np.float32).eps)
    return 8.0 * (d + 4) * eps32 * max(sq_max, 1.0) * 4.0


def iter_tile_edges(
    points: Optional[np.ndarray] = None,
    dists: Optional[np.ndarray] = None,
    tau_max: float = np.inf,
    tile_m: int = DEFAULT_TILE,
    tile_n: int = DEFAULT_TILE,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    stats: Optional[TileStats] = None,
) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Yield COO edge chunks ``(iu, ju, lens)`` per tile, ``i < j`` only.

    Every unordered pair (i < j) lives in exactly one tile — the one indexed
    by ``(i // tile_m, j // tile_n)`` — so chunks are disjoint and their
    union is exactly the dense path's thresholded upper triangle.
    """
    if (points is None) == (dists is None):
        raise ValueError("provide exactly one of points or dists")
    backend = _resolve_backend(backend) if points is not None else "numpy"
    if stats is not None:
        stats.tile_m, stats.tile_n, stats.backend = tile_m, tile_n, backend

    if dists is not None:
        dists = np.asarray(dists)
        n = dists.shape[0]
        if dists.shape != (n, n):
            raise ValueError(f"dists must be square, got {dists.shape}")
    else:
        points = np.asarray(points, dtype=np.float64)
        n = points.shape[0]
        sq = np.sum(points * points, axis=1)
        if backend == "pallas":
            import jax.numpy as jnp

            from ..kernels.pairwise_dist import pairwise_sq_dists
            pts32 = jnp.asarray(points, dtype=jnp.float32)
            margin = _f32_margin(float(sq.max()) if n else 1.0,
                                 points.shape[1])
            thr32 = np.float32(tau_max * tau_max + margin) \
                if np.isfinite(tau_max) else np.float32(np.inf)
    if stats is not None:
        stats.n = n

    for si in range(0, n, tile_m):
        ei = min(si + tile_m, n)
        for sj in range(0, n, tile_n):
            ej = min(sj + tile_n, n)
            if si >= ej - 1:
                continue                      # tile strictly below diagonal
            # tiles fully above the diagonal (the vast majority for large n)
            # need no i<j mask at all
            upper = None if ei - 1 < sj else \
                (np.arange(si, ei)[:, None] < np.arange(sj, ej)[None, :])
            upper_bytes = 0 if upper is None else upper.nbytes
            if stats is not None:
                stats.tiles_visited += 1

            if dists is not None:
                lens_tile = np.asarray(dists[si:ei, sj:ej], dtype=np.float64)
                mask = lens_tile <= tau_max
                if upper is not None:
                    mask &= upper
                if stats is not None:
                    stats.peak_tile_bytes = max(
                        stats.peak_tile_bytes,
                        lens_tile.nbytes + mask.nbytes + upper_bytes)
                ri, rj = np.nonzero(mask)
                yield si + ri, sj + rj, lens_tile[ri, rj]
                continue

            if backend == "pallas":
                d2_32 = np.asarray(pairwise_sq_dists(
                    pts32[si:ei], pts32[sj:ej], interpret=interpret))
                cand = d2_32 <= thr32
                if upper is not None:
                    cand &= upper
                if stats is not None:
                    stats.peak_tile_bytes = max(
                        stats.peak_tile_bytes,
                        d2_32.nbytes + cand.nbytes + upper_bytes)
                ri, rj = np.nonzero(cand)
                iu, ju = si + ri, sj + rj
                # exact f64 re-measure on the sparse candidate set
                lens = np.sqrt(pair_sq_dists(points, iu, ju, sq))
                if stats is not None:
                    stats.candidate_pairs += int(iu.size)
                keep = lens <= tau_max
                yield iu[keep], ju[keep], lens[keep]
                continue

            d2 = block_sq_dists(points[si:ei], points[sj:ej],
                                sq[si:ei], sq[sj:ej])
            lens_tile = np.sqrt(d2, out=d2)
            mask = lens_tile <= tau_max
            if upper is not None:
                mask &= upper
            if stats is not None:
                stats.peak_tile_bytes = max(
                    stats.peak_tile_bytes,
                    lens_tile.nbytes + mask.nbytes + upper_bytes)
            ri, rj = np.nonzero(mask)
            yield si + ri, sj + rj, lens_tile[ri, rj]


def harvest_edges(
    points: Optional[np.ndarray] = None,
    dists: Optional[np.ndarray] = None,
    tau_max: float = np.inf,
    tile_m: int = DEFAULT_TILE,
    tile_n: int = DEFAULT_TILE,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    stats: Optional[TileStats] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All permissible edges as one globally sorted COO list.

    Chunks stream out of :func:`iter_tile_edges` and are merged with a single
    ``(length, i, j)`` lexsort — the same canonical order the dense builder
    uses, so downstream structures match bit for bit.  Chunk lists are
    released as each concatenation lands so the merge's transient peak is
    chunks + one concat copy, then sort index + permuted copies — recorded
    honestly in ``TileStats.merge_peak_bytes``, not just the final arrays.
    """
    ii, jj, ll = [], [], []
    chunk_bytes = 0
    for iu, ju, lens in iter_tile_edges(points=points, dists=dists,
                                        tau_max=tau_max, tile_m=tile_m,
                                        tile_n=tile_n, backend=backend,
                                        interpret=interpret, stats=stats):
        ii.append(iu.astype(np.int64))
        jj.append(ju.astype(np.int64))
        ll.append(lens)
        chunk_bytes += ii[-1].nbytes + jj[-1].nbytes + ll[-1].nbytes
    iu = np.concatenate(ii) if ii else np.zeros(0, dtype=np.int64)
    ii.clear()
    ju = np.concatenate(jj) if jj else np.zeros(0, dtype=np.int64)
    jj.clear()
    lens = np.concatenate(ll) if ll else np.zeros(0)
    ll.clear()
    srt = np.lexsort((ju, iu, lens))
    iu, ju, lens = iu[srt], ju[srt], lens[srt]
    if stats is not None:
        stats.n_e = int(lens.size)
        stats.harvest_bytes = int(iu.nbytes + ju.nbytes + lens.nbytes)
        # worst transient: all chunks + the first concat copy alive together,
        # vs. final arrays + lexsort index + one permuted copy in flight
        stats.merge_peak_bytes = max(chunk_bytes + iu.nbytes,
                                     stats.harvest_bytes + srt.nbytes
                                     + iu.nbytes)
    return iu, ju, lens


def build_filtration_tiled(
    points: Optional[np.ndarray] = None,
    dists: Optional[np.ndarray] = None,
    tau_max: float = np.inf,
    tile_m: int = DEFAULT_TILE,
    tile_n: int = DEFAULT_TILE,
    backend: str = "auto",
    interpret: Optional[bool] = None,
    with_dense_order: bool = False,
    return_stats: bool = False,
):
    """Streamed :class:`Filtration` build — never allocates ``(n, n)``.

    Output is bit-identical (edges, orders, lengths, neighborhoods) to
    ``build_filtration`` on the same input, but peak memory is one
    ``(tile_m, tile_n)`` tile plus ``O(n + n_e)``.  ``with_dense_order``
    defaults to False so the result runs the order-free sparse Dory path;
    flipping it restores DoryNS semantics (and the O(n^2) table).

    Returns ``filt`` or ``(filt, TileStats)`` with ``return_stats``.
    """
    stats = TileStats()
    iu, ju, lens = harvest_edges(points=points, dists=dists, tau_max=tau_max,
                                 tile_m=tile_m, tile_n=tile_n,
                                 backend=backend, interpret=interpret,
                                 stats=stats)
    filt = filtration_from_edges(stats.n, iu, ju, lens, tau_max,
                                 presorted=True,
                                 with_dense_order=with_dense_order)
    stats.base_memory_bytes = filt.base_memory_bytes()
    if return_stats:
        return filt, stats
    return filt

"""Sparse (COO triplet) distance input — Hi-C contact graphs, no dense matrix.

The paper's §6 genome workload starts from a Hi-C contact map: a sparse
symmetric matrix of contact counts over genomic loci.  This module feeds such
data straight into the pipeline as ``(row, col, value)`` triplets — entries
absent from the COO set are treated as infinitely far (no edge), exactly like
a dense matrix whose missing entries exceed ``tau_max``, so
``build_filtration_coo`` is bit-identical to a dense ``dists=`` call on the
materialized matrix (asserted in tests) while never allocating ``O(n^2)``.
Workload walk-through and field reference: ``docs/architecture.md`` and
``docs/api.md``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..core.filtration import Filtration, filtration_from_edges


def coo_symmetrize(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    n: Optional[int] = None,
) -> Tuple[int, np.ndarray, np.ndarray, np.ndarray]:
    """Canonicalize COO triplets to unique upper-triangular ``(i < j)`` form.

    Diagonal entries are dropped; (a, b) and (b, a) collapse to
    ``(min, max)``; duplicate entries for the same pair resolve to the
    *minimum* value (for distance data the shortest measurement wins, and the
    rule is symmetric-input invariant).  Returns ``(n, iu, ju, vals)``.
    """
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float64)
    if not (rows.shape == cols.shape == vals.shape):
        raise ValueError("rows/cols/vals must have identical shapes")
    if rows.size and (rows.min() < 0 or cols.min() < 0):
        raise ValueError("negative vertex ids in COO input")
    inferred = int(max(rows.max(), cols.max())) + 1 if rows.size else 0
    n = inferred if n is None else int(n)
    if inferred > n:
        raise ValueError(f"COO ids need n >= {inferred}, got n={n}")

    iu = np.minimum(rows, cols)
    ju = np.maximum(rows, cols)
    off = iu != ju
    iu, ju, vals = iu[off], ju[off], vals[off]
    # group duplicates: sort by (pair, value) so the first of each run is the min
    pair = iu * np.int64(n) + ju
    srt = np.lexsort((vals, pair))
    pair, iu, ju, vals = pair[srt], iu[srt], ju[srt], vals[srt]
    first = np.ones(pair.size, dtype=bool)
    np.not_equal(pair[1:], pair[:-1], out=first[1:])
    return n, iu[first], ju[first], vals[first]


def build_filtration_coo(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    n: Optional[int] = None,
    tau_max: float = np.inf,
    with_dense_order: bool = False,
) -> Filtration:
    """Sparse-input :class:`Filtration`: COO distances in, Dory structure out.

    Memory is ``O(nnz + n)`` throughout; the dense order matrix stays lazy
    (``with_dense_order=False``) so the sparse Dory path runs order-free.
    Non-finite values (the ``contacts_to_distances`` "no information" inf)
    never become edges, even at ``tau_max=inf``.
    """
    n, iu, ju, vals = coo_symmetrize(rows, cols, vals, n=n)
    keep = (vals <= tau_max) & np.isfinite(vals)
    return filtration_from_edges(n, iu[keep], ju[keep], vals[keep], tau_max,
                                 with_dense_order=with_dense_order)


def contacts_to_distances(
    counts: np.ndarray,
    alpha: float = -1.0,
    scale: float = 1.0,
) -> np.ndarray:
    """Hi-C contact counts -> distances via the power law ``d = s * c^alpha``.

    The standard polymer-physics conversion (Lieberman-Aiden et al.):
    frequently contacting loci are spatially close.  Zero / negative counts
    map to ``inf`` (no information, no edge).
    """
    counts = np.asarray(counts, dtype=np.float64)
    out = np.full(counts.shape, np.inf)
    pos = counts > 0
    out[pos] = scale * np.power(counts[pos], alpha)
    return out

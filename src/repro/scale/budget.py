"""Edge-budget estimation + landmark subsampling (paper §5, appendix E).

Dory's memory story is the ``(3n + 12 n_e) * 4``-byte base account: for a
fixed byte budget the only free knob is ``n_e``, i.e. ``tau_max``.  This
module picks ``tau_max`` *before* any build by sampling pairwise distances
from random tile pairs (never the full matrix) and inverting the empirical
distance CDF at the edge count the budget affords.

For workloads where even the budgeted ``n_e`` is too dense, greedy maxmin
(farthest-point) landmark selection gives the standard sparsified-Rips
fallback: ``O(n k)`` time, ``O(n)`` memory, with the cover radius returned so
callers can bound the interleaving error of the subsampled diagram.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..core.filtration import pair_sq_dists


def account_bytes(n: int, n_e: int) -> int:
    """The paper's predicted base account: ``(3 n + 12 n_e) * 4`` bytes.

    This is the *model* side of the budget story; ``compute_ph`` records it
    as the ``predicted_account_bytes`` gauge next to the observed
    harvest/reduction high-water marks so budget-model drift is a
    measurable quantity (see ``docs/observability.md``).
    """
    return (3 * int(n) + 12 * int(n_e)) * 4


def edge_budget(n: int, memory_budget_bytes: int) -> int:
    """Largest ``n_e`` with ``account_bytes(n, n_e) <= memory_budget_bytes``."""
    return max(0, (int(memory_budget_bytes) // 4 - 3 * n) // 12)


def tile_transient_bytes(tile_m: int, tile_n: int, n_shards: int = 1,
                         backend: str = "numpy", d: int = 8) -> int:
    """Per-device transient of the tiled harvest, outside the paper account.

    The resident tile scratch (f64 lengths + threshold mask + worst-case
    diagonal mask on the numpy path; f32 candidates + masks on the pallas
    path) plus, when sharded over a mesh, the round's stacked f32 gather —
    ``n_shards`` tiles of f32 output and the two stacked ``(tile, d)`` f32
    input blocks land on the host at once (``TileStats.gather_bytes``
    measures the same quantity a posteriori).  ``d`` is the point
    dimension; pass the real one (``estimate_tau_max`` does) or the bound
    under-reserves for wide clouds.
    """
    tile = int(tile_m) * int(tile_n)
    resident = tile * ((8 if backend == "numpy" else 4) + 1 + 1)
    gather = 0
    if n_shards > 1:
        gather = n_shards * (tile * 4 + (tile_m + tile_n) * int(d) * 4)
    return resident + gather


def sharded_edge_budget(n: int, memory_budget_bytes: int, n_shards: int,
                        tile_m: int, tile_n: int,
                        backend: str = "numpy", d: int = 8) -> int:
    """Largest *global* ``n_e`` whose per-device footprint fits the budget.

    ``memory_budget_bytes`` is interpreted **per device**: every device
    duplicates the ``3n`` vertex arrays, holds ``~n_e / n_shards`` of the
    edge arrays, and additionally pays the harvest transient
    (:func:`tile_transient_bytes`, including the round gather).  Inverting
    the per-device account and scaling the edge share back up gives the
    global edge count the fleet affords.
    """
    avail = int(memory_budget_bytes) - tile_transient_bytes(
        tile_m, tile_n, n_shards, backend, d=d)
    if avail <= 0:
        raise ValueError(
            f"memory_budget_bytes={memory_budget_bytes} per device cannot "
            f"even hold the ({tile_m}, {tile_n}) tile transient for "
            f"n_shards={n_shards}")
    return n_shards * edge_budget(n, avail)


def sample_pair_lengths(points: np.ndarray, n_samples: int = 200_000,
                        seed: int = 0) -> np.ndarray:
    """Exact lengths of ``n_samples`` uniform random (i < j) pairs."""
    points = np.asarray(points, dtype=np.float64)
    n = points.shape[0]
    if n < 2:
        return np.zeros(0)
    rng = np.random.default_rng(seed)
    iu = rng.integers(0, n, size=n_samples)
    ju = rng.integers(0, n, size=n_samples)
    neq = iu != ju
    iu, ju = iu[neq], ju[neq]
    lo = np.minimum(iu, ju)
    hi = np.maximum(iu, ju)
    return np.sqrt(pair_sq_dists(points, lo, hi))


def estimate_tau_max(
    points: np.ndarray,
    memory_budget_bytes: int,
    n_samples: int = 200_000,
    seed: int = 0,
    safety: float = 0.9,
    n_shards: int = 1,
    tile_m: Optional[int] = None,
    tile_n: Optional[int] = None,
    backend: str = "numpy",
) -> float:
    """Pick ``tau_max`` so the expected ``n_e`` fits the byte budget.

    The empirical CDF of sampled pair lengths estimates
    ``n_e(tau) ~= q(tau) * n(n-1)/2``; we take the quantile at the budgeted
    edge fraction, shrunk by ``safety`` to absorb sampling error.  Returns
    ``inf`` when the budget covers the full clique.

    With ``n_shards > 1`` (a mesh-sharded build) the budget is interpreted
    **per device**: the ``3n`` vertex arrays are duplicated on every device
    and the per-round gather transient is charged before the edge account is
    inverted (:func:`sharded_edge_budget`) — the serial form assumed one
    resident tile globally, which under-reserved on every device of a mesh.
    ``tile_m``/``tile_n`` size that transient (required when sharded).
    """
    points = np.asarray(points)
    n = int(points.shape[0])
    total_pairs = n * (n - 1) // 2
    if n_shards > 1:
        if tile_m is None or tile_n is None:
            raise ValueError("sharded budgets need tile_m and tile_n to "
                             "account the per-device tile + gather transient")
        max_edges = sharded_edge_budget(n, memory_budget_bytes, n_shards,
                                        tile_m, tile_n, backend=backend,
                                        d=int(points.shape[1]))
    else:
        max_edges = edge_budget(n, memory_budget_bytes)
    if max_edges <= 0:
        raise ValueError(
            f"memory_budget_bytes={memory_budget_bytes} cannot hold even the "
            f"O(n) part of a filtration on n={n} points")
    if total_pairs == 0 or max_edges >= total_pairs:
        return float(np.inf)
    lens = sample_pair_lengths(points, n_samples=n_samples, seed=seed)
    q = min(1.0, safety * max_edges / total_pairs)
    return float(np.quantile(lens, q))


def maxmin_landmarks(
    points: np.ndarray,
    k: int,
    seed: int = 0,
    first: Optional[int] = None,
) -> Tuple[np.ndarray, float]:
    """Greedy farthest-point (maxmin) landmark selection.

    Returns ``(indices, cover_radius)``: up to ``k`` landmark indices into
    ``points`` and the final covering radius ``max_i min_l d(x_i, x_l)`` —
    the Hausdorff distance between cloud and landmarks, which bounds the
    bottleneck error of the sparsified-Rips diagram.  Stops early (fewer
    than ``k`` indices) once the cloud is exactly covered — duplicate points
    never yield duplicate landmarks.  ``O(n k)`` time, ``O(n)`` memory: one
    running min-distance vector, no pairwise matrix.
    """
    points = np.asarray(points, dtype=np.float64)
    n = points.shape[0]
    k = min(int(k), n)
    if k <= 0:
        return np.zeros(0, dtype=np.int64), float(np.inf)
    rng = np.random.default_rng(seed)
    idx = np.empty(k, dtype=np.int64)
    idx[0] = int(rng.integers(0, n)) if first is None else int(first)
    sq = np.sum(points * points, axis=1)
    all_ids = np.arange(n, dtype=np.int64)
    mind = np.sqrt(pair_sq_dists(points, np.full(n, idx[0], dtype=np.int64),
                                 all_ids, sq))
    for t in range(1, k):
        if mind.max() == 0.0:
            return idx[:t].copy(), 0.0
        idx[t] = int(np.argmax(mind))
        d = np.sqrt(pair_sq_dists(points, np.full(n, idx[t], dtype=np.int64),
                                  all_ids, sq))
        np.minimum(mind, d, out=mind)
    return idx, float(mind.max())


def landmark_points(points: np.ndarray, k: int, seed: int = 0,
                    first: Optional[int] = None):
    """Convenience: ``(points[idx], idx, cover_radius)`` for maxmin landmarks."""
    idx, radius = maxmin_landmarks(points, k, seed=seed, first=first)
    return np.asarray(points, dtype=np.float64)[idx], idx, radius

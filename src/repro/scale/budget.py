"""Edge-budget estimation + landmark subsampling (paper §5, appendix E).

Dory's memory story is the ``(3n + 12 n_e) * 4``-byte base account: for a
fixed byte budget the only free knob is ``n_e``, i.e. ``tau_max``.  This
module picks ``tau_max`` *before* any build by sampling pairwise distances
from random tile pairs (never the full matrix) and inverting the empirical
distance CDF at the edge count the budget affords.

For workloads where even the budgeted ``n_e`` is too dense, greedy maxmin
(farthest-point) landmark selection gives the standard sparsified-Rips
fallback: ``O(n k)`` time, ``O(n)`` memory, with the cover radius returned so
callers can bound the interleaving error of the subsampled diagram.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from ..core.filtration import pair_sq_dists


def edge_budget(n: int, memory_budget_bytes: int) -> int:
    """Largest ``n_e`` with ``(3n + 12 n_e) * 4 <= memory_budget_bytes``."""
    return max(0, (int(memory_budget_bytes) // 4 - 3 * n) // 12)


def sample_pair_lengths(points: np.ndarray, n_samples: int = 200_000,
                        seed: int = 0) -> np.ndarray:
    """Exact lengths of ``n_samples`` uniform random (i < j) pairs."""
    points = np.asarray(points, dtype=np.float64)
    n = points.shape[0]
    if n < 2:
        return np.zeros(0)
    rng = np.random.default_rng(seed)
    iu = rng.integers(0, n, size=n_samples)
    ju = rng.integers(0, n, size=n_samples)
    neq = iu != ju
    iu, ju = iu[neq], ju[neq]
    lo = np.minimum(iu, ju)
    hi = np.maximum(iu, ju)
    return np.sqrt(pair_sq_dists(points, lo, hi))


def estimate_tau_max(
    points: np.ndarray,
    memory_budget_bytes: int,
    n_samples: int = 200_000,
    seed: int = 0,
    safety: float = 0.9,
) -> float:
    """Pick ``tau_max`` so the expected ``n_e`` fits the byte budget.

    The empirical CDF of sampled pair lengths estimates
    ``n_e(tau) ~= q(tau) * n(n-1)/2``; we take the quantile at the budgeted
    edge fraction, shrunk by ``safety`` to absorb sampling error.  Returns
    ``inf`` when the budget covers the full clique.
    """
    n = int(np.asarray(points).shape[0])
    total_pairs = n * (n - 1) // 2
    max_edges = edge_budget(n, memory_budget_bytes)
    if max_edges <= 0:
        raise ValueError(
            f"memory_budget_bytes={memory_budget_bytes} cannot hold even the "
            f"O(n) part of a filtration on n={n} points")
    if total_pairs == 0 or max_edges >= total_pairs:
        return float(np.inf)
    lens = sample_pair_lengths(points, n_samples=n_samples, seed=seed)
    q = min(1.0, safety * max_edges / total_pairs)
    return float(np.quantile(lens, q))


def maxmin_landmarks(
    points: np.ndarray,
    k: int,
    seed: int = 0,
    first: Optional[int] = None,
) -> Tuple[np.ndarray, float]:
    """Greedy farthest-point (maxmin) landmark selection.

    Returns ``(indices, cover_radius)``: up to ``k`` landmark indices into
    ``points`` and the final covering radius ``max_i min_l d(x_i, x_l)`` —
    the Hausdorff distance between cloud and landmarks, which bounds the
    bottleneck error of the sparsified-Rips diagram.  Stops early (fewer
    than ``k`` indices) once the cloud is exactly covered — duplicate points
    never yield duplicate landmarks.  ``O(n k)`` time, ``O(n)`` memory: one
    running min-distance vector, no pairwise matrix.
    """
    points = np.asarray(points, dtype=np.float64)
    n = points.shape[0]
    k = min(int(k), n)
    if k <= 0:
        return np.zeros(0, dtype=np.int64), float(np.inf)
    rng = np.random.default_rng(seed)
    idx = np.empty(k, dtype=np.int64)
    idx[0] = int(rng.integers(0, n)) if first is None else int(first)
    sq = np.sum(points * points, axis=1)
    all_ids = np.arange(n, dtype=np.int64)
    mind = np.sqrt(pair_sq_dists(points, np.full(n, idx[0], dtype=np.int64),
                                 all_ids, sq))
    for t in range(1, k):
        if mind.max() == 0.0:
            return idx[:t].copy(), 0.0
        idx[t] = int(np.argmax(mind))
        d = np.sqrt(pair_sq_dists(points, np.full(n, idx[t], dtype=np.int64),
                                  all_ids, sq))
        np.minimum(mind, d, out=mind)
    return idx, float(mind.max())


def landmark_points(points: np.ndarray, k: int, seed: int = 0,
                    first: Optional[int] = None):
    """Convenience: ``(points[idx], idx, cover_radius)`` for maxmin landmarks."""
    idx, radius = maxmin_landmarks(points, k, seed=seed, first=first)
    return np.asarray(points, dtype=np.float64)[idx], idx, radius

"""repro.scale: streaming tiled filtration for million-point PH (paper §5-6).

Builds the sparse Dory :class:`~repro.core.filtration.Filtration` without any
``O(n^2)`` allocation: tiled distance harvesting (``tiles``), multi-device
tile sharding over the ``data`` mesh axis (``shard``), byte-budget
``tau_max`` estimation + maxmin landmarks (``budget``), and sparse COO
distance input (``sparse_input``).  Entry via ``build_filtration_tiled`` /
``build_filtration_sharded`` / ``build_filtration_coo`` directly, or
``compute_ph(..., backend="tiled", memory_budget_bytes=..., mesh=...)``.

See ``docs/architecture.md`` for the end-to-end pipeline walk and
``docs/api.md`` for the reference of this surface.
"""
from .budget import (account_bytes, edge_budget, estimate_tau_max,
                     landmark_points,
                     maxmin_landmarks, sample_pair_lengths,
                     sharded_edge_budget, tile_transient_bytes)
from .shard import (build_filtration_sharded, harvest_edges_sharded,
                    partition_tiles, shard_of_mesh)
from .sparse_input import (build_filtration_coo, contacts_to_distances,
                           coo_symmetrize)
from .tiles import (TileStats, build_filtration_tiled, harvest_edges,
                    iter_tile_edges, merge_edge_chunks, tile_grid)

__all__ = [
    "TileStats", "build_filtration_tiled", "harvest_edges", "iter_tile_edges",
    "merge_edge_chunks", "tile_grid",
    "build_filtration_sharded", "harvest_edges_sharded", "partition_tiles",
    "shard_of_mesh",
    "account_bytes", "edge_budget", "estimate_tau_max", "maxmin_landmarks",
    "landmark_points",
    "sample_pair_lengths", "sharded_edge_budget", "tile_transient_bytes",
    "build_filtration_coo", "contacts_to_distances", "coo_symmetrize",
]

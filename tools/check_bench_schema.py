"""CI gate: BENCH JSONs must carry their per-phase span breakdowns.

    python tools/check_bench_schema.py BENCH_reduce.json BENCH_scale.json \
        [--trace trace.json --min-lanes 4]

Each benchmark record type declares the ``phases`` keys its entries must
emit (docs/observability.md documents the fields); a record missing its
breakdown — e.g. a producer dropping a stats gauge during a refactor —
fails the push instead of silently flattening the perf trajectory.

``--trace`` additionally validates an exported Chrome trace: parseable
``trace_event`` JSON with complete (``"X"``) events and, with
``--min-lanes N``, at least ``N`` device lanes (``tid > 0``) so the
distributed timeline renders as parallel tracks in Perfetto.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

# benchmark name -> phases keys required on each engine/top-level entry
ENGINE_PHASES = ("filtration", "h0", "h1", "h2")
DIST_PHASES = ("conc", "sweep", "sync")
SCALE_PHASES = ("budget", "filtration", "ph")
SCALE_MEMORY = ("predicted_account_bytes", "observed_peak_harvest_bytes",
                "budget_drift_ratio")
SERVE_PHASES = ("cold", "warm")
SERVE_FIELDS = ("requests_per_s", "cache_hit_ratio", "latency_p50_s",
                "latency_p95_s")
CHAOS_PHASES = ("reduce", "serve", "checkpoint")
CHAOS_REDUCE_COUNTS = ("n_shard_deaths", "n_redeals",
                       "n_straggler_sidelines", "n_exchange_retries",
                       "n_exchange_deferrals", "n_wire_corruptions")


def _check_phases(where: str, entry: Dict, keys) -> List[str]:
    errors: List[str] = []
    phases = entry.get("phases")
    if not isinstance(phases, dict):
        return [f"{where}: missing per-phase breakdown 'phases'"]
    for k in keys:
        v = phases.get(k)
        if not isinstance(v, (int, float)) or v < 0:
            errors.append(f"{where}: phases[{k!r}] missing or negative "
                          f"(got {v!r})")
    return errors


def check_reduce(record: Dict) -> List[str]:
    errors: List[str] = []
    engines = record.get("engines", {})
    if not engines:
        errors.append("reduce_bench: no engines recorded")
    for name, entry in engines.items():
        errors += _check_phases(f"engines[{name}]", entry, ENGINE_PHASES)
    for name, entry in record.get("distributed", {}).items():
        errors += _check_phases(f"distributed[{name}]", entry, DIST_PHASES)
        wall = entry.get("sim_wall_s")
        if isinstance(wall, (int, float)) and "phases" in entry:
            parts = sum(entry["phases"].get(k, 0.0) for k in DIST_PHASES)
            if abs(parts - wall) > max(0.01, 0.01 * wall):
                errors.append(
                    f"distributed[{name}]: phase decomposition "
                    f"{parts:.4f}s does not add up to sim_wall_s "
                    f"{wall:.4f}s")
    return errors


def check_scale(record: Dict) -> List[str]:
    errors = _check_phases("scale_smoke", record, SCALE_PHASES)
    for k in SCALE_MEMORY:
        if not isinstance(record.get(k), (int, float)):
            errors.append(f"scale_smoke: missing memory field {k!r}")
    return errors


def check_serve(record: Dict) -> List[str]:
    errors = _check_phases("serve_bench", record, SERVE_PHASES)
    for k in SERVE_FIELDS:
        v = record.get(k)
        if not isinstance(v, (int, float)) or v < 0:
            errors.append(f"serve_bench: service-level field {k!r} missing "
                          f"or negative (got {v!r})")
    p50, p95 = record.get("latency_p50_s"), record.get("latency_p95_s")
    if isinstance(p50, (int, float)) and isinstance(p95, (int, float)) \
            and p95 < p50:
        errors.append(f"serve_bench: latency_p95_s {p95} < latency_p50_s "
                      f"{p50}")
    if record.get("n_warm_verified", 0) < 1:
        errors.append("serve_bench: no warm response was verified against "
                      "a cold reduction (n_warm_verified < 1)")
    return errors


def check_chaos(record: Dict) -> List[str]:
    errors = _check_phases("chaos_soak", record, CHAOS_PHASES)
    if record.get("n_faults_injected", 0) < 1:
        errors.append("chaos_soak: no fault ever fired (n_faults_injected "
                      "< 1) - the soak tested nothing")
    if record.get("exact_recovery") is not True:
        errors.append("chaos_soak: exact_recovery is not True - a faulted "
                      "run diverged from the fault-free diagrams")
    for k in ("mttr_mean_s", "mttr_max_s"):
        v = record.get(k)
        if not isinstance(v, (int, float)) or v < 0:
            errors.append(f"chaos_soak: recovery field {k!r} missing or "
                          f"negative (got {v!r})")
    reduce_soak = record.get("reduce")
    if not isinstance(reduce_soak, dict):
        errors.append("chaos_soak: missing 'reduce' soak section")
    else:
        for k in CHAOS_REDUCE_COUNTS:
            v = reduce_soak.get(k)
            if not isinstance(v, int) or v < 0:
                errors.append(f"chaos_soak: reduce[{k!r}] missing or "
                              f"negative (got {v!r})")
        if reduce_soak.get("n_shard_deaths", 0) >= 1 \
                and reduce_soak.get("n_redeals", 0) < 1:
            errors.append("chaos_soak: shards died but no queue was ever "
                          "re-dealt - recovery path not exercised")
    serve_soak = record.get("serve")
    if not isinstance(serve_soak, dict):
        errors.append("chaos_soak: missing 'serve' soak section")
    elif serve_soak.get("all_degraded_explicit") is not True:
        errors.append("chaos_soak: a degraded serve response carried no "
                      "reason (all_degraded_explicit is not True)")
    ckpt = record.get("checkpoint")
    if not isinstance(ckpt, dict):
        errors.append("chaos_soak: missing 'checkpoint' soak section")
    elif ckpt.get("all_detected") is not True:
        errors.append("chaos_soak: a corrupted checkpoint loaded without "
                      "detection (all_detected is not True)")
    return errors


CHECKERS = {"reduce_bench": check_reduce, "scale_smoke": check_scale,
            "serve_bench": check_serve, "chaos_soak": check_chaos}


def check_bench_file(path: str) -> List[str]:
    try:
        with open(path, encoding="utf-8") as f:
            record = json.load(f)
    except (OSError, ValueError) as exc:
        return [f"{path}: unreadable BENCH JSON ({exc})"]
    kind = record.get("benchmark")
    checker = CHECKERS.get(kind)
    if checker is None:
        return [f"{path}: unknown benchmark kind {kind!r} "
                f"(known: {sorted(CHECKERS)})"]
    return [f"{path}: {e}" for e in checker(record)]


def check_trace_file(path: str, min_lanes: int) -> List[str]:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as exc:
        return [f"{path}: unreadable trace JSON ({exc})"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: no traceEvents; not a Chrome trace"]
    errors: List[str] = []
    xs = [e for e in events if e.get("ph") == "X"]
    if not xs:
        errors.append(f"{path}: no complete ('X') span events")
    for e in xs:
        if not {"name", "ts", "dur", "pid", "tid"} <= set(e):
            errors.append(f"{path}: malformed X event {e!r}")
            break
    lanes = {e["tid"] for e in xs if e.get("tid", 0) > 0}
    if len(lanes) < min_lanes:
        errors.append(f"{path}: {len(lanes)} device lanes < required "
                      f"{min_lanes}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", nargs="*", help="BENCH JSON files to validate")
    ap.add_argument("--trace", action="append", default=[],
                    help="exported Chrome trace JSON to validate (repeatable)")
    ap.add_argument("--min-lanes", type=int, default=0,
                    help="require at least N device lanes in each --trace")
    args = ap.parse_args(argv)
    if not args.bench and not args.trace:
        ap.error("nothing to check: pass BENCH files and/or --trace")

    errors: List[str] = []
    for path in args.bench:
        errors += check_bench_file(path)
    for path in args.trace:
        errors += check_trace_file(path, args.min_lanes)
    for e in errors:
        print(f"FAIL {e}", file=sys.stderr)
    if not errors:
        n = len(args.bench) + len(args.trace)
        print(f"ok: {n} file(s) carry the per-phase schema")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Scoped strict type check: the analysis layer must stay mypy-clean.

Run from the repo root (CI does):

    python tools/check_types.py

Runs ``mypy --strict`` over the modules whose contracts are load-bearing
for correctness tooling — ``src/repro/analyze/`` (the checker must not
itself be sloppier than what it checks) and ``src/repro/core/
pivot_cache.py`` (the replication codec the analyzer verifies).  Imports
*into* the rest of the untyped tree are followed permissively
(``--ignore-missing-imports`` + per-run ``--follow-imports=silent``) so
the scope stays exactly these files.

Skips gracefully (exit 0 with a notice) when mypy is not installed —
the container image does not bake it in; CI installs it from
requirements-dev.txt.
"""
from __future__ import annotations

import os
import shutil
import subprocess
import sys

SCOPE = (
    os.path.join("src", "repro", "analyze"),
    os.path.join("src", "repro", "core", "pivot_cache.py"),
)

MYPY_ARGS = (
    "--strict",
    "--follow-imports=silent",
    "--ignore-missing-imports",
    "--no-error-summary",
)


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if shutil.which("mypy") is None:
        try:
            import mypy  # noqa: F401
        except ImportError:
            print("check_types: mypy not installed; skipping "
                  "(CI installs it from requirements-dev.txt)")
            return 0
    cmd = [sys.executable, "-m", "mypy", *MYPY_ARGS,
           *(os.path.join(root, p) for p in SCOPE)]
    env = dict(os.environ)
    env["MYPYPATH"] = os.path.join(root, "src")
    proc = subprocess.run(cmd, cwd=root, env=env)
    if proc.returncode == 0:
        print(f"check_types: mypy --strict clean over {len(SCOPE)} scope(s)")
    return proc.returncode


if __name__ == "__main__":
    sys.exit(main())

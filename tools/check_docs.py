"""Docs rot check: dead relative links + doctest on ``>>>`` examples.

Run from the repo root (CI does, with ``PYTHONPATH=src``):

    PYTHONPATH=src python tools/check_docs.py [files...]

Defaults to ``docs/*.md`` + ``README.md``.  Two checks per file:

* **links** — every relative markdown link target (``[x](path)`` with no
  scheme) must exist on disk relative to the linking file (anchors are
  stripped; ``http(s)``/``mailto`` links are skipped — CI is offline);
* **doctests** — ``doctest.testfile`` runs every ``>>>`` example in the
  file in one shared namespace, so examples can build on each other.
  Illustrative fenced blocks without ``>>>`` are ignored.

``tests/test_docs.py`` runs the same functions under pytest so the tier-1
suite protects the docs too; this script is the standalone CI entry.
"""
from __future__ import annotations

import doctest
import glob
import os
import re
import sys

# doctest examples import both ``repro`` (src layout) and ``benchmarks``
# (repo root); make the script runnable from anywhere without env setup
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

# [text](target) — excludes images' leading ! from the text capture on
# purpose (the target still gets checked) and ignores in-page #anchors
_LINK_RE = re.compile(r"\[[^\]^]*\]\(([^)\s]+)\)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")

DOCTEST_FLAGS = (doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE
                 | doctest.IGNORE_EXCEPTION_DETAIL)


def default_files(root: str = ".") -> list:
    docs = sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    readme = os.path.join(root, "README.md")
    return docs + ([readme] if os.path.exists(readme) else [])


def dead_links(path: str) -> list:
    """Relative link targets in ``path`` that do not exist on disk."""
    with open(path, encoding="utf-8") as f:
        text = f.read()
    base = os.path.dirname(os.path.abspath(path))
    bad = []
    for target in _LINK_RE.findall(text):
        if target.startswith(_SKIP_SCHEMES):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            bad.append(target)
    return bad


def run_doctests(path: str):
    """(failed, attempted) for the ``>>>`` examples in ``path``."""
    result = doctest.testfile(os.path.abspath(path), module_relative=False,
                              optionflags=DOCTEST_FLAGS, verbose=False)
    return result.failed, result.attempted


def check(files: list) -> int:
    status = 0
    for path in files:
        bad = dead_links(path)
        if bad:
            status = 1
            for target in bad:
                print(f"DEAD LINK {path}: {target}")
        failed, attempted = run_doctests(path)
        if failed:
            status = 1
        print(f"{path}: {attempted - failed}/{attempted} doctests ok, "
              f"{len(bad)} dead links")
    return status


if __name__ == "__main__":
    files = sys.argv[1:] or default_files()
    if not files:
        print("no docs found", file=sys.stderr)
        sys.exit(1)
    sys.exit(check(files))

"""Batched serving: submit a queue of requests to the fixed-slot engine and
stream generations — prefill batches newcomers, decode advances all active
slots one token per step.

    PYTHONPATH=src python examples/serve_batch.py [--requests 12]
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.obs.trace import stopwatch
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config("qwen3-0.6b", reduced=True)
    engine = ServeEngine(cfg, max_batch=args.max_batch, prompt_len=16,
                         s_max=64)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 16)),
                              dtype=np.int32)
        engine.submit(Request(uid=uid, prompt=prompt, max_new=args.max_new))

    steps = 0
    with stopwatch("serve/run") as sw:
        while engine.queue or any(s is not None for s in engine._slots):
            engine.step()
            steps += 1
    wall = sw.elapsed

    done = engine.done
    total = sum(len(v) for v in done.values())
    print(f"served {len(done)} requests / {total} tokens in {wall:.2f}s "
          f"({steps} engine steps, {total / wall:.0f} tok/s on CPU)")
    assert len(done) == args.requests
    for uid in sorted(done)[:3]:
        print(f"  req {uid:2d} -> {done[uid]}")
    stats = engine.stats()
    print(f"engine stats: {stats['serve_n_prefills']:.0f} prefills, "
          f"{stats['serve_n_decode_steps']:.0f} decode steps, "
          f"{stats['serve_tokens_per_request_sum']:.0f} tokens total")
    print("OK: all requests completed through batched prefill+decode.")


if __name__ == "__main__":
    main()

"""The paper's §6 application, reproduced end-to-end at laptop scale:
topology of a (synthetic) genome under cohesin degradation.

A folded-polymer point cloud stands in for the Hi-C contact geometry: the
*control* condition has cohesin loop anchors pulling loci pairs together;
the *auxin* condition releases them (cohesin degraded).  Dory's PH engine
computes H0/H1/H2 for both conditions; the paper's Fig. 21 result is the
signed direction of the change — auxin REMOVES loops (H1 down, strongly)
and voids (H2 down).

At the default laptop scale the dense builder is fine; pass
``--backend tiled`` (optionally with ``--memory-budget-mb``) to stream the
filtration through ``repro.scale`` instead — the 50k-200k-loci regimes of a
real Hi-C map, where a dense ``(n, n)`` matrix would not fit, run only there:

    PYTHONPATH=src python examples/genome_hic.py [--n 400] [--loops 24]
    PYTHONPATH=src python examples/genome_hic.py --n 50000 --loops 200 \
        --backend tiled --memory-budget-mb 128 --maxdim 1
"""
import argparse

import numpy as np

from repro.core import compute_ph
from repro.data.pointclouds import hic_pair


def betti_curve(pd: np.ndarray, taus: np.ndarray) -> np.ndarray:
    if pd.size == 0:
        return np.zeros_like(taus)
    return np.array([((pd[:, 0] <= t) & (pd[:, 1] > t)).sum() for t in taus])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--loops", type=int, default=24)
    ap.add_argument("--tau-max", type=float, default=0.8)
    ap.add_argument("--maxdim", type=int, default=2)
    ap.add_argument("--backend", choices=("dense", "tiled"), default="dense",
                    help="'tiled' streams the filtration (repro.scale); "
                         "required beyond a few thousand loci")
    ap.add_argument("--memory-budget-mb", type=float, default=None,
                    help="tiled backend: pick tau_max so the filtration "
                         "fits this many MB (overrides --tau-max)")
    ap.add_argument("--tile", type=int, default=2048)
    args = ap.parse_args()

    control, auxin = hic_pair(args.n, n_loops=args.loops, seed=1)
    print(f"genome-like cloud: {args.n} loci, {args.loops} cohesin loops "
          f"({args.backend} filtration)")

    eff_tau = args.tau_max
    if args.memory_budget_mb is not None:
        if args.backend != "tiled":
            ap.error("--memory-budget-mb requires --backend tiled")
        from repro.scale import estimate_tau_max

        # one shared threshold for both conditions: per-condition budgets
        # would pick different tau (the budget fixes n_e, not scale) and
        # feature counts at different tau are not comparable
        budget = int(args.memory_budget_mb * 2**20)
        eff_tau = min(estimate_tau_max(control, budget),
                      estimate_tau_max(auxin, budget))
        if not np.isfinite(eff_tau):
            # budget covers the full clique — fall back to the geometric cap
            eff_tau = args.tau_max
        print(f"budgeted tau_max: {eff_tau:.3f} "
              f"({args.memory_budget_mb:g} MB for both conditions)")

    ph_kwargs = dict(maxdim=args.maxdim, engine="batch",
                     backend=args.backend, tile_m=args.tile,
                     tile_n=args.tile, tau_max=eff_tau)
    res_c = compute_ph(points=control, **ph_kwargs)
    res_a = compute_ph(points=auxin, **ph_kwargs)

    for d in range(1, args.maxdim + 1):
        pc, pa = res_c.diagrams[d], res_a.diagrams[d]
        # count features with non-trivial persistence (paper counts loops
        # robust to noise)
        thr = 0.05
        nc = int((pc[:, 1] - pc[:, 0] > thr).sum()) if pc.size else 0
        na = int((pa[:, 1] - pa[:, 0] > thr).sum()) if pa.size else 0
        pct = 100.0 * (na - nc) / max(nc, 1)
        print(f"H{d}: control {nc} features, auxin {na} "
              f"({pct:+.1f}% — paper Fig. 21 expects a decrease)")

    # betti-1 curve over scale (Fig. 21's x-axis is the threshold)
    taus = np.linspace(0.05, eff_tau * 0.9, 8)
    bc = betti_curve(res_c.diagrams[1], taus)
    ba = betti_curve(res_a.diagrams[1], taus)
    print("tau:     ", "  ".join(f"{t:5.2f}" for t in taus))
    print("control: ", "  ".join(f"{v:5d}" for v in bc))
    print("auxin:   ", "  ".join(f"{v:5d}" for v in ba))

    nc = int((res_c.diagrams[1][:, 1] - res_c.diagrams[1][:, 0] > 0.05).sum())
    na = int((res_a.diagrams[1][:, 1] - res_a.diagrams[1][:, 0] > 0.05).sum())
    assert na < nc, "expected auxin to remove H1 loops (paper Fig. 21)"
    print("OK: auxin removes loops — Fig. 21 direction reproduced.")


if __name__ == "__main__":
    main()

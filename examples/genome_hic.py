"""The paper's §6 application, reproduced end-to-end at laptop scale:
topology of a (synthetic) genome under cohesin degradation.

A folded-polymer point cloud stands in for the Hi-C contact geometry: the
*control* condition has cohesin loop anchors pulling loci pairs together;
the *auxin* condition releases them (cohesin degraded).  Dory's PH engine
computes H0/H1/H2 for both conditions; the paper's Fig. 21 result is the
signed direction of the change — auxin REMOVES loops (H1 down, strongly)
and voids (H2 down).

    PYTHONPATH=src python examples/genome_hic.py [--n 400] [--loops 24]
"""
import argparse

import numpy as np

from repro.core import compute_ph
from repro.data.pointclouds import hic_pair


def betti_curve(pd: np.ndarray, taus: np.ndarray) -> np.ndarray:
    if pd.size == 0:
        return np.zeros_like(taus)
    return np.array([((pd[:, 0] <= t) & (pd[:, 1] > t)).sum() for t in taus])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--loops", type=int, default=24)
    ap.add_argument("--tau-max", type=float, default=0.8)
    ap.add_argument("--maxdim", type=int, default=2)
    args = ap.parse_args()

    control, auxin = hic_pair(args.n, n_loops=args.loops, seed=1)
    print(f"genome-like cloud: {args.n} loci, {args.loops} cohesin loops")

    res_c = compute_ph(points=control, tau_max=args.tau_max,
                       maxdim=args.maxdim, engine="batch")
    res_a = compute_ph(points=auxin, tau_max=args.tau_max,
                       maxdim=args.maxdim, engine="batch")

    for d in range(1, args.maxdim + 1):
        pc, pa = res_c.diagrams[d], res_a.diagrams[d]
        # count features with non-trivial persistence (paper counts loops
        # robust to noise)
        thr = 0.05
        nc = int((pc[:, 1] - pc[:, 0] > thr).sum()) if pc.size else 0
        na = int((pa[:, 1] - pa[:, 0] > thr).sum()) if pa.size else 0
        pct = 100.0 * (na - nc) / max(nc, 1)
        print(f"H{d}: control {nc} features, auxin {na} "
              f"({pct:+.1f}% — paper Fig. 21 expects a decrease)")

    # betti-1 curve over scale (Fig. 21's x-axis is the threshold)
    taus = np.linspace(0.05, args.tau_max * 0.9, 8)
    bc = betti_curve(res_c.diagrams[1], taus)
    ba = betti_curve(res_a.diagrams[1], taus)
    print("tau:     ", "  ".join(f"{t:5.2f}" for t in taus))
    print("control: ", "  ".join(f"{v:5d}" for v in bc))
    print("auxin:   ", "  ".join(f"{v:5d}" for v in ba))

    nc = int((res_c.diagrams[1][:, 1] - res_c.diagrams[1][:, 0] > 0.05).sum())
    na = int((res_a.diagrams[1][:, 1] - res_a.diagrams[1][:, 0] > 0.05).sum())
    assert na < nc, "expected auxin to remove H1 loops (paper Fig. 21)"
    print("OK: auxin removes loops — Fig. 21 direction reproduced.")


if __name__ == "__main__":
    main()

"""Quickstart: persistent homology of a point cloud in ten lines.

Two circles -> two H1 loops, born at the sample spacing and dying at the
circle diameter; the large separation between the circles shows up in H0.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import compute_ph
from repro.data.pointclouds import clifford_torus, two_circles


def main() -> None:
    # --- two circles: 2 components, 2 loops ------------------------------
    pts = two_circles(n=24, separation=6.0)
    res = compute_ph(points=pts, maxdim=1)
    h0, h1 = res.diagrams[0], res.diagrams[1]
    print(f"two_circles: {len(h0)} H0 pairs, {len(h1)} H1 loops")
    essential = np.isinf(h0[:, 1]).sum() if h0.size else 0
    print(f"  essential H0 classes: {essential} "
          f"(tau_max=inf: everything eventually connects -> 1)")
    for b, d in h1:
        print(f"  loop: born tau={b:.3f}, dies tau={d:.3f} "
              f"(persistence {d - b:.3f})")

    # --- Clifford torus (paper's torus4): Betti (1, 2, 1) ---------------
    torus = clifford_torus(400, seed=3)
    res = compute_ph(points=torus, tau_max=0.9, maxdim=2)
    # Betti numbers at a mid scale: the torus has b0=1, b1=2, b2=1
    betti = res.betti_at(0.55)
    print(f"clifford_torus(400): betti at tau=0.55 -> {betti}")
    print("  stats:", {k: round(v, 4) for k, v in res.stats.items()
                       if k.startswith(("n", "t_"))})


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~30M-parameter qwen3-family model for a few
hundred steps on the synthetic affine-recurrence corpus and verify the loss
drops well below the unigram entropy — the framework's full training path
(data pipeline -> microbatched/remat'd step -> AdamW -> checkpointing) on one
host.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile

from repro.configs import get_config
from repro.launch.train import TrainJob, run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config("qwen3-0.6b").reduced(
        n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 32, d_ff=args.d_model * 4, vocab=512)
    import numpy as np
    n_params = None
    with tempfile.TemporaryDirectory() as ckpt_dir:
        job = TrainJob(cfg=cfg, steps=args.steps, global_batch=args.batch,
                       seq_len=args.seq, n_micro=2, lr=1e-3, warmup=30,
                       ckpt_dir=ckpt_dir, ckpt_every=100, log_every=20)
        out = run(job)
        import jax
        n_params = sum(int(np.prod(l.shape))
                       for l in jax.tree.leaves(out["state"].params))

    first = out["history"][0]["loss"]
    final = out["final_loss"]
    print(f"\nmodel: {n_params / 1e6:.1f}M params | "
          f"loss {first:.3f} -> {final:.3f} over {args.steps} steps "
          f"({out['wall_s']:.0f}s)")
    # the corpus is a noisy affine recurrence: a model that learns the
    # transition pools beats the uniform baseline log(512)=6.24 decisively
    assert final < first - 0.5, (
        f"loss did not drop: {first:.3f} -> {final:.3f}")
    print("OK: loss dropped > 0.5 nats — the model learned the recurrence.")


if __name__ == "__main__":
    main()

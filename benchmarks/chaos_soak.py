"""Chaos soak: seeded fault schedule over the PH pipeline -> BENCH_resilience.json.

Replays a deterministic :class:`repro.resilience.faults.FaultPlan` against
every recovery path the repo ships and gates on the two resilience
contracts (docs/resilience.md):

* **exactness** — diagrams from the faulted distributed reduction are
  bit-identical to the fault-free run and to the single engine, for every
  fault class (shard kill at superstep start and mid-superstep, straggler
  sideline, exchange drop / corrupt / delay, harvest tile failure);
* **bounded recovery** — mean time-to-recover (the ``resilience_recover_s``
  histogram: re-deal + backlog adoption work after a shard death) stays
  under ``--max-mttr``.

The serve soak drives overload and repeated cold failure through
``PHServeEngine`` and asserts every brown-out is *explicit* (``degraded``
flag + reason, never an exception, never silently wrong diagrams); the
checkpoint round bit-flips a saved :class:`ReductionCheckpoint` and
requires detection + cold fall-back.

    PYTHONPATH=src python -m benchmarks.chaos_soak --rounds 3 \
        --out BENCH_resilience.json --require-exact --max-mttr 1.0

Everything derives from ``--seed`` — two runs emit identical fault
histories (and identical diagrams), so a red CI run replays locally.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def _dim_sum(stats: dict, suffix: str) -> float:
    """Sum a per-dim-prefixed (``h1_``/``h2_``) resilience stat."""
    return float(sum(v for k, v in stats.items() if k.endswith(suffix)))


def _round_plan(seed: int, n_shards: int):
    """One soak round's deterministic fault schedule (every class)."""
    from repro.resilience.faults import FaultPlan, FaultSpec
    rng = np.random.default_rng(seed)
    kill_when = ("start", "mid")[int(rng.integers(2))]
    return FaultPlan.of(
        FaultSpec("harvest.tile", "fail_tile",
                  at=int(rng.integers(0, 4))),
        FaultSpec("reduce.superstep", "kill_shard", at=2,
                  shard=int(rng.integers(n_shards)),
                  params=(("when", kill_when),)),
        FaultSpec("reduce.superstep", "slow_shard", at=1,
                  shard=int(rng.integers(n_shards)),
                  params=(("lag", 2.0), ("duration", 2))),
        FaultSpec("exchange.wire", "drop", at=1,
                  shard=int(rng.integers(n_shards)), times=2),
        FaultSpec("exchange.wire", "corrupt", at=2,
                  shard=int(rng.integers(n_shards)),
                  params=(("bit", int(rng.integers(0, 256))),)),
        FaultSpec("exchange.wire", "delay", at=3,
                  shard=int(rng.integers(n_shards)),
                  params=(("delay_s", 1e-3),)),
        seed=seed)


def _diagram_equal(a: dict, b: dict) -> bool:
    return set(a) == set(b) and all(
        np.array_equal(a[d], b[d]) for d in a)


def run_reduce_soak(args) -> dict:
    from repro.core.homology import compute_ph
    from repro.obs.trace import stopwatch
    from repro.resilience.faults import inject

    rng = np.random.default_rng(args.seed)
    kw = dict(tau_max=args.tau, maxdim=2, engine="packed",
              batch_size=args.batch_size, n_shards=args.n_shards,
              exchange_every=1)
    out = {"rounds": [], "n_faults_injected": 0, "n_shard_deaths": 0,
           "n_redeals": 0, "n_straggler_sidelines": 0,
           "n_exchange_retries": 0, "n_exchange_deferrals": 0,
           "n_wire_corruptions": 0, "exact_recovery": True,
           "mttr_mean_s": 0.0, "mttr_max_s": 0.0}
    recover_sum = recover_count = 0.0
    recover_max = 0.0
    with stopwatch("chaos/reduce") as sw:
        for r in range(args.rounds):
            pts = rng.normal(size=(args.cloud_size, 3))
            clean = compute_ph(pts, **kw)
            single = compute_ph(pts, tau_max=args.tau, maxdim=2,
                                engine="single")
            plan = _round_plan(args.seed + 1000 * r, args.n_shards)
            with inject(plan) as inj:
                faulted = compute_ph(pts, **kw)
                n_fired = len(inj.fired)
            exact = (_diagram_equal(faulted.diagrams, clean.diagrams)
                     and _diagram_equal(faulted.diagrams, single.diagrams))
            st = faulted.stats
            recover_sum += _dim_sum(st, "resilience_recover_s_sum")
            recover_count += _dim_sum(st, "resilience_recover_s_count")
            recover_max = max(recover_max,
                              max([v for k, v in st.items()
                                   if k.endswith("resilience_recover_s_max")]
                                  or [0.0]))
            out["rounds"].append({"seed": args.seed + 1000 * r,
                                  "n_fired": n_fired, "exact": exact})
            out["n_faults_injected"] += n_fired
            out["exact_recovery"] &= exact
            for key in ("n_shard_deaths", "n_redeals",
                        "n_straggler_sidelines", "n_exchange_retries",
                        "n_exchange_deferrals", "n_wire_corruptions"):
                out[key] += int(_dim_sum(st, f"resilience_{key}"))
    out["exact_recovery"] = bool(out["exact_recovery"])
    out["mttr_mean_s"] = (recover_sum / recover_count
                          if recover_count else 0.0)
    out["mttr_max_s"] = float(recover_max)
    out["wall_s"] = sw.elapsed
    return out


def run_serve_soak(args) -> dict:
    from repro.obs.trace import stopwatch
    from repro.resilience.faults import FaultPlan, FaultSpec, inject
    from repro.serve.ph import PHRequest, PHServeEngine

    rng = np.random.default_rng(args.seed + 7)
    eng = PHServeEngine(max_cold_retries=1, breaker_threshold=2,
                        breaker_cooldown_steps=2, seed=args.seed)
    plan = FaultPlan.of(
        FaultSpec("serve.step", "overload", at=2),
        FaultSpec("serve.step", "fail_reduce", at=3, times=2),
        FaultSpec("serve.step", "fail_reduce", at=4, times=2),
        seed=args.seed)
    out = {"n_requests": 0, "n_degraded": 0, "n_ok": 0,
           "all_degraded_explicit": True, "n_undegraded_wrong": 0}
    with stopwatch("chaos/serve") as sw:
        with inject(plan):
            for step in range(6):
                pts = rng.normal(size=(24, 3))
                eng.submit(PHRequest(uid=step, points=pts, tau_max=1.3,
                                     dataset=f"ds{step}"))
                eng.step()
                out["n_requests"] += 1
        for resp in eng.done.values():
            if resp.degraded:
                out["n_degraded"] += 1
                # the degradation contract: explicit reason, no exception
                if not resp.degraded_reason:
                    out["all_degraded_explicit"] = False
            else:
                out["n_ok"] += 1
                if resp.diagrams is None:
                    out["n_undegraded_wrong"] += 1
    stats = eng.stats()
    for key in ("serve_ph_n_shed", "serve_ph_n_circuit_open",
                "serve_ph_n_cold_retries", "serve_ph_n_degraded"):
        out[key] = int(stats.get(key, 0))
    out["wall_s"] = sw.elapsed
    return out


def run_checkpoint_soak(args, tmp_dir: str) -> dict:
    import os

    from repro.core.filtration import build_filtration
    from repro.core.resume import ReductionCheckpoint, cold_reduce
    from repro.obs.trace import stopwatch
    from repro.resilience.faults import (CheckpointCorruption, FaultPlan,
                                         FaultSpec, inject)

    rng = np.random.default_rng(args.seed + 13)
    out = {"n_corruptions_detected": 0, "n_fallbacks_ok": 0,
           "n_harmless_flips": 0, "all_detected": True}
    with stopwatch("chaos/checkpoint") as sw:
        for r in range(args.rounds):
            pts = rng.normal(size=(32, 3))
            filt = build_filtration(points=pts, tau_max=args.tau)
            diags, ck = cold_reduce(filt, maxdim=2)
            path = os.path.join(tmp_dir, f"ck_{r}.npz")
            digest = ck.save(path)
            kind = ("bitflip", "truncate")[r % 2]
            plan = FaultPlan.of(
                FaultSpec("resume.load", kind,
                          params=(("bit", int(rng.integers(0, 1 << 16))),)),
                seed=args.seed + r)
            with inject(plan):
                try:
                    loaded = ReductionCheckpoint.load(path)
                    # a flip in zip dead bytes (padding / unread local
                    # headers) can be a no-op; the contract violated only
                    # if WRONG content loads without an exception
                    if loaded.content_hash() == digest:
                        out["n_harmless_flips"] += 1
                    else:
                        out["all_detected"] = False
                except CheckpointCorruption:
                    out["n_corruptions_detected"] += 1
                    # recovery line: fall back to a cold reduction
                    cold_diags, _ = cold_reduce(filt, maxdim=2)
                    if _diagram_equal(cold_diags, diags):
                        out["n_fallbacks_ok"] += 1
    out["all_detected"] = bool(out["all_detected"])
    out["wall_s"] = sw.elapsed
    return out


def run(args) -> dict:
    import tempfile

    reduce_soak = run_reduce_soak(args)
    serve_soak = run_serve_soak(args)
    with tempfile.TemporaryDirectory() as tmp:
        ckpt_soak = run_checkpoint_soak(args, tmp)
    record = {
        "benchmark": "chaos_soak",
        "seed": args.seed,
        "rounds": args.rounds,
        "n_shards": args.n_shards,
        "cloud_size": args.cloud_size,
        "reduce": reduce_soak,
        "serve": serve_soak,
        "checkpoint": ckpt_soak,
        "n_faults_injected": (reduce_soak["n_faults_injected"]
                              + serve_soak["serve_ph_n_degraded"]
                              + ckpt_soak["n_corruptions_detected"]),
        "exact_recovery": reduce_soak["exact_recovery"],
        "mttr_mean_s": reduce_soak["mttr_mean_s"],
        "mttr_max_s": reduce_soak["mttr_max_s"],
        "phases": {
            "reduce": reduce_soak["wall_s"],
            "serve": serve_soak["wall_s"],
            "checkpoint": ckpt_soak["wall_s"],
        },
    }
    return record


def gate(record: dict, args) -> list:
    failures = []
    if record["reduce"]["n_faults_injected"] < 1:
        failures.append("no reduction fault ever fired - dead soak")
    if args.require_exact and not record["exact_recovery"]:
        bad = [r for r in record["reduce"]["rounds"] if not r["exact"]]
        failures.append(f"recovery not exact in rounds {bad}")
    if args.max_mttr is not None and record["mttr_mean_s"] > args.max_mttr:
        failures.append(f"mean MTTR {record['mttr_mean_s']:.4f}s exceeds "
                        f"--max-mttr {args.max_mttr}")
    if not record["serve"]["all_degraded_explicit"]:
        failures.append("a degraded serve response carried no reason")
    if record["serve"]["n_undegraded_wrong"]:
        failures.append("an un-degraded response had no diagrams")
    if not record["checkpoint"]["all_detected"]:
        failures.append("a corrupted checkpoint loaded without detection")
    if record["checkpoint"]["n_fallbacks_ok"] \
            != record["checkpoint"]["n_corruptions_detected"]:
        failures.append("cold fall-back after corruption was not exact")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--cloud-size", type=int, default=48)
    ap.add_argument("--tau", type=float, default=1.2)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--n-shards", type=int, default=4)
    ap.add_argument("--out", default="BENCH_resilience.json")
    ap.add_argument("--require-exact", action="store_true",
                    help="fail unless every faulted run is bit-identical")
    ap.add_argument("--max-mttr", type=float, default=None,
                    help="fail if mean recovery time exceeds this (s)")
    args = ap.parse_args(argv)

    record = run(args)
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}: {record['n_faults_injected']} faults, "
          f"exact={record['exact_recovery']}, "
          f"mttr_mean={record['mttr_mean_s']:.4f}s")
    failures = gate(record, args)
    for failure in failures:
        print(f"GATE FAIL: {failure}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Benchmark orchestrator: one section per paper table/figure + the
roofline report.

    PYTHONPATH=src python -m benchmarks.run [--scale 1.0] [--skip table3]
"""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="dataset size multiplier (--scale 4 ~ paper-size "
                         "regimes, minutes of CPU)")
    ap.add_argument("--skip", action="append", default=[],
                    help="section name to skip (repeatable)")
    args = ap.parse_args()

    from . import (fig21_hic, roofline, table1_datasets, table2_phases,
                   table3_vs_baseline, table4_variants)

    sections = [
        ("table1_datasets (paper Table 1)",
         lambda: table1_datasets.main(args.scale)),
        ("table2_phases (paper Table 2)",
         lambda: table2_phases.main(["--scale", str(args.scale)])),
        ("table3_vs_baseline (paper Table 3 / Fig. 18)",
         table3_vs_baseline.main),
        ("table4_variants (paper Table 4)",
         lambda: table4_variants.main(args.scale)),
        ("fig21_hic (paper Fig. 21)",
         lambda: fig21_hic.main(args.scale)),
        ("roofline (EXPERIMENTS.md §Roofline, from dry-run artifacts)",
         roofline.main),
    ]

    failures = 0
    for name, fn in sections:
        short = name.split(" ")[0]
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}")
        if short in args.skip:
            print("(skipped)")
            continue
        t0 = time.perf_counter()
        try:
            fn()
            print(f"-- section ok in {time.perf_counter() - t0:.1f}s")
        except Exception:                                # noqa: BLE001
            failures += 1
            print(f"-- SECTION FAILED:\n{traceback.format_exc()[-2000:]}")
    print(f"\n{'=' * 72}\nbenchmarks done, {failures} failed sections")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

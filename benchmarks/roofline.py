"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads ``benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json`` (written
by ``repro.launch.dryrun``) and renders, per (arch x shape x mesh):

* the three roofline terms (compute / memory / collective, seconds),
* the dominant term,
* MODEL_FLOPS = 6·N_active·D and the useful-compute ratio,
* per-device peak HBM bytes (fits-in-16GB check),
* the MFU upper bound implied by the dominant term.

Also ranks the hillclimb candidates: worst useful-ratio, most
collective-bound, and the decode cell most representative of serving.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "artifacts", "dryrun")


def load(mesh: str = "single") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(mesh: str = "single") -> List[Dict]:
    out = []
    for r in load(mesh):
        if r["status"] == "skip":
            out.append(dict(arch=r["arch"], shape=r["shape"], mesh=mesh,
                            status="SKIP", note=r["skip_reason"]))
            continue
        if r["status"] != "ok":
            out.append(dict(arch=r["arch"], shape=r["shape"], mesh=mesh,
                            status="FAIL", note=r.get("error", "")[:60]))
            continue
        t = r["roofline"]
        out.append(dict(
            arch=r["arch"], shape=r["shape"], mesh=mesh, status="ok",
            compute_ms=round(t["compute_s"] * 1e3, 2),
            memory_ms=round(t["memory_s"] * 1e3, 2),
            collective_ms=round(t["collective_s"] * 1e3, 2),
            dcn_ms=round(t["collective_dcn_s"] * 1e3, 2),
            dominant=t["dominant"].replace("_s", ""),
            useful_ratio=round(t["useful_flop_ratio"], 3),
            mfu_bound=round(t["mfu_upper_bound"], 3),
            peak_gib=round(r["memory"]["peak_bytes"] / 2**30, 2),
            fits_16g=r["memory"]["peak_bytes"] < 16 * 2**30,
        ))
    return out


def hillclimb_candidates(rows: List[Dict]) -> Dict[str, str]:
    ok = [r for r in rows if r["status"] == "ok"]
    worst = min(ok, key=lambda r: r["useful_ratio"] if r["useful_ratio"] > 0
                else 1.0)
    coll = max(ok, key=lambda r: r["collective_ms"])
    decodes = [r for r in ok if "decode" in r["shape"] or
               "long" in r["shape"]]
    rep = max(decodes, key=lambda r: r["memory_ms"]) if decodes else ok[0]
    key = lambda r: f"{r['arch']} x {r['shape']} ({r['mesh']})"
    return {"worst_useful_ratio": key(worst),
            "most_collective_bound": key(coll),
            "serving_representative": key(rep)}


def main() -> None:
    for mesh in ("single", "multi"):
        rows = table(mesh)
        if not rows:
            print(f"# no artifacts for mesh={mesh}; run "
                  f"`python -m repro.launch.dryrun --sweep --mesh {mesh}`")
            continue
        print(f"\n## roofline ({mesh}-pod mesh)")
        cols = ["arch", "shape", "status", "compute_ms", "memory_ms",
                "collective_ms", "dcn_ms", "dominant", "useful_ratio",
                "mfu_bound", "peak_gib", "fits_16g"]
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r.get(c, "")) for c in cols))
        ok = [r for r in rows if r["status"] == "ok"]
        fits = sum(1 for r in ok if r["fits_16g"])
        print(f"# {len(ok)} compiled, {fits}/{len(ok)} fit 16 GiB/chip")
        if mesh == "single" and ok:
            print("# hillclimb candidates:", hillclimb_candidates(rows))


if __name__ == "__main__":
    main()

"""Table 3 analog: Dory engine vs the textbook baseline (standard column
reduction over the full boundary matrix, Ripser-style full-filtration
materialization) — time and memory.

The paper's headline is the *memory wall*: representing the full filtration
costs O(n^4) simplices while Dory's working set is O(n_e).  We measure:

* baseline: wall time + peak tracemalloc of ``core/ref.py`` (which
  materializes every simplex up to dim-3, exactly the wall the paper
  describes) — and the simplex count it had to touch;
* Dory (explicit / implicit x single / batch): wall time + peak tracemalloc
  + the engine's own stored-bytes accounting (R^⊥ or V^⊥).

Equality of the output diagrams is asserted — this benchmark doubles as an
end-to-end correctness check.  Scaling n shows the gap growing; the paper's
Table 3 shows the same effect at 5e4-3e6 points where the baseline cannot
run at all.
"""
from __future__ import annotations

import time
import tracemalloc
from typing import Dict, List

import numpy as np

from repro.core import compute_ph, diagrams, ref
from repro.data.pointclouds import clifford_torus


def _measure(fn):
    tracemalloc.start()
    t0 = time.perf_counter()
    out = fn()
    wall = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return out, wall, peak


def run(sizes=(30, 45, 60), maxdim: int = 2) -> List[Dict]:
    rows = []
    for n in sizes:
        pts = clifford_torus(n, seed=0)
        tau = 1.0          # dense enough for real H1/H2 work at small n
        dists = None

        base_pds, base_t, base_mem = _measure(
            lambda: ref.standard_reduction_points(pts, tau_max=tau,
                                                  maxdim=maxdim))
        n_simplices = len(ref.vr_simplices(
            ref.pairwise_distances(pts), tau, maxdim))

        row = dict(n=n, tau=tau, baseline_s=round(base_t, 3),
                   baseline_peak_mb=round(base_mem / 2**20, 2),
                   baseline_simplices=n_simplices)
        for mode in ("explicit", "implicit"):
            res, t, mem = _measure(
                lambda m=mode: compute_ph(points=pts, tau_max=tau,
                                          maxdim=maxdim, mode=m,
                                          engine="batch"))
            diagrams.assert_diagrams_equal(res.diagrams, base_pds,
                                           dims=range(maxdim + 1))
            stored = res.stats.get("h1_stored_bytes", 0) + \
                res.stats.get("h2_stored_bytes", 0)
            row[f"dory_{mode}_s"] = round(t, 3)
            row[f"dory_{mode}_peak_mb"] = round(mem / 2**20, 2)
            row[f"dory_{mode}_stored_kb"] = round(stored / 1024, 1)
        row["n_e"] = int(res.stats["n_e"])
        rows.append(row)
    return rows


def main() -> None:
    rows = run()
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    last = rows[-1]
    print(f"# memory wall: baseline touches {last['baseline_simplices']} "
          f"simplices; Dory stores O(n_e)={last['n_e']} edges "
          f"(+{last['dory_implicit_stored_kb']} kB of V^T) — "
          f"diagrams identical (asserted)")


if __name__ == "__main__":
    main()

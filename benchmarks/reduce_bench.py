"""CI benchmark for the reduction engines -> BENCH_reduce.json.

Runs the same reduction-heavy smoke workload through every engine
(``single`` / ``batch`` / ``packed``) in both storage modes and records
reduction wall time plus reductions/sec, so the perf trajectory of the
packed hot path is pinned per push.  The workload is the suite's
``fractal`` regime (a self-similar random distance matrix, ``maxdim=2``) —
the reduction-bound corner of Table 2, where column chains are deep and the
engines differ the most; the geometric datasets are filtration-bound and
land in ``BENCH_scale.json`` instead.

    PYTHONPATH=src python -m benchmarks.reduce_bench --n 64 --out BENCH_reduce.json

``--min-speedup X`` makes the run assert that the packed engine beats the
single engine by at least ``X``x reductions/sec in the implicit (paper
§4.3.4, memory-bound) mode — the CI contract.  Diagrams are asserted
identical across engines while at it, so the benchmark doubles as an
end-to-end bit-identity check.

``--dist-shards 1,4`` additionally runs the distributed packed driver at
each listed shard count (over a real ``data`` mesh when that many jax
devices exist, the host-partitioned simulation otherwise) and records the
simulated critical-path reduction wall ``sim_wall_s`` per run — the wall a
``P``-device mesh would execute, with per-superstep concurrent phases
taking the slowest shard's time and exchange/tournament/sweep costs on the
critical path (for ``P == 1`` the same accounting reproduces the measured
wall).  ``--max-dist-ratio X`` asserts
``sim_wall(P_max) <= X * sim_wall(P=1)`` in implicit mode — the 4-device
CI contract (``BENCH_reduce_4dev.json``).  Distributed diagrams are
asserted bit-identical to every engine's while at it.
"""
from __future__ import annotations

import argparse
import json
import time

ENGINES = ("single", "batch", "packed")
MODES = ("explicit", "implicit")

# distributed pivot-exchange cadence per mode: implicit ships gens-only
# payloads (cheap wire) and likes frequent rounds; explicit ships full
# R^perp columns, so batching more supersteps per round pays for itself
DIST_EXCHANGE_EVERY = {"implicit": 4, "explicit": 8}

# packed-engine stats surfaced per entry: block-engine counters plus the
# shared pivot-cache counters (cache_n_packs ~ one pack per stored pivot;
# cache_n_pack_hits counts the re-packs the cache absorbed)
PACKED_COUNTERS = (
    "n_rounds", "n_expansions", "n_evictions", "n_consolidations",
    "peak_block_bytes",
    "cache_n_packs", "cache_n_pack_hits",
    "cache_n_materializations", "cache_n_mat_hits",
)
DIST_COUNTERS = (
    "n_supersteps", "n_exchange_rounds", "n_tournament_reductions",
    "n_sweep_probes", "exchange_bytes",
)


def _summed(stats: dict, key: str) -> float:
    """Sum a per-dimension packed counter over the H1 + H2 passes."""
    return stats.get(f"h1_{key}", 0.0) + stats.get(f"h2_{key}", 0.0)


def _cache_summary(stats: dict) -> dict:
    """The S1 story in three numbers: with the shared pivot cache each
    committed pivot is bit-packed once, then every later probe reuses the
    cached positions — packs/pivot sits at ~1 instead of growing with the
    number of times a pivot is hit."""
    packs = _summed(stats, "cache_n_packs")
    hits = _summed(stats, "cache_n_pack_hits")
    stored = _summed(stats, "n_stored_columns")
    return {
        "cache_n_packs": int(packs),
        "cache_n_pack_hits": int(hits),
        "packs_per_stored_pivot": round(packs / max(stored, 1.0), 3),
    }


def run(n: int, seed: int, batch_size: int, maxdim: int = 2) -> dict:
    from repro.core import compute_ph
    from repro.core.diagrams import assert_diagrams_equal
    from repro.data import pointclouds as pc

    dists = pc.fractal_like(n, seed=seed)
    record: dict = {
        "benchmark": "reduce_bench",
        "dataset": "fractal",
        "n": int(n),
        "maxdim": int(maxdim),
        "batch_size": int(batch_size),
        "engines": {},
    }
    reference = None
    for mode in MODES:
        for engine in ENGINES:
            t0 = time.perf_counter()
            res = compute_ph(dists=dists, maxdim=maxdim, engine=engine,
                             mode=mode, batch_size=batch_size)
            wall = time.perf_counter() - t0
            s = res.stats
            red_t = s.get("t_h1", 0.0) + s.get("t_h2", 0.0)
            n_red = s.get("h1_n_reductions", 0.0) \
                + s.get("h2_n_reductions", 0.0)
            entry = {
                "mode": mode,
                "t_reduction_s": round(red_t, 4),
                "t_total_s": round(wall, 4),
                "n_reductions": int(n_red),
                "reductions_per_s": round(n_red / max(red_t, 1e-9), 1),
                "stored_bytes": int(s.get("h2_stored_bytes", 0)),
                # per-phase span breakdown (docs/observability.md;
                # schema-checked by tools/check_bench_schema.py)
                "phases": {
                    "filtration": round(s.get("t_filtration", 0.0), 4),
                    "h0": round(s.get("t_h0", 0.0), 4),
                    "h1": round(s.get("t_h1", 0.0), 4),
                    "h2": round(s.get("t_h2", 0.0), 4),
                },
            }
            if engine == "packed":
                for k in PACKED_COUNTERS:
                    entry[k] = int(_summed(s, k))
                entry.update(_cache_summary(s))
            record["engines"][f"{engine}_{mode}"] = entry
            record["n_e"] = int(s["n_e"])
            if reference is None:
                reference = res.diagrams
            else:   # every engine x mode must reproduce identical diagrams
                assert_diagrams_equal(reference, res.diagrams,
                                      dims=list(range(maxdim + 1)))

    eng = record["engines"]
    for mode in MODES:
        record[f"speedup_rps_packed_vs_single_{mode}"] = round(
            eng[f"packed_{mode}"]["reductions_per_s"]
            / max(eng[f"single_{mode}"]["reductions_per_s"], 1e-9), 2)
    # headline: the memory-bound (implicit) regime the paper optimizes for
    record["speedup_rps_packed_vs_single"] = \
        record["speedup_rps_packed_vs_single_implicit"]
    record["_reference_diagrams"] = reference
    return record


def run_distributed(record: dict, dists, shards: list, batch_size: int,
                    maxdim: int) -> None:
    """Distributed packed runs at each shard count, into ``record``.

    A run at ``P`` shards uses the real ``(data=P,)`` mesh when jax exposes
    exactly ``P`` devices (collective pivot exchange through
    ``jax.lax.all_gather``), and the host-partitioned ``n_shards``
    simulation otherwise — the work split and diagrams are identical either
    way; only the exchange transport differs.
    """
    import jax

    from repro.core import compute_ph
    from repro.core.diagrams import assert_diagrams_equal
    from repro.launch.mesh import make_data_mesh

    reference = record.pop("_reference_diagrams")
    n_dev = jax.device_count()
    record["distributed"] = {}
    for mode in MODES:
        ee = DIST_EXCHANGE_EVERY[mode]
        for P in shards:
            kwargs = ({"mesh": make_data_mesh()} if P == n_dev and P > 1
                      else {"n_shards": P})
            t0 = time.perf_counter()
            res = compute_ph(dists=dists, maxdim=maxdim, engine="packed",
                             mode=mode, batch_size=batch_size,
                             exchange_every=ee, **kwargs)
            wall = time.perf_counter() - t0
            s = res.stats
            entry = {
                "mode": mode,
                "n_shards": int(P),
                "transport": "mesh" if "mesh" in kwargs else "host",
                "exchange_every": int(ee),
                "sim_wall_s": round(_summed(s, "sim_wall_s"), 4),
                "sim_conc_s": round(_summed(s, "sim_conc_s"), 4),
                "sim_sweep_s": round(_summed(s, "sim_sweep_s"), 4),
                "sim_sync_s": round(_summed(s, "sim_sync_s"), 4),
                "t_total_s": round(wall, 4),
                # the sim_wall_s decomposition as the per-phase breakdown
                "phases": {
                    "conc": round(_summed(s, "sim_conc_s"), 4),
                    "sweep": round(_summed(s, "sim_sweep_s"), 4),
                    "sync": round(_summed(s, "sim_sync_s"), 4),
                },
            }
            for k in DIST_COUNTERS:
                entry[k] = int(_summed(s, k))
            entry.update(_cache_summary(s))
            record["distributed"][f"p{P}_{mode}"] = entry
            # the exit bar: diagrams bit-identical to every single-device
            # engine for every shard count
            assert_diagrams_equal(reference, res.diagrams,
                                  dims=list(range(maxdim + 1)))

    p_max = max(shards)
    dist = record["distributed"]
    for mode in MODES:
        base = dist[f"p1_{mode}"]["sim_wall_s"]
        record[f"dist_sim_ratio_{mode}"] = round(
            dist[f"p{p_max}_{mode}"]["sim_wall_s"] / max(base, 1e-9), 3)
    # headline gate metric: the implicit regime (gens-only wire payloads)
    record["dist_sim_ratio"] = record["dist_sim_ratio_implicit"]
    record["dist_p_max"] = int(p_max)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=64,
                    help="fractal point count (reduction work grows ~n^3)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--maxdim", type=int, default=2)
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="assert packed >= X times single reductions/sec "
                         "(implicit mode); the CI contract")
    ap.add_argument("--dist-shards", type=str, default=None,
                    help="comma list of shard counts to run the distributed "
                         "packed driver at, e.g. 1,4")
    ap.add_argument("--max-dist-ratio", type=float, default=None,
                    help="assert sim_wall(P_max) <= X * sim_wall(P=1) in "
                         "implicit mode; the 4-device CI contract")
    ap.add_argument("--out", type=str, default="BENCH_reduce.json")
    args = ap.parse_args()

    from repro.data import pointclouds as pc

    record = run(args.n, args.seed, args.batch_size, maxdim=args.maxdim)
    if args.dist_shards:
        # analyze: allow[raw-filtration-sort] shard counts, not filtration values
        shards = sorted({int(p) for p in args.dist_shards.split(",")})
        assert shards[0] == 1, "--dist-shards needs the P=1 baseline"
        dists = pc.fractal_like(args.n, seed=args.seed)
        run_distributed(record, dists, shards, args.batch_size, args.maxdim)
    else:
        record.pop("_reference_diagrams")
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps(record, indent=2, sort_keys=True))
    print(f"wrote {args.out}")
    if args.min_speedup is not None:
        got = record["speedup_rps_packed_vs_single"]
        assert got >= args.min_speedup, (
            f"packed engine speedup regressed: {got}x < "
            f"{args.min_speedup}x (implicit mode)")
        print(f"speedup {got}x >= {args.min_speedup}x: ok")
    if args.max_dist_ratio is not None:
        got = record["dist_sim_ratio"]
        assert got <= args.max_dist_ratio, (
            f"distributed reduction scaling regressed: sim_wall ratio "
            f"{got} > {args.max_dist_ratio} at P={record['dist_p_max']} "
            f"(implicit mode)")
        print(f"dist sim_wall ratio {got} <= {args.max_dist_ratio}: ok")


if __name__ == "__main__":
    main()

"""CI benchmark for the reduction engines -> BENCH_reduce.json.

Runs the same reduction-heavy smoke workload through every engine
(``single`` / ``batch`` / ``packed``) in both storage modes and records
reduction wall time plus reductions/sec, so the perf trajectory of the
packed hot path is pinned per push.  The workload is the suite's
``fractal`` regime (a self-similar random distance matrix, ``maxdim=2``) —
the reduction-bound corner of Table 2, where column chains are deep and the
engines differ the most; the geometric datasets are filtration-bound and
land in ``BENCH_scale.json`` instead.

    PYTHONPATH=src python -m benchmarks.reduce_bench --n 64 --out BENCH_reduce.json

``--min-speedup X`` makes the run assert that the packed engine beats the
single engine by at least ``X``x reductions/sec in the implicit (paper
§4.3.4, memory-bound) mode — the CI contract.  Diagrams are asserted
identical across engines while at it, so the benchmark doubles as an
end-to-end bit-identity check.
"""
from __future__ import annotations

import argparse
import json
import time

ENGINES = ("single", "batch", "packed")
MODES = ("explicit", "implicit")


def run(n: int, seed: int, batch_size: int, maxdim: int = 2) -> dict:
    from repro.core import compute_ph
    from repro.core.diagrams import assert_diagrams_equal
    from repro.data import pointclouds as pc

    dists = pc.fractal_like(n, seed=seed)
    record: dict = {
        "benchmark": "reduce_bench",
        "dataset": "fractal",
        "n": int(n),
        "maxdim": int(maxdim),
        "batch_size": int(batch_size),
        "engines": {},
    }
    reference = None
    for mode in MODES:
        for engine in ENGINES:
            t0 = time.perf_counter()
            res = compute_ph(dists=dists, maxdim=maxdim, engine=engine,
                             mode=mode, batch_size=batch_size)
            wall = time.perf_counter() - t0
            s = res.stats
            red_t = s.get("t_h1", 0.0) + s.get("t_h2", 0.0)
            n_red = s.get("h1_n_reductions", 0.0) \
                + s.get("h2_n_reductions", 0.0)
            entry = {
                "mode": mode,
                "t_reduction_s": round(red_t, 4),
                "t_total_s": round(wall, 4),
                "n_reductions": int(n_red),
                "reductions_per_s": round(n_red / max(red_t, 1e-9), 1),
                "stored_bytes": int(s.get("h2_stored_bytes", 0)),
            }
            if engine == "packed":
                for k in ("n_rounds", "n_expansions", "n_evictions",
                          "n_consolidations", "peak_block_bytes"):
                    entry[k] = int(s.get(f"h2_{k}", 0))
            record["engines"][f"{engine}_{mode}"] = entry
            record["n_e"] = int(s["n_e"])
            if reference is None:
                reference = res.diagrams
            else:   # every engine x mode must reproduce identical diagrams
                assert_diagrams_equal(reference, res.diagrams,
                                      dims=list(range(maxdim + 1)))

    eng = record["engines"]
    for mode in MODES:
        record[f"speedup_rps_packed_vs_single_{mode}"] = round(
            eng[f"packed_{mode}"]["reductions_per_s"]
            / max(eng[f"single_{mode}"]["reductions_per_s"], 1e-9), 2)
    # headline: the memory-bound (implicit) regime the paper optimizes for
    record["speedup_rps_packed_vs_single"] = \
        record["speedup_rps_packed_vs_single_implicit"]
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=64,
                    help="fractal point count (reduction work grows ~n^3)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--maxdim", type=int, default=2)
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="assert packed >= X times single reductions/sec "
                         "(implicit mode); the CI contract")
    ap.add_argument("--out", type=str, default="BENCH_reduce.json")
    args = ap.parse_args()

    record = run(args.n, args.seed, args.batch_size, maxdim=args.maxdim)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps(record, indent=2, sort_keys=True))
    print(f"wrote {args.out}")
    if args.min_speedup is not None:
        got = record["speedup_rps_packed_vs_single"]
        assert got >= args.min_speedup, (
            f"packed engine speedup regressed: {got}x < "
            f"{args.min_speedup}x (implicit mode)")
        print(f"speedup {got}x >= {args.min_speedup}x: ok")


if __name__ == "__main__":
    main()

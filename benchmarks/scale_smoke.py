"""CI benchmark smoke for ``repro.scale``: tiled build + PH -> BENCH_scale.json.

Small enough for a CI runner, real enough to populate the perf trajectory:
streams a torus4 cloud through the tiled builder under a byte budget, runs
``compute_ph`` on the resulting order-free filtration, and writes one JSON
record (n, n_e, tau, peak-RSS estimate, wall times, memory accounts).
``--devices N`` shards the harvest (mesh or host-partitioned) and adds the
per-device fields.  Field-by-field reference: docs/benchmarks.md.

    PYTHONPATH=src python -m benchmarks.scale_smoke --n 3000 --out BENCH_scale.json
"""
from __future__ import annotations

import argparse
import json
import resource
import sys
import time


def peak_rss_bytes() -> int:
    """ru_maxrss is KiB on Linux, bytes on macOS."""
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return int(rss) * (1 if sys.platform == "darwin" else 1024)


def run(n: int, budget_mb: float, tile: int, maxdim: int, seed: int,
        devices: int = 1) -> dict:
    import numpy as np

    from repro.core import compute_ph
    from repro.data import pointclouds as pc
    from repro.scale import (build_filtration_sharded, build_filtration_tiled,
                             estimate_tau_max)

    budget = int(budget_mb * 2**20)
    pts = pc.clifford_torus(n, seed=seed)

    t0 = time.perf_counter()
    tau = estimate_tau_max(pts, budget, seed=seed, n_shards=devices,
                           tile_m=tile, tile_n=tile)
    t_budget = time.perf_counter() - t0

    shard_mode = None
    t0 = time.perf_counter()
    if devices > 1:
        # real (data=N,) mesh when the process has the devices (CI's
        # 4-virtual-device job), host-partitioned shards otherwise — the
        # tile split, merge, and per-device accounting are identical
        import jax
        mesh = None
        if len(jax.devices()) >= devices:
            from repro.launch.mesh import make_data_mesh
            mesh = make_data_mesh(devices)
            shard_mode = "mesh"
        else:
            shard_mode = "host"
        filt, stats = build_filtration_sharded(
            points=pts, tau_max=tau, tile_m=tile, tile_n=tile, mesh=mesh,
            n_shards=None if mesh is not None else devices,
            return_stats=True)
    else:
        filt, stats = build_filtration_tiled(points=pts, tau_max=tau,
                                             tile_m=tile, tile_n=tile,
                                             return_stats=True)
    t_filtration = time.perf_counter() - t0

    t0 = time.perf_counter()
    res = compute_ph(filtration=filt, maxdim=maxdim)
    t_ph = time.perf_counter() - t0

    from repro.scale import account_bytes
    predicted = account_bytes(filt.n, filt.n_e)

    record = {
        "benchmark": "scale_smoke",
        "dataset": "torus4",
        "n": int(filt.n),
        "n_e": int(filt.n_e),
        "maxdim": int(maxdim),
        "tau_max": float(tau) if np.isfinite(tau) else None,   # stable schema
        "tile": int(tile),
        "backend": stats.backend,
        "tiles_visited": int(stats.tiles_visited),
        "memory_budget_bytes": budget,
        "base_memory_bytes": int(filt.base_memory_bytes()),
        "peak_tile_bytes": int(stats.peak_tile_bytes),
        "harvest_bytes": int(stats.harvest_bytes),
        "dense_path_bytes": int(n) * int(n) * 8,   # what the seed path needs
        "peak_rss_bytes": peak_rss_bytes(),
        "t_budget_s": round(t_budget, 4),
        "t_filtration_s": round(t_filtration, 4),
        "t_ph_s": round(t_ph, 4),
        # per-phase breakdown (docs/observability.md; schema-checked by
        # tools/check_bench_schema.py) + observed-vs-predicted memory
        "phases": {
            "budget": round(t_budget, 4),
            "filtration": round(t_filtration, 4),
            "ph": round(t_ph, 4),
        },
        "predicted_account_bytes": int(predicted),
        "observed_peak_harvest_bytes": int(stats.peak_extra_bytes()),
        "budget_drift_ratio": round(
            (filt.base_memory_bytes() + stats.peak_extra_bytes())
            / max(predicted, 1.0), 3),
        "n_pairs": {str(d): int(len(pd)) for d, pd in res.diagrams.items()},
    }
    if devices > 1:
        record.update({
            "n_shards": int(stats.n_shards),
            "shard_mode": shard_mode,
            "gather_bytes": int(stats.gather_bytes),
            "shard_peak_harvest_bytes": int(stats.shard_peak_harvest_bytes),
            "per_device_peak_bytes": int(stats.per_device_peak_bytes()),
            "per_device_base_bytes": int(stats.per_device_base_bytes()),
        })
    # the whole point: the streamed build must fit the account it was given
    # (per device when sharded — every device duplicates the 3n vertex words
    # but holds only its edge share)
    fit = record["per_device_base_bytes"] if devices > 1 \
        else record["base_memory_bytes"]
    assert fit <= 1.2 * budget, record
    assert record["peak_tile_bytes"] < record["dense_path_bytes"], record
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=3000)
    ap.add_argument("--budget-mb", type=float, default=1.5)
    ap.add_argument("--tile", type=int, default=1024)
    ap.add_argument("--maxdim", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the harvest over N devices (a real mesh "
                         "when available, host-partitioned otherwise)")
    ap.add_argument("--out", type=str, default="BENCH_scale.json")
    args = ap.parse_args()

    record = run(args.n, args.budget_mb, args.tile, args.maxdim, args.seed,
                 devices=args.devices)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps(record, indent=2, sort_keys=True))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()

"""CI benchmark for the PH serving engine -> BENCH_serve.json.

Drives ``PHServeEngine`` through the canonical serving traffic shape — a
cold wave of distinct datasets (union-batched into block-diagonal
reductions), then an update wave of warm tau-growth and point-arrival
requests against the cache — and records the service-level numbers CI
gates on: requests/sec, cache-hit ratio, and p50/p95 per-request latency.

    PYTHONPATH=src python -m benchmarks.serve_bench --requests 24 \
        --out BENCH_serve.json

``--min-rps X`` asserts end-to-end throughput (the CI contract);
``--min-hit-ratio X`` asserts the update wave actually lands on the cache.
Diagrams on the warm paths are asserted bit-identical to cold
``compute_ph`` while at it, so the benchmark doubles as an end-to-end
warm-start correctness check.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

SERVE_COUNTERS = (
    "serve_ph_n_requests", "serve_ph_n_admitted", "serve_ph_n_rejected",
    "serve_ph_n_cache_hits", "serve_ph_n_cache_misses",
    "serve_ph_n_warm_tau", "serve_ph_n_warm_points", "serve_ph_n_cold",
    "serve_ph_n_batched", "serve_ph_n_batches", "serve_ph_n_evictions",
)


def run(args) -> dict:
    from repro.core.homology import compute_ph
    from repro.core.resume import canonical_diagram
    from repro.obs.trace import stopwatch
    from repro.serve.ph import PHRequest, PHServeEngine

    engine = PHServeEngine(
        memory_budget_bytes=args.budget_bytes,
        store_budget_bytes=args.store_budget_bytes,
        max_batch_clouds=args.max_batch_clouds,
        seed=args.seed,
        engine=args.reduce_engine,
        batch_size=args.batch_size)
    rng = np.random.default_rng(args.seed)
    n_cold = max(1, args.requests // 2)
    clouds = [rng.normal(size=(args.cloud_size, 3)) for _ in range(n_cold)]

    uid = 0
    for k, p in enumerate(clouds):
        engine.submit(PHRequest(uid=uid, points=p, tau_max=args.tau,
                                dataset=f"ds{k}"))
        uid += 1
    with stopwatch("serve_bench/cold") as sw_cold:
        engine.run()

    verify = []
    while uid < args.requests:
        k = int(rng.integers(0, n_cold))
        if uid % 2 == 0:
            req = PHRequest(uid=uid, points=clouds[k],
                            tau_max=args.tau * 1.5, dataset=f"ds{k}")
        else:
            grown = np.concatenate(
                [clouds[k], rng.normal(size=(args.arrivals, 3))], axis=0)
            req = PHRequest(uid=uid, points=grown, tau_max=args.tau,
                            dataset=f"ds{k}")
        engine.submit(req)
        verify.append((uid, req.points))
        uid += 1
    with stopwatch("serve_bench/warm") as sw_warm:
        engine.run()

    # warm responses must be bit-identical to cold compute_ph
    n_verified = 0
    for vuid, pts in verify[:args.verify]:
        resp = engine.done[vuid]
        if not resp.admitted:
            continue
        ref = compute_ph(points=pts, tau_max=resp.granted_tau, maxdim=2,
                         mode="implicit")
        for d in (0, 1, 2):
            assert np.array_equal(resp.diagrams[d],
                                  canonical_diagram(ref.diagrams[d])), \
                (vuid, d, resp.path)
        n_verified += 1

    s = engine.stats()
    lat = sorted(r.latency_s for r in engine.done.values())
    lat_arr = np.array(lat) if lat else np.zeros(1)
    wall = sw_cold.elapsed + sw_warm.elapsed
    n_req = len(engine.done)
    record = {
        "benchmark": "serve_bench",
        "requests": int(n_req),
        "cloud_size": int(args.cloud_size),
        "reduce_engine": args.reduce_engine,
        "requests_per_s": round(n_req / max(wall, 1e-9), 2),
        "cache_hit_ratio": round(
            s.get("serve_ph_n_cache_hits", 0.0)
            / max(s.get("serve_ph_n_requests", 0.0), 1.0), 4),
        "latency_p50_s": round(float(np.quantile(lat_arr, 0.5)), 4),
        "latency_p95_s": round(float(np.quantile(lat_arr, 0.95)), 4),
        "latency_max_s": round(float(lat_arr.max()), 4),
        "t_total_s": round(wall, 4),
        "n_warm_verified": int(n_verified),
        "store_bytes": int(s.get("serve_ph_store_bytes", 0)),
        "phases": {
            "cold": round(sw_cold.elapsed, 4),
            "warm": round(sw_warm.elapsed, 4),
        },
    }
    for k in SERVE_COUNTERS:
        record[k] = int(s.get(k, 0.0))
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--cloud-size", type=int, default=40)
    ap.add_argument("--tau", type=float, default=1.6)
    ap.add_argument("--arrivals", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-bytes", type=int, default=None)
    ap.add_argument("--store-budget-bytes", type=int, default=None)
    ap.add_argument("--max-batch-clouds", type=int, default=8)
    ap.add_argument("--reduce-engine", default="single",
                    choices=("single", "batch", "packed"))
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--verify", type=int, default=4,
                    help="warm responses to check bit-identical vs cold")
    ap.add_argument("--min-rps", type=float, default=None,
                    help="assert requests/sec >= X; the CI contract")
    ap.add_argument("--min-hit-ratio", type=float, default=None,
                    help="assert cache-hit ratio >= X")
    ap.add_argument("--out", type=str, default="BENCH_serve.json")
    args = ap.parse_args()

    record = run(args)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps(record, indent=2, sort_keys=True))
    print(f"wrote {args.out}")
    if args.min_rps is not None:
        got = record["requests_per_s"]
        assert got >= args.min_rps, (
            f"serving throughput regressed: {got} req/s < {args.min_rps}")
        print(f"throughput {got} req/s >= {args.min_rps}: ok")
    if args.min_hit_ratio is not None:
        got = record["cache_hit_ratio"]
        assert got >= args.min_hit_ratio, (
            f"cache-hit ratio regressed: {got} < {args.min_hit_ratio}")
        print(f"cache-hit ratio {got} >= {args.min_hit_ratio}: ok")


if __name__ == "__main__":
    main()

"""Fig. 21 analog: percentage change in loops (H1) and voids (H2) upon
auxin treatment of the genome-like cloud, as a function of the persistence
threshold."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import compute_ph

from .suite import build_suite


def _count(pd: np.ndarray, thr: float) -> int:
    if pd.size == 0:
        return 0
    return int((pd[:, 1] - pd[:, 0] > thr).sum())


def run(scale: float = 1.0) -> List[Dict]:
    suite = build_suite(scale)
    res_c = compute_ph(engine="batch", **suite["hic_control"].kwargs())
    res_a = compute_ph(engine="batch", **suite["hic_auxin"].kwargs())
    rows = []
    for thr in (0.02, 0.05, 0.08):
        for d in (1, 2):
            nc = _count(res_c.diagrams[d], thr)
            na = _count(res_a.diagrams[d], thr)
            rows.append(dict(
                dim=f"H{d}", threshold=thr, control=nc, auxin=na,
                pct_change=round(100.0 * (na - nc) / max(nc, 1), 1)))
    return rows


def main(scale: float = 1.0) -> None:
    rows = run(scale)
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    h1 = [r for r in rows if r["dim"] == "H1" and r["threshold"] >= 0.05]
    assert all(r["pct_change"] < 0 for r in h1), \
        "auxin should remove persistent loops (Fig. 21)"
    print("# direction reproduced: auxin removes loops/voids "
          "(paper Fig. 21)")


if __name__ == "__main__":
    main()

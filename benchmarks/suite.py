"""Shared benchmark dataset suite (paper Table 1, scaled to CPU budgets).

The paper's six data sets are reproduced at laptop scale: ``o3`` and
``torus4`` follow the published definitions exactly (scaled n); ``dragon`` /
``fractal`` are generated stand-ins with the same regimes (3-D surface
cloud; self-similar network distance matrix); the Hi-C pair is the §6
genome workload (control vs auxin).  ``--full`` in benchmarks/run.py scales
n up ~4x.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.data import pointclouds as pc


@dataclasses.dataclass
class Dataset:
    name: str
    maxdim: int
    tau_max: float
    points: Optional[np.ndarray] = None
    dists: Optional[np.ndarray] = None

    def kwargs(self) -> Dict:
        out: Dict = dict(tau_max=self.tau_max, maxdim=self.maxdim)
        if self.points is not None:
            out["points"] = self.points
        else:
            out["dists"] = self.dists
        return out


def build_suite(scale: float = 1.0) -> Dict[str, Dataset]:
    s = scale
    control, auxin = pc.hic_pair(int(350 * s), n_loops=24, seed=1)
    return {
        "dragon": Dataset("dragon", maxdim=1, tau_max=np.inf,
                          points=pc.dragon_like(int(800 * s), seed=0)),
        "fractal": Dataset("fractal", maxdim=2, tau_max=np.inf,
                           dists=pc.fractal_like(int(128 * s), seed=0)),
        "o3": Dataset("o3", maxdim=2, tau_max=0.9,
                      points=pc.o3_points(int(512 * s), seed=0)),
        "torus4_1": Dataset("torus4_1", maxdim=1, tau_max=0.4,
                            points=pc.clifford_torus(int(800 * s), seed=0)),
        "torus4_2": Dataset("torus4_2", maxdim=2, tau_max=0.4,
                            points=pc.clifford_torus(int(800 * s), seed=0)),
        "hic_control": Dataset("hic_control", maxdim=2, tau_max=0.6,
                               points=control),
        "hic_auxin": Dataset("hic_auxin", maxdim=2, tau_max=0.6,
                             points=auxin),
    }

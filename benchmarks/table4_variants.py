"""Table 4 analog: engine-variant comparison — explicit vs implicit
(V^⊥-only) storage x single-column vs serial-parallel batched reduction,
plus batch-size sensitivity (the paper's hyperparameter discussion)."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import compute_ph
from repro.core.diagrams import assert_diagrams_equal

from .suite import build_suite

_BENCH = ("o3", "torus4_2", "hic_control")


def run(scale: float = 1.0) -> List[Dict]:
    rows = []
    for name, ds in build_suite(scale).items():
        if name not in _BENCH:
            continue
        ref_pds = None
        for mode in ("explicit", "implicit"):
            for engine, bs in (("single", 0), ("batch", 32), ("batch", 128),
                               ("batch", 512)):
                t0 = time.perf_counter()
                res = compute_ph(engine=engine, mode=mode, batch_size=bs or 128,
                                 **ds.kwargs())
                wall = time.perf_counter() - t0
                if ref_pds is None:
                    ref_pds = res.diagrams
                else:
                    assert_diagrams_equal(res.diagrams, ref_pds)
                stored = res.stats.get("h1_stored_bytes", 0) + \
                    res.stats.get("h2_stored_bytes", 0)
                reductions = res.stats.get("h1_n_reductions", 0) + \
                    res.stats.get("h2_n_reductions", 0)
                rows.append(dict(
                    dataset=name, mode=mode, engine=engine,
                    batch=bs, total_s=round(wall, 3),
                    stored_kb=round(stored / 1024, 1),
                    n_reductions=int(reductions)))
    return rows


def main(scale: float = 1.0) -> None:
    rows = run(scale)
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    print("# all variants produce identical diagrams (asserted); implicit "
          "trades stored bytes for re-enumeration time (paper Table 4)")


if __name__ == "__main__":
    main()

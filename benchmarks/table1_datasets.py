"""Table 1 analog: dataset statistics — n, tau_max, n_e, maxdim, simplex
counts, base memory.

``N`` (the number of simplices a full-filtration representation must touch,
the paper's memory-wall column) is counted exactly for edges/triangles via
sparse adjacency intersection; the paper's point is that ``n_e`` (what Dory
stores) is orders of magnitude below ``N``.

``--large`` adds the regime the dense path cannot touch: o3/torus4 at 50k+
points through the ``repro.scale`` tiled builder under a byte budget — the
dense ``(n, n)`` float64 matrix alone would be 20+ GB — asserting that peak
filtration memory (one tile + COO harvest + the paper's base account) stays
under budget.

    PYTHONPATH=src python -m benchmarks.table1_datasets [--scale S]
        [--large] [--large-n 50000] [--budget-mb 96]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from repro.core.filtration import build_filtration
from repro.data import pointclouds as pc
from repro.scale import build_filtration_tiled, estimate_tau_max

from .suite import Dataset, build_suite


def count_triangles(filt) -> int:
    """Exact permissible-triangle count via neighborhood intersections."""
    n = filt.n
    adj: List[set] = [set() for _ in range(n)]
    for a, b in filt.edges:
        adj[int(a)].add(int(b))
        adj[int(b)].add(int(a))
    total = 0
    for a, b in filt.edges:
        a, b = int(a), int(b)
        # count each triangle once: at its diameter edge? cheaper: count
        # (a,b,c) with c in both neighborhoods, divide by 3 at the end
        total += len(adj[a] & adj[b])
    return total // 3


def run(scale: float = 1.0) -> List[Dict]:
    rows = []
    for name, ds in build_suite(scale).items():
        filt = build_filtration(points=ds.points, dists=ds.dists,
                                tau_max=ds.tau_max)
        n_tri = count_triangles(filt) if filt.n_e < 200_000 else -1
        rows.append(dict(
            dataset=name, n=filt.n,
            tau_max=("inf" if np.isinf(ds.tau_max) else ds.tau_max),
            d=ds.maxdim, n_e=filt.n_e, n_triangles=n_tri,
            base_memory_mb=round(filt.base_memory_bytes() / 2**20, 3),
            edge_density=round(
                filt.n_e / (filt.n * (filt.n - 1) / 2), 4),
        ))
    return rows


def run_large(large_n: int = 50_000, budget_mb: float = 96.0,
              tile: int = 2048, datasets=("torus4", "o3")) -> List[Dict]:
    """Large-n rows via the tiled builder — impossible on the dense path.

    Picks ``tau_max`` from the byte budget, streams the build, and asserts
    the memory account: base memory fits the budget and peak transient
    memory is one tile + O(n + n_e), orders of magnitude under the dense
    ``(n, n)`` matrix the seed builder would allocate.
    """
    budget = int(budget_mb * 2**20)
    makers = {"torus4": lambda n: pc.clifford_torus(n, seed=0),
              "o3": lambda n: pc.o3_points(n, seed=0)}
    rows = []
    for name in datasets:
        pts = makers[name](large_n)
        tau = estimate_tau_max(pts, budget, seed=0)
        t0 = time.perf_counter()
        filt, stats = build_filtration_tiled(points=pts, tau_max=tau,
                                             tile_m=tile, tile_n=tile,
                                             return_stats=True)
        t_build = time.perf_counter() - t0
        base = filt.base_memory_bytes()
        peak = stats.peak_extra_bytes() + base
        dense_bytes = large_n * large_n * 8       # f64 dists the seed needs
        assert base <= 1.2 * budget, (name, base, budget)
        # the streamed-build guarantee: one tile (f64 + two bool masks) plus
        # O(n_e) COO merge transients — never an O(n^2) term
        tile_scratch = tile * tile * 10
        assert stats.peak_extra_bytes() <= tile_scratch + 48 * filt.n_e \
            + 2**20, (name, stats.peak_extra_bytes(), tile_scratch, filt.n_e)
        assert filt.dense_order is None           # no O(n^2) order matrix
        rows.append(dict(
            dataset=f"{name}@{large_n}", n=filt.n,
            tau_max=round(float(tau), 4), d="1 (tiled)", n_e=filt.n_e,
            n_triangles=-1,
            base_memory_mb=round(base / 2**20, 3),
            peak_build_mb=round(peak / 2**20, 3),
            dense_path_mb=round(dense_bytes / 2**20, 1),
            t_build_s=round(t_build, 2),
            edge_density=round(
                filt.n_e / (filt.n * (filt.n - 1) / 2), 6),
        ))
    return rows


def _print_rows(rows: List[Dict]) -> None:
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


def main(scale: float = 1.0, large: bool = False, large_n: int = 50_000,
         budget_mb: float = 96.0) -> None:
    _print_rows(run(scale))
    if large:
        print()
        _print_rows(run_large(large_n=large_n, budget_mb=budget_mb))


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--large", action="store_true",
                    help="add 50k+-point tiled rows (minutes of CPU)")
    ap.add_argument("--large-n", type=int, default=50_000)
    ap.add_argument("--budget-mb", type=float, default=96.0)
    args = ap.parse_args()
    main(args.scale, large=args.large, large_n=args.large_n,
         budget_mb=args.budget_mb)

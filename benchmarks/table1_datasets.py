"""Table 1 analog: dataset statistics — n, tau_max, n_e, maxdim, simplex
counts, base memory.

``N`` (the number of simplices a full-filtration representation must touch,
the paper's memory-wall column) is counted exactly for edges/triangles via
sparse adjacency intersection; the paper's point is that ``n_e`` (what Dory
stores) is orders of magnitude below ``N``.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.filtration import build_filtration

from .suite import Dataset, build_suite


def count_triangles(filt) -> int:
    """Exact permissible-triangle count via neighborhood intersections."""
    n = filt.n
    adj: List[set] = [set() for _ in range(n)]
    for a, b in filt.edges:
        adj[int(a)].add(int(b))
        adj[int(b)].add(int(a))
    total = 0
    for a, b in filt.edges:
        a, b = int(a), int(b)
        # count each triangle once: at its diameter edge? cheaper: count
        # (a,b,c) with c in both neighborhoods, divide by 3 at the end
        total += len(adj[a] & adj[b])
    return total // 3


def run(scale: float = 1.0) -> List[Dict]:
    rows = []
    for name, ds in build_suite(scale).items():
        filt = build_filtration(points=ds.points, dists=ds.dists,
                                tau_max=ds.tau_max)
        n_tri = count_triangles(filt) if filt.n_e < 200_000 else -1
        rows.append(dict(
            dataset=name, n=filt.n,
            tau_max=("inf" if np.isinf(ds.tau_max) else ds.tau_max),
            d=ds.maxdim, n_e=filt.n_e, n_triangles=n_tri,
            base_memory_mb=round(filt.base_memory_bytes() / 2**20, 3),
            edge_density=round(
                filt.n_e / (filt.n * (filt.n - 1) / 2), 4),
        ))
    return rows


def main(scale: float = 1.0) -> None:
    rows = run(scale)
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()

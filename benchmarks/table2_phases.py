"""Table 2 analog: per-phase timing of the Dory pipeline — filtration (+
neighborhoods), H0, H1*, H2* — on the benchmark suite.

    PYTHONPATH=src python -m benchmarks.table2_phases --engine packed --scale 0.5

``--engine`` picks the reduction engine (``single`` / ``batch`` /
``packed``); per-phase reduction counts ride along so the engines'
reduction throughput can be compared row by row.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

from repro.core import compute_ph

from .suite import build_suite


def run(scale: float = 1.0, engine: str = "batch",
        batch_size: int = 256) -> List[Dict]:
    rows = []
    for name, ds in build_suite(scale).items():
        t0 = time.perf_counter()
        res = compute_ph(engine=engine, batch_size=batch_size, **ds.kwargs())
        wall = time.perf_counter() - t0
        s = res.stats
        rows.append(dict(
            dataset=name, n=int(s["n"]), n_e=int(s["n_e"]),
            engine=engine,
            t_filtration_s=round(s["t_filtration"], 3),
            t_h0_s=round(s["t_h0"], 3),
            t_h1_s=round(s.get("t_h1", 0.0), 3),
            t_h2_s=round(s.get("t_h2", 0.0), 3),
            n_reductions_h1=int(s.get("h1_n_reductions", 0)),
            n_reductions_h2=int(s.get("h2_n_reductions", 0)),
            total_s=round(wall, 3),
            h1_pairs=len(res.diagrams.get(1, ())),
            h2_pairs=len(res.diagrams.get(2, ())),
        ))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", default="batch",
                    choices=["single", "batch", "packed"],
                    help="reduction engine for the H1*/H2* phases")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="dataset size multiplier (suite is laptop-scale "
                         "at 1.0)")
    ap.add_argument("--batch-size", type=int, default=256,
                    help="serial-parallel batch width (batch/packed)")
    args = ap.parse_args(argv)

    rows = run(args.scale, engine=args.engine, batch_size=args.batch_size)
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()

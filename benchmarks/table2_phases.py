"""Table 2 analog: per-phase timing of the Dory pipeline — filtration (+
neighborhoods), H0, H1*, H2* — on the benchmark suite."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import compute_ph

from .suite import build_suite


def run(scale: float = 1.0, engine: str = "batch") -> List[Dict]:
    rows = []
    for name, ds in build_suite(scale).items():
        t0 = time.perf_counter()
        res = compute_ph(engine=engine, **ds.kwargs())
        wall = time.perf_counter() - t0
        s = res.stats
        rows.append(dict(
            dataset=name, n=int(s["n"]), n_e=int(s["n_e"]),
            t_filtration_s=round(s["t_filtration"], 3),
            t_h0_s=round(s["t_h0"], 3),
            t_h1_s=round(s.get("t_h1", 0.0), 3),
            t_h2_s=round(s.get("t_h2", 0.0), 3),
            total_s=round(wall, 3),
            h1_pairs=len(res.diagrams.get(1, ())),
            h2_pairs=len(res.diagrams.get(2, ())),
        ))
    return rows


def main(scale: float = 1.0) -> None:
    rows = run(scale)
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


if __name__ == "__main__":
    main()

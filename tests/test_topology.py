"""Known-topology checks: the engine recovers textbook Betti structure."""
import numpy as np
import pytest

from repro.core import compute_ph
from repro.data.pointclouds import (circle_points, clifford_torus, o3_points,
                                    sphere_points, two_circles)


def top_persistence(pd, k=1):
    pd = pd[np.isfinite(pd[:, 1])] if pd.size else pd
    if pd.size == 0:
        return np.zeros(k)
    pers = np.sort(pd[:, 1] - pd[:, 0])
    return pers[-k:]


def test_circle_h1():
    """Unit circle: one H1 class; for a fine regular sample the death is at
    sqrt(3) (equilateral-triangle fill) — an exact, analytic check."""
    r = compute_ph(points=circle_points(24), maxdim=1)
    pd1 = r.diagrams[1]
    pers = pd1[:, 1] - pd1[:, 0]
    dominant = pd1[np.argmax(pers)]
    assert np.isclose(dominant[1], np.sqrt(3), atol=1e-9)
    # exactly one class at intermediate scale
    assert r.betti_at(1.0)[1] == 1


def test_two_circles_h1():
    r = compute_ph(points=two_circles(n=20, separation=6.0), maxdim=1)
    assert r.betti_at(1.0)[1] == 2
    assert r.betti_at(1.0)[0] == 2      # two components at small scale


def test_sphere_h2():
    pts = sphere_points(42, seed=0)
    r = compute_ph(points=pts, maxdim=2)
    pd2 = r.diagrams[2]
    assert pd2.shape[0] >= 1
    # the dominant void should clearly outlive noise
    pers = np.sort(pd2[:, 1] - pd2[:, 0])
    assert pers[-1] > 3 * (pers[-2] if len(pers) > 1 else 0.01)


def test_clifford_torus_h1():
    """Clifford torus S1 x S1: two independent H1 generators."""
    pts = clifford_torus(n=144, seed=1, grid=True)
    r = compute_ph(points=pts, tau_max=0.8, maxdim=1)
    # after the lattice squares fill (death ~0.518) only the two torus
    # generators survive; they never die below tau_max.
    assert r.betti_at(0.6)[1] == 2, r.diagrams[1]
    pd1 = r.diagrams[1]
    assert int(np.isinf(pd1[:, 1]).sum()) == 2


def test_o3_generation_shape():
    """o3 data set (paper Table 1): random orthogonal 3x3 matrices as points
    in R^9 — verify orthogonality and PH pipeline runs with tau_max=1."""
    pts = o3_points(64, seed=0)
    assert pts.shape == (64, 9)
    m = pts.reshape(-1, 3, 3)
    eye = np.einsum("nij,nkj->nik", m, m)
    assert np.allclose(eye, np.eye(3), atol=1e-8)
    r = compute_ph(points=pts, tau_max=1.0, maxdim=1)
    assert r.stats["n_e"] > 0


@pytest.mark.parametrize("tau", [0.3, 0.7])
def test_betti_curve_monotonicity_h0(tau):
    """beta_0 decreases with scale (components only merge)."""
    pts = circle_points(30, noise=0.05, seed=2)
    r = compute_ph(points=pts, maxdim=0)
    assert r.betti_at(tau)[0] >= r.betti_at(tau + 0.5)[0]

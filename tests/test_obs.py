"""repro.obs: span tracing, Chrome-trace export, metrics registry.

Covers the ISSUE 8 acceptance criteria directly: span nesting and
exception safety, disabled-mode cost, Chrome trace_event schema with
per-device lanes and >= 95% wall coverage on the packed 4-shard path,
stats-dict backward compatibility across engines x modes x shard
counts, and the span-derived simulated critical path agreeing with the
engine's own bookkeeping.
"""
import json
import os
import time

import numpy as np
import pytest

from repro.core import compute_ph
from repro.obs.metrics import SCHEMA, MetricsRegistry, schema_markdown
from repro.obs.trace import (Span, Tracer, active_tracer, chrome_trace,
                             coverage, critical_path, span, stopwatch,
                             traced, tracing)


def cloud(seed=3, n=24):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, 3))


# ---------------------------------------------------------------------------
# span lifecycle
# ---------------------------------------------------------------------------

def test_spans_nest_and_record_attrs():
    tr = Tracer()
    with tracing(tr):
        with span("ph/compute_ph", engine="packed"):
            with span("harvest/tile", tile="0,1", lane=2) as sp:
                sp.set(n_edges=7)
    tr.assert_balanced()
    names = [s.name for s in tr.spans]
    assert names == ["harvest/tile", "ph/compute_ph"]  # inner closes first
    tile = tr.spans[0]
    assert tile.lane == 2
    assert tile.attrs == {"tile": "0,1", "n_edges": 7}
    assert tile.dur >= 0.0
    outer = tr.spans[1]
    assert outer.t0 <= tile.t0 and tile.t1 <= outer.t1


def test_span_closes_on_exception_path():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tracing(tr):
            with span("ph/h1"):
                raise RuntimeError("boom")
    # the span still closed and recorded; nothing left open
    tr.assert_balanced()
    assert [s.name for s in tr.spans] == ["ph/h1"]
    assert active_tracer() is None          # tracing() restored the global


def test_open_spans_reported_while_inside():
    tr = Tracer()
    with tracing(tr):
        with span("ph/h1"):
            assert tr.open_spans() == ["ph/h1"]
            with pytest.raises(RuntimeError):
                tr.assert_balanced()
    tr.assert_balanced()


def test_stopwatch_times_even_when_disabled():
    assert active_tracer() is None
    with stopwatch("ph/filtration") as sw:
        time.sleep(0.002)
    assert sw.elapsed >= 0.002
    tr = Tracer()
    with tracing(tr):
        with stopwatch("ph/filtration") as sw:
            pass
    assert [s.name for s in tr.spans] == ["ph/filtration"]
    assert sw.elapsed >= 0.0


def test_traced_decorator_records_qualname():
    tr = Tracer()

    @traced()
    def work(x):
        return x + 1

    with tracing(tr):
        assert work(1) == 2
    assert len(tr.spans) == 1 and "work" in tr.spans[0].name


def test_disabled_mode_is_a_shared_noop():
    assert active_tracer() is None
    a = span("reduce/fused", step=0)
    b = span("harvest/tile", tile="0,0")
    assert a is b                           # singleton: no allocation
    with a as sp:
        sp.set(anything=1)                  # no-op, no state
    assert a.dur == 0.0


def test_disabled_mode_overhead_is_small():
    """100k disabled span entries must cost well under a second."""
    assert active_tracer() is None
    t0 = time.perf_counter()
    for _ in range(100_000):
        with span("reduce/fused", step=0):
            pass
    assert time.perf_counter() - t0 < 1.0


# ---------------------------------------------------------------------------
# tracing() knob resolution
# ---------------------------------------------------------------------------

def test_tracing_false_is_noop():
    with tracing(False):
        assert active_tracer() is None
        assert span("ph/h1").dur == 0.0


def test_tracing_env_path_exports(tmp_path, monkeypatch):
    out = tmp_path / "env_trace.json"
    monkeypatch.setenv("REPRO_TRACE", str(out))
    with tracing(None):
        with span("ph/compute_ph"):
            pass
    doc = json.loads(out.read_text())
    assert any(e.get("name") == "ph/compute_ph" for e in doc["traceEvents"])


def test_tracing_nested_none_keeps_outer_tracer():
    tr = Tracer()
    with tracing(tr):
        with tracing(None) as inner:
            assert inner is tr
            with span("ph/h0"):
                pass
    assert [s.name for s in tr.spans] == ["ph/h0"]


def test_tracing_rejects_garbage():
    with pytest.raises(TypeError):
        with tracing(123):
            pass


# ---------------------------------------------------------------------------
# Chrome trace schema
# ---------------------------------------------------------------------------

def _check_chrome_schema(doc):
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    ms = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert xs and ms
    for e in xs:
        assert set(e) >= {"name", "ph", "ts", "dur", "pid", "tid"}
        assert e["ts"] >= 0 and e["dur"] >= 0
        json.dumps(e["args"])               # attrs must be JSON-clean
    names = {e["args"]["name"] for e in ms if e["name"] == "thread_name"}
    return xs, names


def test_chrome_trace_synthetic_lanes():
    tr = Tracer()
    with tracing(tr):
        with span("reduce/slice", lane=0, step=0):
            pass
        with span("reduce/slice", lane=3, step=0):
            pass
        with span("ph/compute_ph"):
            pass
    xs, thread_names = _check_chrome_schema(tr.chrome_trace())
    assert {e["tid"] for e in xs} == {0, 1, 4}   # host + lanes 0 and 3
    assert "host" in thread_names and "device:3" in thread_names


def test_export_refuses_unbalanced(tmp_path):
    tr = Tracer()
    with tracing(tr):
        ctx = tr.span("ph/h1")
        ctx.__enter__()                     # deliberately leaked open
        with pytest.raises(RuntimeError):
            tr.export_chrome(str(tmp_path / "bad.json"))
        ctx.__exit__(None, None, None)


def test_compute_ph_trace_has_device_lanes_and_coverage(tmp_path):
    """Acceptance: packed 4-shard trace is Perfetto-loadable, >= 4 device
    lanes, spans covering >= 95% of the traced wall."""
    out = tmp_path / "packed4.json"
    res = compute_ph(points=cloud(), engine="packed", n_shards=4,
                     trace=str(out))
    doc = json.loads(out.read_text())
    xs, thread_names = _check_chrome_schema(doc)
    device_tids = {e["tid"] for e in xs if e["tid"] > 0}
    assert len(device_tids) >= 4
    assert {"device:0", "device:1", "device:2", "device:3"} <= thread_names
    # reconstruct coverage: union of spans / extent of the trace
    t0 = min(e["ts"] for e in xs)
    t1 = max(e["ts"] + e["dur"] for e in xs)
    ivs = sorted((e["ts"], e["ts"] + e["dur"]) for e in xs)
    covered, hi = 0.0, t0
    for a, b in ivs:
        a = max(a, hi)
        if b > a:
            covered += b - a
            hi = b
    assert covered / (t1 - t0) >= 0.95
    assert res.stats["h1_n_pairs"] >= 0        # result itself is intact


def test_coverage_helper_merges_overlaps():
    mk = lambda a, b: Span("x", None, a, b, {})
    assert coverage([mk(0, 1), mk(0.5, 2), mk(3, 4)]) == pytest.approx(0.75)
    assert coverage([]) == 0.0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_is_typed():
    reg = MetricsRegistry()
    reg.counter("n_reductions").inc(3)
    with pytest.raises(TypeError):
        reg.gauge("n_reductions")           # declared a counter
    with pytest.raises(KeyError):
        reg.counter("not_a_metric")
    reg.register("not_a_metric", "counter")
    reg.counter("not_a_metric").inc()
    assert reg.as_stats()["not_a_metric"] == 1.0


def test_registry_histogram_flattens():
    reg = MetricsRegistry()
    h = reg.histogram("superstep_conc_s")
    for v in (0.5, 1.5, 1.0):
        h.observe(v)
    s = reg.as_stats()
    assert s["superstep_conc_s_count"] == 3.0
    assert s["superstep_conc_s_sum"] == pytest.approx(3.0)
    assert s["superstep_conc_s_min"] == 0.5
    assert s["superstep_conc_s_max"] == 1.5


def test_registry_update_from_legacy_dict():
    reg = MetricsRegistry()
    reg.counter("cache_n_pack_hits").inc(2)
    reg.update_from({"cache_n_pack_hits": 3, "stored_bytes": 100,
                     "unknown_key": 1})
    s = reg.as_stats()
    assert s["cache_n_pack_hits"] == 5.0      # counters add
    assert s["stored_bytes"] == 100.0       # gauges set
    assert "unknown_key" not in s           # off-schema keys dropped


def test_schema_markdown_lists_every_metric():
    table = schema_markdown()
    for name in SCHEMA:
        assert f"`{name}`" in table


# ---------------------------------------------------------------------------
# stats backward compatibility across engines x modes x shards
# ---------------------------------------------------------------------------

LEGACY_KEYS = ("n", "n_e", "t_filtration", "t_h1",
               "h1_n_columns", "h1_n_reductions", "h1_n_pairs",
               "h1_stored_bytes", "h2_n_columns",
               "predicted_account_bytes", "budget_drift_ratio")


@pytest.mark.parametrize("engine", ["single", "batch", "packed"])
@pytest.mark.parametrize("mode", ["explicit", "implicit"])
def test_stats_schema_stable_across_engines(engine, mode):
    res = compute_ph(points=cloud(), engine=engine, mode=mode)
    for key in LEGACY_KEYS:
        assert key in res.stats, key
    # every emitted stat resolves to a schema entry (base name for
    # histogram expansions, h1_/h2_ prefixes stripped)
    for key in res.stats:
        base = key[3:] if key.startswith(("h1_", "h2_")) else key
        for suffix in ("_count", "_sum", "_min", "_max"):
            if base.endswith(suffix) and base[:-len(suffix)] in SCHEMA:
                base = base[:-len(suffix)]
                break
        assert base in SCHEMA, key


@pytest.mark.parametrize("n_shards", [1, 4])
def test_stats_schema_stable_across_shards(n_shards):
    res = compute_ph(points=cloud(), engine="packed", n_shards=n_shards)
    assert res.stats["h1_n_shards"] == n_shards
    for key in ("h1_sim_wall_s", "h1_sim_wall_bookkeeping_s",
                "h1_n_supersteps"):
        assert key in res.stats, key


def test_engines_agree_on_counted_work():
    """Migrating stats to the registry must not change their values:
    pair/column counts agree across engines on the same cloud."""
    pts = cloud(seed=11)
    per = {e: compute_ph(points=pts, engine=e).stats
           for e in ("single", "batch", "packed")}
    for key in ("h1_n_pairs", "h1_n_essential", "h2_n_pairs", "n", "n_e"):
        vals = {round(s[key], 6) for s in per.values()}
        assert len(vals) == 1, (key, per)


# ---------------------------------------------------------------------------
# simulated critical path (satellite b)
# ---------------------------------------------------------------------------

def test_critical_path_synthetic_dag():
    mk = lambda name, lane, dur, **at: Span(name, lane, 0.0, dur, at)
    spans = [
        mk("reduce/fused", None, 1.0, step=0, weights=(0.5, 0.5)),
        mk("reduce/slice", 0, 0.2, step=0),
        mk("reduce/slice", 1, 0.6, step=0),
        mk("reduce/tournament", None, 0.1, step=0),
        mk("reduce/sweep", 0, 0.3, step=0, deps=()),
        mk("reduce/sweep", 1, 0.4, step=0, deps=(0,)),
        mk("reduce/encode", 0, 0.2, step=0),
        mk("reduce/encode", 1, 0.5, step=0),
        mk("reduce/exchange", None, 0.3, step=0),
        mk("ph/compute_ph", None, 99.0),          # ignored: not reduce/*
    ]
    cp = critical_path(spans)
    assert cp["sim_conc_s"] == pytest.approx(1.1)    # max(.5+.2, .5+.6)
    assert cp["sim_sweep_s"] == pytest.approx(0.7)   # 0.3 then dependent 0.4
    assert cp["sim_sync_s"] == pytest.approx(0.9)    # .1 + max(enc) + .3
    assert cp["sim_wall_s"] == pytest.approx(2.7)


def test_sim_wall_matches_bookkeeping_on_4dev_path():
    """ISSUE 8 bugfix regression: the span-derived critical path and the
    engine's own bookkeeping are two accountings of the same timeline and
    must agree on the 4-virtual-device path."""
    res = compute_ph(points=cloud(seed=5, n=32), engine="packed",
                     n_shards=4)
    for dim in ("h1", "h2"):
        wall = res.stats[f"{dim}_sim_wall_s"]
        book = res.stats[f"{dim}_sim_wall_bookkeeping_s"]
        assert wall == pytest.approx(book, rel=1e-9, abs=1e-12), dim
        assert wall > 0.0


# ---------------------------------------------------------------------------
# memory observability
# ---------------------------------------------------------------------------

def test_memory_gauges_on_tiled_backend():
    from repro.scale import account_bytes
    pts = cloud(seed=7, n=64)
    res = compute_ph(points=pts, backend="tiled", tile_m=16, tile_n=16)
    s = res.stats
    n, n_e = int(s["n"]), int(s["n_e"])
    assert s["predicted_account_bytes"] == account_bytes(n, n_e)
    assert account_bytes(n, n_e) == (3 * n + 12 * n_e) * 4
    assert s["observed_peak_harvest_bytes"] > 0
    assert s["observed_peak_reduce_bytes"] > 0
    assert s["budget_drift_ratio"] > 0

"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp/numpy oracles,
swept over shapes and dtypes, plus hypothesis property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gf2 import gf2_find_low, gf2_serial_reduce
from repro.kernels.pairwise_dist import pairwise_sq_dists
from repro.kernels import ops


# ---------------------------------------------------------------------------
# pairwise_dist
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,d,block", [
    (256, 256, 3, 128), (128, 256, 9, 128), (256, 128, 4, 64),
    (512, 256, 16, 256),
])
def test_pairwise_dist_kernel(m, n, d, block):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    out = pairwise_sq_dists(x, y, block_m=block, block_n=block, interpret=True)
    expect = kref.pairwise_sq_dists_ref(x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_dist_dtypes(dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(128, 8)), dtype)
    out = pairwise_sq_dists(x, x, block_m=128, block_n=128, interpret=True)
    expect = kref.pairwise_sq_dists_ref(x, x)
    atol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=atol)
    assert np.allclose(np.diag(np.asarray(out)), 0.0, atol=atol)


def test_ops_pairwise_padding_path():
    """ops wrapper pads ragged row counts before tiling."""
    rng = np.random.default_rng(2)
    x = rng.normal(size=(77, 5))
    out = ops.pairwise_distances(x, use_pallas=True, interpret=True, block=64)
    from repro.core.filtration import pairwise_distances as np_pd
    np.testing.assert_allclose(np.asarray(out), np_pd(x), rtol=1e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# gf2
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,w", [(128, 8), (256, 64), (128, 1)])
def test_find_low_kernel(c, w):
    rng = np.random.default_rng(3)
    cols = rng.integers(0, 2**32, size=(c, w), dtype=np.uint32)
    cols[::7] = 0                             # some empty columns
    out = np.asarray(gf2_find_low(jnp.asarray(cols), block_c=128,
                                  interpret=True))
    np.testing.assert_array_equal(out, kref.gf2_find_low_ref(cols))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_find_low_hypothesis(seed):
    rng = np.random.default_rng(seed)
    w = int(rng.integers(1, 16))
    cols = (rng.integers(0, 2**32, size=(128, w), dtype=np.uint32)
            * rng.integers(0, 2, size=(128, w), dtype=np.uint32))
    out = np.asarray(gf2_find_low(jnp.asarray(cols), interpret=True))
    np.testing.assert_array_equal(out, kref.gf2_find_low_ref(cols))


@pytest.mark.parametrize("g,c,w", [(1, 8, 4), (2, 16, 8), (4, 32, 2)])
def test_gf2_serial_reduce_kernel(g, c, w):
    rng = np.random.default_rng(4)
    # sparse-ish random columns so collisions actually happen
    blocks = (rng.integers(0, 2**32, size=(g, c, w), dtype=np.uint32)
              & rng.integers(0, 2**32, size=(g, c, w), dtype=np.uint32)
              & rng.integers(0, 2**32, size=(g, c, w), dtype=np.uint32))
    got_b, got_l, got_r = gf2_serial_reduce(jnp.asarray(blocks),
                                            interpret=True)
    exp_b, exp_l, exp_r = kref.gf2_serial_reduce_ref(blocks)
    np.testing.assert_array_equal(np.asarray(got_b), exp_b)
    np.testing.assert_array_equal(np.asarray(got_l), exp_l)
    np.testing.assert_array_equal(np.asarray(got_r), exp_r)


def test_gf2_serial_reduce_invariant():
    """Post-condition: non-empty columns have pairwise-distinct lows."""
    rng = np.random.default_rng(5)
    blocks = (rng.integers(0, 2**32, size=(2, 24, 4), dtype=np.uint32)
              & rng.integers(0, 2**32, size=(2, 24, 4), dtype=np.uint32))
    _, lows, _ = gf2_serial_reduce(jnp.asarray(blocks), interpret=True)
    lows = np.asarray(lows)
    for g in range(lows.shape[0]):
        nz = lows[g][lows[g] != 2**31 - 1]
        assert len(np.unique(nz)) == len(nz)


def test_gf2_reduction_preserves_span():
    """GF(2) row space of the block is invariant under reduction."""
    rng = np.random.default_rng(6)
    blocks = rng.integers(0, 2**8, size=(1, 10, 1), dtype=np.uint32)
    red, _, _ = gf2_serial_reduce(jnp.asarray(blocks), interpret=True)

    def span(mat):
        vecs = set()
        rows = [int(x) for x in mat]
        for m in range(2 ** len(rows)):
            acc = 0
            for i, r in enumerate(rows):
                if m >> i & 1:
                    acc ^= r
            vecs.add(acc)
        return vecs

    assert span(blocks[0, :, 0]) == span(np.asarray(red)[0, :, 0])


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,d,bq,bk", [(128, 64, 64, 64), (256, 32, 128, 128)])
def test_flash_attention_kernel(causal, s, d, bq, bk):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, s, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    expect = kref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_window():
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(1, 256, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=64, block_q=64,
                          block_k=64, interpret=True)
    expect = kref.attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, tol):
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 128, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 128, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 128, 64)), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    expect = kref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_kernel_vs_engine_distance_path():
    """The Pallas distance kernel feeds the PH engine identically to the
    numpy path (filtration-level end-to-end check)."""
    rng = np.random.default_rng(10)
    pts = rng.normal(size=(40, 3))
    d_pallas = np.asarray(ops.pairwise_distances(pts, use_pallas=True,
                                                 interpret=True, block=64))
    from repro.core import compute_ph
    from repro.core.diagrams import assert_diagrams_equal
    a = compute_ph(points=pts, maxdim=1)
    b = compute_ph(dists=np.asarray(d_pallas, np.float64), maxdim=1)
    assert_diagrams_equal(a.diagrams, b.diagrams, dims=[0, 1], atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.sampled_from([64, 128, 192]),
       st.sampled_from([32, 64, 128]), st.booleans(),
       st.integers(0, 2**31 - 1))
def test_flash_attention_hypothesis_sweep(b, s, d, causal, seed):
    """Property sweep: kernel == oracle across random (B, S, D, causal)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    expect = kref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-4, atol=3e-4)

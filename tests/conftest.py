"""Test bootstrap: make ``repro`` importable and the suite runnable with or
without the real dev dependencies installed.

* prepends ``src/`` to ``sys.path`` so ``python -m pytest`` works without
  ``PYTHONPATH=src``;
* if ``hypothesis`` (declared in requirements-dev.txt) is missing from the
  environment, registers the deterministic API-compatible fallback in
  ``_hypothesis_fallback.py`` so the property tests still collect and run.
"""
import importlib.util
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    _path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "_hypothesis_fallback.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies

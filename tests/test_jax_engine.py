"""JAX engine exactness: jitted column algebra vs the host engine, Borůvka
H0 vs union-find, and the device parallel phase against a complete pivot
table.  (The multi-device shard_map round is exercised in
``tests/test_distributed.py`` via a subprocess with fake devices.)
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import build_filtration
from repro.core.coboundary import edge_cobdy_ns, min_edge_cobdy_all
from repro.core.h0 import compute_h0
from repro.core.homology import make_h1_adapter
from repro.core.jax_engine import (EMPTY, h0_msf_mask, merge_cancel_jax,
                                   parallel_reduce_jit, truncate_width,
                                   connected_labels)
from repro.core.pairing import EMPTY_KEY
from repro.core.reduction import merge_cancel, reduce_dimension


def pad_to(arr, width):
    out = np.full(width, EMPTY_KEY, dtype=np.int64)
    out[:len(arr)] = arr
    return out


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_merge_cancel_jax_matches_numpy(data):
    a = np.unique(np.array(
        data.draw(st.lists(st.integers(0, 200), max_size=24)), dtype=np.int64))
    b = np.unique(np.array(
        data.draw(st.lists(st.integers(0, 200), max_size=24)), dtype=np.int64))
    W = 32
    out = np.asarray(merge_cancel_jax(pad_to(a, W)[None], pad_to(b, W)[None]))[0]
    got = out[out != EMPTY_KEY]
    assert np.array_equal(got, merge_cancel(a, b))


def test_truncate_width_flags_overflow():
    cols = jnp.asarray(pad_to(np.arange(10, dtype=np.int64), 16)[None])
    t, ov = truncate_width(cols, 8)
    assert t.shape == (1, 8) and bool(ov[0])
    t, ov = truncate_width(cols, 12)
    assert t.shape == (1, 12) and not bool(ov[0])


@pytest.mark.parametrize("seed", [0, 5, 9])
def test_h0_boruvka_matches_union_find(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 80))
    pts = rng.normal(size=(n, 3))
    tau = float(rng.uniform(0.5, 2.5))
    filt = build_filtration(points=pts, tau_max=tau)
    if filt.n_e == 0:
        pytest.skip("empty filtration")
    uf = compute_h0(filt)
    mask = np.asarray(h0_msf_mask(jnp.asarray(filt.edges), n))
    assert set(np.where(mask)[0].tolist()) == set(uf.death_edges.tolist())
    labels = np.asarray(connected_labels(jnp.asarray(filt.edges), n))
    assert len(np.unique(labels)) == uf.n_essential


def test_device_parallel_phase_reproduces_host_pivots():
    """For each probe column, hand the device parallel phase exactly the
    pivots committed *before* it (committed R columns of earlier edges +
    trivial pairs owned by earlier edges) and check the device reduces the
    raw coboundary to exactly the host-computed pivot low (or to zero for
    essential columns).  This proves the jitted path performs the same GF(2)
    reduction as the host engine under true usage semantics."""
    rng = np.random.default_rng(12)
    pts = rng.normal(size=(14, 3))
    filt = build_filtration(points=pts)
    h0 = compute_h0(filt)
    cleared = set(int(e) for e in h0.death_edges)
    adapter = make_h1_adapter(filt, sparse=False)
    cols = np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)
    _, store = reduce_dimension(adapter, cols, mode="explicit",
                                cleared=cleared, return_store=True)
    min_cob = min_edge_cobdy_all(filt, sparse=False)

    committed_low_of = {store.col_ids[i]: low
                        for low, i in store.low_to_idx.items()}
    host_low = dict(committed_low_of)
    for e in range(filt.n_e):
        mc = int(min_cob[e])
        if e not in host_low and e not in cleared and \
                mc != EMPTY_KEY and (mc >> 32) == e:
            host_low[e] = mc            # trivial pair (mc, e)

    W = 512
    probe_ids = [int(e) for e in cols if int(e) not in cleared][::3][:12]
    for e in probe_ids:
        entries = {}
        for low, idx in store.low_to_idx.items():
            if store.col_ids[idx] > e:          # processed earlier (desc)
                entries[low] = store.columns[idx]
        for e2 in range(e + 1, filt.n_e):
            mc = int(min_cob[e2])
            if mc != EMPTY_KEY and (mc >> 32) == e2 and mc not in entries \
                    and e2 not in cleared:
                cob = edge_cobdy_ns(filt, np.array([e2]))[0]
                entries[mc] = cob[cob != EMPTY_KEY]
        keys = np.array(sorted(entries), dtype=np.int64) if entries else \
            np.array([EMPTY_KEY], dtype=np.int64)
        table = np.stack([pad_to(entries[k], W) for k in sorted(entries)]) \
            if entries else np.full((1, W), EMPTY_KEY, dtype=np.int64)
        raw = edge_cobdy_ns(filt, np.array([e]))[0]
        raw_p = pad_to(raw[raw != EMPTY_KEY], W)[None]
        out, _ = parallel_reduce_jit(jnp.asarray(raw_p), jnp.asarray(keys),
                                     jnp.asarray(table), n_iters=256)
        low = int(np.asarray(out)[0, 0])
        expect = host_low.get(e, int(EMPTY_KEY))
        assert low == expect, (e, low, expect)

"""Edge cases of the admission-side memory account (ISSUE 9, satellite).

``estimate_tau_max`` inverts the paper's ``(3n + 12 n_e) * 4``-byte base
account into a tau cap.  These tests pin its behavior exactly at the
account boundary, on degenerate (<= 2 point, duplicate, all-tied) clouds,
and end-to-end through the serving engine's admission controller.
"""
import numpy as np
import pytest

from repro.scale.budget import (account_bytes, edge_budget,
                                estimate_tau_max)
from repro.serve.ph import PHRequest, PHServeEngine


def simplex_points(n):
    """n points pairwise equidistant (sqrt(2)): rows of the identity."""
    return np.eye(n)


# ---------------------------------------------------------------------------
# the account boundary
# ---------------------------------------------------------------------------

def test_budget_exactly_at_account_boundary_covers_full_clique():
    """budget == (3n + 12 n_e) * 4 with n_e the full clique -> inf."""
    n = 10
    total_pairs = n * (n - 1) // 2
    budget = account_bytes(n, total_pairs)
    assert edge_budget(n, budget) == total_pairs
    pts = np.random.default_rng(0).normal(size=(n, 3))
    assert estimate_tau_max(pts, budget) == np.inf


def test_budget_one_edge_below_boundary_is_finite():
    n = 10
    total_pairs = n * (n - 1) // 2
    budget = account_bytes(n, total_pairs) - 1   # one byte under
    assert edge_budget(n, budget) == total_pairs - 1
    pts = np.random.default_rng(0).normal(size=(n, 3))
    tau = estimate_tau_max(pts, budget, n_samples=50_000)
    assert np.isfinite(tau) and tau > 0


def test_budget_below_o_n_floor_raises():
    n = 10
    floor = 3 * n * 4            # the O(n) vertex arrays alone
    pts = np.random.default_rng(0).normal(size=(n, 3))
    with pytest.raises(ValueError, match="cannot hold even the O\\(n\\)"):
        estimate_tau_max(pts, floor)     # zero edges affordable
    # one more edge's worth admits
    assert estimate_tau_max(pts, account_bytes(n, 1),
                            n_samples=10_000) >= 0.0


def test_edge_budget_inverts_account_bytes_exactly():
    for n in (2, 7, 100):
        for n_e in (0, 1, 13, n * (n - 1) // 2):
            assert edge_budget(n, account_bytes(n, n_e)) == n_e
            assert edge_budget(n, account_bytes(n, n_e) + 47) == n_e
            assert edge_budget(n, account_bytes(n, n_e) + 48) == n_e + 1


# ---------------------------------------------------------------------------
# degenerate clouds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [0, 1, 2])
def test_tiny_clouds_with_room_return_inf(n):
    pts = np.zeros((n, 3))
    assert estimate_tau_max(pts, 10_000) == np.inf


def test_two_points_exact_boundary():
    pts = np.array([[0.0, 0.0], [3.0, 4.0]])     # one pair, length 5
    assert estimate_tau_max(pts, account_bytes(2, 1)) == np.inf
    with pytest.raises(ValueError):
        estimate_tau_max(pts, account_bytes(2, 0))


def test_duplicate_points_give_zero_tau():
    """All sampled pair lengths are 0, so every quantile is 0."""
    pts = np.zeros((40, 3))
    budget = account_bytes(40, 100)          # affords 100 of 780 pairs
    assert estimate_tau_max(pts, budget, n_samples=5_000) == 0.0


def test_all_tied_distances_return_the_tied_value():
    """With every pairwise distance equal, the empirical quantile is that
    distance at any budgeted fraction — the estimate cannot separate
    edges the metric does not separate (callers see the whole clique
    admitted at tau = the tie)."""
    pts = simplex_points(12)                 # all distances sqrt(2)
    budget = account_bytes(12, 5)            # affords only 5 of 66 pairs
    tau = estimate_tau_max(pts, budget, n_samples=5_000)
    assert tau == pytest.approx(np.sqrt(2.0))


def test_estimate_is_deterministic_in_seed():
    pts = np.random.default_rng(1).normal(size=(30, 3))
    budget = account_bytes(30, 60)
    a = estimate_tau_max(pts, budget, n_samples=2_000, seed=7)
    b = estimate_tau_max(pts, budget, n_samples=2_000, seed=7)
    c = estimate_tau_max(pts, budget, n_samples=2_000, seed=8)
    assert a == b
    assert np.isfinite(a) and np.isfinite(c)


# ---------------------------------------------------------------------------
# the same edges through the serving admission controller
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2])
def test_serve_tiny_clouds_end_to_end(n):
    pts = np.arange(n * 3, dtype=np.float64).reshape(n, 3)
    eng = PHServeEngine(engine="single")
    eng.submit(PHRequest(uid=0, points=pts, tau_max=10.0))
    eng.run()
    r = eng.done[0]
    assert r.admitted
    assert r.diagrams[1].shape == (0, 2)
    assert r.diagrams[2].shape == (0, 2)
    # H0: n - 1 finite deaths at most, one essential component
    assert np.isinf(r.diagrams[0]).sum() == 1


def test_serve_duplicate_points_cloud():
    pts = np.zeros((8, 3))
    eng = PHServeEngine(engine="single")
    eng.submit(PHRequest(uid=0, points=pts, tau_max=1.0))
    eng.run()
    r = eng.done[0]
    assert r.admitted
    # zero-length edges merge everything at 0: no finite H0 bars survive
    # the zero-persistence filter, one essential component, no H1/H2
    assert np.isinf(r.diagrams[0]).sum() == 1
    assert r.diagrams[1].shape == (0, 2)


def test_serve_admission_account_at_boundary():
    n = 12
    pts = np.random.default_rng(2).normal(size=(n, 3))
    total_pairs = n * (n - 1) // 2
    eng = PHServeEngine(memory_budget_bytes=account_bytes(n, total_pairs),
                        engine="single")
    eng.submit(PHRequest(uid=0, points=pts, tau_max=np.inf))
    eng.run()
    r = eng.done[0]
    # the boundary budget covers the full clique: nothing clamped
    assert r.admitted and r.granted_tau == np.inf
    assert r.admission.n_e_est == total_pairs
    assert r.admission.predicted_bytes == account_bytes(n, total_pairs)

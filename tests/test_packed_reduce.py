"""Packed reduction engine: bit-identity vs the other engines, block
primitive properties, spill policy, and the reductions/sec contract.

The packed engine must be a pure performance move: every diagram it
produces is asserted bit-identical to ``reduce_dimension`` across modes,
budgets, batch sizes, kernel paths, and tie-heavy filtrations.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import build_filtration, compute_ph
from repro.core.diagrams import assert_diagrams_equal
from repro.core.h0 import compute_h0
from repro.core.homology import make_h1_adapter, make_h2_adapter, h2_columns
from repro.core.packed_reduce import reduce_dimension_packed
from repro.core.reduction import (DimensionAdapter, PivotStore,
                                  merge_cancel, reduce_dimension)
from repro.kernels.gf2 import (NO_LOW, bits_to_keys, find_low_np,
                               gf2_parallel_xor, gf2_serial_reduce,
                               pack_keys_to_bits, scatter_bits,
                               set_bit_positions)
from repro.kernels import ref as kref


def random_cloud(seed, n=None, d=3):
    rng = np.random.default_rng(seed)
    n = n or int(rng.integers(8, 20))
    return rng.normal(size=(n, d))


def tie_heavy_cloud(seed, n=16):
    """Integer grid points: many exactly-equal pairwise distances."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 4, size=(n, 3)).astype(np.float64)


# ---------------------------------------------------------------------------
# block primitives
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(seed):
    rng = np.random.default_rng(seed)
    universe = np.unique(rng.integers(0, 2**40, size=60).astype(np.int64))
    rows = [np.sort(rng.choice(universe, size=rng.integers(0, len(universe)),
                               replace=False))
            for _ in range(int(rng.integers(1, 9)))]
    packed = pack_keys_to_bits(rows, universe)
    back = bits_to_keys(packed, universe)
    assert len(back) == len(rows)
    for a, b in zip(rows, back):
        np.testing.assert_array_equal(a, b)
    # find-low == rank of each row's min key; numpy mirror == kernel ref
    lows = find_low_np(packed)
    for i, r in enumerate(rows):
        expect = NO_LOW if not r.size else int(
            np.searchsorted(universe, r[0]))
        assert lows[i] == expect
    np.testing.assert_array_equal(lows, kref.gf2_find_low_ref(packed))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_set_bit_positions_matches_unpackbits(seed):
    rng = np.random.default_rng(seed)
    block = (rng.integers(0, 2**32, size=(6, 5), dtype=np.uint32)
             & rng.integers(0, 2**32, size=(6, 5), dtype=np.uint32))
    ridx, pos, counts = set_bit_positions(block)
    bits = np.unpackbits(np.ascontiguousarray(block).view(np.uint8),
                         bitorder="little").reshape(6, -1)
    rr, pp = np.nonzero(bits)
    np.testing.assert_array_equal(ridx, rr)
    np.testing.assert_array_equal(pos, pp)
    np.testing.assert_array_equal(counts, bits.sum(axis=1))


def test_scatter_bits_matches_pack():
    rng = np.random.default_rng(7)
    universe = np.unique(rng.integers(0, 10**6, size=80).astype(np.int64))
    rows = [np.sort(rng.choice(universe, size=k, replace=False))
            for k in (0, 3, 17, 40)]
    packed = pack_keys_to_bits(rows, universe)
    manual = np.zeros_like(packed)
    lens = np.array([len(r) for r in rows])
    ridx = np.repeat(np.arange(len(rows)), lens)
    pos = np.searchsorted(universe, np.concatenate(rows))
    scatter_bits(manual, ridx, pos)
    np.testing.assert_array_equal(packed, manual)


@pytest.mark.parametrize("c,w", [(8, 4), (128, 16), (130, 3)])
def test_gf2_parallel_xor_kernel(c, w):
    rng = np.random.default_rng(11)
    a = rng.integers(0, 2**32, size=(c, w), dtype=np.uint32)
    b = rng.integers(0, 2**32, size=(c, w), dtype=np.uint32)
    out = np.asarray(gf2_parallel_xor(jnp.asarray(a), jnp.asarray(b),
                                      interpret=True))
    np.testing.assert_array_equal(out, a ^ b)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_packed_lows_equal_merge_cancel_lows(seed):
    """Property: serial-reducing a packed block yields exactly the lows a
    merge_cancel-based left-to-right reduction of the same GF(2) columns
    produces (the canonical-pairing invariant the engine leans on)."""
    rng = np.random.default_rng(seed)
    universe = np.unique(rng.integers(0, 10**9, size=48).astype(np.int64))
    C = int(rng.integers(2, 12))
    rows = [np.sort(rng.choice(universe, size=rng.integers(0, 20),
                               replace=False)) for _ in range(C)]

    # oracle: standard column algorithm on sorted key arrays
    reduced, low_of = [], {}
    oracle_lows = []
    for r in rows:
        r = r.copy()
        while r.size and int(r[0]) in low_of:
            r = merge_cancel(r, reduced[low_of[int(r[0])]])
        if r.size:
            low_of[int(r[0])] = len(reduced)
        oracle_lows.append(int(r[0]) if r.size else None)
        reduced.append(r)

    packed = pack_keys_to_bits(rows, universe)
    _, lows, _ = gf2_serial_reduce(jnp.asarray(packed[None]),
                                   interpret=True)
    got = np.asarray(lows)[0]
    for i in range(C):
        if oracle_lows[i] is None:
            assert got[i] == NO_LOW
        else:
            assert universe[got[i]] == oracle_lows[i]


# ---------------------------------------------------------------------------
# bit-identity sweep: packed vs single vs batch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["explicit", "implicit"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_packed_matches_single_full_pipeline(mode, seed):
    pts = random_cloud(seed)
    tau = np.inf if seed % 2 == 0 else 1.6
    a = compute_ph(points=pts, tau_max=tau, maxdim=2, mode=mode,
                   engine="single")
    b = compute_ph(points=pts, tau_max=tau, maxdim=2, mode=mode,
                   engine="packed")
    for d in (0, 1, 2):
        assert np.array_equal(a.diagrams[d], b.diagrams[d]), d


@pytest.mark.parametrize("budget", [None, 200, 2000])
@pytest.mark.parametrize("batch_size", [3, 32, 256])
def test_packed_budget_batchsize_sweep(budget, batch_size):
    pts = random_cloud(5, n=18)
    a = compute_ph(points=pts, tau_max=1.8, maxdim=2, engine="single")
    b = compute_ph(points=pts, tau_max=1.8, maxdim=2, engine="packed",
                   batch_size=batch_size, memory_budget_bytes=budget,
                   backend="dense")
    for d in (0, 1, 2):
        assert np.array_equal(a.diagrams[d], b.diagrams[d]), d


@pytest.mark.parametrize("seed", [0, 3])
def test_packed_tie_heavy_cloud(seed):
    """Integer grids maximize filtration ties — the stress case for
    low-collision bookkeeping."""
    pts = tie_heavy_cloud(seed)
    for mode in ("explicit", "implicit"):
        a = compute_ph(points=pts, maxdim=2, mode=mode, engine="single")
        b = compute_ph(points=pts, maxdim=2, mode=mode, engine="packed",
                       batch_size=16)
        c = compute_ph(points=pts, maxdim=2, mode=mode, engine="batch",
                       batch_size=16)
        for d in (0, 1, 2):
            assert np.array_equal(a.diagrams[d], b.diagrams[d]), (mode, d)
            assert np.array_equal(a.diagrams[d], c.diagrams[d]), (mode, d)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), batch_size=st.sampled_from([2, 16, 64]))
def test_packed_equals_single_hypothesis(seed, batch_size):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(int(rng.integers(8, 16)), 3))
    filt = build_filtration(points=pts, tau_max=np.inf)
    h0 = compute_h0(filt)
    cols = np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)
    a1 = make_h1_adapter(filt, sparse=True)
    single = reduce_dimension(a1, cols, mode="explicit",
                              cleared=h0.death_edges)
    packed = reduce_dimension_packed(a1, cols, mode="implicit",
                                     cleared=h0.death_edges,
                                     batch_size=batch_size)
    assert np.array_equal(single.diagram(), packed.diagram())
    assert set(single.pivot_lows.tolist()) == set(packed.pivot_lows.tolist())


def test_packed_kernel_path_matches_host():
    """use_kernels=True (interpret off-TPU) must match the numpy block
    path bit for bit, H1* and H2*."""
    pts = random_cloud(13, n=14)
    filt = build_filtration(points=pts)
    h0 = compute_h0(filt)
    cols = np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)
    a1 = make_h1_adapter(filt, sparse=True)
    host = reduce_dimension_packed(a1, cols, cleared=h0.death_edges,
                                   use_kernels=False, batch_size=16)
    kern = reduce_dimension_packed(a1, cols, cleared=h0.death_edges,
                                   use_kernels=True, batch_size=16)
    assert np.array_equal(host.diagram(), kern.diagram())
    a2 = make_h2_adapter(filt, sparse=True)
    cols2 = h2_columns(filt, host.pivot_lows, sparse=True)
    h2h = reduce_dimension_packed(a2, cols2, use_kernels=False,
                                  batch_size=16)
    h2k = reduce_dimension_packed(a2, cols2, use_kernels=True,
                                  batch_size=16)
    assert np.array_equal(h2h.diagram(), h2k.diagram())


def test_packed_h2_full_pipeline_vs_oracle():
    from repro.core import ref

    pts = random_cloud(42, n=16)
    o = ref.standard_reduction_points(pts, maxdim=2)
    r = compute_ph(points=pts, maxdim=2, engine="packed", batch_size=8,
                   mode="implicit")
    assert_diagrams_equal(r.diagrams, o, dims=[0, 1, 2])


# ---------------------------------------------------------------------------
# budget semantics: batched engine + largest-first spill policy
# ---------------------------------------------------------------------------

def test_batched_engine_budget_same_diagrams():
    pts = random_cloud(8, n=24)
    a = compute_ph(points=pts, maxdim=2, engine="single")
    b = compute_ph(points=pts, maxdim=2, engine="batch",
                   memory_budget_bytes=64, backend="dense")
    for d in (0, 1, 2):
        assert np.array_equal(a.diagrams[d], b.diagrams[d]), d
    spilled = b.stats["h1_n_spilled"] + b.stats["h2_n_spilled"]
    assert spilled > 0      # the budget actually engaged


def test_spill_policy_demotes_largest_first():
    """With a budget, the explicit set keeps the *smallest* columns: a big
    incoming column demotes nothing (it spills itself), while a small
    incoming column demotes the largest resident."""
    adapter = DimensionAdapter(*([None] * 5))   # commit never probes it
    store = PivotStore(adapter, "explicit", store_budget_bytes=200)
    gens = np.zeros(0, dtype=np.int64)

    def col(n):
        return np.arange(n, dtype=np.int64)

    store.commit(1, 101, col(10), gens, False)   # 80 B
    store.commit(2, 102, col(12), gens, False)   # 96 B -> 176 B stored
    assert store.col_modes == ["explicit", "explicit"]
    # bigger than everything resident: it goes implicit itself
    store.commit(3, 103, col(20), gens, False)
    assert store.col_modes == ["explicit", "explicit", "implicit"]
    assert store.n_spilled == 1
    # small column: the largest resident (col 102, 96 B) is demoted for it
    store.commit(4, 104, col(4), gens, False)
    assert store.col_modes == ["explicit", "implicit", "implicit",
                               "explicit"]
    assert store.n_spilled == 2
    assert store.bytes_stored <= 200


def test_packed_stats_shape():
    pts = random_cloud(2, n=16)
    r = compute_ph(points=pts, maxdim=2, engine="packed")
    for key in ("h1_n_reductions", "h1_peak_block_bytes", "h1_n_rounds",
                "h1_n_evictions", "h2_n_reductions", "h2_stored_bytes"):
        assert key in r.stats, key


# ---------------------------------------------------------------------------
# the perf contract, in-suite (coarse: CI runners are noisy)
# ---------------------------------------------------------------------------

def test_packed_beats_single_reductions_per_sec():
    """The point of the engine: more reductions/sec than the single-column
    engine on a reduction-heavy workload (the benchmark asserts >= 5x in
    CI; in-suite we only require a win to stay robust to runner noise)."""
    from repro.data import pointclouds as pc

    dists = pc.fractal_like(40, seed=0)
    rps = {}
    for engine in ("single", "packed"):
        res = compute_ph(dists=dists, maxdim=2, engine=engine,
                         mode="implicit", batch_size=256)
        s = res.stats
        red_t = s["t_h1"] + s["t_h2"]
        n_red = s["h1_n_reductions"] + s["h2_n_reductions"]
        rps[engine] = n_red / max(red_t, 1e-9)
    assert rps["packed"] > rps["single"], rps

"""Deterministic stand-in for the subset of the hypothesis API this suite
uses, registered by ``conftest.py`` only when the real package is absent
(e.g. an offline container).  It is NOT a property-testing engine: each
``@given`` test runs ``max_examples`` seeded draws — enough to exercise the
properties reproducibly, with none of hypothesis' shrinking or coverage
guidance.  CI installs the real hypothesis from requirements-dev.txt.
"""
from __future__ import annotations

import functools
import types

import numpy as np

__version__ = "0.0-fallback"

_BASE_SEED = 0x5EED


class SearchStrategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def example(self, rng):
        return self._draw_fn(rng)


def integers(min_value, max_value):
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def booleans():
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements):
    elements = list(elements)
    return SearchStrategy(
        lambda rng: elements[int(rng.integers(0, len(elements)))])


def tuples(*strategies):
    return SearchStrategy(
        lambda rng: tuple(s.example(rng) for s in strategies))


def lists(elements, min_size=0, max_size=10, unique=False):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        out, seen, tries = [], set(), 0
        while len(out) < n and tries < 50 * (n + 1):
            tries += 1
            v = elements.example(rng)
            if unique:
                if v in seen:
                    continue
                seen.add(v)
            out.append(v)
        return out

    return SearchStrategy(draw)


class DataObject:
    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example(self._rng)


_DATA = SearchStrategy(None)        # sentinel realized to a DataObject


def data():
    return _DATA


def settings(max_examples=None, deadline=None, **kwargs):
    """Works in either decorator order relative to @given: it only pins an
    attribute that the @given wrapper reads at call time."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


# alias used as e.g. ``settings.default`` in some suites; keep it callable
settings.default = None


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        # all test params come from strategies, so the wrapper must present
        # a zero-arg signature or pytest goes hunting for fixtures
        def wrapper():
            n = (getattr(wrapper, "_fallback_max_examples", None)
                 or getattr(fn, "_fallback_max_examples", None) or 10)
            for i in range(int(n)):
                rng = np.random.default_rng([_BASE_SEED, i])

                def realize(s):
                    return DataObject(rng) if s is _DATA else s.example(rng)

                pos = [realize(s) for s in arg_strategies]
                kws = {k: realize(s) for k, s in kw_strategies.items()}
                fn(*pos, **kws)

        for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
            setattr(wrapper, attr, getattr(fn, attr))
        wrapper.__dict__.update(fn.__dict__)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return deco


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


strategies = types.ModuleType("hypothesis.strategies")
strategies.SearchStrategy = SearchStrategy
strategies.integers = integers
strategies.booleans = booleans
strategies.sampled_from = sampled_from
strategies.tuples = tuples
strategies.lists = lists
strategies.data = data
strategies.DataObject = DataObject

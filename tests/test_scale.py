"""repro.scale: streamed/tiled/sparse-input filtrations vs the dense builder.

The contract under test is *bit-identity*: the tiled streaming build and the
COO sparse-input build must produce exactly the same Filtration (edges,
orders, lengths, neighborhoods) as dense ``build_filtration`` wherever both
are defined — across tile sizes, tau thresholds sitting exactly on edge
lengths, and duplicate-distance ties.  Runs under real hypothesis or the
offline fallback shim registered by conftest.py.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import build_filtration, compute_ph
from repro.core.filtration import (build_filtration as bf, pair_sq_dists,
                                   pairwise_distances)
from repro.core.homology import h2_columns, make_h1_adapter
from repro.core.reduction import clearing_filter, reduce_dimension
from repro.scale import (TileStats, build_filtration_coo,
                         build_filtration_tiled, contacts_to_distances,
                         coo_symmetrize, edge_budget, estimate_tau_max,
                         harvest_edges, maxmin_landmarks)

FILT_FIELDS = ("edges", "edge_len", "degree", "nbr_vtx", "nbr_vtx_ord",
               "nbr_edge_ord", "nbr_edge_vtx")


def assert_filtrations_identical(a, b, label=""):
    assert a.n == b.n, label
    assert a.n_e == b.n_e, (label, a.n_e, b.n_e)
    for f in FILT_FIELDS:
        assert np.array_equal(getattr(a, f), getattr(b, f)), (label, f)


def tie_heavy_cloud(rng, n, d):
    """Cloud with many duplicate distances (quantized coords + repeated rows)."""
    pts = np.round(rng.normal(size=(n, d)), 1)
    if n >= 4:
        pts[n // 2] = pts[0]            # exact duplicate point (distance 0 tie)
        pts[n // 3] = pts[1]
    return pts


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_tiled_bit_identical_to_dense(data):
    n = data.draw(st.integers(2, 110), label="n")
    d = data.draw(st.integers(1, 5), label="d")
    tile_m = data.draw(st.sampled_from([3, 7, 16, 37, 64, 256]), label="tile_m")
    tile_n = data.draw(st.sampled_from([4, 5, 23, 64, 128, 512]), label="tile_n")
    seed = data.draw(st.integers(0, 2**16), label="seed")
    ties = data.draw(st.booleans(), label="ties")
    rng = np.random.default_rng(seed)
    pts = tie_heavy_cloud(rng, n, d) if ties else rng.normal(size=(n, d))

    # tau drawn to include inf, a quantile, and a value equal to a real edge
    # length (the <= boundary must agree bitwise between the two paths)
    mode = data.draw(st.sampled_from(["inf", "quantile", "exact-edge"]),
                     label="tau_mode")
    iu, ju = np.triu_indices(n, k=1)
    all_lens = np.sqrt(pair_sq_dists(pts, iu, ju)) if iu.size else np.zeros(0)
    if mode == "inf" or all_lens.size == 0:
        tau = np.inf
    elif mode == "quantile":
        tau = float(np.quantile(all_lens, 0.4))
    else:
        tau = float(all_lens[data.draw(
            st.integers(0, all_lens.size - 1), label="edge_pick")])

    dense = build_filtration(points=pts, tau_max=tau)
    tiled = build_filtration_tiled(points=pts, tau_max=tau,
                                   tile_m=tile_m, tile_n=tile_n,
                                   backend="numpy")
    assert_filtrations_identical(dense, tiled, f"tiles {tile_m}x{tile_n}")
    assert tiled.dense_order is None          # streamed build stays order-free
    assert np.array_equal(tiled.order, dense.order)   # lazy materialization


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_tiled_from_dists_matrix_matches(data):
    n = data.draw(st.integers(2, 60), label="n")
    tile = data.draw(st.sampled_from([5, 17, 64]), label="tile")
    rng = np.random.default_rng(data.draw(st.integers(0, 2**16), label="seed"))
    pts = rng.normal(size=(n, 3))
    dmat = pairwise_distances(pts)
    tau = float(np.quantile(dmat[np.triu_indices(n, k=1)], 0.5)) if n > 1 \
        else np.inf
    dense = build_filtration(dists=dmat, tau_max=tau)
    tiled = build_filtration_tiled(dists=dmat, tau_max=tau,
                                   tile_m=tile, tile_n=tile + 3)
    assert_filtrations_identical(dense, tiled, "dists-matrix tiles")


def test_pallas_backend_bit_identical():
    """f32 Pallas candidate filter + f64 refine == dense, in interpret mode."""
    rng = np.random.default_rng(3)
    pts = rng.normal(size=(130, 4)) * 5.0       # larger scale stresses margin
    tau = 6.0
    dense = build_filtration(points=pts, tau_max=tau)
    tiled, stats = build_filtration_tiled(
        points=pts, tau_max=tau, tile_m=64, tile_n=48, backend="pallas",
        interpret=True, return_stats=True)
    assert_filtrations_identical(dense, tiled, "pallas")
    assert stats.backend == "pallas"
    assert stats.candidate_pairs >= dense.n_e    # filter may over-, never under-


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_coo_input_matches_dense_dists(data):
    n = data.draw(st.integers(2, 50), label="n")
    nnz = data.draw(st.integers(0, 300), label="nnz")
    tau = data.draw(st.sampled_from([0.5, 1.0, 2.5]), label="tau")
    rng = np.random.default_rng(data.draw(st.integers(0, 2**16), label="seed"))
    rows = rng.integers(0, n, size=nnz)
    cols = rng.integers(0, n, size=nnz)
    vals = rng.uniform(0.05, 3.0, size=nnz)

    # dense reference: missing entries larger than any tau (no edge)
    big = 1e18
    dmat = np.full((n, n), big)
    np.fill_diagonal(dmat, 0.0)
    for i, j, v in zip(rows, cols, vals):
        if i == j:
            continue
        a, b = min(i, j), max(i, j)
        dmat[a, b] = dmat[b, a] = min(dmat[a, b], v)

    coo = build_filtration_coo(rows, cols, vals, n=n, tau_max=tau)
    dense = build_filtration(dists=dmat, tau_max=tau)
    assert_filtrations_identical(coo, dense, "coo")
    assert coo.dense_order is None


def test_coo_symmetrize_dedup_rules():
    rows = np.array([0, 1, 2, 2, 0, 3])
    cols = np.array([1, 0, 2, 0, 2, 0])
    vals = np.array([0.5, 0.3, 9.9, 1.0, 2.0, 4.0])
    n, iu, ju, v = coo_symmetrize(rows, cols, vals)
    assert n == 4
    # diagonal (2,2) dropped; (0,1)/(1,0) dedup to min 0.3; (2,0)/(0,2) -> 1.0
    tri = {(int(a), int(b)): float(x) for a, b, x in zip(iu, ju, v)}
    assert tri == {(0, 1): 0.3, (0, 2): 1.0, (0, 3): 4.0}
    assert np.all(iu < ju)


def test_contacts_to_distances_power_law():
    c = np.array([0.0, 1.0, 4.0, -2.0])
    d = contacts_to_distances(c, alpha=-0.5, scale=2.0)
    assert np.isinf(d[0]) and np.isinf(d[3])
    assert d[1] == pytest.approx(2.0)
    assert d[2] == pytest.approx(1.0)


def test_coo_inf_entries_never_become_edges():
    """inf = 'no information' must stay a non-edge even at tau_max=inf."""
    rows = np.array([0, 1, 2])
    cols = np.array([1, 2, 3])
    vals = np.array([0.5, np.inf, 1.5])
    filt = build_filtration_coo(rows, cols, vals, n=4, tau_max=np.inf)
    assert filt.n_e == 2
    assert sorted(map(tuple, filt.edges.tolist())) == [(0, 1), (2, 3)]


def test_budget_tau_fits_memory_account():
    rng = np.random.default_rng(7)
    pts = rng.normal(size=(400, 3))
    budget = 150_000
    tau = estimate_tau_max(pts, budget, n_samples=100_000, seed=0)
    assert np.isfinite(tau) and tau > 0
    filt = build_filtration_tiled(points=pts, tau_max=tau,
                                  tile_m=128, tile_n=128)
    # quantile estimate + 0.9 safety: actual n_e lands under the budgeted
    # count up to sampling noise
    assert filt.n_e <= 1.1 * edge_budget(len(pts), budget) + 16
    assert filt.base_memory_bytes() <= 1.15 * budget


def test_budget_edge_cases():
    assert edge_budget(100, (3 * 100 + 12 * 50) * 4) == 50
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(30, 2))
    # huge budget -> full clique allowed -> inf
    assert np.isinf(estimate_tau_max(pts, 10**9))
    with pytest.raises(ValueError):
        estimate_tau_max(pts, 10)     # cannot hold even the O(n) part


def test_maxmin_landmarks_properties():
    rng = np.random.default_rng(11)
    pts = rng.normal(size=(200, 3))
    idx16, r16 = maxmin_landmarks(pts, 16, seed=0)
    idx64, r64 = maxmin_landmarks(pts, 64, seed=0)
    assert len(np.unique(idx16)) == 16 and len(np.unique(idx64)) == 64
    assert r64 <= r16                       # more landmarks, tighter cover
    # returned radius is the true Hausdorff distance to the landmark set
    dm = pairwise_distances(pts)
    assert r16 == pytest.approx(dm[:, idx16].min(axis=1).max())
    # full-cloud landmarks cover exactly
    idx_all, r_all = maxmin_landmarks(pts, 200, seed=0)
    assert len(idx_all) == 200 and r_all == pytest.approx(0.0)
    # duplicate points: early stop, never duplicated landmarks
    dup = np.zeros((10, 2))
    idx_dup, r_dup = maxmin_landmarks(dup, 5, seed=0)
    assert len(idx_dup) == 1 and r_dup == 0.0


def test_pairwise_distances_blocked_and_clamped():
    rng = np.random.default_rng(5)
    pts = rng.normal(size=(97, 4)) * 100.0
    pts[1] = pts[0]                          # exact duplicate
    pts[2] = pts[0] + 1e-9                   # near-duplicate: cancellation
    for block in (7, 32, 97, 4096):
        dm = pairwise_distances(pts, block_rows=block)
        assert dm.shape == (97, 97)
        assert np.all(np.isfinite(dm)) and np.all(dm >= 0)
        assert np.array_equal(np.diag(dm), np.zeros(97))
        assert np.array_equal(dm, dm.T)
        assert dm[0, 1] == 0.0
    # blocked results are block-size invariant (fixed-order cross term)
    assert np.array_equal(pairwise_distances(pts, block_rows=7),
                          pairwise_distances(pts, block_rows=97))


def test_streamed_compute_ph_runs_order_free():
    """The sparse Dory pipeline must never materialize the O(n^2) table."""
    rng = np.random.default_rng(2)
    pts = rng.normal(size=(150, 3))
    filt = build_filtration_tiled(points=pts, tau_max=1.0,
                                  tile_m=64, tile_n=64)
    assert filt.dense_order is None
    res = compute_ph(filtration=filt, maxdim=2)
    assert filt.dense_order is None          # sparse path stayed order-free
    ref = compute_ph(points=pts, tau_max=1.0, maxdim=2, sparse=True)
    for dim in (0, 1, 2):
        assert np.array_equal(res.diagrams[dim], ref.diagrams[dim])


def test_compute_ph_tiled_backend_with_budget():
    rng = np.random.default_rng(4)
    pts = rng.normal(size=(220, 3))
    res = compute_ph(points=pts, maxdim=1, backend="tiled",
                     memory_budget_bytes=120_000, tile_m=100, tile_n=100)
    assert "tau_max_estimated" in res.stats
    tau = res.stats["tau_max_estimated"]
    ref = compute_ph(points=pts, tau_max=tau, maxdim=1)
    for dim in (0, 1):
        assert np.array_equal(res.diagrams[dim], ref.diagrams[dim])
    assert res.stats["base_memory_bytes"] <= 1.15 * 120_000
    with pytest.raises(ValueError):
        compute_ph(points=pts, backend="no-such-backend")


def test_harvest_edges_stats_account():
    rng = np.random.default_rng(9)
    pts = rng.normal(size=(300, 3))
    stats = TileStats()
    iu, ju, lens = harvest_edges(points=pts, tau_max=0.8,
                                 tile_m=64, tile_n=64, backend="numpy",
                                 stats=stats)
    assert stats.n == 300 and stats.n_e == len(lens)
    assert stats.harvest_bytes == iu.nbytes + ju.nbytes + lens.nbytes
    # merge accounting is the transient worst case, not just the final arrays
    assert stats.merge_peak_bytes >= stats.harvest_bytes + 2 * iu.nbytes
    # one f64 tile + two bool masks, never O(n^2)
    assert 0 < stats.peak_tile_bytes <= 64 * 64 * (8 + 1 + 1)
    assert stats.peak_extra_bytes() < 300 * 300 * 8
    assert np.all(np.diff(lens) >= 0)        # globally sorted merge


def test_clearing_filter_matches_set_semantics():
    ids = np.array([9, 4, 7, 2, 4, 0], dtype=np.int64)
    cleared = {4, 0}
    out = clearing_filter(ids, cleared)
    assert out.tolist() == [9, 7, 2]
    assert clearing_filter(ids, None).tolist() == ids.tolist()
    assert clearing_filter(ids, np.array([], dtype=np.int64)).tolist() \
        == ids.tolist()
    assert clearing_filter(np.zeros(0, dtype=np.int64), cleared).size == 0
    # array and set forms agree
    assert np.array_equal(out, clearing_filter(ids, np.array([4, 0])))


def test_h2_columns_vectorized_matches_reference():
    rng = np.random.default_rng(6)
    pts = rng.normal(size=(40, 3))
    filt = bf(points=pts, tau_max=1.5)
    adapter = make_h1_adapter(filt, sparse=True)
    cols1 = np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)
    res1 = reduce_dimension(adapter, cols1, cleared=None)
    got = h2_columns(filt, res1.pivot_lows, sparse=True)

    # reference: the seed's per-int loop implementation
    from repro.core import coboundary as cb
    cleared = set(int(k) for k in res1.pivot_lows)
    ref = []
    for s in range(0, filt.n_e, 2048):
        ids = np.arange(filt.n_e - 1, -1, -1, dtype=np.int64)[s:s + 2048]
        for keys in cb.case1_triangles_of_edges(filt, ids, sparse=True):
            for k in keys[::-1]:
                if int(k) not in cleared:
                    ref.append(int(k))
    assert got.tolist() == ref

"""Negative fixture: raw sorts on filtration values.

Edge lengths sorted without the canonical ``(length, i, j)`` tie-break
make diagrams schedule-dependent on ties.  Never imported; linted as
text by tests/test_analyze.py.
"""
import numpy as np


def order_edges(edge_lens, rows, cols):
    order = np.argsort(edge_lens)             # BAD: no tie-break
    ranked = sorted(edge_lens)                # BAD: raw sorted()
    short = np.lexsort((rows, edge_lens))     # BAD: 2-key lexsort
    good = np.lexsort((cols, rows, edge_lens))   # fine: full tie-break
    return order, ranked, short, good

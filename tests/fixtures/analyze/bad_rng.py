"""Negative fixture: unseeded RNG in a benchmark-style script.

Never imported; linted as text by tests/test_analyze.py (with
``force=True`` standing in for living under benchmarks/).
"""
import random

import numpy as np


def sample_points(n):
    pts = np.random.rand(n, 3)           # BAD: legacy global RNG
    rng = np.random.default_rng()        # BAD: unseeded generator
    jitter = random.random()             # BAD: stdlib global state
    return pts + rng.normal(size=(n, 3)) * jitter

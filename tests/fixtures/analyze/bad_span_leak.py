"""Negative fixture: spans opened without a guarding ``with``.

Never imported; linted as text by tests/test_analyze.py.  The bare
calls create context managers that never enter/exit, so the span is
lost — or, with a manual ``__enter__``, leaks open when the body
raises.
"""
from repro.obs.trace import span, stopwatch


def leaky(tl, work):
    sp = span("harvest/tile", tile="0,0")    # BAD: never entered
    sw = stopwatch("ph/filtration")          # BAD: .elapsed never set
    tl.span("reduce/fused", step=0)          # BAD: tracer-method form
    work()
    return sp, sw


def clean(work):
    with span("harvest/tile", tile="0,0"):   # OK: with item
        with stopwatch("ph/filtration") as sw:
            work()
    return sw.elapsed

"""Negative fixture: Pallas Ref store inside a fori_loop body.

The store ``acc_ref[...] = ...`` is issued from the nested loop-body
function, so interpret-mode discharge silently drops it — the exact bug
class ``pallas-ref-mutation`` exists to catch.  This file is never
imported; it is linted as text by tests/test_analyze.py.
"""
import jax
import jax.numpy as jnp


def bad_kernel(x_ref, acc_ref):
    def body(i, carry):
        acc_ref[i] = x_ref[i] * 2.0   # BAD: store in nested trace scope
        acc_ref[i] += carry           # BAD: aug-store in nested trace scope
        return carry + 1

    jax.lax.fori_loop(0, x_ref.shape[0], body, 0)


def good_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0     # fine: top-level store

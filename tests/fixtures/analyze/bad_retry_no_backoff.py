"""Negative fixture for retry-without-backoff (linted as text, not run)."""
import time
from time import sleep


def hammer(fn, attempts=5):
    for _ in range(attempts):
        try:
            return fn()
        except ValueError:
            time.sleep(0.1)     # BAD: fixed cadence, no jitter, no backoff


def hammer_bare_sleep(fn):
    while True:
        try:
            return fn()
        except ValueError:
            sleep(1)            # BAD: bare `sleep` imported from time


def computed_schedule_is_fine(fn, delays):
    for a, delay_s in enumerate(delays):
        try:
            return fn(a)
        except ValueError:
            time.sleep(delay_s)  # good: computed (backoff) duration


def sleep_outside_retry_is_fine():
    for _ in range(3):
        time.sleep(0.01)         # good: no try/except -> not a retry loop

"""Negative fixture for the bare-except rule (linted as text, not run)."""


def swallow_everything(load):
    try:
        return load()
    except:             # BAD: absorbs KeyboardInterrupt and injected faults
        return None


def swallow_in_loop(attempts, fn):
    out = None
    for _ in range(attempts):
        try:
            out = fn()
            break
        except:         # BAD: the retry can never distinguish fault classes
            continue
    return out


def typed_is_fine(load):
    try:
        return load()
    except ValueError:  # good: names the recoverable failure
        return None
    except (OSError, KeyError) as err:  # good: typed tuple with binding
        raise RuntimeError("unreadable") from err

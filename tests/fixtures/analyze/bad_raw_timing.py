"""Negative fixture: ad-hoc clock reads instead of the tracer.

Never imported; linted as text by tests/test_analyze.py (with
``force=True`` standing in for living outside repro/obs/ and
benchmarks/).
"""
import time
from time import perf_counter


def measure(fn):
    t0 = time.time()                     # BAD: raw wall clock
    fn()
    t1 = time.perf_counter()             # BAD: raw perf counter
    t2 = perf_counter()                  # BAD: imported bare
    t3 = time.process_time()             # BAD: cpu clock
    deadline = time.monotonic() + 1.0    # OK: deadline arithmetic
    time.sleep(0.0)                      # OK: not a measurement
    return t1 - t0, t2, t3, deadline

"""Negative fixture: host↔device syncs inside a hot loop.

# analyze: hot

The marker above opts this file into the ``host-sync`` rule the same way
the real superstep/harvest modules are.  Never imported; linted as text.
"""
import jax
import jax.numpy as jnp
import numpy as np

step = jax.jit(lambda x: x * 2.0)


def hot_loop(xs):
    total = 0.0
    for x in xs:
        y = step(x)
        total += y.sum().item()          # BAD: one sync per iteration
        host = np.asarray(step(x))       # BAD: host gather of device fn
        jax.device_get(y)                # BAD: device round-trip
        y.block_until_ready()            # BAD: serializes dispatch
        _ = host
    return total

"""Negative fixture: f32 candidate compared against the exact threshold.

``d2_32`` is f32-tainted (assigned through ``astype(np.float32)``) and
must be compared against the margin-widened f32 threshold, never the
exact ``tau_max``.  Never imported; linted as text.
"""
import numpy as np


def harvest(d2, tau_max):
    d2_32 = d2.astype(np.float32)
    keep = d2_32 <= tau_max * tau_max     # BAD: exact-threshold compare
    return keep

"""repro.analyze: lint rules, collective-schedule checks, GF(2) sanitizer.

Every checker must catch its negative fixture — a checker that cannot
fail its target bug class is decoration, not analysis.  Fixtures under
``tests/fixtures/analyze/`` are linted as text and never imported.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analyze import SanitizeViolation, Sanitizer, sanitizing
from repro.analyze.collectives import (
    CollectiveOp, check_exchange_consistency, check_repo, collective_schedule,
    collective_schedule_from_hlo, repo_programs, schedule_signature,
    verify_axes)
from repro.analyze.lint import (
    BareExceptRule, DtypeBoundaryRule, HostSyncRule, RawFiltrationSortRule,
    RawTimingRule, RefMutationRule, RetryWithoutBackoffRule, SpanLeakRule,
    UnseededRngRule, default_rules, lint_file, lint_source)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "analyze")


def lint_fixture(name, rule):
    path = os.path.join(FIXTURES, name)
    return lint_file(path, root=REPO, rules=[rule], force=True)


# ---------------------------------------------------------------------------
# Lint rules vs their negative fixtures
# ---------------------------------------------------------------------------

def test_ref_mutation_fixture_caught():
    found = lint_fixture("bad_ref_mutation.py", RefMutationRule())
    assert len(found) == 2          # the Assign and the AugAssign, not the
    assert all(f.rule == "pallas-ref-mutation" for f in found)   # good kernel


def test_host_sync_fixture_caught():
    found = lint_fixture("bad_host_sync.py", HostSyncRule())
    msgs = " ".join(f.message for f in found)
    assert len(found) == 4
    assert ".item()" in msgs and "block_until_ready" in msgs
    assert "device_get" in msgs and "host gather" in msgs


def test_host_sync_applies_via_marker_not_force():
    # the "# analyze: hot" marker alone must opt the file in
    path = os.path.join(FIXTURES, "bad_host_sync.py")
    found = lint_file(path, root=REPO, rules=[HostSyncRule()], force=False)
    assert len(found) == 4


def test_sort_fixture_caught():
    found = lint_fixture("bad_sort.py", RawFiltrationSortRule())
    assert len(found) == 3          # argsort, sorted, 2-key lexsort
    lines = sorted(f.line for f in found)
    src = open(os.path.join(FIXTURES, "bad_sort.py")).read().splitlines()
    assert "good" not in src[lines[-1] - 1]   # 3-key lexsort stays clean


def test_dtype_fixture_caught():
    found = lint_fixture("bad_dtype.py", DtypeBoundaryRule())
    assert len(found) == 1
    assert found[0].rule == "f32-exact-compare"


def test_rng_fixture_caught():
    found = lint_fixture("bad_rng.py", UnseededRngRule())
    assert len(found) == 3          # np.random.rand, default_rng(), random.random
    # the seeded rng.normal(...) must not be flagged
    assert all("normal" not in f.message for f in found)


def test_raw_timing_fixture_caught():
    found = lint_fixture("bad_raw_timing.py", RawTimingRule())
    assert len(found) == 4          # time, perf_counter x2, process_time
    assert all("stopwatch" in f.message for f in found)
    # monotonic (deadlines) and sleep stay legal
    assert all("monotonic" not in f.message and "sleep" not in f.message
               for f in found)


def test_raw_timing_exempts_obs_and_benchmarks():
    src = "import time\nt0 = time.perf_counter()\n"
    rule = RawTimingRule()
    assert not lint_source(src, "src/repro/obs/trace.py", rules=[rule])
    assert not lint_source(src, "benchmarks/reduce_bench.py", rules=[rule])
    assert len(lint_source(src, "src/repro/core/homology.py",
                           rules=[rule])) == 1


def test_span_leak_fixture_caught():
    found = lint_fixture("bad_span_leak.py", SpanLeakRule())
    assert len(found) == 3          # span, stopwatch, tl.span — bare calls
    # the `with span(...)` / `with stopwatch(...)` uses must not be flagged
    assert all(f.line < 18 for f in found)


def test_bare_except_fixture_caught():
    found = lint_fixture("bad_bare_except.py", BareExceptRule())
    assert len(found) == 2          # the two bare handlers, not the typed ones
    assert all(f.rule == "bare-except" for f in found)
    assert all("typed fault" in f.message for f in found)


def test_retry_without_backoff_fixture_caught():
    found = lint_fixture("bad_retry_no_backoff.py",
                         RetryWithoutBackoffRule())
    assert len(found) == 2          # time.sleep(0.1) and bare sleep(1)
    assert all("retry_with_backoff" in f.message for f in found)
    # computed-duration sleeps and sleeps outside try/except stay legal
    lines = sorted(f.line for f in found)
    src = open(os.path.join(FIXTURES, "bad_retry_no_backoff.py")
               ).read().splitlines()
    assert all("BAD" in src[ln - 1] for ln in lines)


def test_retry_with_backoff_itself_lints_clean():
    # the blessed helper's own retry loop (variable delay via its `sleep`
    # parameter) must not trip the rule that points offenders at it
    path = os.path.join(REPO, "src", "repro", "resilience", "faults.py")
    assert not [f for f in lint_file(path, root=REPO,
                                     rules=[RetryWithoutBackoffRule(),
                                            BareExceptRule()], force=True)
                if not f.allowed]


def test_new_rules_registered_in_defaults():
    names = {r.name for r in default_rules()}
    assert {"raw-timing", "span-leak",
            "bare-except", "retry-without-backoff"} <= names


def test_allow_pragma_suppresses_with_justification():
    src = (
        "import numpy as np\n"
        "def f(edge_lens):\n"
        "    # analyze: allow[raw-filtration-sort] presorted upstream\n"
        "    return np.argsort(edge_lens)\n")
    found = lint_source(src, "x.py", rules=[RawFiltrationSortRule()],
                        force=True)
    assert len(found) == 1 and found[0].allowed
    assert found[0].justification == "presorted upstream"


def test_bare_allow_pragma_is_itself_a_finding():
    src = (
        "import numpy as np\n"
        "def f(edge_lens):\n"
        "    return np.argsort(edge_lens)  # analyze: allow\n")
    found = lint_source(src, "x.py", rules=[RawFiltrationSortRule()],
                        force=True)
    rules = {f.rule for f in found}
    assert "bare-allow" in rules
    # and the unjustified pragma does NOT suppress the real finding
    assert any(f.rule == "raw-filtration-sort" and not f.allowed
               for f in found)


def test_repo_tree_lints_clean():
    """Satellite contract: zero unexplained findings at merge."""
    from repro.analyze.lint import lint_paths
    bad = [f for f in lint_paths(REPO) if not f.allowed]
    assert not bad, "\n".join(f.format() for f in bad)


# ---------------------------------------------------------------------------
# Collective schedules: jaxpr walker
# ---------------------------------------------------------------------------

def test_divergent_cond_detected():
    def fn(x):
        return jax.lax.cond(x.sum() > 0,
                            lambda v: jax.lax.psum(v, "data"),
                            lambda v: v,
                            x)

    sched = collective_schedule(fn, (jnp.zeros(4, jnp.float32),),
                                axis_env=(("data", 4),))
    assert any(v.kind == "divergent-cond" for v in sched.violations)
    # the longest branch still contributes to the schedule
    assert schedule_signature(sched.ops) == (("psum", ("data",)),)


def test_uniform_cond_is_clean():
    def fn(x):
        return jax.lax.cond(x.sum() > 0,
                            lambda v: jax.lax.psum(v, "data") + 1.0,
                            lambda v: jax.lax.psum(v, "data") - 1.0,
                            x)

    sched = collective_schedule(fn, (jnp.zeros(4, jnp.float32),),
                                axis_env=(("data", 4),))
    assert not sched.violations
    assert schedule_signature(sched.ops) == (("psum", ("data",)),)


def test_while_collective_detected():
    def fn(x):
        return jax.lax.while_loop(lambda v: v.sum() < 10.0,
                                  lambda v: jax.lax.psum(v, "data") + 1.0,
                                  x)

    sched = collective_schedule(fn, (jnp.zeros(4, jnp.float32),),
                                axis_env=(("data", 4),))
    assert any(v.kind == "while-collective" for v in sched.violations)


def test_unknown_axis_detected():
    def fn(x):
        return jax.lax.psum(x, "data")

    sched = collective_schedule(fn, (jnp.zeros(4, jnp.float32),),
                                axis_env=(("data", 4),))
    assert not verify_axes(sched, mesh_axes=("data",))
    bad = verify_axes(sched, mesh_axes=("batch",))
    assert bad and bad[0].kind == "unknown-axis"


def test_schedule_recurses_through_scan():
    def fn(x):
        def body(carry, _):
            return jax.lax.psum(carry, "data"), None
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    sched = collective_schedule(fn, (jnp.zeros(4, jnp.float32),),
                                axis_env=(("data", 4),))
    assert ("psum", ("data",)) in schedule_signature(sched.ops)


# ---------------------------------------------------------------------------
# Collective schedules: HLO cross-check
# ---------------------------------------------------------------------------

_HLO_CLEAN = """\
HloModule clean

ENTRY %main (p0: f32[8]) -> f32[32] {
  %p0 = f32[8] parameter(0)
  ROOT %ag = f32[32] all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""

_HLO_WHILE = """\
HloModule loopy

%body (x: f32[8]) -> f32[8] {
  %x = f32[8] parameter(0)
  ROOT %ar = f32[8] all-reduce(%x), replica_groups={{0,1,2,3}}
}

%cond (x.1: f32[8]) -> pred[] {
  %x.1 = f32[8] parameter(0)
  ROOT %lt = pred[] constant(1)
}

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8] parameter(0)
  ROOT %w = f32[8] while(%p), condition=%cond, body=%body
}
"""


def test_hlo_schedule_extraction():
    sched = collective_schedule_from_hlo(_HLO_CLEAN)
    assert [op.name for op in sched.ops] == ["all-gather"]
    assert sched.ops[0].group_size == 4
    assert not sched.violations


def test_hlo_while_collective_flagged():
    sched = collective_schedule_from_hlo(_HLO_WHILE)
    assert [op.name for op in sched.ops] == ["all-reduce"]
    assert any(v.kind == "while-collective" for v in sched.violations)


def test_hlo_cross_check_on_real_lowering():
    """The HLO walker agrees with a real XLA lowering (no collectives)."""
    def f(a):
        return jnp.tanh(a) @ a

    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    hlo = jax.jit(f).lower(x).compile().as_text()
    sched = collective_schedule_from_hlo(hlo)
    assert sched.ops == [] and not sched.violations


# ---------------------------------------------------------------------------
# The repo registry
# ---------------------------------------------------------------------------

def test_repo_registry_traces_clean():
    schedules, violations = check_repo()
    assert len(schedules) == len(repo_programs())
    assert not violations, "\n".join(str(v) for v in violations)


def test_exchange_consistency_clean():
    assert check_exchange_consistency() == []


# ---------------------------------------------------------------------------
# GF(2) sanitizer
# ---------------------------------------------------------------------------

def test_duplicate_pivot_low_caught():
    san = Sanitizer()
    san.check_fresh_pivot({}, 5)                      # fresh: fine
    with pytest.raises(SanitizeViolation) as exc:
        san.check_fresh_pivot({5: 0}, 5)
    assert exc.value.check == "pivot-low-unique"
    assert "REPRO_SANITIZE[pivot-low-unique]" in str(exc.value)


def test_noncanonical_column_caught():
    san = Sanitizer()
    san.check_canonical_column(np.array([1, 4, 9], dtype=np.int64))
    with pytest.raises(SanitizeViolation):
        san.check_canonical_column(np.array([1, 9, 4], dtype=np.int64))
    with pytest.raises(SanitizeViolation):      # duplicates are not strict
        san.check_canonical_column(np.array([1, 4, 4], dtype=np.int64))


def test_pair_order_caught():
    san = Sanitizer()
    san.check_pair_orders(np.array([0.0, 1.0]), np.array([0.5, 2.0]))
    with pytest.raises(SanitizeViolation) as exc:
        san.check_pair_orders(np.array([1.0]), np.array([0.5]))
    assert exc.value.check == "pair-order"


def test_rematerialization_mismatch_caught():
    san = Sanitizer()
    a = np.array([2, 5], dtype=np.int64)
    san.check_rematerialization(a, a.copy(), col_id=3)
    with pytest.raises(SanitizeViolation) as exc:
        san.check_rematerialization(a, np.array([2, 7], dtype=np.int64), 3)
    assert exc.value.check == "spill-rematerialization"


def test_corrupted_packed_segment_caught():
    """A stray bit planted past a segment's key universe must be caught
    by consolidation instead of silently dropped by its keep filter."""
    from repro.core.packed_reduce import EMPTY_KEY, _PackedBatch

    def build():
        cob = np.full((2, 3), EMPTY_KEY, dtype=np.int64)
        cob[0] = [2, 5, 9]
        cob[1, :2] = [5, 11]
        batch = _PackedBatch(cob, [], use_kernels=False)
        batch.add_segment(np.array([20, 30], dtype=np.int64))
        return batch

    with sanitizing(True):
        build().consolidate()                    # clean block: no violation
        batch = build()
        # plant a set bit at rank 5 of the 2-key second segment
        batch.block[0, batch.seg_off[1]] |= np.uint32(1 << 5)
        with pytest.raises(SanitizeViolation) as exc:
            batch.consolidate()
    assert exc.value.check == "packed-segment"


def test_broken_wire_roundtrip_caught():
    from repro.core.pivot_cache import decode_commit_delta, encode_commit_delta

    records = [{"low": 3, "col_id": 7, "mode": "explicit",
                "column": np.array([3, 5, 9], dtype=np.int64),
                "gens": np.array([1], dtype=np.int64)}]
    with sanitizing(True):                      # honest codec: no violation
        payload = encode_commit_delta(records)

    san = Sanitizer()

    def lossy_decode(p):
        out = decode_commit_delta(p)
        out[0]["low"] += 1
        return out

    with pytest.raises(SanitizeViolation) as exc:
        san.check_wire_roundtrip(records, payload, lossy_decode)
    assert exc.value.check == "wire-roundtrip"

    corrupt = payload.copy()
    corrupt[0] = 0                              # smash the magic word
    with pytest.raises(SanitizeViolation):
        san.check_wire_roundtrip(records, corrupt, decode_commit_delta)


def test_violation_carries_context_and_location():
    san = Sanitizer()
    san.set_context(dim=2, superstep=7)
    with pytest.raises(SanitizeViolation) as exc:
        san.check_fresh_pivot({1: 0}, 1)
    v = exc.value
    assert v.context == {"dim": 2, "superstep": 7}
    assert __file__.split(os.sep)[-1] in v.location   # this call site
    san.set_context(dim=None, superstep=None)
    assert san.context == {}


def test_sanitizing_scopes_nest_and_restore():
    from repro.analyze import active_sanitizer
    with sanitizing(False):
        assert active_sanitizer() is None
        with sanitizing(True) as inner:
            assert active_sanitizer() is inner and inner is not None
            with sanitizing(None) as ambient:   # None defers to ambient
                assert ambient is inner
        assert active_sanitizer() is None


def test_compute_ph_sanitize_end_to_end():
    from repro.core import compute_ph
    from repro.core.diagrams import assert_diagrams_equal

    rng = np.random.default_rng(0)
    pts = rng.normal(size=(14, 3))
    plain = compute_ph(points=pts, maxdim=1, mode="implicit")
    checked = compute_ph(points=pts, maxdim=1, mode="implicit",
                         sanitize=True)
    assert_diagrams_equal(plain.diagrams, checked.diagrams, dims=[0, 1])
    assert checked.stats["sanitize_checks"] > 0
    assert "sanitize_checks" not in plain.stats


def test_compute_ph_sanitize_packed_engine():
    from repro.core import compute_ph
    from repro.core.diagrams import assert_diagrams_equal

    rng = np.random.default_rng(1)
    pts = rng.normal(size=(14, 3))
    plain = compute_ph(points=pts, maxdim=1, engine="packed",
                       mode="explicit", batch_size=8)
    checked = compute_ph(points=pts, maxdim=1, engine="packed",
                         mode="explicit", batch_size=8, sanitize=True)
    assert_diagrams_equal(plain.diagrams, checked.diagrams, dims=[0, 1])
    assert checked.stats["sanitize_checks"] > 0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_lint_exits_zero():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analyze", "lint", "--root", REPO],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "lint: 0 finding(s)" in proc.stdout

"""Docs stay honest: dead-link + doctest checks on docs/*.md and README.

Runs the same checks as ``tools/check_docs.py`` (the standalone CI entry)
under pytest, so the tier-1 suite fails when a doc example or a relative
link rots.  Each file is a separate parametrized case so a failure names
the document.
"""
import importlib.util
import os

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_spec = importlib.util.spec_from_file_location(
    "check_docs", os.path.join(_ROOT, "tools", "check_docs.py"))
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)

DOCS = check_docs.default_files(_ROOT)


def test_docs_exist():
    names = {os.path.basename(p) for p in DOCS}
    assert {"architecture.md", "api.md", "benchmarks.md",
            "README.md"} <= names


@pytest.mark.parametrize("path", DOCS, ids=[os.path.relpath(p, _ROOT)
                                            for p in DOCS])
def test_no_dead_links(path):
    assert check_docs.dead_links(path) == []


@pytest.mark.parametrize("path", DOCS, ids=[os.path.relpath(p, _ROOT)
                                            for p in DOCS])
def test_doctests_pass(path):
    failed, attempted = check_docs.run_doctests(path)
    assert failed == 0, f"{failed}/{attempted} doctests failed in {path}"


def test_docs_have_examples():
    """The three scale docs must keep at least one runnable example each —
    a doc with zero doctests can't rot, but it can't protect itself
    either."""
    for name in ("architecture.md", "api.md", "benchmarks.md"):
        path = os.path.join(_ROOT, "docs", name)
        _, attempted = check_docs.run_doctests(path)
        assert attempted > 0, name

"""Metamorphic suite for PH serving (ISSUE 9).

The load-bearing property: a *warm-started* reduction — tau growth reusing
committed pivots, point arrival replaying recorded V-expansions — is
**bit-identical** to a cold ``compute_ph`` of the same inputs, across
engines (``single`` / ``packed``), shard counts, and update kinds; and a
*batched* union reduction of many clouds splits into per-cloud diagrams
exactly equal to each cloud's standalone reduction.  Diagrams compare after
canonical row sorting (processing order differs; the multiset does not).
"""
import numpy as np
import pytest

from repro.core import build_filtration, compute_ph
from repro.core.resume import (batched_cold_reduce, canonical_diagram,
                               cold_reduce, edge_order_map, make_reducer,
                               warm_point_arrival, warm_tau_growth)
from repro.serve.ph import (PHRequest, PHServeEngine, fingerprint_points)

# both reduction engines, the packed one at >= 2 distributed shard counts
ENGINE_CONFIGS = [
    pytest.param({"engine": "single"}, id="single"),
    pytest.param({"engine": "packed", "batch_size": 16}, id="packed"),
    pytest.param({"engine": "packed", "batch_size": 16, "n_shards": 2},
                 id="packed-p2"),
    pytest.param({"engine": "packed", "batch_size": 16, "n_shards": 3},
                 id="packed-p3"),
]
DIMS = (0, 1, 2)


def cloud(seed, n, d=3):
    return np.random.default_rng(seed).normal(size=(n, d))


def cold_diagrams(points, tau, maxdim=2):
    res = compute_ph(points=points, tau_max=tau, maxdim=maxdim,
                     mode="implicit")
    return {d: canonical_diagram(res.diagrams[d]) for d in res.diagrams}


def assert_same(diagrams, reference, dims=DIMS, ctx=""):
    for d in dims:
        assert np.array_equal(canonical_diagram(diagrams[d]),
                              reference[d]), (ctx, d)


# ---------------------------------------------------------------------------
# cold capture
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opts", ENGINE_CONFIGS)
def test_cold_reduce_matches_compute_ph(opts):
    pts = cloud(0, 22)
    filt = build_filtration(points=pts, tau_max=1.8)
    diagrams, ckpt = cold_reduce(filt, mode="implicit", **opts)
    assert_same(diagrams, cold_diagrams(pts, 1.8))
    assert ckpt.n == 22 and ckpt.n_e == filt.n_e
    assert ckpt.nbytes() > 0
    # every essential + committed non-trivial column carries an expansion
    for d in (1, 2):
        for e in ckpt.dims[d].essential_ids:
            assert int(e) in ckpt.dims[d].gens


def test_capture_requires_tracked_gens():
    with pytest.raises(ValueError, match="tracked"):
        make_reducer(engine="single", mode="explicit")
    # explicit + budget tracks gens, so capture is allowed
    make_reducer(engine="single", mode="explicit", store_budget_bytes=1 << 20)


def test_make_reducer_rejects_bad_engine():
    with pytest.raises(ValueError, match="unknown engine"):
        make_reducer(engine="gpu9000")
    with pytest.raises(ValueError, match="n_shards"):
        make_reducer(engine="single", n_shards=2)


# ---------------------------------------------------------------------------
# warm start: tau growth
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opts", ENGINE_CONFIGS)
def test_warm_tau_growth_bit_identical(opts):
    pts = cloud(1, 26)
    _, ckpt = cold_reduce(build_filtration(points=pts, tau_max=1.3),
                          mode="implicit", **opts)
    filt1 = build_filtration(points=pts, tau_max=2.2)
    diagrams, ckpt1 = warm_tau_growth(filt1, ckpt, mode="implicit", **opts)
    assert_same(diagrams, cold_diagrams(pts, 2.2))
    assert ckpt1.tau_max == 2.2 and ckpt1.n_e == filt1.n_e


def test_warm_tau_growth_noop_extension():
    """Growing tau without adding any edge reproduces the old diagrams."""
    pts = cloud(2, 16)
    filt0 = build_filtration(points=pts, tau_max=1.5)
    d0, ckpt = cold_reduce(filt0, mode="implicit", engine="single")
    gap = 1.5 + 1e-9      # no pairwise distance lands in (1.5, gap]
    filt1 = build_filtration(points=pts, tau_max=gap)
    assert filt1.n_e == filt0.n_e
    d1, _ = warm_tau_growth(filt1, ckpt, mode="implicit", engine="single")
    assert_same(d1, {d: canonical_diagram(d0[d]) for d in DIMS})


def test_warm_tau_growth_rejects_non_extension():
    pts_a, pts_b = cloud(3, 14), cloud(4, 14)
    _, ckpt = cold_reduce(build_filtration(points=pts_a, tau_max=1.4),
                          mode="implicit", engine="single")
    with pytest.raises(ValueError, match="extend"):
        warm_tau_growth(build_filtration(points=pts_b, tau_max=2.0), ckpt,
                        mode="implicit", engine="single")
    with pytest.raises(ValueError, match="extend"):   # tau shrink
        warm_tau_growth(build_filtration(points=pts_a, tau_max=0.7), ckpt,
                        mode="implicit", engine="single")


# ---------------------------------------------------------------------------
# warm start: point arrival
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opts", ENGINE_CONFIGS)
def test_warm_point_arrival_bit_identical(opts):
    pts = cloud(5, 20)
    _, ckpt = cold_reduce(build_filtration(points=pts, tau_max=1.9),
                          mode="implicit", **opts)
    grown = np.concatenate([pts, cloud(6, 7)], axis=0)
    filt1 = build_filtration(points=grown, tau_max=1.9)
    diagrams, ckpt1 = warm_point_arrival(filt1, ckpt, mode="implicit",
                                         **opts)
    assert_same(diagrams, cold_diagrams(grown, 1.9))
    assert ckpt1.n == 27


def test_warm_point_arrival_with_tau_growth_together():
    """Arrivals and a larger tau in one update still replay exactly."""
    pts = cloud(7, 18)
    _, ckpt = cold_reduce(build_filtration(points=pts, tau_max=1.2),
                          mode="implicit", engine="single")
    grown = np.concatenate([pts, cloud(8, 5)], axis=0)
    filt1 = build_filtration(points=grown, tau_max=2.0)
    diagrams, _ = warm_point_arrival(filt1, ckpt, mode="implicit",
                                     engine="single")
    assert_same(diagrams, cold_diagrams(grown, 2.0))


@pytest.mark.parametrize("opts", [ENGINE_CONFIGS[0], ENGINE_CONFIGS[2]])
def test_chained_updates_bit_identical(opts):
    """tau growth -> point arrival -> tau growth, each warm, each exact."""
    pts = cloud(9, 21)
    _, ckpt = cold_reduce(build_filtration(points=pts, tau_max=1.2),
                          mode="implicit", **opts)
    d, ckpt = warm_tau_growth(build_filtration(points=pts, tau_max=1.8),
                              ckpt, mode="implicit", **opts)
    assert_same(d, cold_diagrams(pts, 1.8))
    grown = np.concatenate([pts, cloud(10, 6)], axis=0)
    d, ckpt = warm_point_arrival(
        build_filtration(points=grown, tau_max=1.8), ckpt,
        mode="implicit", **opts)
    assert_same(d, cold_diagrams(grown, 1.8))
    d, ckpt = warm_tau_growth(build_filtration(points=grown, tau_max=2.4),
                              ckpt, mode="implicit", **opts)
    assert_same(d, cold_diagrams(grown, 2.4))


def test_edge_order_map_preserves_relative_order():
    pts = cloud(11, 15)
    filt0 = build_filtration(points=pts, tau_max=1.6)
    _, ckpt = cold_reduce(filt0, mode="implicit", engine="single")
    grown = np.concatenate([pts, cloud(12, 4)], axis=0)
    filt1 = build_filtration(points=grown, tau_max=1.6)
    emap = edge_order_map(ckpt, filt1)
    assert emap.shape == (filt0.n_e,)
    assert (np.diff(emap) > 0).all()
    # the mapped edges are the same vertex pairs at the same lengths
    assert np.array_equal(filt1.edges[emap], filt0.edges)
    assert np.array_equal(filt1.edge_len[emap], filt0.edge_len)


def test_edge_order_map_rejects_disjoint_cloud():
    _, ckpt = cold_reduce(build_filtration(points=cloud(13, 12),
                                           tau_max=1.5),
                          mode="implicit", engine="single")
    other = build_filtration(points=cloud(14, 12), tau_max=1.5)
    with pytest.raises(ValueError):
        edge_order_map(ckpt, other)


# ---------------------------------------------------------------------------
# batched union reduction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opts", [ENGINE_CONFIGS[0], ENGINE_CONFIGS[1],
                                  ENGINE_CONFIGS[2]])
def test_batched_union_matches_per_cloud(opts):
    clouds = [cloud(20 + k, n) for k, n in enumerate((13, 8, 19, 6))]
    taus = [1.7, 2.4, 1.4, np.inf]
    filts = [build_filtration(points=p, tau_max=t)
             for p, t in zip(clouds, taus)]
    batch = batched_cold_reduce(filts, mode="implicit", **opts)
    assert len(batch) == len(filts)
    for k, (diagrams, ckpt) in enumerate(batch):
        ref = cold_diagrams(clouds[k], taus[k])
        assert_same(diagrams, ref, ctx=f"cloud {k}")
        assert ckpt.n == clouds[k].shape[0]
        assert ckpt.n_e == filts[k].n_e


def test_batched_checkpoint_chains_into_warm_updates():
    """A checkpoint split out of a union batch warm-starts like any other."""
    clouds = [cloud(30, 16), cloud(31, 11)]
    filts = [build_filtration(points=p, tau_max=1.5) for p in clouds]
    batch = batched_cold_reduce(filts, mode="implicit", engine="single")
    d, _ = warm_tau_growth(build_filtration(points=clouds[0], tau_max=2.3),
                           batch[0][1], mode="implicit", engine="single")
    assert_same(d, cold_diagrams(clouds[0], 2.3))
    grown = np.concatenate([clouds[1], cloud(32, 5)], axis=0)
    d, _ = warm_point_arrival(
        build_filtration(points=grown, tau_max=1.5), batch[1][1],
        mode="implicit", engine="single")
    assert_same(d, cold_diagrams(grown, 1.5))


def test_batched_single_cloud_degenerates_to_cold():
    pts = cloud(33, 17)
    filt = build_filtration(points=pts, tau_max=1.8)
    [(diagrams, _)] = batched_cold_reduce([filt], mode="implicit",
                                          engine="single")
    assert_same(diagrams, cold_diagrams(pts, 1.8))


def test_canonical_diagram_sorts_and_handles_empty():
    d = np.array([[2.0, 3.0], [1.0, 5.0], [1.0, 2.0]])
    out = canonical_diagram(d)
    assert np.array_equal(out, np.array([[1.0, 2.0], [1.0, 5.0],
                                         [2.0, 3.0]]))
    assert canonical_diagram(np.zeros((0, 2))).shape == (0, 2)


# ---------------------------------------------------------------------------
# the serve engine
# ---------------------------------------------------------------------------

def test_serve_cold_then_hit_then_warm():
    pts = cloud(40, 19)
    eng = PHServeEngine(engine="single")
    eng.submit(PHRequest(uid=0, points=pts, tau_max=1.6, dataset="a"))
    eng.run()
    assert eng.done[0].path == "cold"
    assert_same(eng.done[0].diagrams, cold_diagrams(pts, 1.6))
    eng.submit(PHRequest(uid=1, points=pts, tau_max=1.6, dataset="a"))
    eng.run()
    assert eng.done[1].path == "hit"
    for d in DIMS:
        assert np.array_equal(eng.done[1].diagrams[d],
                              eng.done[0].diagrams[d])
    eng.submit(PHRequest(uid=2, points=pts, tau_max=2.4, dataset="a"))
    eng.run()
    assert eng.done[2].path == "warm_tau"
    assert_same(eng.done[2].diagrams, cold_diagrams(pts, 2.4))


@pytest.mark.parametrize("opts", [ENGINE_CONFIGS[1], ENGINE_CONFIGS[2]])
def test_serve_warm_paths_exact_on_packed(opts):
    pts = cloud(41, 18)
    eng = PHServeEngine(**opts)
    eng.submit(PHRequest(uid=0, points=pts, tau_max=1.4, dataset="a"))
    eng.run()
    eng.submit(PHRequest(uid=1, points=pts, tau_max=2.1, dataset="a"))
    eng.run()
    assert eng.done[1].path == "warm_tau"
    assert_same(eng.done[1].diagrams, cold_diagrams(pts, 2.1))
    grown = np.concatenate([pts, cloud(42, 6)], axis=0)
    eng.submit(PHRequest(uid=2, points=grown, tau_max=2.1, dataset="a"))
    eng.run()
    assert eng.done[2].path == "warm_points"
    assert_same(eng.done[2].diagrams, cold_diagrams(grown, 2.1))


def test_serve_batched_multi_cloud_matches_per_cloud():
    clouds = [cloud(50 + k, n) for k, n in enumerate((11, 16, 8, 13, 9))]
    eng = PHServeEngine(engine="single", max_batch_clouds=3)
    for uid, p in enumerate(clouds):
        eng.submit(PHRequest(uid=uid, points=p, tau_max=1.8,
                             dataset=f"d{uid}"))
    eng.run()
    paths = [eng.done[u].path for u in range(len(clouds))]
    assert paths.count("batched") >= 3       # chunks of 3 then 2
    for uid, p in enumerate(clouds):
        assert_same(eng.done[uid].diagrams, cold_diagrams(p, 1.8),
                    ctx=f"req {uid}")
    s = eng.stats()
    assert s["serve_ph_n_batches"] >= 1
    assert s["serve_ph_batch_clouds_max"] <= 3


def test_serve_admission_rejects_below_on_floor():
    eng = PHServeEngine(memory_budget_bytes=16, engine="single")
    eng.submit(PHRequest(uid=0, points=cloud(60, 30), tau_max=2.0))
    eng.run()
    r = eng.done[0]
    assert not r.admitted and r.path == "rejected" and r.diagrams is None
    assert eng.stats()["serve_ph_n_rejected"] == 1
    # the decision is reproducible from the logged account
    dec = eng.admission_log[0]
    replay = eng.admission_account(cloud(60, 30), 2.0)
    assert (replay.admitted, replay.reason) == (dec.admitted, dec.reason)
    assert replay.predicted_bytes == dec.predicted_bytes


def test_serve_admission_clamps_tau_to_budget():
    pts = cloud(61, 40)
    eng = PHServeEngine(memory_budget_bytes=30_000, engine="single")
    eng.submit(PHRequest(uid=0, points=pts, tau_max=np.inf))
    eng.run()
    r = eng.done[0]
    assert r.admitted and np.isfinite(r.granted_tau)
    assert "clamped" in r.admission.reason
    # the served diagram is the cold diagram at the granted tau
    assert_same(r.diagrams, cold_diagrams(pts, r.granted_tau))
    # and the realized edge count respects the budget's account
    filt = build_filtration(points=pts, tau_max=r.granted_tau)
    assert filt.base_memory_bytes() <= 30_000


def test_serve_tenant_isolation_under_store_budget():
    eng = PHServeEngine(store_budget_bytes=50_000, engine="single")
    for uid in range(6):
        eng.submit(PHRequest(uid=uid, points=cloud(70 + uid, 14),
                             tau_max=2.0, dataset=f"d{uid}",
                             tenant="a" if uid % 2 else "b"))
    eng.run()
    for tenant, nbytes in eng.tenant_bytes().items():
        assert nbytes <= 50_000, tenant
    # all requests still answered exactly even when their state was evicted
    for uid in range(6):
        assert eng.done[uid].admitted


def test_serve_eviction_is_lru_within_tenant():
    eng = PHServeEngine(store_budget_bytes=1, engine="single")
    eng.submit(PHRequest(uid=0, points=cloud(80, 12), tau_max=1.8,
                         dataset="d0"))
    eng.run()
    # entry larger than the tenant budget: answered but not cached
    assert eng.done[0].admitted and not eng.done[0].cached
    assert eng.tenant_bytes() == {}


def test_serve_landmark_cap_and_cache():
    big = cloud(81, 60)
    eng = PHServeEngine(landmark_cap=20, engine="single")
    eng.submit(PHRequest(uid=0, points=big, tau_max=2.5, dataset="big"))
    eng.run()
    r0 = eng.done[0]
    assert r0.n_landmarks == 20 and r0.cover_radius > 0
    # landmarked result == cold PH of the landmark subcloud
    from repro.scale.budget import maxmin_landmarks
    idx, _ = maxmin_landmarks(big, 20, seed=0)
    assert_same(r0.diagrams, cold_diagrams(big[idx], 2.5))
    # tau growth on the landmarked dataset reuses the cached landmark set
    eng.submit(PHRequest(uid=1, points=big, tau_max=3.2, dataset="big"))
    eng.run()
    assert eng.done[1].path == "warm_tau"
    assert_same(eng.done[1].diagrams, cold_diagrams(big[idx], 3.2))


def test_serve_maxdim_mismatch_goes_cold():
    pts = cloud(82, 15)
    eng = PHServeEngine(engine="single")
    eng.submit(PHRequest(uid=0, points=pts, tau_max=1.7, dataset="a",
                         maxdim=2))
    eng.run()
    eng.submit(PHRequest(uid=1, points=pts, tau_max=2.2, dataset="a",
                         maxdim=1))
    eng.run()
    assert eng.done[1].path in ("cold", "batched")
    assert 2 not in eng.done[1].diagrams
    assert_same(eng.done[1].diagrams, cold_diagrams(pts, 2.2, maxdim=1),
                dims=(0, 1))


def test_fingerprint_is_content_addressed():
    a = cloud(83, 10)
    assert fingerprint_points(a) == fingerprint_points(a.copy())
    assert fingerprint_points(a) != fingerprint_points(a + 1e-12)
    assert fingerprint_points(a) != fingerprint_points(a[:9])


def test_serve_latency_and_store_metrics_populated():
    eng = PHServeEngine(engine="single")
    eng.submit(PHRequest(uid=0, points=cloud(84, 12), tau_max=1.8))
    eng.run()
    s = eng.stats()
    assert s["serve_ph_latency_s_count"] == 1
    assert s["serve_ph_latency_s_sum"] > 0
    assert s["serve_ph_store_bytes"] > 0
    assert eng.done[0].latency_s > 0


def test_same_step_warm_entry_survives_eviction_at_byte_cap():
    """Regression (ISSUE 10 bugfix): at the tenant byte cap, LRU eviction
    used to reclaim the dataset warmed *in the same step* to make room for
    a cold arrival — throwing away the entry the step just paid to warm.
    In-flight entries are now pinned for the step; the incoming cold entry
    is sacrificed instead (served, just not cached)."""
    p_warm, p_cold = cloud(90, 24), cloud(91, 24)
    # pilot sizes both datasets at the final tau so the budget can be set
    # to hold either one alone, but never both
    pilot = PHServeEngine(engine="single")
    pilot.submit(PHRequest(uid=0, points=p_warm, tau_max=1.3, dataset="w"))
    pilot.submit(PHRequest(uid=1, points=p_cold, tau_max=1.3, dataset="c"))
    pilot.run()
    s_warm = pilot._cache[("default", "w")].nbytes()
    s_cold = pilot._cache[("default", "c")].nbytes()

    eng = PHServeEngine(
        engine="single",
        store_budget_bytes=max(s_warm, s_cold) + min(s_warm, s_cold) // 2)
    eng.submit(PHRequest(uid=0, points=p_warm, tau_max=1.0, dataset="w"))
    eng.step()
    # one drain holds [warm_tau "w", cold "c"]; warm is served inline
    # first, the cold batch lands after and hits the byte cap
    eng.submit(PHRequest(uid=1, points=p_warm, tau_max=1.3, dataset="w"))
    eng.submit(PHRequest(uid=2, points=p_cold, tau_max=1.3, dataset="c"))
    eng.step()
    warm, cold = eng.done[1], eng.done[2]
    assert warm.path == "warm_tau" and warm.cached
    assert ("default", "w") in eng._cache, "just-warmed entry was evicted"
    assert not cold.cached               # the incoming entry is sacrificed
    assert_same(cold.diagrams, cold_diagrams(p_cold, 1.3))  # still served
    # the byte-cap invariant holds throughout
    total = sum(e.nbytes() for e in eng._cache.values())
    assert total <= eng.store_budget_bytes
    # next step, the warmed entry is reusable (the whole point of pinning)
    eng.submit(PHRequest(uid=3, points=p_warm, tau_max=1.3, dataset="w"))
    eng.step()
    assert eng.done[3].path == "hit"

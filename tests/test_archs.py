"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs forward / train / decode on CPU — shapes right,
no NaNs (task spec deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.transformer import (decode_step, forward, init_params,
                                      make_cache)
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.train_step import TrainState, make_train_step

B, S = 2, 16


def _inputs(cfg, kind: str):
    rng = np.random.default_rng(0)
    d = cfg.d_model
    if cfg.enc_dec:
        if kind == "train":
            return {"tokens": rng.integers(0, cfg.vocab_size, (B, S + 1))
                    .astype(np.int32),
                    "enc_embeds": rng.normal(size=(B, S, d))
                    .astype(np.float32)}
        return {"tokens": rng.integers(0, cfg.vocab_size, (B, S))
                .astype(np.int32),
                "enc_embeds": rng.normal(size=(B, S, d)).astype(np.float32)}
    if cfg.input_kind != "tokens":
        out = {"embeds": rng.normal(size=(B, S, d)).astype(np.float32)}
        if kind == "train":
            out["labels"] = rng.integers(0, cfg.vocab_size, (B, S)) \
                .astype(np.int32)
        if cfg.rope_kind == "mrope":
            out["positions3"] = np.broadcast_to(
                np.arange(S, dtype=np.int32), (3, B, S)).copy()
        return out
    if kind == "train":
        return {"tokens": rng.integers(0, cfg.vocab_size, (B, S + 1))
                .astype(np.int32)}
    return {"tokens": rng.integers(0, cfg.vocab_size, (B, S))
            .astype(np.int32)}


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = get_config(request.param, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return request.param, cfg, params


def test_forward_shapes_and_finite(arch_setup):
    _, cfg, params = arch_setup
    batch = {k: jnp.asarray(v) for k, v in _inputs(cfg, "fwd").items()}
    logits, aux = forward(params, cfg, batch)
    s_out = S
    assert logits.shape == (B, s_out, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"
    assert bool(jnp.isfinite(aux)), "NaN aux loss"


def test_train_step_decreases_nothing_nan(arch_setup):
    _, cfg, params = arch_setup
    opt = AdamW(lr=warmup_cosine(1e-3, 2, 10))
    step = jax.jit(make_train_step(cfg, opt, n_micro=1))
    state = TrainState(params=params, opt=opt.init(params))
    batch = {k: jnp.asarray(v) for k, v in _inputs(cfg, "train").items()}
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), metrics
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert metrics["grad_norm"] > 0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, state.params)
    assert max(jax.tree.leaves(moved)) > 0


def test_prefill_then_decode(arch_setup):
    name, cfg, params = arch_setup
    batch = {k: jnp.asarray(v) for k, v in _inputs(cfg, "prefill").items()}
    logits, _aux, caches = forward(params, cfg, batch, return_caches=True)
    assert bool(jnp.isfinite(logits).all())
    s_max = S + 4
    from repro.serve.steps import extend_cache
    cache = extend_cache(cfg, caches, S, s_max)
    dec_in = {"cache_pos": jnp.int32(S)}
    if cfg.input_kind == "tokens":
        dec_in["tokens"] = jnp.asarray([[1]] * B, dtype=jnp.int32)
    else:
        dec_in["embeds"] = jnp.zeros((B, 1, cfg.d_model), jnp.float32)
        if cfg.rope_kind == "mrope":
            dec_in["positions3"] = jnp.full((3, B, 1), S, dtype=jnp.int32)
    logits2, new_cache = decode_step(params, cfg, cache, dec_in)
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits2).all())
    # cache structure preserved
    a = jax.tree_util.tree_structure(cache)
    b = jax.tree_util.tree_structure(new_cache)
    assert a == b


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "deepseek_v2_lite_16b"])
def test_decode_matches_forward_suffix(arch):
    """Greedy decode logits must match teacher-forced forward logits (the
    KV-cache path — including the absorbed-MLA decode — is numerically
    consistent with the parallel path).

    MoE capacity dropping is batch-size dependent (8 teacher-forced tokens
    can collide, a single decode token cannot), so the MoE config runs
    drop-free (high capacity factor) to isolate cache-path numerics."""
    import dataclasses
    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
    full_logits, _ = forward(params, cfg, {"tokens": jnp.asarray(toks)})

    prefix = toks[:, :4]
    _, _, caches = forward(params, cfg, {"tokens": jnp.asarray(prefix)},
                           return_caches=True)
    from repro.serve.steps import extend_cache
    cache = extend_cache(cfg, caches, 4, 8)
    for i in range(4, 8):
        logits_i, cache = decode_step(
            params, cfg, cache,
            {"tokens": jnp.asarray(toks[:, i:i + 1]), "cache_pos": jnp.int32(i)})
        np.testing.assert_allclose(np.asarray(logits_i[0, 0]),
                                   np.asarray(full_logits[0, i]),
                                   rtol=2e-4, atol=2e-4)


def test_whisper_decode_matches_forward():
    """Enc-dec decode with cached cross-KV must match teacher-forced
    forward (cross K/V computed at prefill == recomputed per step)."""
    cfg = get_config("whisper_small", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(5)
    toks = rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
    enc = rng.normal(size=(1, 6, cfg.d_model)).astype(np.float32)
    full_logits, _ = forward(params, cfg, {
        "tokens": jnp.asarray(toks), "enc_embeds": jnp.asarray(enc)})
    _, _, caches = forward(params, cfg, {
        "tokens": jnp.asarray(toks[:, :4]), "enc_embeds": jnp.asarray(enc)},
        return_caches=True)
    from repro.serve.steps import extend_cache
    cache = extend_cache(cfg, caches, 4, 8)
    for i in range(4, 8):
        logits_i, cache = decode_step(
            params, cfg, cache,
            {"tokens": jnp.asarray(toks[:, i:i + 1]),
             "cache_pos": jnp.int32(i)})
        np.testing.assert_allclose(np.asarray(logits_i[0, 0]),
                                   np.asarray(full_logits[0, i]),
                                   rtol=2e-4, atol=2e-4)

"""Unit tests for the repro.dist subsystem beyond the system-level contract:
EF-compression edge inputs, spec_for_param replication fallbacks, rule
binding, and the no-mesh import/run regression."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


# ---------------------------------------------------------------------------
# ef_compress edge inputs
# ---------------------------------------------------------------------------

def _roundtrip(x):
    from repro.dist.compression import dequantize_int8, ef_compress
    err = jnp.zeros_like(x)
    q, scale, new_err = ef_compress(x, err)
    assert q.dtype == jnp.int8
    assert np.isfinite(float(scale)) and float(scale) > 0
    assert np.isfinite(np.asarray(new_err)).all()
    np.testing.assert_allclose(
        np.asarray(dequantize_int8(q, scale) + new_err), np.asarray(x),
        rtol=0, atol=1e-6)
    return q, scale, new_err


def test_ef_compress_zeros():
    q, scale, new_err = _roundtrip(jnp.zeros((32,), jnp.float32))
    assert not np.asarray(q).any()
    assert not np.asarray(new_err).any()


@pytest.mark.parametrize("c", [1.0, -3.5, 1e-6, 2e30])
def test_ef_compress_constant(c):
    q, scale, new_err = _roundtrip(jnp.full((16,), c, jnp.float32))
    # a constant saturates the top quantization level exactly
    np.testing.assert_array_equal(np.asarray(q),
                                  np.full((16,), np.sign(c) * 127, np.int8))


def test_ef_compress_denormal():
    """Denormal inputs must not produce inf/nan: the scale underflow guard
    degrades to q=0 with the whole signal carried in the feedback error."""
    tiny = np.float32(1e-42)                       # denormal in f32
    x = jnp.asarray(np.array([tiny, -tiny, 0.0], np.float32))
    q, scale, new_err = _roundtrip(x)
    deq = np.asarray(q, np.float32) * float(scale)
    assert np.isfinite(deq).all()


def test_ef_feedback_accumulates_unbiased():
    """Over repeated steps of the same gradient, the running dequantized sum
    plus the carried error equals the exact running sum."""
    from repro.dist.compression import dequantize_int8, ef_compress
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    err = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for step in range(5):
        q, scale, err = ef_compress(g, err)
        sent = sent + dequantize_int8(q, scale)
        np.testing.assert_allclose(np.asarray(sent + err),
                                   np.asarray(g * (step + 1)),
                                   rtol=0, atol=1e-4)


# ---------------------------------------------------------------------------
# spec_for_param fallbacks
# ---------------------------------------------------------------------------

def test_spec_for_param_replication_fallback():
    from repro.dist.sharding import spec_for_param
    rep = []
    # no dim divides either 16-way axis -> fully replicated, recorded
    spec = spec_for_param("groups/0/odd/w", (4, 7, 9), FakeMesh(), rep)
    assert spec == P(None, None, None)
    assert rep == ["groups/0/odd/w"]
    # 1-D norm scales replicate by design and are NOT recorded
    spec = spec_for_param("groups/0/ln1/scale", (4, 64), FakeMesh(), rep)
    assert spec == P(None, None)
    assert rep == ["groups/0/odd/w"]


def test_spec_for_param_misaligned_heads_and_dmodel():
    """Both the head dim and d_model misaligned: the projection keeps its
    data-axis shard but gets no TP."""
    from repro.dist.sharding import spec_for_param
    rep = []
    spec = spec_for_param("groups/0/attn/wk", (2, 100, 48), FakeMesh(), rep,
                          heads={"q": 16, "kv": 3})
    assert spec == P(None, None, "data")        # 100 % 16 != 0, 48 % 16 = 0
    assert rep == []


def test_spec_for_param_serving_no_fsdp():
    from repro.dist.sharding import spec_for_param
    rep = []
    spec = spec_for_param("groups/0/attn/wq", (28, 1024, 2048), FakeMesh(),
                          rep, heads={"q": 16, "kv": 8}, fsdp=False)
    assert spec == P(None, None, "model")       # TP only, data-replicated
    assert rep == []


def test_shard_params_report():
    from repro.dist.sharding import shard_params
    params = {"embed": {"table": jnp.zeros((512, 64))},
              "final_norm": {"scale": jnp.zeros((64,))}}
    class SmallMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}
    specs, report = shard_params(params, SmallMesh())
    assert specs["embed"]["table"] == P("model", "data")
    assert report["n_leaves"] == 2 and report["n_sharded"] == 1
    assert report["replicated"] == []


# ---------------------------------------------------------------------------
# rule binding
# ---------------------------------------------------------------------------

def test_constrain_noop_without_rules():
    from repro.dist.sharding import bound_axis, bound_mesh, constrain
    x = jnp.ones((4, 8))
    assert constrain(x, "batch", None) is x
    assert bound_axis("batch") is None and bound_mesh() is None


def test_bind_activation_rules_scopes_the_binding():
    from repro.configs import get_config
    from repro.dist.sharding import (activation_rules, bind_activation_rules,
                                     bound_axis)
    rules = activation_rules(get_config("qwen3_0_6b"), FakeMesh())

    def probe(_):
        return bound_axis("heads")

    assert bind_activation_rules(probe, rules)(0) == "model"
    assert bound_axis("heads") is None          # binding did not leak


def test_constrain_applies_bound_mesh(tmp_path):
    """With a real mesh bound, constrain emits a NamedSharding constraint."""
    from repro.configs import get_config
    from repro.dist.sharding import (activation_rules, bind_activation_rules,
                                     constrain)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("model",))
    cfg = get_config("qwen3_0_6b", reduced=True)
    rules = activation_rules(cfg, mesh)

    def fn(x):
        return constrain(x, "batch", None, "heads", None) * 2

    x = jnp.ones((2, 3, cfg.n_heads, 4))
    out = jax.jit(bind_activation_rules(fn, rules))(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x) * 2)


# ---------------------------------------------------------------------------
# no-mesh regression
# ---------------------------------------------------------------------------

def test_import_transformer_without_mesh():
    """`import repro.models.transformer` (and a forward pass) must work in a
    fresh process with no mesh/rules active — the dist layer is opt-in."""
    code = (
        "import jax, numpy as np;"
        "from repro.configs import get_config;"
        "from repro.models.transformer import forward, init_params;"
        "cfg = get_config('qwen3_0_6b', reduced=True);"
        "params = init_params(cfg, jax.random.PRNGKey(0));"
        "logits, aux = forward(params, cfg, {'tokens': np.zeros((2, 8), np.int32)});"
        "assert logits.shape[:2] == (2, 8), logits.shape;"
        "print('NO_MESH_OK')"
    )
    import os
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(
                   os.path.dirname(os.path.abspath(__file__))), "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "NO_MESH_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]

"""Pivot-cache satellites: EF commit-delta codec edge cases and
``PivotStore._make_room`` spill-policy hardening.

The codec is load-bearing for the distributed bit-identity contract —
replicas install exactly what decode returns — so every boundary the
encoder can reach (empty deltas, single pivots, the raw-fallback key
range, duplicate commits, arbitrary record slices) must round-trip
losslessly.  ``_make_room`` is one-way (demotion drops explicit R keys),
so its order and refusal behaviour must be deterministic.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analyze import sanitizing
from repro.core.pivot_cache import (PackedPivotCache, decode_commit_delta,
                                    encode_commit_delta)
from repro.core.reduction import PivotStore


def _records_equal(sent, got):
    assert len(sent) == len(got)
    for a, b in zip(sent, got):
        assert int(a["low"]) == int(b["low"])
        assert int(a["col_id"]) == int(b["col_id"])
        assert a["mode"] == b["mode"]
        if a["mode"] == "explicit":
            assert np.array_equal(np.asarray(a["column"]), b["column"])
        else:
            assert b["column"] is None
        sent_gens = (np.sort(np.asarray(a["gens"], dtype=np.int64))
                     if a.get("gens") is not None
                     else np.zeros(0, dtype=np.int64))
        assert np.array_equal(sent_gens, b["gens"])


def _record(rng, low, col_id, max_key=10_000):
    mode = "explicit" if rng.integers(2) else "implicit"
    n_col = int(rng.integers(0, 9))
    column = np.sort(rng.choice(max_key, size=n_col, replace=False)
                     ).astype(np.int64)
    gens = rng.integers(0, max_key, size=int(rng.integers(0, 5))
                        ).astype(np.int64)
    return {"low": low, "col_id": col_id, "mode": mode,
            "column": column if mode == "explicit" else None, "gens": gens}


# ---------------------------------------------------------------------------
# EF commit-delta codec edge cases
# ---------------------------------------------------------------------------

def test_delta_empty_set():
    payload = encode_commit_delta([])
    assert decode_commit_delta(payload) == []


def test_delta_single_pivot():
    records = [{"low": 42, "col_id": 7, "mode": "explicit",
                "column": np.array([42, 99], dtype=np.int64),
                "gens": np.array([3], dtype=np.int64)}]
    _records_equal(records, decode_commit_delta(encode_commit_delta(records)))


def test_delta_empty_column_and_gens():
    records = [{"low": 1, "col_id": 2, "mode": "explicit",
                "column": np.zeros(0, dtype=np.int64), "gens": None},
               {"low": 3, "col_id": 4, "mode": "implicit",
                "column": None, "gens": np.zeros(0, dtype=np.int64)}]
    _records_equal(records, decode_commit_delta(encode_commit_delta(records)))


def test_delta_max_key_boundary_takes_raw_fallback():
    """Keys near 2**62 overflow the EF column embedding (``U * ncols``),
    forcing the raw body encoding — which must round-trip identically."""
    big = 2**62 - 3
    records = [{"low": big, "col_id": big - 1, "mode": "explicit",
                "column": np.array([big - 5, big], dtype=np.int64),
                "gens": np.array([0, big - 7], dtype=np.int64)},
               {"low": 5, "col_id": 6, "mode": "implicit",
                "column": None, "gens": np.array([big], dtype=np.int64)}]
    payload = encode_commit_delta(records)
    _records_equal(records, decode_commit_delta(payload))


def test_delta_sanitized_encode_is_clean():
    rng = np.random.default_rng(7)
    records = [_record(rng, low=int(l), col_id=i)
               for i, l in enumerate(rng.choice(5000, 20, replace=False))]
    with sanitizing(True):
        _records_equal(records,
                       decode_commit_delta(encode_commit_delta(records)))


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(0, 12),
       start=st.integers(0, 12), stop=st.integers(0, 12))
def test_delta_roundtrip_under_random_slices(seed, n, start, stop):
    rng = np.random.default_rng(seed)
    lows = rng.choice(100_000, size=n, replace=False)
    records = [_record(rng, low=int(l), col_id=int(rng.integers(1_000_000)))
               for l in lows]
    subset = records[min(start, stop):max(start, stop)]
    _records_equal(subset, decode_commit_delta(encode_commit_delta(subset)))


def test_put_column_duplicate_commit_idempotent():
    """Committing the same low twice counts the call but stores one copy."""
    cache = PackedPivotCache()
    keys = np.array([3, 8, 11], dtype=np.int64)
    cache.put_column(5, keys)
    first_bytes = cache.column_bytes
    cache.put_column(5, np.array([999], dtype=np.int64))   # dup: ignored
    assert cache.n_materializations == 2
    assert cache.column_bytes == first_bytes
    assert np.array_equal(cache.get_column(5), keys)
    assert cache.n_mat_hits == 1


# ---------------------------------------------------------------------------
# PivotStore._make_room hardening
# ---------------------------------------------------------------------------

class _NoAdapter:
    """Commit/_make_room never touch the adapter with the sanitizer off."""

    def __getattr__(self, name):
        raise AssertionError(f"adapter.{name} touched by spill bookkeeping")


def _store(budget):
    return PivotStore(_NoAdapter(), "explicit", store_budget_bytes=budget)


def _commit(store, low, n_keys):
    r = np.arange(low, low + n_keys, dtype=np.int64)
    store.commit(low, low + 1, r, np.zeros(0, dtype=np.int64), trivial=False)


def test_make_room_demotes_oldest_on_equal_sizes():
    """Equal-size heap entries tie-break on index: oldest demoted first,
    deterministically — the spill order is part of the perf contract."""
    with sanitizing(False):
        store = _store(budget=48)
        _commit(store, 100, 3)       # idx 0: 24 bytes
        _commit(store, 200, 3)       # idx 1: 24 bytes
        assert store.col_modes == ["explicit", "explicit"]
        _commit(store, 300, 2)       # 16 bytes: must demote exactly idx 0
        assert store.col_modes == ["implicit", "explicit", "explicit"]
        _commit(store, 400, 2)       # next tie pops idx 1
        assert store.col_modes == ["implicit", "implicit",
                                   "explicit", "explicit"]
        assert store.n_spilled == 2
        assert store.bytes_stored <= 48


def test_make_room_zero_budget_degrades_to_all_implicit():
    with sanitizing(False):
        store = _store(budget=0)
        for i, low in enumerate((10, 20, 30)):
            _commit(store, low, n_keys=i + 1)    # must not raise
        assert store.col_modes == ["implicit"] * 3
        assert store.n_spilled == 3
        # implicit columns hold the (empty) gens, not the R keys
        assert store.bytes_stored == 0


def test_make_room_refuses_when_incoming_is_biggest():
    """An incoming column at least as big as every stored explicit column
    spills itself; nothing already stored is demoted for it."""
    with sanitizing(False):
        store = _store(budget=48)
        _commit(store, 100, 3)
        _commit(store, 200, 3)
        _commit(store, 300, 3)       # 24 bytes == heap max: refuses
        assert store.col_modes == ["explicit", "explicit", "implicit"]
        assert store.n_spilled == 1


def test_make_room_rolls_back_doomed_demotion_plan():
    """When demoting everything still cannot fit the incoming column, no
    planned demotion may be applied — demotion is one-way."""
    with sanitizing(False):
        store = _store(budget=48)
        _commit(store, 100, 3)
        _commit(store, 200, 3)
        # incoming: r = 16 bytes but 48 bytes of tracked gens -> total 64
        # never fits even after demoting both stored columns
        r = np.array([900, 901], dtype=np.int64)
        gens = np.arange(6, dtype=np.int64)
        store.commit(900, 901, r, gens, trivial=False)
        assert store.col_modes == ["explicit", "explicit", "implicit"]
        assert store.n_spilled == 1
        assert len(store._explicit_heap) == 2    # plan fully rolled back

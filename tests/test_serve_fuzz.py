"""Fuzz + soak of randomized PH-serving request streams (ISSUE 9).

Properties, over randomized streams of cold / tau-growth / point-arrival /
repeat requests across tenants:

* **determinism** — the engine is a pure function of (seed, arrival
  order): same stream twice -> byte-identical responses, paths, and
  admission log;
* **exactness** — every admitted response equals a cold ``compute_ph`` at
  the granted tau, whatever path served it;
* **isolation** — no tenant's resident cache bytes ever exceed
  ``store_budget_bytes``, checked after every step;
* **accountability** — every rejection is reproducible offline from the
  logged admission account.

Runs under real hypothesis or the deterministic fallback shim in
``tests/_hypothesis_fallback.py`` (same API subset).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.homology import compute_ph
from repro.core.resume import canonical_diagram
from repro.serve.ph import PHRequest, PHServeEngine


def _gen_stream(rng, n_requests, n_datasets):
    """A randomized but replayable request stream (list of PHRequest)."""
    base = {k: rng.normal(size=(int(rng.integers(6, 16)), 3))
            for k in range(n_datasets)}
    latest = dict(base)
    taus = {k: 1.2 for k in range(n_datasets)}
    reqs = []
    for uid in range(n_requests):
        k = int(rng.integers(0, n_datasets))
        kind = int(rng.integers(0, 4))
        if kind == 0:        # cold / repeat at current state
            pts, tau = latest[k], taus[k]
        elif kind == 1:      # tau growth
            taus[k] = taus[k] + float(rng.uniform(0.1, 0.6))
            pts, tau = latest[k], taus[k]
        elif kind == 2:      # point arrival
            latest[k] = np.concatenate(
                [latest[k], rng.normal(size=(int(rng.integers(1, 4)), 3))],
                axis=0)
            pts, tau = latest[k], taus[k]
        else:                # reset to the base cloud (cache invalidation)
            latest[k] = base[k]
            taus[k] = 1.2
            pts, tau = latest[k], taus[k]
        reqs.append(PHRequest(uid=uid, points=pts, tau_max=tau,
                              dataset=f"ds{k}",
                              tenant=f"t{k % 2}"))
    return reqs


def _run_stream(reqs, **engine_kw):
    eng = PHServeEngine(engine="single", **engine_kw)
    tenant_ok = True
    for req in reqs:
        eng.submit(PHRequest(uid=req.uid, points=req.points,
                             tau_max=req.tau_max, dataset=req.dataset,
                             tenant=req.tenant, maxdim=req.maxdim))
        eng.step()
        budget = engine_kw.get("store_budget_bytes")
        if budget is not None:
            tenant_ok &= all(v <= budget
                             for v in eng.tenant_bytes().values())
    return eng, tenant_ok


def _response_signature(eng):
    sig = []
    for uid in sorted(eng.done):
        r = eng.done[uid]
        dg = tuple((d, r.diagrams[d].tobytes()) for d in sorted(r.diagrams)) \
            if r.diagrams is not None else None
        sig.append((uid, r.path, r.admitted, round(r.granted_tau, 12), dg))
    return sig


@settings(max_examples=3)
@given(st.integers(0, 10_000), st.integers(6, 10), st.integers(1, 3))
def test_stream_determinism(seed, n_requests, n_datasets):
    reqs = _gen_stream(np.random.default_rng(seed), n_requests, n_datasets)
    eng_a, _ = _run_stream(reqs)
    eng_b, _ = _run_stream(reqs)
    assert _response_signature(eng_a) == _response_signature(eng_b)
    assert [(d.uid, d.admitted, d.predicted_bytes, d.granted_tau)
            for d in eng_a.admission_log] == \
        [(d.uid, d.admitted, d.predicted_bytes, d.granted_tau)
         for d in eng_b.admission_log]


@settings(max_examples=3)
@given(st.integers(0, 10_000), st.integers(5, 9))
def test_every_path_matches_cold_compute(seed, n_requests):
    reqs = _gen_stream(np.random.default_rng(seed), n_requests, 2)
    eng, _ = _run_stream(reqs)
    assert sorted(eng.done) == list(range(n_requests))
    for req in reqs:
        r = eng.done[req.uid]
        assert r.admitted, r
        ref = compute_ph(points=req.points, tau_max=r.granted_tau,
                         maxdim=2, mode="implicit")
        for d in (0, 1, 2):
            assert np.array_equal(r.diagrams[d],
                                  canonical_diagram(ref.diagrams[d])), \
                (req.uid, r.path, d)


@settings(max_examples=3)
@given(st.integers(0, 10_000), st.sampled_from([20_000, 60_000, 200_000]))
def test_tenant_bytes_never_exceed_store_budget(seed, budget):
    reqs = _gen_stream(np.random.default_rng(seed), 8, 3)
    eng, tenant_ok = _run_stream(reqs, store_budget_bytes=budget)
    assert tenant_ok
    # final state also respects the budget, and the gauge agrees
    tb = eng.tenant_bytes()
    assert all(v <= budget for v in tb.values())
    assert eng.stats()["serve_ph_store_bytes"] == pytest.approx(
        sum(tb.values()))


@settings(max_examples=3)
@given(st.integers(0, 10_000), st.sampled_from([800, 3_000, 12_000]))
def test_rejections_reproducible_from_admission_log(seed, budget):
    rng = np.random.default_rng(seed)
    reqs = [PHRequest(uid=u, points=rng.normal(size=(int(rng.integers(4, 40)),
                                                     3)),
                      tau_max=2.0, dataset=f"d{u}")
            for u in range(6)]
    eng, _ = _run_stream(reqs, memory_budget_bytes=budget)
    assert len(eng.admission_log) == len(reqs)
    for req, dec in zip(reqs, eng.admission_log):
        replay = eng.admission_account(req.points, req.tau_max)
        assert replay.admitted == dec.admitted
        assert replay.granted_tau == dec.granted_tau
        assert replay.predicted_bytes == dec.predicted_bytes
        assert replay.reason == dec.reason
        if not dec.admitted:
            assert eng.done[req.uid].path == "rejected"
        else:
            assert eng.done[req.uid].diagrams is not None


def test_soak_long_mixed_stream():
    """One long deterministic stream: every request answered, metrics
    internally consistent, warm paths actually exercised."""
    rng = np.random.default_rng(1234)
    reqs = _gen_stream(rng, 30, 3)
    eng, tenant_ok = _run_stream(reqs, store_budget_bytes=300_000)
    assert tenant_ok
    assert sorted(eng.done) == list(range(30))
    s = eng.stats()
    assert s["serve_ph_n_requests"] == 30
    assert s["serve_ph_n_admitted"] + s.get("serve_ph_n_rejected", 0) == 30
    assert s["serve_ph_n_cache_hits"] + s["serve_ph_n_cache_misses"] \
        == s["serve_ph_n_admitted"]
    assert s["serve_ph_n_warm_tau"] > 0
    assert s["serve_ph_n_warm_points"] > 0
    assert s["serve_ph_latency_s_count"] == 30
    # spot-check exactness across the stream tail
    for req in reqs[-6:]:
        r = eng.done[req.uid]
        ref = compute_ph(points=req.points, tau_max=r.granted_tau,
                         maxdim=2, mode="implicit")
        for d in (0, 1, 2):
            assert np.array_equal(r.diagrams[d],
                                  canonical_diagram(ref.diagrams[d]))

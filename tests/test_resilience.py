"""repro.resilience: deterministic fault injection + recovery (ISSUE 10).

The contract under test, per fault class:

* **exactness under failure** — a distributed reduction that loses a
  shard, drops/corrupts exchange payloads, or limps behind a straggler
  produces diagrams *bit-identical* to the fault-free run (and to the
  single engine);
* **determinism of the adversary** — a :class:`FaultPlan` replays an
  identical failure history from its seed, so every red run is
  reproducible;
* **checkpoint integrity** — a bit-flipped, truncated, or
  version-skewed :class:`ReductionCheckpoint` is *detected*
  (:class:`CheckpointCorruption`), never silently restored;
* **graceful degradation** — the serve engine answers overload and
  repeated cold failure with explicit ``degraded`` responses, never an
  exception and never silently wrong diagrams.

Runs under real hypothesis or the deterministic fallback shim in
``tests/_hypothesis_fallback.py``.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.homology import compute_ph
from repro.core.pivot_cache import (decode_commit_delta, encode_commit_delta,
                                    verify_commit_delta)
from repro.core.resume import CHECKPOINT_VERSION, cold_reduce
from repro.core.filtration import build_filtration
from repro.resilience.faults import (CheckpointCorruption, FaultInjector,
                                     FaultPlan, FaultSpec, TransientFault,
                                     WireCorruption, backoff_delays,
                                     corrupt_payload, flip_bit, inject,
                                     retry_with_backoff)
from repro.serve.ph import PHRequest, PHServeEngine


def _cloud(n=48, seed=7):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, 3))


def _diagrams(points, plan=None, **kw):
    kw.setdefault("tau_max", 1.2)
    kw.setdefault("maxdim", 2)
    with inject(plan):
        return compute_ph(points, **kw)


def _assert_same(res_a, res_b):
    assert set(res_a.diagrams) == set(res_b.diagrams)
    for d in res_a.diagrams:
        np.testing.assert_array_equal(res_a.diagrams[d], res_b.diagrams[d])


DIST = dict(engine="packed", n_shards=4, batch_size=16, exchange_every=1)

FAULT_CASES = [
    ("kill_start", FaultSpec("reduce.superstep", "kill_shard", at=2, shard=1,
                             params=(("when", "start"),))),
    ("kill_mid", FaultSpec("reduce.superstep", "kill_shard", at=2, shard=2,
                           params=(("when", "mid"),))),
    ("slow_shard", FaultSpec("reduce.superstep", "slow_shard", at=1, shard=3,
                             times=2, params=(("lag", 2.0),
                                              ("duration", 2)))),
    ("drop", FaultSpec("exchange.wire", "drop", at=1, shard=0, times=2)),
    ("corrupt", FaultSpec("exchange.wire", "corrupt", at=1, shard=1,
                          params=(("bit", 37),))),
    ("delay", FaultSpec("exchange.wire", "delay", at=1, shard=2,
                        params=(("delay_s", 1e-3),))),
]


# ---------------------------------------------------------------------------
# fault sweep: exactness under every fault class
# ---------------------------------------------------------------------------

class TestFaultSweepExactness:
    @pytest.fixture(scope="class")
    def clean(self):
        pts = _cloud()
        return {
            "pts": pts,
            "single": _diagrams(pts, engine="single"),
            "dist": _diagrams(pts, **DIST),
        }

    def test_fault_free_distributed_matches_single(self, clean):
        _assert_same(clean["dist"], clean["single"])

    @pytest.mark.parametrize("name,spec",
                             FAULT_CASES, ids=[n for n, _ in FAULT_CASES])
    def test_faulted_run_is_bit_identical(self, clean, name, spec):
        plan = FaultPlan.of(spec, seed=11)
        with inject(plan) as inj:
            faulted = compute_ph(clean["pts"], tau_max=1.2, maxdim=2, **DIST)
            assert inj.fired, f"{name} never fired - dead test"
        _assert_same(faulted, clean["dist"])
        _assert_same(faulted, clean["single"])

    @pytest.mark.parametrize("n_shards", [2, 4])
    def test_combined_plan_across_shard_counts(self, clean, n_shards):
        plan = FaultPlan.of(
            FaultSpec("reduce.superstep", "kill_shard", at=2, shard=1,
                      params=(("when", "start"),)),
            FaultSpec("exchange.wire", "drop", at=2, shard=0),
            FaultSpec("exchange.wire", "corrupt", at=3, shard=0,
                      params=(("bit", 5),)),
            seed=3)
        kw = dict(DIST, n_shards=n_shards)
        with inject(plan) as inj:
            faulted = compute_ph(clean["pts"], tau_max=1.2, maxdim=2, **kw)
            assert inj.fired
        _assert_same(faulted, clean["single"])

    def test_recovery_counters_surface_in_stats(self, clean):
        plan = FaultPlan.of(FAULT_CASES[0][1], seed=0)
        with inject(plan):
            res = compute_ph(clean["pts"], tau_max=1.2, maxdim=2, **DIST)
        # per-dim reduction stats are prefixed h{d}_; the kill at superstep 2
        # lands in whichever dimension is reducing then — require it counted
        deaths = sum(v for k, v in res.stats.items()
                     if k.endswith("resilience_n_shard_deaths"))
        redeals = sum(v for k, v in res.stats.items()
                      if k.endswith("resilience_n_redeals"))
        assert deaths == 1 and redeals >= 1

    def test_all_shards_dead_raises(self, clean):
        specs = [FaultSpec("reduce.superstep", "kill_shard", at=1, shard=s,
                           params=(("when", "start"),)) for s in range(4)]
        with inject(FaultPlan.of(*specs)):
            with pytest.raises(RuntimeError, match="every reduction shard"):
                compute_ph(clean["pts"], tau_max=1.2, maxdim=2, **DIST)


# ---------------------------------------------------------------------------
# FaultPlan determinism (hypothesis fuzz)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       n_faults=st.integers(min_value=1, max_value=8))
def test_random_plan_is_pure_function_of_seed(seed, n_faults):
    a = FaultPlan.random(seed, n_faults=n_faults)
    b = FaultPlan.random(seed, n_faults=n_faults)
    assert a == b and hash(a) == hash(b)
    assert len(a.specs) == n_faults
    for spec in a.specs:
        FaultSpec(site=spec.site, kind=spec.kind, at=spec.at,
                  shard=spec.shard, times=spec.times, params=spec.params)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_injector_replays_identical_history(seed):
    plan = FaultPlan.random(seed, n_faults=6)
    rng = np.random.default_rng(seed ^ 0xA5)
    sites = [(s, int(rng.integers(0, 9)), int(rng.integers(0, 4)))
             for s in np.array(
                 [sp.site for sp in plan.specs])[
                     rng.integers(0, len(plan.specs), size=40)]]
    logs = []
    for _ in range(2):
        inj = FaultInjector(plan)
        for site, idx, shard in sites:
            inj.fire(site, index=idx, shard=shard)
        logs.append(inj.fired)
    assert logs[0] == logs[1]


class TestFaultPlanDeterminism:
    def test_spec_validation_fails_loudly(self):
        with pytest.raises(ValueError, match="unknown injection site"):
            FaultSpec("no.such.site", "drop")
        with pytest.raises(ValueError, match="not legal"):
            FaultSpec("exchange.wire", "kill_shard")
        with pytest.raises(ValueError, match="times"):
            FaultSpec("exchange.wire", "drop", times=0)

    def test_backoff_schedule_is_deterministic_and_monotone_in_base(self):
        a = backoff_delays(6, base_s=1e-3, seed=9)
        b = backoff_delays(6, base_s=1e-3, seed=9)
        np.testing.assert_array_equal(a, b)
        assert (a > 0).all()
        # exponential envelope: delay[a] within [base*2^a, base*2^a*(1+j)]
        env = 1e-3 * 2.0 ** np.arange(6)
        assert (a >= env).all() and (a <= env * 1.5 + 1e-12).all()

    def test_retry_with_backoff_budget(self):
        calls = []

        def flaky(a):
            calls.append(a)
            if a < 2:
                raise TransientFault("again")
            return "ok"

        assert retry_with_backoff(flaky, attempts=3, sleep=None) == "ok"
        assert calls == [0, 1, 2]
        with pytest.raises(TransientFault):
            retry_with_backoff(lambda a: (_ for _ in ()).throw(
                TransientFault("always")), attempts=2, sleep=None)


# ---------------------------------------------------------------------------
# wire integrity
# ---------------------------------------------------------------------------

class TestWireIntegrity:
    def _payload(self):
        records = [
            {"low": 5, "col_id": 9, "mode": "explicit",
             "column": np.array([1, 5, 8], dtype=np.int64), "gens": None},
            {"low": 12, "col_id": 3, "mode": "implicit", "column": None,
             "gens": np.array([3, 7], dtype=np.int64)},
        ]
        return encode_commit_delta(records), records

    def test_checksum_round_trip(self):
        payload, records = self._payload()
        assert verify_commit_delta(payload)
        out = decode_commit_delta(payload)
        assert len(out) == len(records)
        for got, want in zip(out, records):
            assert (got["low"], got["col_id"], got["mode"]) == \
                (want["low"], want["col_id"], want["mode"])
            if want["column"] is not None:
                np.testing.assert_array_equal(got["column"], want["column"])

    def test_single_bit_flip_detected(self):
        payload, _ = self._payload()
        rng = np.random.default_rng(0)
        for bit in rng.integers(0, payload.nbytes * 8, size=16):
            bad = corrupt_payload(payload, int(bit))
            if np.array_equal(bad, payload):    # flipped a don't-care? never
                continue
            assert not verify_commit_delta(bad)
            with pytest.raises(WireCorruption):
                decode_commit_delta(bad)

    def test_flip_bit_is_involution(self):
        buf = b"resilience"
        assert flip_bit(flip_bit(buf, 13), 13) == buf
        assert flip_bit(b"", 3) == b""


# ---------------------------------------------------------------------------
# checkpoint integrity
# ---------------------------------------------------------------------------

class TestCheckpointIntegrity:
    @pytest.fixture()
    def ckpt(self):
        filt = build_filtration(points=_cloud(32, seed=5), tau_max=1.1)
        diags, ck = cold_reduce(filt, maxdim=2)
        return diags, ck

    def test_round_trip_preserves_hash_and_state(self, ckpt, tmp_path):
        _, ck = ckpt
        path = str(tmp_path / "ck.npz")
        digest = ck.save(path)
        loaded = type(ck).load(path)
        assert loaded.content_hash() == digest == ck.content_hash()

    def test_bitflip_detected(self, ckpt, tmp_path):
        _, ck = ckpt
        path = str(tmp_path / "ck.npz")
        ck.save(path)
        plan = FaultPlan.of(FaultSpec("resume.load", "bitflip",
                                      params=(("bit", 31337),)))
        with inject(plan) as inj:
            with pytest.raises(CheckpointCorruption):
                type(ck).load(path)
            assert inj.n_fired("resume.load", "bitflip") == 1

    def test_truncation_detected(self, ckpt, tmp_path):
        _, ck = ckpt
        path = str(tmp_path / "ck.npz")
        ck.save(path)
        plan = FaultPlan.of(FaultSpec("resume.load", "truncate"))
        with inject(plan):
            with pytest.raises(CheckpointCorruption, match="unreadable"):
                type(ck).load(path)

    def test_wrong_version_detected(self, ckpt, tmp_path):
        _, ck = ckpt
        path = str(tmp_path / "ck.npz")
        ck.save(path)
        with np.load(path, allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
        meta = arrays["__meta__"].copy()
        meta[0] = CHECKPOINT_VERSION + 1
        arrays["__meta__"] = meta
        np.savez_compressed(path, **arrays)
        with pytest.raises(CheckpointCorruption, match="version"):
            type(ck).load(path)

    def test_corruption_falls_back_to_cold(self, ckpt, tmp_path):
        diags, ck = ckpt
        path = str(tmp_path / "ck.npz")
        ck.save(path)
        plan = FaultPlan.of(FaultSpec("resume.load", "bitflip"))
        filt = build_filtration(points=_cloud(32, seed=5), tau_max=1.1)
        with inject(plan):
            try:
                type(ck).load(path)
                raise AssertionError("corruption must be detected")
            except CheckpointCorruption:
                cold_diags, _ = cold_reduce(filt, maxdim=2)
        for d in diags:
            np.testing.assert_array_equal(diags[d], cold_diags[d])


# ---------------------------------------------------------------------------
# serve degradation (graceful, explicit, never silent)
# ---------------------------------------------------------------------------

class TestServeDegradation:
    def _pts(self, seed=0, n=24):
        return np.random.default_rng(seed).normal(size=(n, 3))

    def test_cold_failure_degrades_then_breaker_opens(self):
        eng = PHServeEngine(max_cold_retries=1, breaker_threshold=1,
                            breaker_cooldown_steps=2)
        plan = FaultPlan.of(
            FaultSpec("serve.step", "fail_reduce", at=1, times=2))
        with inject(plan):
            eng.submit(PHRequest(uid=0, points=self._pts(), tau_max=1.4))
            eng.step()
            r0 = eng.done[0]
            assert r0.degraded and r0.degraded_reason == "cold_failed"
            assert r0.diagrams is None and r0.path == "degraded"
            eng.submit(PHRequest(uid=1, points=self._pts(), tau_max=1.4))
            eng.step()
            assert eng.done[1].degraded_reason == "circuit_open"
            for _ in range(2):          # cooldown passes
                eng.step()
            eng.submit(PHRequest(uid=2, points=self._pts(), tau_max=1.4))
            eng.step()
        r2 = eng.done[2]
        assert not r2.degraded and r2.diagrams is not None
        s = eng.stats()
        assert s["serve_ph_n_degraded"] == 2
        assert s["serve_ph_n_cold_retries"] == 1
        assert s["serve_ph_n_circuit_open"] == 1

    def test_overload_sheds_with_clamped_contract(self):
        eng = PHServeEngine(degrade_tau_factor=0.5, degrade_maxdim=1)
        with inject(FaultPlan.of(FaultSpec("serve.step", "overload", at=1))):
            eng.submit(PHRequest(uid=0, points=self._pts(1), tau_max=2.0,
                                 maxdim=2))
            eng.step()
        r = eng.done[0]
        assert r.degraded and r.degraded_reason == "overload"
        assert r.granted_tau == pytest.approx(1.0)
        assert set(r.diagrams) == {0, 1}     # maxdim clamped to 1
        assert not r.cached                  # brown-outs never cached
        assert eng.stats()["serve_ph_n_shed"] == 1

    def test_queue_depth_shedding_is_positional_and_explicit(self):
        eng = PHServeEngine(shed_queue_depth=1)
        eng.submit(PHRequest(uid=0, points=self._pts(2), tau_max=1.2))
        eng.submit(PHRequest(uid=1, points=self._pts(3), tau_max=1.2))
        eng.step()
        assert not eng.done[0].degraded
        assert eng.done[1].degraded
        assert eng.done[1].degraded_reason == "queue_depth"
        assert eng.done[1].diagrams is not None   # degraded, not refused

    def test_deadline_degrade_uses_observed_cold_latency(self):
        eng = PHServeEngine(default_deadline_s=1e-12, degrade_maxdim=1)
        eng.submit(PHRequest(uid=0, points=self._pts(4), tau_max=1.2,
                             maxdim=2))
        eng.step()                  # establishes the cold-latency EWMA
        assert not eng.done[0].degraded
        eng.submit(PHRequest(uid=1, points=self._pts(5), tau_max=1.2,
                             maxdim=2))
        eng.step()
        r = eng.done[1]
        assert r.degraded and r.degraded_reason == "deadline"
        assert set(r.diagrams) == {0, 1}
        assert eng.stats()["serve_ph_n_deadline_degraded"] == 1
        # a per-request deadline overrides the engine default
        eng2 = PHServeEngine(default_deadline_s=None, degrade_maxdim=1)
        eng2.submit(PHRequest(uid=0, points=self._pts(4), tau_max=1.2))
        eng2.step()
        eng2.submit(PHRequest(uid=1, points=self._pts(5), tau_max=1.2,
                              maxdim=2, deadline_s=1e-12))
        eng2.step()
        assert eng2.done[1].degraded_reason == "deadline"

    def test_degraded_diagrams_match_direct_clamped_request(self):
        pts = self._pts(6)
        eng = PHServeEngine(degrade_tau_factor=0.5, degrade_maxdim=1)
        with inject(FaultPlan.of(FaultSpec("serve.step", "overload", at=1))):
            eng.submit(PHRequest(uid=0, points=pts, tau_max=2.0, maxdim=2))
            eng.step()
        ref = PHServeEngine()
        ref.submit(PHRequest(uid=0, points=pts, tau_max=1.0, maxdim=1))
        ref.step()
        for d in ref.done[0].diagrams:
            np.testing.assert_array_equal(eng.done[0].diagrams[d],
                                          ref.done[0].diagrams[d])
